"""Fabric-topology layer: routing, progressive-filling fairness, the
star-topology seed regression, and the oversubscribed-fabric scenarios."""

import numpy as np
import pytest

from repro.configs.metronome_testbed import make_snapshot
from repro.core.cluster import (Cluster, Node, Resources, make_fabric_cluster,
                                make_testbed_cluster)
from repro.core.harness import run_experiment
from repro.core.simulator import (BackgroundFlow, SimConfig, _max_min_fair,
                                  _progressive_fill)
from repro.core.topology import Topology, is_uplink, uplink_id
from repro.core.workload import HIGH, Workload, make_job


def fabric2x2(oversub=2.0):
    return make_fabric_cluster(n_leaves=2, hosts_per_leaf=2, bw_gbps=25.0,
                               oversubscription=oversub)


class TestRouting:
    def test_star_paths_are_host_links_only(self):
        topo = Topology.star(["a", "b", "c"])
        assert topo.is_star
        assert topo.flow_links("a", ["b", "c"]) == ("a",)
        assert topo.placement_links(["a", "b"]) == ["a", "b"]
        assert topo.uplink_ids == []

    def test_cross_leaf_flow_traverses_uplink(self):
        cl = fabric2x2()
        topo = cl.topology
        assert topo.flow_links("leaf0-host0", ["leaf1-host0"]) == (
            "leaf0-host0", uplink_id("leaf0"))
        # intra-leaf stays off the spine
        assert topo.flow_links("leaf0-host0", ["leaf0-host1"]) == (
            "leaf0-host0",)

    def test_placement_links_union(self):
        cl = fabric2x2()
        links = cl.topology.placement_links(
            ["leaf0-host0", "leaf0-host1", "leaf1-host0"])
        assert links == ["leaf0-host0", "leaf0-host1", "leaf1-host0",
                         uplink_id("leaf0"), uplink_id("leaf1")]

    def test_oversubscription_sets_uplink_capacity(self):
        cl = fabric2x2(oversub=2.0)
        assert cl.link_capacity(uplink_id("leaf0")) == pytest.approx(25.0)
        cl4 = make_fabric_cluster(n_leaves=2, hosts_per_leaf=4,
                                  oversubscription=4.0)
        assert cl4.link_capacity(uplink_id("leaf1")) == pytest.approx(25.0)
        assert is_uplink(uplink_id("leaf0"))
        assert not is_uplink("leaf0-host0")

    def test_cluster_copy_preserves_topology(self):
        cl = fabric2x2()
        cp = cl.copy()
        assert cp.topology.uplink_ids == cl.topology.uplink_ids
        cp.topology.uplinks["leaf0"].allocatable_gbps = 1.0
        assert cl.topology.uplinks["leaf0"].allocatable_gbps is None

    def test_topology_must_cover_all_nodes(self):
        nodes = [Node("n0", Resources(1, 1, 1), bw_gbps=10.0)]
        with pytest.raises(ValueError):
            Cluster(nodes, topology=Topology.star(["other"]))


class TestProgressiveFill:
    def test_single_link_matches_water_filling(self):
        demands = np.array([2.0, 20.0, 20.0])
        paths = [("l",), ("l",), ("l",)]
        got = _progressive_fill(demands, paths, {"l": 25.0})
        want = _max_min_fair(demands, 25.0)
        assert np.allclose(sorted(got), sorted(want))

    def test_shared_uplink_bottleneck(self):
        # two flows from different hosts share one uplink of 10G
        demands = np.array([20.0, 20.0])
        paths = [("h0", "up"), ("h1", "up")]
        caps = {"h0": 25.0, "h1": 25.0, "up": 10.0}
        got = _progressive_fill(demands, paths, caps)
        assert np.allclose(got, [5.0, 5.0])

    def test_mixed_bottlenecks(self):
        # flow 0 limited by its host link, flow 1 takes the uplink rest
        demands = np.array([4.0, 30.0])
        paths = [("h0", "up"), ("h1", "up")]
        caps = {"h0": 4.0, "h1": 25.0, "up": 20.0}
        got = _progressive_fill(demands, paths, caps)
        assert got[0] == pytest.approx(4.0)
        assert got[1] == pytest.approx(16.0)

    def test_demand_capped(self):
        got = _progressive_fill(np.array([3.0, 6.0]),
                                [("h0",), ("h0",)], {"h0": 25.0})
        assert np.allclose(got, [3.0, 6.0])

    def test_zero_capacity_link(self):
        got = _progressive_fill(np.array([5.0]), [("h0", "up")],
                                {"h0": 25.0, "up": 0.0})
        assert got[0] == pytest.approx(0.0)


class TestStarRegression:
    """The default star topology must reproduce the seed simulator exactly."""

    # golden values recorded from the pre-topology (seed) simulator:
    # S2, metronome, SimConfig(duration_ms=60_000, seed=7, jitter_std=0.02),
    # n_iterations=150
    GOLD_SUM = {"vgg16-ft": 14594.402578030573, "vgg19-ft": 14591.186839507718}
    GOLD_PER1000 = {"vgg16-ft": 97.29601718687049, "vgg19-ft": 97.27457893005145}
    GOLD_GAMMA = 0.2231999999999988
    GOLD_TCT = 14686.935911363906

    def _run(self, cluster=None):
        cfg = SimConfig(duration_ms=60_000, seed=7, jitter_std=0.02)
        cl, wls, bg = make_snapshot("S2", n_iterations=150)
        if cluster is not None:
            cl = cluster
        return run_experiment("metronome", cl, wls, cfg, background=bg)

    def test_bit_for_bit_vs_seed_golden(self):
        res = self._run()
        for j, want in self.GOLD_SUM.items():
            assert sum(res.sim.durations_ms[j]) == want
        for j, want in self.GOLD_PER1000.items():
            assert res.sim.time_per_1000_iters_s[j] == want
        assert res.sim.avg_bw_utilization == self.GOLD_GAMMA
        assert res.sim.total_completion_ms == self.GOLD_TCT
        # host links keep their node-name keys; a star fabric has no uplinks
        assert set(res.sim.link_utilization) == {
            "worker-a30-0", "worker-a30-1", "worker-a30-2", "worker-t4-0"}
        assert res.sim.uplink_utilization == {}

    def test_explicit_star_identical_to_default(self):
        base = self._run()
        explicit = make_testbed_cluster()
        explicit.topology = Topology.star(explicit.node_names)
        res = self._run(cluster=explicit)
        assert res.sim.durations_ms == base.sim.durations_ms
        assert res.sim.link_utilization == base.sim.link_utilization
        assert res.sim.total_completion_ms == base.sim.total_completion_ms


class TestFabricScenarios:
    CFG = SimConfig(duration_ms=120_000, seed=3, jitter_std=0.01)

    def _avg_jct(self, res):
        fin = [v for v in res.sim.finish_times_ms.values()
               if not np.isnan(v)]
        return float(np.mean(fin))

    def test_f2_uplink_contention_and_metronome_wins(self):
        """Acceptance: on the 2:1 fabric the simulator reports uplink
        contention and Metronome beats Default on avg JCT."""
        out = {}
        for sched in ("metronome", "default"):
            cluster, wls, bg = make_snapshot("F2", n_iterations=300)
            out[sched] = run_experiment(sched, cluster, wls, self.CFG,
                                        background=bg)
        for res in out.values():
            assert res.sim.uplink_utilization
            assert all(u > 0.0 for u in res.sim.uplink_utilization.values())
        assert self._avg_jct(out["metronome"]) < self._avg_jct(out["default"])

    def test_f2_host_links_never_saturate(self):
        """F2's contention is INVISIBLE to the host-link-only model: summed
        host demand stays below capacity, so only the uplink contends."""
        cluster, wls, bg = make_snapshot("F2", n_iterations=300)
        per_host = sum(j.traffic.bw_gbps for wl in wls for j in wl.jobs)
        assert per_host < cluster.node("leaf0-host0").bw_gbps

    def test_f4_metronome_beats_default(self):
        out = {}
        for sched in ("metronome", "default"):
            cluster, wls, bg = make_snapshot("F4", n_iterations=300)
            out[sched] = run_experiment(sched, cluster, wls, self.CFG,
                                        background=bg)
        assert self._avg_jct(out["metronome"]) < self._avg_jct(out["default"])

    def test_background_flow_on_uplink(self):
        """Cross-rack unregulated traffic eats uplink headroom."""
        cluster = fabric2x2()
        job = make_job("x", n_tasks=4, period_ms=100.0, duty=0.4,
                       bw_gbps=12.0, priority=HIGH, n_iterations=100)
        wl = Workload(name="wl-x", jobs=[job])
        for t in job.tasks:
            t.workload = wl.name
        job.workload = wl.name
        cfg = SimConfig(duration_ms=60_000, seed=0, jitter_std=0.0)
        free = run_experiment("default", cluster.copy(), [wl], cfg)
        bg = [BackgroundFlow(node="leaf0-host0", rate_gbps=15.0,
                             link=uplink_id("leaf0"))]
        cluster2 = fabric2x2()
        congested = run_experiment("default", cluster2, [wl], cfg,
                                   background=bg)
        # 24G of job demand vs 25G free uplink -> fine; vs 10G left -> slow
        assert (congested.sim.mean_iter_ms("x")
                > free.sim.mean_iter_ms("x") * 1.2)

    def test_uplink_filter_rejects_oversized_pod(self):
        """Eq. 14 on the uplink: a pod whose demand exceeds the uplink's
        allocatable bandwidth cannot be placed across leaves."""
        cluster = fabric2x2()
        for up in cluster.topology.uplinks.values():
            up.allocatable_gbps = 5.0
        # 4 tasks @ 12G, spread=1 -> needs all 4 hosts -> must cross leaves,
        # but 12G > 5G allocatable on every uplink -> unschedulable
        job = make_job("big", n_tasks=4, period_ms=100.0, duty=0.4,
                       bw_gbps=12.0, n_iterations=10)
        res = run_experiment("metronome", cluster, [Workload("w", [job])],
                             SimConfig(duration_ms=1_000))
        assert "big" in res.rejected


class TestControllerLinkKeys:
    def test_uplink_scheme_registered_and_cleared(self):
        from repro.core.controller import StopAndWaitController
        from repro.core.framework import SchedulingFramework
        from repro.core.scheduler import MetronomePlugin

        cluster, wls, bg = make_snapshot("F2", n_iterations=10)
        ctrl = StopAndWaitController()
        fw = SchedulingFramework(cluster, MetronomePlugin(controller=ctrl))
        for wl in wls:
            assert fw.schedule_workload(wl)
        up_keys = [k for k in ctrl.links if is_uplink(k)]
        assert up_keys, "uplink contention must produce uplink schemes"
        # both jobs participate in each uplink scheme
        for k in up_keys:
            assert len(ctrl.links[k].scheme.jobs) == 2
        # alignment is available for the low-priority job
        lo = wls[1].jobs[0].name
        assert ctrl.job_alignment(lo) is not None
        # eviction drains the job from uplink schemes too
        for wl in wls:
            for j in wl.jobs:
                fw.evict_job(j)
        assert not any(is_uplink(k) for k in ctrl.links)

"""Dynamic-environment event engine: typed event stream, controller
reconfiguration (paper section III-C), and the D1/D2 snapshots."""
import numpy as np
import pytest

from repro.configs.metronome_testbed import make_dynamic_snapshot, make_snapshot
from repro.core.cluster import Cluster, Node, Resources
from repro.core.controller import StopAndWaitController
from repro.core.events import (BackgroundFlowChange, JobDeparture,
                               LinkCapacityChange, TrafficChange,
                               normalize_events)
from repro.core.framework import SchedulingFramework
from repro.core.harness import priority_split, run_experiment
from repro.core.scheduler import MetronomePlugin
from repro.core.simulator import ClusterSimulator, SimConfig
from repro.core.workload import HIGH, LOW, Workload, make_job


def small_cluster(n=2, bw=25.0):
    nodes = [Node(f"n{i}", Resources(cpu=32, mem=256, gpu=4), bw_gbps=bw)
             for i in range(n)]
    return Cluster(nodes)


def wl(job):
    return Workload(name=job.name, jobs=[job])


def schedule_contending(reconfigure=True):
    ctrl = StopAndWaitController(reconfigure=reconfigure)
    cl = small_cluster()
    fw = SchedulingFramework(cl, MetronomePlugin(controller=ctrl))
    hi = make_job("hi", n_tasks=2, period_ms=100, duty=0.4, bw_gbps=20.0,
                  priority=HIGH, n_iterations=200)
    lo = make_job("lo", n_tasks=2, period_ms=100, duty=0.4, bw_gbps=20.0,
                  priority=LOW, submit_time_s=1.0, n_iterations=200)
    fw.schedule_workload(wl(hi))
    fw.schedule_workload(wl(lo))
    ctrl.run_offline_recalculation(fw.registry, cl)
    return ctrl, fw, cl, hi, lo


class TestNormalize:
    def test_merges_and_orders(self):
        evs = normalize_events(
            events=[JobDeparture(500.0, job="x"),
                    LinkCapacityChange(100.0, link="n0",
                                       allocatable_gbps=5.0)],
            traffic_changes=[(300.0, "b", 1.5), (300.0, "a", 2.0)],
        )
        assert [e.time_ms for e in evs] == [100.0, 300.0, 300.0, 500.0]
        # legacy tuples keep their historical full-tuple sort (job name
        # breaks same-time ties)
        assert isinstance(evs[1], TrafficChange) and evs[1].job == "a"
        assert isinstance(evs[2], TrafficChange) and evs[2].job == "b"

    def test_empty(self):
        assert normalize_events() == []


class TestControllerReconfiguration:
    def test_capacity_drop_triggers_recalc(self):
        ctrl, fw, cl, hi, lo = schedule_contending()
        before = ctrl.recalc_count
        cl.node("n0").allocatable_gbps = 12.0
        done = ctrl.on_link_change(fw.registry, cl, "n0")
        assert done >= 1
        assert ctrl.recalc_count > before
        assert ctrl.reconf_count == 1

    def test_rebaselines_to_expected_iteration(self):
        """After the drop, the monitor's baseline tracks the unavoidable
        comm-phase stretch (demand/allocatable) instead of fighting it."""
        ctrl, fw, cl, hi, lo = schedule_contending()
        ctrl.set_baseline("lo", 100.0, LOW)
        ctrl.set_baseline("hi", 100.0, HIGH)
        cl.node("n0").allocatable_gbps = 10.0
        ctrl.on_link_change(fw.registry, cl, "n0")
        # 20G demand over 10G allocatable -> comm (40 ms) stretches 2x
        assert ctrl._baseline_ms["lo"] == pytest.approx(140.0)
        # monitor no longer trips at the stretched-but-expected pace
        for _ in range(20):
            assert not ctrl.report_iteration("lo", 139.0)

    def test_ablation_does_nothing(self):
        ctrl, fw, cl, hi, lo = schedule_contending(reconfigure=False)
        before = ctrl.recalc_count
        cl.node("n0").allocatable_gbps = 12.0
        assert ctrl.on_link_change(fw.registry, cl, "n0") == 0
        assert ctrl.recalc_count == before
        assert ctrl.reconf_count == 0

    def test_unknown_link_is_noop(self):
        ctrl, fw, cl, hi, lo = schedule_contending()
        assert ctrl.on_link_change(fw.registry, cl, "uplink:nowhere") == 0


class TestSimulatorEvents:
    CFG = SimConfig(duration_ms=40_000, seed=0, jitter_std=0.0)

    def _pair(self, n_iterations=200):
        hi = make_job("hi", n_tasks=2, period_ms=100, duty=0.4, bw_gbps=20.0,
                      priority=HIGH, n_iterations=n_iterations)
        lo = make_job("lo", n_tasks=2, period_ms=100, duty=0.4, bw_gbps=20.0,
                      priority=LOW, submit_time_s=0.001,
                      n_iterations=n_iterations)
        return [wl(hi), wl(lo)]

    def test_background_flow_round_trip(self):
        """A ramp-up/ramp-down pair slows iterations only inside the window
        and restores the allocatable share afterwards."""
        cl = small_cluster()
        evs = [BackgroundFlowChange(5_000.0, link="n0", rate_gbps=10.0),
               BackgroundFlowChange(20_000.0, link="n0", rate_gbps=0.0)]
        quiet = run_experiment("default", cl, self._pair(), self.CFG)
        noisy = run_experiment("default", cl, self._pair(), self.CFG,
                               events=evs)
        assert (np.mean(noisy.sim.durations_ms["hi"])
                > np.mean(quiet.sim.durations_ms["hi"]) * 1.05)

    def test_background_flow_adjusts_allocatable(self):
        cl = small_cluster()
        sim = ClusterSimulator(
            cl, [w.jobs[0] for w in self._pair(50)], self.CFG,
            events=[BackgroundFlowChange(1_000.0, link="n0", rate_gbps=10.0)])
        sim.run()
        assert cl.node("n0").allocatable_gbps == pytest.approx(15.0)
        assert any(bg.link_id == "n0" for bg in sim.background)

    def test_capacity_drop_clamps_stale_allocatable(self):
        """A capacity-only event must not leave an earlier explicit
        allocatable share above the new physical capacity."""
        cl = small_cluster()
        evs = [BackgroundFlowChange(1_000.0, link="n0", rate_gbps=5.0),
               LinkCapacityChange(2_000.0, link="n0", capacity_gbps=10.0)]
        sim = ClusterSimulator(cl, [w.jobs[0] for w in self._pair(50)],
                               self.CFG, events=evs)
        sim.run()
        assert cl.node("n0").bw_gbps == pytest.approx(10.0)
        assert cl.node("n0").alloc_bw <= 10.0

    def test_job_departure_frees_link_and_schemes(self):
        ctrl, fw, cl, hi, lo = schedule_contending()
        sim = ClusterSimulator(
            cl, [hi, lo], self.CFG, controller=ctrl, registry=fw.registry,
            events=[JobDeparture(3_000.0, job="lo")])
        res = sim.run()
        assert res.finish_times_ms["lo"] == pytest.approx(3_000.0, abs=1.0)
        assert res.iterations_done["lo"] < lo.n_iterations
        # schemes retired, resources released, registry cleaned
        assert all("lo" not in st.scheme.jobs for st in ctrl.links.values())
        assert not any(t.job == "lo" for t in fw.registry.tasks.values())
        assert all("lo" not in uid for n in cl.nodes.values() for uid in n.pods)

    def test_legacy_traffic_change_tuples_still_work(self):
        cl = small_cluster()
        res = run_experiment("default", cl, self._pair(100), self.CFG,
                             traffic_changes=[(5_000.0, "lo", 2.0)])
        assert res.sim.iterations_done["lo"] > 0


class TestDynamicSnapshots:
    CFG = SimConfig(duration_ms=120_000.0, seed=3, jitter_std=0.01)

    def _run(self, sid, sched, amplitude, reconfigure=True):
        cluster, wls, bg, evs = make_dynamic_snapshot(
            sid, n_iterations=300, amplitude=amplitude)
        res = run_experiment(sched, cluster, wls, self.CFG, background=bg,
                             events=evs, reconfigure=reconfigure)
        return res, wls

    @staticmethod
    def _jct(res, jobs):
        f = res.sim.finish_times_ms
        return float(np.mean([f[j] for j in jobs if not np.isnan(f[j])]))

    @pytest.mark.parametrize("sid,amp", [("D1", 0.2), ("D2", 0.3)])
    def test_metronome_beats_default(self, sid, amp):
        me, wls = self._run(sid, "metronome", amp)
        de, _ = self._run(sid, "default", amp)
        jobs = list(me.sim.finish_times_ms)
        assert self._jct(me, jobs) < self._jct(de, jobs)
        assert me.sim.reconfigurations > 0

    @pytest.mark.parametrize("sid,amp", [("D1", 0.2), ("D2", 0.3)])
    def test_reconfiguration_beats_ablation_on_low_priority(self, sid, amp):
        """Acceptance: the section III-C loop measurably reduces
        low-priority JCT vs the no-reconfigure ablation."""
        me, wls = self._run(sid, "metronome", amp)
        ab, _ = self._run(sid, "metronome", amp, reconfigure=False)
        _, lo = priority_split(wls)
        assert self._jct(me, lo) < self._jct(ab, lo)
        assert ab.sim.reconfigurations == 0

    def test_d2_reconfiguration_stops_monitor_storm(self):
        """Re-baselining to the expected stretched iteration stops the
        A_T/O_T monitor from pausing low-priority jobs throughout the
        capacity dip."""
        me, _ = self._run("D2", "metronome", 0.3)
        ab, _ = self._run("D2", "metronome", 0.3, reconfigure=False)
        assert ab.sim.readjustments > 0
        assert me.sim.readjustments < ab.sim.readjustments

    def test_d2_uplink_capacity_restored(self):
        cluster, wls, bg, evs = make_dynamic_snapshot("D2", n_iterations=300,
                                                      amplitude=0.3)
        res = run_experiment("metronome", cluster, wls, self.CFG,
                             background=bg, events=evs)
        # events mutate the sim's COPY of the cluster, not the input
        for up in cluster.topology.uplinks.values():
            assert up.capacity_gbps == pytest.approx(25.0)


class TestOnlinePending:
    def test_pending_jobs_property(self):
        """A workload that never fits stays in the public pending list."""
        cl = small_cluster(n=1)
        fw = SchedulingFramework(cl, MetronomePlugin())
        big = make_job("big", n_tasks=3, period_ms=100, duty=0.3, bw_gbps=5.0,
                       spread=1, n_iterations=10)  # needs 3 nodes, has 1
        sim = ClusterSimulator(
            cl, [], SimConfig(duration_ms=2_000), registry=fw.registry,
            framework=fw, arrivals=[wl(big)])
        sim.run()
        assert sim.pending_jobs == ["big"]


class TestTraceDepartures:
    """Trace truncation via JobDeparture events instead of iteration caps
    (ROADMAP PR 2 follow-up, wired through harness.run_trace_experiment)."""

    def _trace(self):
        from repro.configs.metronome_testbed import MODEL_FLEET
        from repro.core.trace import generate_trace
        return MODEL_FLEET, generate_trace(
            MODEL_FLEET, duration_s=600, total_gpus=13, target_load=0.8,
            seed=2, job_duration_range_s=(60, 120))[:6]

    def test_departure_events_match_trace(self):
        from repro.core.trace import (trace_departure_events, trace_to_jobs,
                                      OPEN_ENDED_ITERATIONS)
        fleet, trace = self._trace()
        jobs = trace_to_jobs(trace, fleet, time_scale=1.0, open_ended=True)
        evs = trace_departure_events(trace, time_scale=1.0)
        assert len(evs) == len(jobs)
        assert all(j.n_iterations == OPEN_ENDED_ITERATIONS for j in jobs)
        for j, ev, spec in zip(jobs, evs, trace):
            assert ev.job == j.name
            assert ev.time_ms == pytest.approx(
                (spec.submit_time_s + spec.duration_s) * 1e3)

    def test_open_ended_trace_ends_by_departure(self):
        """Jobs end when their departure fires — not an iteration cap — and
        a job that never got capacity departs from the pending queue."""
        from repro.core.harness import run_trace_experiment
        from repro.core.trace import trace_departure_events, trace_to_jobs
        fleet, trace = self._trace()
        cluster, _, _ = make_snapshot("S1")
        jobs = trace_to_jobs(trace, fleet, time_scale=1.0, open_ended=True)
        wls = [Workload(name=j.name, jobs=[j]) for j in jobs]
        for w in wls:
            for j in w.jobs:
                j.workload = w.name
                for t in j.tasks:
                    t.workload = w.name
        evs = trace_departure_events(trace, time_scale=1.0)
        cfg = SimConfig(duration_ms=900_000, seed=0, jitter_std=0.01)
        res = run_trace_experiment("metronome", cluster, wls, cfg, events=evs)
        ends = {ev.job: ev.time_ms for ev in evs}
        ran = [n for n, f in res.sim.finish_times_ms.items()
               if not np.isnan(f)]
        assert ran, "at least one trace job must run"
        for n in ran:
            assert res.sim.finish_times_ms[n] <= ends[n] + 1e-6
            # open-ended: the job cannot have exhausted its budget
            assert res.sim.iterations_done[n] < 10**9
        # nobody is left queued forever: every non-admitted job departed
        assert res.rejected == []

    def test_multi_job_workload_departure_strips_only_the_departed(self):
        """A pending HPO-style workload keeps its sibling jobs when one of
        them departs before ever being admitted."""
        cl = small_cluster(n=1)  # 1 node, gpu capacity for 4 task pods
        fw = SchedulingFramework(cl, MetronomePlugin())
        blocker = make_job("blocker", n_tasks=4, period_ms=100, duty=0.2,
                           bw_gbps=4.0, spread=0, n_iterations=5)
        sib_a = make_job("sib-a", n_tasks=4, period_ms=100, duty=0.2,
                         bw_gbps=4.0, spread=0, n_iterations=5,
                         submit_time_s=0.001)
        sib_b = make_job("sib-b", n_tasks=4, period_ms=100, duty=0.2,
                         bw_gbps=4.0, spread=0, n_iterations=5,
                         submit_time_s=0.001)
        hpo = Workload(name="hpo", jobs=[sib_a, sib_b])
        for j in (sib_a, sib_b):
            j.workload = "hpo"
            for t in j.tasks:
                t.workload = "hpo"
        evs = [JobDeparture(time_ms=100.0, job="sib-a")]
        sim = ClusterSimulator(
            cl, [], SimConfig(duration_ms=30_000), registry=fw.registry,
            framework=fw, arrivals=[wl(blocker), hpo], events=evs)
        res = sim.run()
        # the departed sibling never ran; the survivor did once the blocker
        # released the node
        assert "sib-a" not in sim.jobs
        assert "sib-b" in sim.jobs
        assert res.iterations_done["sib-b"] == 5
        assert sim.pending_jobs == []

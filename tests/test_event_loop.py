"""The array event loop (DESIGN.md section 17).

Four layers of evidence that the vectorized hot path is safe:

  * oracle parity — ``event_loop='array'`` (the default) must reproduce
    ``event_loop='legacy'`` (the pre-array per-object loop, retained
    verbatim) BIT-FOR-BIT on every pinned golden (S1–S5, F2, F4, J1, D1,
    D2) and on an online production-trace run with arrivals/departures;
  * edge cases the vectorized reductions must not regress: starved flows
    with zero rate (no finish event until the duration cap), multiple
    events sharing one timestamp, an arrival tied exactly with an event;
  * structured once-per-offender warnings for events naming unknown
    links/jobs (previously silently dropped);
  * the machinery that rides along: ``SimConfig.profile`` phase counters,
    ``FluidEngine.solve_batch`` memoization, and shape-bucketed
    ``fill_corpus`` batching with occupancy stats.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.configs.metronome_testbed import (DYNAMIC_SNAPSHOTS, MODEL_FLEET,
                                             dynamic_scenario, make_snapshot,
                                             snapshot_scenario)
from repro.core import fluid
from repro.core.events import (BackgroundFlowChange, LinkCapacityChange,
                               TrafficChange, UnknownEventTargetWarning)
from repro.core.experiment import Policy, run
from repro.core.cluster import Cluster, Node, Resources
from repro.core.framework import SchedulingFramework
from repro.core.scheduler import MetronomePlugin
from repro.core.simulator import COMM, ClusterSimulator, SimConfig
from repro.core.workload import Workload, make_job

CFG = SimConfig(duration_ms=20_000.0, seed=3, jitter_std=0.01)
LEGACY = dataclasses.replace(CFG, event_loop="legacy")

PINNED = ["S1", "S2", "S3", "S4", "S5", "F2", "F4", "J1"]


def _eq(x, y):
    if isinstance(x, float) and isinstance(y, float):
        return (math.isnan(x) and math.isnan(y)) or x == y
    return x == y


def _map_eq(x, y):
    return set(x) == set(y) and all(_eq(x[k], y[k]) for k in x)


def sim_equal(a, b):
    """Bit-for-bit SimResult equality (NaN-aware float maps)."""
    assert a.durations_ms == b.durations_ms
    assert _map_eq(a.time_per_1000_iters_s, b.time_per_1000_iters_s)
    assert _map_eq(a.link_utilization, b.link_utilization)
    assert _eq(a.avg_bw_utilization, b.avg_bw_utilization)
    assert a.readjustments == b.readjustments
    assert _map_eq(a.finish_times_ms, b.finish_times_ms)
    assert _eq(a.total_completion_ms, b.total_completion_ms)
    assert a.iterations_done == b.iterations_done
    assert a.reconfigurations == b.reconfigurations


def small_cluster(n=2, bw=25.0):
    nodes = [Node(f"n{i}", Resources(cpu=32, mem=256, gpu=4), bw_gbps=bw)
             for i in range(n)]
    return Cluster(nodes)


def wl(job):
    return Workload(name=job.name, jobs=[job])


def _scheduled(jobs):
    """Place ``jobs`` on a fresh 2-node cluster (real comm flows need task
    placements); returns (cluster, registry)."""
    cl = small_cluster()
    fw = SchedulingFramework(cl, MetronomePlugin())
    for j in jobs:
        assert fw.schedule_workload(wl(j))
    return cl, fw.registry


def _both_loops(jobs_factory, cfg, **sim_kwargs):
    """Run the same scheduled setup through both loops."""
    out = []
    for loop in ("array", "legacy"):
        jobs = jobs_factory()
        cl, registry = _scheduled(jobs)
        sim = ClusterSimulator(
            cl, jobs, dataclasses.replace(cfg, event_loop=loop),
            registry=registry,
            **{k: (v() if callable(v) else v) for k, v in sim_kwargs.items()})
        out.append((sim, sim.run()))
    return out


# ---------------------------------------------------------------------------
# oracle parity: array loop bit-for-bit against the retained legacy loop
# ---------------------------------------------------------------------------

class TestOracleParity:
    @pytest.mark.parametrize("sid", PINNED)
    def test_static_snapshots(self, sid):
        scen = snapshot_scenario(sid, n_iterations=30)
        arr = run(scen, Policy("metronome"), CFG)
        leg = run(scen, Policy("metronome"), LEGACY)
        sim_equal(arr.sim, leg.sim)
        assert arr.accepted == leg.accepted
        assert arr.placements == leg.placements

    @pytest.mark.parametrize("sid", DYNAMIC_SNAPSHOTS)
    def test_dynamic_snapshots(self, sid):
        scen = dynamic_scenario(sid, n_iterations=30)
        arr = run(scen, Policy("metronome"), CFG)
        leg = run(scen, Policy("metronome"), LEGACY)
        sim_equal(arr.sim, leg.sim)
        assert arr.accepted == leg.accepted

    def test_online_trace_with_departures(self):
        """Arrivals + departures through the full online path: both loops
        admit, run, and truncate identically."""
        from repro.core.harness import run_trace_experiment
        from repro.core.trace import (generate_trace, trace_departure_events,
                                      trace_to_jobs)
        trace = generate_trace(
            MODEL_FLEET, duration_s=600, total_gpus=13, target_load=0.8,
            seed=2, job_duration_range_s=(60, 120))[:6]
        evs = trace_departure_events(trace, time_scale=1.0)
        results = []
        for loop in ("array", "legacy"):
            cluster, _, _ = make_snapshot("S1")
            jobs = trace_to_jobs(trace, MODEL_FLEET, time_scale=1.0,
                                 open_ended=True)
            wls = [Workload(name=j.name, jobs=[j]) for j in jobs]
            for w in wls:
                for j in w.jobs:
                    j.workload = w.name
                    for t in j.tasks:
                        t.workload = w.name
            cfg = SimConfig(duration_ms=900_000, seed=0, jitter_std=0.01,
                            event_loop=loop)
            results.append(run_trace_experiment(
                "metronome", cluster, wls, cfg, events=list(evs)))
        sim_equal(results[0].sim, results[1].sim)
        assert results[0].rejected == results[1].rejected

    def test_unknown_event_loop_rejected(self):
        with pytest.raises(ValueError, match="unknown event_loop"):
            ClusterSimulator(small_cluster(), [],
                             SimConfig(event_loop="turbo"))


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------

class TestEdgeCases:
    CFG = SimConfig(duration_ms=10_000.0, seed=0, jitter_std=0.0)

    def _job(self, name="j", **kw):
        kw.setdefault("n_tasks", 2)
        kw.setdefault("period_ms", 100)
        kw.setdefault("duty", 0.4)
        kw.setdefault("bw_gbps", 20.0)
        kw.setdefault("n_iterations", 5)
        return make_job(name, **kw)

    def test_starved_flow_never_finishes(self):
        """Background traffic claims a link's full capacity: the flow rate
        is zero, no finish event ever fires, and the loop still terminates
        at the duration cap (in both loops, identically)."""
        evs = [BackgroundFlowChange(50.0, link="n0", rate_gbps=25.0)]
        (sa, ra), (sl, rl) = _both_loops(
            lambda: [self._job()], self.CFG, events=lambda: list(evs))
        sim_equal(ra, rl)
        for sim, res in ((sa, ra), (sl, rl)):
            st = sim.jobs["j"]
            assert st.phase == COMM  # stuck mid-comm at the cap
            assert math.isnan(res.finish_times_ms["j"])
            assert res.iterations_done["j"] == 0
            assert sim.now == pytest.approx(self.CFG.duration_ms)

    def test_multiple_events_share_one_timestamp(self):
        """All events due at one tick drain together, in stream order."""
        evs = [BackgroundFlowChange(5_000.0, link="n0", rate_gbps=10.0),
               LinkCapacityChange(5_000.0, link="n1", allocatable_gbps=12.0),
               TrafficChange(5_000.0, job="j", duty_mult=1.5)]
        (sa, ra), (sl, rl) = _both_loops(
            lambda: [self._job(n_iterations=40)], self.CFG,
            events=lambda: list(evs))
        sim_equal(ra, rl)
        for sim in (sa, sl):
            assert sim.cluster.node("n0").allocatable_gbps == pytest.approx(15.0)
            assert sim.cluster.node("n1").allocatable_gbps == pytest.approx(12.0)
            # duty 0.4 * 1.5 -> comm 60ms of the 100ms period
            assert sim.jobs["j"].job.traffic.duty == pytest.approx(0.6)

    def test_arrival_tied_with_event_time(self):
        """An online arrival at exactly an event's timestamp: the event
        applies and the job is admitted in the same tick, identically in
        both loops."""
        def arrivals():
            late = self._job("late", submit_time_s=5.0)
            return [wl(late)]

        results = []
        for loop in ("array", "legacy"):
            cl = small_cluster()
            fw = SchedulingFramework(cl, MetronomePlugin())
            early = self._job("early", n_iterations=80)
            assert fw.schedule_workload(wl(early))
            sim = ClusterSimulator(
                cl, [early], dataclasses.replace(self.CFG, event_loop=loop),
                registry=fw.registry, framework=fw, arrivals=arrivals(),
                events=[BackgroundFlowChange(5_000.0, link="n0",
                                             rate_gbps=5.0)])
            results.append((sim, sim.run()))
        (sa, ra), (sl, rl) = results
        sim_equal(ra, rl)
        for sim, res in results:
            assert "late" in sim.jobs
            assert res.iterations_done["late"] > 0
            assert sim.cluster.node("n0").allocatable_gbps == pytest.approx(20.0)


# ---------------------------------------------------------------------------
# unknown-target warnings (once per offender)
# ---------------------------------------------------------------------------

class TestUnknownTargetWarnings:
    CFG = SimConfig(duration_ms=3_000.0, seed=0, jitter_std=0.0)

    def _run(self, events):
        sim = ClusterSimulator(
            small_cluster(),
            [make_job("j", n_tasks=2, period_ms=100, duty=0.3,
                      bw_gbps=10.0, n_iterations=5)],
            self.CFG, events=events)
        return sim

    def test_unknown_bg_link_warns_once(self):
        evs = [BackgroundFlowChange(100.0, link="ghost", rate_gbps=5.0),
               BackgroundFlowChange(200.0, link="ghost", rate_gbps=9.0)]
        with pytest.warns(UnknownEventTargetWarning) as rec:
            self._run(evs).run()
        ours = [w for w in rec if isinstance(w.message,
                                             UnknownEventTargetWarning)]
        assert len(ours) == 1  # once per offender, not per event
        assert ours[0].message.kind == "link"
        assert ours[0].message.name == "ghost"
        assert ours[0].message.time_ms == pytest.approx(100.0)

    def test_unknown_traffic_job_warns_once(self):
        evs = [TrafficChange(100.0, job="nobody", duty_mult=2.0),
               TrafficChange(200.0, job="nobody", duty_mult=0.5)]
        with pytest.warns(UnknownEventTargetWarning) as rec:
            self._run(evs).run()
        ours = [w for w in rec if isinstance(w.message,
                                             UnknownEventTargetWarning)]
        assert len(ours) == 1
        assert ours[0].message.kind == "job"
        assert ours[0].message.name == "nobody"

    def test_unknown_capacity_link_warns(self):
        evs = [LinkCapacityChange(100.0, link="uplink:nowhere",
                                  allocatable_gbps=1.0)]
        with pytest.warns(UnknownEventTargetWarning):
            self._run(evs).run()

    def test_distinct_offenders_warn_separately(self):
        evs = [BackgroundFlowChange(100.0, link="ghost-a", rate_gbps=5.0),
               BackgroundFlowChange(150.0, link="ghost-b", rate_gbps=5.0)]
        with pytest.warns(UnknownEventTargetWarning) as rec:
            self._run(evs).run()
        names = sorted(w.message.name for w in rec
                       if isinstance(w.message, UnknownEventTargetWarning))
        assert names == ["ghost-a", "ghost-b"]

    def test_known_targets_do_not_warn(self):
        import warnings as warnings_mod
        evs = [BackgroundFlowChange(100.0, link="n0", rate_gbps=5.0),
               TrafficChange(200.0, job="j", duty_mult=1.2)]
        with warnings_mod.catch_warnings():
            warnings_mod.simplefilter("error", UnknownEventTargetWarning)
            self._run(evs).run()  # must not raise


# ---------------------------------------------------------------------------
# SimConfig.profile
# ---------------------------------------------------------------------------

class TestProfile:
    def _cfg(self, loop):
        return SimConfig(duration_ms=10_000.0, seed=0, jitter_std=0.0,
                         event_loop=loop, profile=True)

    def _jobs(self):
        return [make_job("a", n_tasks=2, period_ms=100, duty=0.4,
                         bw_gbps=20.0, n_iterations=40),
                make_job("b", n_tasks=2, period_ms=130, duty=0.3,
                         bw_gbps=10.0, n_iterations=40, submit_time_s=0.013)]

    @pytest.mark.parametrize("loop", ["array", "legacy"])
    def test_profile_populated(self, loop):
        jobs = self._jobs()
        cl, registry = _scheduled(jobs)
        sim = ClusterSimulator(cl, jobs, self._cfg(loop), registry=registry)
        res = sim.run()
        p = res.profile
        assert p is not None and p.loop == loop
        assert p.ticks > 0 and p.steps > 0 and p.solves > 0
        phases = p.phase_seconds()
        assert set(phases) == {"assign", "next_event", "advance", "events",
                               "step"}
        assert all(v >= 0.0 for v in phases.values())
        assert p.as_dict()["ticks"] == p.ticks

    def test_array_loop_skips_clean_assigns(self):
        """Dirty-link tracking: ticks where no flow/capacity changed skip
        the rate solve entirely.  The single-task job's flowless phase
        timers fire inside the others' comm windows — pure-timer ticks
        that leave every link clean."""
        jobs = self._jobs() + [
            make_job("c", n_tasks=1, period_ms=17, duty=0.3, bw_gbps=1.0,
                     n_iterations=400)]
        cl, registry = _scheduled(jobs)
        sim = ClusterSimulator(cl, jobs, self._cfg("array"),
                               registry=registry)
        p = sim.run().profile
        assert p.skipped_assigns > 0
        assert p.solves + p.skipped_assigns <= p.ticks

    def test_profile_off_by_default(self):
        sim = ClusterSimulator(small_cluster(), self._jobs(),
                               SimConfig(duration_ms=2_000.0))
        assert sim.run().profile is None


# ---------------------------------------------------------------------------
# batched multi-problem solves + shape-bucketed corpus batching
# ---------------------------------------------------------------------------

def _random_problems(rng, n, fabric=True):
    probs = []
    for _ in range(n):
        n_hosts = int(rng.integers(2, 7))
        n_flows = int(rng.integers(1, 13))
        demands = rng.uniform(0.2, 30.0, size=n_flows)
        caps = {f"h{k}": float(rng.uniform(1.0, 40.0))
                for k in range(n_hosts)}
        paths = []
        for _ in range(n_flows):
            h = int(rng.integers(n_hosts))
            path = [f"h{h}"]
            if fabric and rng.random() < 0.5:
                path.append(f"uplink:{h % 2}")
            paths.append(tuple(path))
        if fabric:
            caps["uplink:0"] = float(rng.uniform(2.0, 25.0))
            caps["uplink:1"] = float(rng.uniform(2.0, 25.0))
        probs.append((demands, paths, caps))
    return probs


class TestSolveBatch:
    TOL = 5e-3

    def test_python_matches_sequential_oracle(self):
        probs = _random_problems(np.random.default_rng(11), 8)
        eng = fluid.FluidEngine("python")
        for got, (d, p, c) in zip(eng.solve_batch(probs), probs):
            np.testing.assert_array_equal(
                got, fluid.fill_python(np.asarray(d, dtype=float), p, c))

    def test_jnp_batch_within_tolerance(self):
        probs = _random_problems(np.random.default_rng(12), 8)
        eng = fluid.FluidEngine("jnp")
        for got, (d, p, c) in zip(eng.solve_batch(probs), probs):
            gold = fluid.fill_python(np.asarray(d, dtype=float), p, c)
            np.testing.assert_allclose(got, gold, atol=self.TOL, rtol=0)
        # shape-bucketed dispatch recorded its occupancy
        cs = eng.corpus_stats
        assert cs.calls >= 1 and cs.problems == 8
        assert 0.0 < cs.flow_occupancy <= 1.0
        assert 0.0 < cs.link_occupancy <= 1.0

    def test_incremental_memo_hits(self):
        probs = _random_problems(np.random.default_rng(13), 5)
        eng = fluid.FluidEngine("python", incremental=True)
        first = eng.solve_batch(probs)
        assert eng.stats.misses == 5
        second = eng.solve_batch(probs)
        assert eng.stats.hits == 5
        for a, b in zip(first, second):
            np.testing.assert_array_equal(a, b)

    def test_sampling_for_error_audit(self):
        """sample_stride captures (problem, solution) pairs so benches can
        re-solve them against the oracle for a max-abs-err figure."""
        probs = _random_problems(np.random.default_rng(14), 6)
        eng = fluid.FluidEngine("python")
        eng.sample_stride = 2
        eng.solve_batch(probs)
        assert len(eng.samples) == 3
        d, p, c, rates = eng.samples[0]
        np.testing.assert_array_equal(
            rates, fluid.fill_python(np.asarray(d, dtype=float), p, c))


class TestCorpusBucketing:
    def test_bucketed_matches_unbucketed(self):
        probs = _random_problems(np.random.default_rng(15), 12)
        mats = [fluid.problem_matrix(*p)[:3] for p in probs]
        plain = fluid.fill_corpus(mats, backend="jnp")
        stats = fluid.CorpusStats()
        bucketed = fluid.fill_corpus(mats, backend="jnp",
                                     bucket_shapes=True, stats=stats)
        for a, b in zip(plain, bucketed):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=0)
        assert stats.problems == 12
        assert stats.buckets >= 1  # batched dispatches happened
        # padding is visible, never silent: dispatched >= real slot counts
        assert stats.flow_slots >= stats.flow_used > 0
        assert stats.link_slots >= stats.link_used > 0

    def test_round_pow2(self):
        assert fluid._round_pow2(1) == 4
        assert fluid._round_pow2(4) == 4
        assert fluid._round_pow2(5) == 8
        assert fluid._round_pow2(17) == 32

    def test_stats_as_dict(self):
        stats = fluid.CorpusStats()
        d = stats.as_dict()
        assert d["calls"] == 0
        assert d["flow_occupancy"] == 1.0  # no dispatch -> no waste

"""Unit + property tests for the TDM circle abstraction (paper section II-B)."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import geometry as G


class TestUnifyPeriods:
    def test_exact_multiples(self):
        u = G.unify_periods([100.0, 50.0, 25.0])
        assert u.base_ms == 100.0
        assert list(u.muls) == [1, 2, 4]
        assert np.all(u.ok)
        assert np.allclose(u.injected_ms, 0.0)

    def test_gt_merge_small_mismatch(self):
        # 2.5ms mismatch <= G_T=5 -> commensurate at mul 2 with injection
        # into the low-priority task (drift compensation)
        u = G.unify_periods([245.0, 120.0], priorities=[1, 0])
        assert list(u.muls) == [1, 2]
        assert u.ok.all()
        assert u.injected_ms[1] == pytest.approx(2.5)

    def test_et_injection(self):
        # paper S2: 96 vs 90 -> 6ms > G_T, <= 10% of 90 -> inject 6ms
        u = G.unify_periods([96.0, 90.0], priorities=[1, 0])
        assert list(u.muls) == [1, 1]
        assert u.ok.all()
        assert u.injected_ms[1] == pytest.approx(6.0)

    def test_never_injects_into_high_priority(self):
        u = G.unify_periods([96.0, 90.0], priorities=[0, 1])
        # the high-priority second task cannot be slowed down
        assert u.injected_ms[1] == 0.0

    def test_incompatible_periods_flagged(self):
        u = G.unify_periods([100.0, 73.0], priorities=[1, 0], max_mul=1)
        assert not u.ok.all()

    def test_reference_period_unchanged(self):
        u = G.unify_periods([100.0, 52.0], priorities=[1, 0])
        # reference (high priority) keeps an exact divisor of the base
        assert u.base_ms % 100.0 == 0.0


class TestPatterns:
    def test_pattern_total_equals_duty(self):
        for mul in (1, 2, 3, 4):
            for duty in (0.1, 0.3, 0.5):
                pat = G.pattern_vector(mul, duty, 72)
                assert pat.sum() == pytest.approx(duty * 72, abs=1e-6)

    def test_pattern_bursts(self):
        pat = G.pattern_vector(2, 0.25, 72)
        # two bursts of 9 slots at offsets 0 and 36
        assert pat[:9].sum() == pytest.approx(9.0)
        assert pat[36:45].sum() == pytest.approx(9.0)
        assert pat[10:35].sum() == pytest.approx(0.0)

    def test_roll_is_rotation(self):
        pats = G.pattern_matrix([1], [0.3], 72)
        rolled = G.roll_patterns(pats, np.array([10]))
        assert np.allclose(np.roll(pats[0], 10), rolled[0])


class TestDemandAndScore:
    def test_demand_eq4(self):
        pats = G.pattern_matrix([1, 1], [0.5, 0.5], 72)
        d = G.demand(pats, np.array([10.0, 20.0]), np.array([0, 36]))
        assert d.max() == pytest.approx(20.0)
        assert d.min() == pytest.approx(10.0)

    def test_score_perfect_iff_no_excess(self):
        pats = G.pattern_matrix([1, 1], [0.4, 0.4], 72)
        # disjoint comm phases -> perfect
        s = G.score(pats, np.array([20.0, 20.0]), np.array([0, 36]), 25.0)
        assert s == pytest.approx(100.0)
        # fully overlapping -> not perfect
        s = G.score(pats, np.array([20.0, 20.0]), np.array([0, 0]), 25.0)
        assert s < 100.0

    def test_utilization_bounds(self):
        pats = G.pattern_matrix([1, 2], [0.5, 0.4], 72)
        u = G.link_utilization(pats, np.array([30.0, 20.0]),
                               np.array([0, 5]), 25.0)
        assert 0.0 <= u <= 1.0

    def test_psi_distance(self):
        # two contending single-burst tasks 36 slots apart -> Psi = 36
        psi = G.min_comm_interval([1, 1], [0.1, 0.1], [20.0, 20.0],
                                  [0, 36], 25.0, 72)
        assert psi == pytest.approx(36.0, abs=1.0)

    def test_non_contending_pairs_ignored(self):
        psi = G.min_comm_interval([1, 1], [0.1, 0.1], [5.0, 5.0],
                                  [0, 1], 25.0, 72)
        assert psi == 72.0  # no contending pair -> sentinel


class TestConversions:
    def test_shift_delay_roundtrip(self):
        delays = G.shifts_to_delay_ms(np.array([0, 18, 36]), 1000.0, 72)
        assert np.allclose(delays, [0.0, 250.0, 500.0])
        assert G.delay_to_shift_slots(250.0, 1000.0, 72) == 18


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@given(
    duties=st.lists(st.floats(0.05, 0.45), min_size=2, max_size=4),
    shift=st.integers(0, 71),
)
def test_property_common_rotation_invariance(duties, shift):
    """Rotating ALL tasks by the same angle preserves demand profile stats
    (rotation is relative — paper Eq. 16 rationale)."""
    n = len(duties)
    pats = G.pattern_matrix([1] * n, duties, 72)
    bw = np.full(n, 10.0)
    base = np.arange(n) * 7
    d1 = G.demand(pats, bw, base)
    d2 = G.demand(pats, bw, (base + shift) % 72)
    assert np.allclose(sorted(d1), sorted(d2), atol=1e-9)
    assert G.excess(pats, bw, base, 15.0) == pytest.approx(
        G.excess(pats, bw, (base + shift) % 72, 15.0), abs=1e-9)


@given(
    duty=st.floats(0.01, 0.99),
    mul=st.integers(1, 6),
    bw=st.floats(1.0, 30.0),
    cap=st.floats(5.0, 30.0),
    shift=st.integers(0, 71),
)
def test_property_score_bounds(duty, mul, bw, cap, shift):
    pats = G.pattern_matrix([mul], [duty], 72)
    s = G.score(pats, np.array([bw]), np.array([shift]), cap)
    assert 0.0 <= s <= 100.0
    if bw <= cap:
        assert s == pytest.approx(100.0)


@given(
    duties=st.lists(st.floats(0.05, 0.3), min_size=1, max_size=4),
)
def test_property_utilization_le_demand_fraction(duties):
    """Utilization can never exceed sum of duty cycles x bw/cap."""
    n = len(duties)
    pats = G.pattern_matrix([1] * n, duties, 72)
    bw = np.full(n, 10.0)
    cap = 25.0
    u = G.link_utilization(pats, bw, np.zeros(n, int), cap)
    ub = min(1.0, sum(d * 10.0 for d in duties) / cap)
    assert u <= ub + 1e-9

"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _qkv(b, h, hkv, s, d, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (b, hkv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (b, hkv, s, d), jnp.float32).astype(dtype)
    return q, k, v


class TestFlashAttention:
    @pytest.mark.parametrize("s", [128, 256, 1024])
    @pytest.mark.parametrize("d", [64, 128])
    @pytest.mark.parametrize("g", [1, 4])
    def test_causal_shapes(self, s, d, g):
        q, k, v = _qkv(2, 4, 4 // g, s, d, jnp.float32)
        out = ops.flash_attention(q, k, v, True, 0, True)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("window", [64, 256])
    def test_sliding_window(self, window):
        q, k, v = _qkv(1, 2, 1, 512, 64, jnp.float32)
        out = ops.flash_attention(q, k, v, True, window, True)
        want = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    def test_bidirectional(self):
        q, k, v = _qkv(1, 2, 2, 256, 64, jnp.float32)
        out = ops.flash_attention(q, k, v, False, 0, True)
        want = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    def test_bf16(self):
        q, k, v = _qkv(1, 2, 2, 256, 64, jnp.bfloat16)
        out = ops.flash_attention(q, k, v, True, 0, True)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            out.astype(jnp.float32), want.astype(jnp.float32),
            atol=2e-2, rtol=2e-2)

    def test_gradients_match_reference(self):
        q, k, v = _qkv(1, 2, 1, 128, 64, jnp.float32)

        def f_kernel(q_, k_, v_):
            return (ops.flash_attention(q_, k_, v_, True, 0, True) ** 2).sum()

        def f_ref(q_, k_, v_):
            return (ref.attention_ref(q_, k_, v_, causal=True) ** 2).sum()

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


class TestMetronomeScoreKernel:
    @pytest.mark.parametrize("ra,rb,s", [(36, 72, 72), (9, 24, 72), (5, 7, 64)])
    def test_sweep(self, ra, rb, s):
        rng = np.random.default_rng(1)
        base = rng.uniform(0, 12, s)
        a = rng.uniform(0, 15, (ra, s))
        b = rng.uniform(0, 15, (rb, s))
        got = ops.score_pairwise(base, a, b, 25.0, interpret=True)
        want = ref.metronome_score_ref(base, a, b, 25.0)
        np.testing.assert_allclose(got, want, atol=1e-4)

    @given(cap=st.floats(5.0, 40.0))
    @settings(max_examples=10)
    def test_property_bounds(self, cap):
        rng = np.random.default_rng(2)
        base = rng.uniform(0, 10, 72)
        a = rng.uniform(0, 10, (12, 72))
        b = rng.uniform(0, 10, (12, 72))
        got = ops.score_pairwise(base, a, b, cap, interpret=True)
        assert np.all(got >= 0.0) and np.all(got <= 100.0)


class TestRgLruKernel:
    @pytest.mark.parametrize("s,w", [(256, 512), (512, 1024), (128, 2560)])
    def test_sweep(self, s, w):
        k1, k2 = jax.random.split(KEY)
        a = jax.nn.sigmoid(jax.random.normal(k1, (2, s, w))) * 0.3 + 0.65
        x = jax.random.normal(k2, (2, s, w), jnp.float32)
        got = ops.rg_lru(a, x, interpret=True)
        want = ref.rg_lru_ref(a, x)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def test_matches_model_assoc_scan(self):
        """Kernel == the model's associative-scan path (same recurrence)."""
        import jax.lax as lax
        k1, k2 = jax.random.split(KEY)
        a = jax.nn.sigmoid(jax.random.normal(k1, (1, 256, 256))) * 0.3 + 0.6
        x = jax.random.normal(k2, (1, 256, 256), jnp.float32)

        def combine(c1, c2):
            a1, x1 = c1
            a2, x2 = c2
            return a1 * a2, a2 * x1 + x2

        _, want = lax.associative_scan(combine, (a, x), axis=1)
        got = ops.rg_lru(a, x, interpret=True)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


class TestProgressiveFillKernel:
    """Direct dispatcher-level parity for the Pallas fill kernel (the
    fluid-engine suites only cover it through fill_many)."""

    @pytest.mark.parametrize("b,f,l", [(1, 3, 2), (2, 9, 5), (1, 17, 130)])
    def test_matches_ref(self, b, f, l):
        k1, k2, k3 = jax.random.split(KEY, 3)
        demands = jax.random.uniform(k1, (b, f), minval=0.0, maxval=20.0)
        routes = (jax.random.uniform(k2, (b, f, l)) > 0.5).astype(
            jnp.float32)
        caps = jax.random.uniform(k3, (b, l), minval=5.0, maxval=30.0)
        got = ops.progressive_fill(demands, routes, caps, interpret=True)
        want = np.asarray(ref.progressive_fill_ref(demands, routes, caps))
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def test_padding_is_excess_neutral(self):
        """Zero-demand flows never activate; rates match the oracle even
        when flow/link counts are far from the tile sizes."""
        demands = jnp.array([[0.0, 10.0, 0.0, 4.0]])
        routes = jnp.ones((1, 4, 1), jnp.float32)
        caps = jnp.array([[8.0]])
        got = ops.progressive_fill(demands, routes, caps, interpret=True)
        want = np.asarray(ref.progressive_fill_ref(demands, routes, caps))
        np.testing.assert_allclose(got, want, atol=1e-5)
        assert got[0, 0] == 0.0 and got[0, 2] == 0.0

"""Algorithm 1 scheduler + stop-and-wait controller behavior tests."""

from repro.core.baselines import DefaultPlugin, DiktyoPlugin, ExclusivePlugin
from repro.core.cluster import Cluster, Node, Resources
from repro.core.controller import StopAndWaitController
from repro.core.framework import SchedulingFramework
from repro.core.scheduler import MetronomePlugin
from repro.core.workload import HIGH, LOW, Workload, make_job


def small_cluster(n=4, bw=25.0, gpus=4):
    nodes = [Node(f"n{i}", Resources(cpu=32, mem=256, gpu=gpus), bw_gbps=bw)
             for i in range(n)]
    return Cluster(nodes)


def wl(job):
    return Workload(name=job.name, jobs=[job])


def make_fw(controller=None):
    cl = small_cluster()
    plugin = MetronomePlugin(controller=controller)
    return SchedulingFramework(cl, plugin), cl, plugin


class TestFilter:
    def test_resource_filter(self):
        fw, cl, _ = make_fw()
        big = make_job("big", n_tasks=1, period_ms=100, duty=0.3, bw_gbps=5,
                       resources=Resources(cpu=64, mem=1, gpu=1), spread=0)
        assert not fw.schedule_job(big)

    def test_bandwidth_filter_eq14(self):
        fw, cl, _ = make_fw()
        hungry = make_job("hungry", n_tasks=1, period_ms=100, duty=0.3,
                          bw_gbps=30.0, spread=0)  # > 25G on every link
        assert not fw.schedule_job(hungry)

    def test_allocatable_bandwidth_respected(self):
        cl = small_cluster()
        cl.node("n0").allocatable_gbps = 5.0
        fw = SchedulingFramework(cl, MetronomePlugin())
        j = make_job("j", n_tasks=1, period_ms=100, duty=0.3, bw_gbps=10.0,
                     spread=0)
        assert fw.schedule_job(j)
        assert j.tasks[0].node != "n0"

    def test_all_or_nothing_rollback(self):
        """Coscheduling (Eqs. 11-12): partial placements roll back."""
        cl = small_cluster(n=2, gpus=1)
        fw = SchedulingFramework(cl, MetronomePlugin())
        j = make_job("j", n_tasks=3, period_ms=100, duty=0.3, bw_gbps=5.0,
                     spread=1)  # needs 3 nodes, only 2 exist
        assert not fw.schedule_job(j)
        assert all(t.node is None for t in j.tasks)
        assert all(not n.pods for n in cl.nodes.values())


class TestScoreAndNormalize:
    def test_early_return_no_contention(self):
        fw, cl, plugin = make_fw()
        j1 = make_job("a", n_tasks=2, period_ms=100, duty=0.3, bw_gbps=10.0)
        fw.schedule_workload(wl(j1))
        # 2x10G <= 25G: every node early-returns -> skip flag set
        j2 = make_job("b", n_tasks=2, period_ms=100, duty=0.3, bw_gbps=10.0)
        fw.schedule_workload(wl(j2))
        assert all(m.skip_phase_three for m in plugin.messages)

    def test_lowcomm_takes_worst_network_node(self):
        cl = small_cluster()
        cl.set_latency("n3", "n0", 50.0)
        cl.set_latency("n3", "n1", 50.0)
        cl.set_latency("n3", "n2", 50.0)
        fw = SchedulingFramework(cl, MetronomePlugin())
        j = make_job("lc", n_tasks=1, period_ms=100, duty=0.0, bw_gbps=0.0,
                     spread=0)
        assert fw.schedule_job(j)
        assert j.tasks[0].node == "n3"  # LowComm -> worst latency node

    def test_contending_pods_get_interleaved(self):
        ctrl = StopAndWaitController()
        cl = small_cluster(n=2)
        fw = SchedulingFramework(cl, MetronomePlugin(controller=ctrl))
        j1 = make_job("hi", n_tasks=2, period_ms=100, duty=0.4, bw_gbps=20.0,
                      priority=HIGH)
        j2 = make_job("lo", n_tasks=2, period_ms=100, duty=0.4, bw_gbps=20.0,
                      priority=LOW, submit_time_s=1.0)
        fw.schedule_workload(wl(j1))
        fw.schedule_workload(wl(j2))
        # both jobs span both nodes -> contention -> rotation assigned
        assert ctrl.links
        off = ctrl.job_offset_ms("lo")
        assert off > 0.0  # low-priority job shifted off the reference

    def test_congested_node_avoided_via_latency(self):
        cl = small_cluster()
        for other in ("n0", "n1", "n2"):
            cl.set_latency("n3", other, 40.0)
        fw = SchedulingFramework(cl, MetronomePlugin())
        j = make_job("j", n_tasks=2, period_ms=100, duty=0.3, bw_gbps=10.0)
        fw.schedule_job(j)
        assert "n3" not in j.nodes_used()


class TestController:
    def _schedule_contending(self):
        ctrl = StopAndWaitController()
        cl = small_cluster(n=2)
        fw = SchedulingFramework(cl, MetronomePlugin(controller=ctrl))
        hi = make_job("hi", n_tasks=2, period_ms=100, duty=0.4, bw_gbps=20.0,
                      priority=HIGH)
        lo = make_job("lo", n_tasks=2, period_ms=100, duty=0.4, bw_gbps=20.0,
                      priority=LOW, submit_time_s=1.0)
        fw.schedule_workload(wl(hi))
        fw.schedule_workload(wl(lo))
        return ctrl, fw, cl

    def test_global_offset_reference_is_high_priority(self):
        ctrl, fw, cl = self._schedule_contending()
        assert ctrl.global_offsets_ms.get("hi", 0.0) == 0.0  # Eq. 16

    def test_offsets_consistent_across_links(self):
        """A job spanning 2 links gets ONE offset (Eq. 17)."""
        ctrl, fw, cl = self._schedule_contending()
        offs = set()
        for node, state in ctrl.links.items():
            sch = state.scheme
            if "lo" in sch.jobs:
                offs.add(round(ctrl.job_offset_ms("lo"), 6))
        assert len(offs) == 1

    def test_offline_recalculation_runs(self):
        ctrl, fw, cl = self._schedule_contending()
        n = ctrl.run_offline_recalculation(fw.registry, cl)
        assert ctrl.recalc_count == n
        assert not ctrl.pending_recalc

    def test_drift_monitor_triggers_after_ot(self):
        ctrl, fw, cl = self._schedule_contending()
        ctrl.set_baseline("lo", 100.0, LOW)
        ctrl.set_baseline("hi", 100.0, HIGH)
        actions = []
        for _ in range(10):
            actions = ctrl.report_iteration("lo", 120.0)  # >110% baseline
            if actions:
                break
        assert actions, "monitor should trip within the window"
        assert all(a.job != "hi" for a in actions), \
            "high-priority jobs are never paused"
        assert ctrl.readjust_count == 1

    def test_no_trigger_within_threshold(self):
        ctrl, fw, cl = self._schedule_contending()
        ctrl.set_baseline("lo", 100.0, LOW)
        for _ in range(20):
            assert not ctrl.report_iteration("lo", 105.0)  # < A_T=110%

    def test_traffic_change_recalculates(self):
        ctrl, fw, cl = self._schedule_contending()
        spec = fw.registry.job_tasks("lo")[0].traffic
        import dataclasses
        new = dataclasses.replace(spec, duty=min(0.9, spec.duty * 1.5))
        before = ctrl.recalc_count
        ctrl.report_traffic_change(fw.registry, cl, "lo", new)
        assert ctrl.recalc_count > before
        assert fw.registry.job_tasks("lo")[0].traffic.duty == new.duty


class TestBaselines:
    def test_default_prefers_emptier_nodes(self):
        cl = small_cluster()
        cl.node("n0").allocate("x", Resources(cpu=16, mem=128, gpu=3), 0.0)
        fw = SchedulingFramework(cl, DefaultPlugin())
        j = make_job("j", n_tasks=1, period_ms=100, duty=0.3, bw_gbps=5.0,
                     spread=0)
        fw.schedule_job(j)
        assert j.tasks[0].node != "n0"

    def test_exclusive_rejects_oversubscription(self):
        cl = small_cluster(n=1)
        fw = SchedulingFramework(cl, ExclusivePlugin())
        a = make_job("a", n_tasks=1, period_ms=100, duty=0.3, bw_gbps=20.0,
                     spread=0)
        b = make_job("b", n_tasks=1, period_ms=100, duty=0.3, bw_gbps=20.0,
                     spread=0)
        assert fw.schedule_job(a)
        assert not fw.schedule_job(b)  # 40G > 25G -> REJECTED

    def test_diktyo_compacts_near_dependencies(self):
        cl = small_cluster()
        cl.set_latency("n0", "n1", 1.0)
        cl.set_latency("n0", "n2", 30.0)
        cl.set_latency("n0", "n3", 30.0)
        fw = SchedulingFramework(cl, DiktyoPlugin())
        j = make_job("j", n_tasks=2, period_ms=100, duty=0.3, bw_gbps=5.0)
        fw.schedule_job(j)
        used = j.nodes_used()
        assert used == ["n0", "n1"] or used == ["n0"]


class TestRackLocality:
    """Beyond-paper rack-locality Score bonus: intra-leaf placements win
    before any uplink rotation is needed (ROADMAP PR 1 follow-up)."""

    def _fabric(self):
        from repro.core.cluster import make_fabric_cluster
        return make_fabric_cluster(n_leaves=2, hosts_per_leaf=2,
                                   bw_gbps=25.0, oversubscription=2.0)

    def test_two_task_job_stays_intra_leaf(self):
        """An F2-style fabric, one 2-task job: both pods land in ONE leaf
        even though all four hosts are empty and latency-equal."""
        cl = self._fabric()
        fw = SchedulingFramework(cl, MetronomePlugin())
        j = make_job("solo", n_tasks=2, period_ms=100, duty=0.35,
                     bw_gbps=12.0)
        assert fw.schedule_job(j)
        leaves = {cl.topology.leaf_of[n] for n in j.nodes_used()}
        assert len(leaves) == 1, "rack-locality bonus must keep it intra-leaf"

    def test_second_job_also_compacts(self):
        """With the first leaf partially used, a second 2-task job fills the
        other leaf intra-leaf rather than straddling the spine."""
        cl = self._fabric()
        fw = SchedulingFramework(cl, MetronomePlugin())
        a = make_job("a", n_tasks=2, period_ms=100, duty=0.35, bw_gbps=12.0)
        b = make_job("b", n_tasks=2, period_ms=100, duty=0.35, bw_gbps=12.0,
                     submit_time_s=0.001)
        assert fw.schedule_job(a) and fw.schedule_job(b)
        for j in (a, b):
            leaves = {cl.topology.leaf_of[n] for n in j.nodes_used()}
            assert len(leaves) == 1

    def test_star_unaffected(self):
        """No uplinks -> the penalty is identically zero (seed behavior)."""
        from repro.core.scheduler import RACK_LOCALITY_PENALTY
        from repro.core.contention import LinkView
        cl = small_cluster()
        fw = SchedulingFramework(cl, MetronomePlugin())
        j = make_job("j", n_tasks=2, period_ms=100, duty=0.3, bw_gbps=10.0)
        assert fw.schedule_job(j)
        plugin = fw.plugin
        view = LinkView.from_registry(cl, fw.registry)
        assert plugin._rack_penalty(view, j.tasks[0]) == 0.0
        assert RACK_LOCALITY_PENALTY < 1.0  # must stay below the loop cap

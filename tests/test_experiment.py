"""Scenario/Policy experiment API (DESIGN.md section 14).

Three pillars:

  * golden equivalence — the legacy ``run_experiment`` /
    ``run_trace_experiment`` shims and a directly-constructed
    Scenario+Policy ``run()`` are bit-for-bit identical on every pinned
    snapshot family (S1–S5, F2/F4, D1/D2, J1);
  * the trace-mode knob gap is CLOSED — reconfigure / rotation_joint /
    skip_third_stage provably change trace runs (the legacy trace path
    dropped them silently);
  * results round-trip through schema-versioned JSON and sweeps isolate
    per-cell failures.
"""
import json
import math

import pytest

from repro.configs.metronome_testbed import (MODEL_FLEET, dynamic_scenario,
                                             make_snapshot,
                                             make_dynamic_snapshot,
                                             snapshot_scenario,
                                             trace_scenario)
from repro.core.baselines import DefaultPlugin
from repro.core.experiment import (Policy, Scenario, register_scheduler, run,
                                   scheduler_names, sweep)
from repro.core.harness import run_experiment, run_trace_experiment
from repro.core.results import (SCHEMA_VERSION, ExperimentResult, SweepResult,
                                to_bench_dict, validate_bench_dict)
from repro.core.simulator import SimConfig
from repro.core.trace import generate_trace

CFG = SimConfig(duration_ms=20_000.0, seed=3, jitter_std=0.01)
N_ITER = 30


def _eq_float(a, b):
    if isinstance(a, float) and isinstance(b, float):
        return (math.isnan(a) and math.isnan(b)) or a == b
    return a == b


def _map_eq(a, b):
    return set(a) == set(b) and all(_eq_float(a[k], b[k]) for k in a)


def assert_sim_equal(a, b):
    """Bit-for-bit SimResult equality (NaN-aware on the float maps)."""
    assert a.durations_ms == b.durations_ms  # exact float lists
    assert _map_eq(a.time_per_1000_iters_s, b.time_per_1000_iters_s)
    assert _map_eq(a.link_utilization, b.link_utilization)
    assert _eq_float(a.avg_bw_utilization, b.avg_bw_utilization)
    assert a.readjustments == b.readjustments
    assert _map_eq(a.finish_times_ms, b.finish_times_ms)
    assert _eq_float(a.total_completion_ms, b.total_completion_ms)
    assert a.iterations_done == b.iterations_done
    assert a.reconfigurations == b.reconfigurations


class TestGoldenEquivalence:
    """Legacy shim == new run() on every pinned snapshot family."""

    @pytest.mark.parametrize("sid", ["S1", "S2", "S3", "S4", "S5", "F2",
                                     "F4", "J1"])
    def test_static_snapshots_metronome(self, sid):
        cluster, wls, bg = make_snapshot(sid, n_iterations=N_ITER)
        legacy = run_experiment("metronome", cluster, wls, CFG,
                                background=bg)
        new = run(snapshot_scenario(sid, n_iterations=N_ITER),
                  Policy("metronome"), CFG)
        assert_sim_equal(legacy.sim, new.sim)
        assert legacy.accepted == new.accepted
        assert legacy.rejected == new.rejected
        assert legacy.placements == new.placements

    @pytest.mark.parametrize("sched", ["default", "diktyo", "exclusive",
                                       "ideal"])
    def test_s2_other_schedulers(self, sched):
        cluster, wls, bg = make_snapshot("S2", n_iterations=N_ITER)
        legacy = run_experiment(sched, cluster, wls, CFG, background=bg)
        new = run(snapshot_scenario("S2", n_iterations=N_ITER),
                  Policy(sched), CFG)
        assert_sim_equal(legacy.sim, new.sim)
        assert legacy.accepted == new.accepted

    @pytest.mark.parametrize("sid", ["D1", "D2"])
    def test_dynamic_snapshots(self, sid):
        kw = dict(n_iterations=N_ITER, amplitude=0.3, t_on_ms=4_000.0,
                  t_off_ms=12_000.0)
        cluster, wls, bg, evs = make_dynamic_snapshot(sid, **kw)
        legacy = run_experiment("metronome", cluster, wls, CFG,
                                background=bg, events=evs)
        new = run(dynamic_scenario(sid, **kw), Policy("metronome"), CFG)
        assert_sim_equal(legacy.sim, new.sim)

    def test_j1_legacy_rotation_ablation(self):
        cluster, wls, bg = make_snapshot("J1", n_iterations=N_ITER)
        legacy = run_experiment("metronome", cluster, wls, CFG,
                                background=bg, rotation_joint=False)
        new = run(snapshot_scenario("J1", n_iterations=N_ITER),
                  Policy("metronome", rotation_joint=False), CFG)
        assert_sim_equal(legacy.sim, new.sim)

    def test_ablation_knobs(self):
        cluster, wls, bg = make_snapshot("S2", n_iterations=N_ITER)
        legacy = run_experiment("metronome", cluster, wls, CFG,
                                background=bg, skip_third_stage=True,
                                rotation_mode="compact")
        new = run(snapshot_scenario("S2", n_iterations=N_ITER),
                  Policy("metronome", skip_third_stage=True,
                         rotation_mode="compact"), CFG)
        assert_sim_equal(legacy.sim, new.sim)

    def test_traffic_changes_normalized_at_boundary(self):
        """Legacy (time, job, duty_mult) tuples == typed TrafficChange
        events through the scenario's event stream."""
        from repro.core.events import TrafficChange
        tc = [(5_000.0, "vgg16-ft", 1.4)]
        cluster, wls, bg = make_snapshot("S2", n_iterations=N_ITER)
        legacy = run_experiment("metronome", cluster, wls, CFG,
                                background=bg, traffic_changes=tc)

        def build():
            cl, w, b = make_snapshot("S2", n_iterations=N_ITER)
            return cl, w, b, [TrafficChange(5_000.0, "vgg16-ft", 1.4)]
        new = run(Scenario("S2-tc", build), Policy("metronome"), CFG)
        assert_sim_equal(legacy.sim, new.sim)

    def test_trace_shim_equivalence(self):
        trace = generate_trace(MODEL_FLEET, duration_s=600, total_gpus=13,
                               target_load=0.85, seed=1,
                               job_duration_range_s=(60, 120))[:5]
        scn = trace_scenario(trace, open_ended=True, name="t")
        cfg = SimConfig(duration_ms=60_000, seed=0, jitter_std=0.01)
        for sched in ("metronome", "default"):
            cluster, wls, _, evs = scn.materialize()
            legacy = run_trace_experiment(sched, cluster, wls, cfg,
                                          events=evs)
            new = run(scn, Policy(sched), cfg)
            assert_sim_equal(legacy.sim, new.sim)
            assert legacy.accepted == new.accepted
            assert legacy.rejected == new.rejected


class TestTraceKnobGap:
    """Trace runs accept the full Policy — the legacy trace path hardcoded
    a default controller and could not ablate anything."""

    CFG = SimConfig(duration_ms=25_000.0, seed=3, jitter_std=0.01)

    @staticmethod
    def _j1_trace():
        def build():
            cluster, wls, bg = make_snapshot("J1", n_iterations=40)
            return cluster, wls, bg
        return Scenario.trace("J1-trace", build)

    def test_rotation_joint_changes_trace_run(self):
        scn = self._j1_trace()
        joint = run(scn, Policy("metronome"), self.CFG)
        legacy = run(scn, Policy("metronome", rotation_joint=False),
                     self.CFG)
        assert joint.accepted == legacy.accepted  # same admissions...
        assert joint.sim.durations_ms != legacy.sim.durations_ms  # ...new plan

    def test_reconfigure_ablation_in_trace_mode(self):
        def build():
            return make_dynamic_snapshot("D2", n_iterations=40,
                                         amplitude=0.4, t_on_ms=4_000.0,
                                         t_off_ms=12_000.0)
        scn = Scenario.trace("D2-trace", build)
        on = run(scn, Policy("metronome"), self.CFG)
        off = run(scn, Policy("metronome", reconfigure=False), self.CFG)
        assert on.sim.reconfigurations > 0
        assert off.sim.reconfigurations == 0

    def test_skip_third_stage_in_trace_mode(self):
        scn = self._j1_trace()
        full = run(scn, Policy("metronome"), self.CFG)
        skipped = run(scn, Policy("metronome", skip_third_stage=True),
                      self.CFG)
        assert full.sim.durations_ms != skipped.sim.durations_ms


class TestResultsSerialization:
    def _result(self) -> ExperimentResult:
        return run(snapshot_scenario("S2", n_iterations=20),
                   Policy("metronome"), CFG)

    def test_experiment_result_round_trip(self):
        res = self._result()
        payload = json.dumps(res.to_json_dict(), allow_nan=False)
        back = ExperimentResult.from_json_dict(json.loads(payload))
        assert back.scenario == res.scenario
        assert back.policy == res.policy
        assert back.scheduler == res.scheduler
        assert back.accepted == res.accepted
        assert back.rejected == res.rejected
        assert back.placements == res.placements
        assert back.high_priority == res.high_priority
        assert back.low_priority == res.low_priority
        assert_sim_equal(back.sim, res.sim)

    def test_compact_serialization_keeps_derived_means(self):
        res = self._result()
        d = res.to_json_dict(include_durations=False)
        assert "durations_ms" not in d["sim"]
        for job, mean in d["sim"]["mean_iter_ms"].items():
            assert mean == pytest.approx(res.sim.mean_iter_ms(job))
        back = ExperimentResult.from_json_dict(d)  # loadable without samples
        assert back.sim.durations_ms == {j: [] for j in res.sim.durations_ms}

    def test_sweep_round_trip_and_file_io(self, tmp_path):
        sw = sweep([snapshot_scenario("S2", n_iterations=15)],
                   [Policy("metronome"), Policy("default")], CFG)
        assert not sw.errors
        path = tmp_path / "sweep.json"
        sw.save(str(path))
        back = SweepResult.load(str(path))
        assert back.schema_version == SCHEMA_VERSION
        assert [c.policy for c in back.cells] == ["metronome", "default"]
        assert_sim_equal(back.get("S2", "metronome").sim,
                         sw.get("S2", "metronome").sim)

    def test_schema_version_mismatch_rejected(self):
        sw = sweep([], [])
        d = sw.to_json_dict()
        d["schema_version"] = SCHEMA_VERSION + 1
        with pytest.raises(ValueError, match="schema version"):
            SweepResult.from_json_dict(d)

    def test_bench_dict_validation(self):
        sw = sweep([snapshot_scenario("S2", n_iterations=15)],
                   [Policy("metronome")], CFG)
        doc = json.loads(json.dumps(to_bench_dict([sw], smoke=True),
                                    allow_nan=False))
        assert validate_bench_dict(doc) == []
        # drift fails loudly: drop a required sim key
        del doc["sweeps"][0]["cells"][0]["result"]["sim"]["iterations_done"]
        assert any("iterations_done" in p for p in validate_bench_dict(doc))
        assert validate_bench_dict({"schema_version": 0, "sweeps": []})


class TestSweepIsolation:
    def test_failing_cell_is_isolated(self):
        def boom():
            raise RuntimeError("scenario exploded")
        grid = sweep([snapshot_scenario("S2", n_iterations=10),
                      Scenario("broken", boom)],
                     [Policy("metronome")], CFG)
        ok = grid.cell("S2", "metronome")
        bad = grid.cell("broken", "metronome")
        assert ok.status == "ok" and ok.result is not None
        assert bad.status == "error" and "scenario exploded" in bad.error
        assert [c.scenario for c in grid.errors] == ["broken"]
        with pytest.raises(RuntimeError, match="scenario exploded"):
            grid.get("broken", "metronome")

    def test_unknown_scheduler_is_isolated_too(self):
        grid = sweep([snapshot_scenario("S2", n_iterations=10)],
                     [Policy("no-such-mechanism")], CFG)
        assert grid.cells[0].status == "error"
        assert "unknown scheduler" in grid.cells[0].error


class TestRegistry:
    def test_register_and_run_custom_scheduler(self):
        name = "custom-default"
        register_scheduler(name, lambda policy: (DefaultPlugin(), None),
                           overwrite=True)
        assert name in scheduler_names()
        res = run(snapshot_scenario("S2", n_iterations=10), Policy(name),
                  CFG)
        baseline = run(snapshot_scenario("S2", n_iterations=10),
                       Policy("default"), CFG)
        assert_sim_equal(res.sim, baseline.sim)

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register_scheduler("metronome",
                               lambda policy: (DefaultPlugin(), None))

    def test_ideal_not_registrable(self):
        with pytest.raises(ValueError, match="ideal"):
            register_scheduler("ideal",
                               lambda policy: (DefaultPlugin(), None))

    def test_unknown_scheduler_message_names_registry(self):
        with pytest.raises(ValueError, match="metronome"):
            run(snapshot_scenario("S2", n_iterations=10), Policy("nope"),
                CFG)


class TestPolicyNaming:
    def test_auto_names_encode_deviations(self):
        assert Policy("metronome").name == "metronome"
        assert Policy("metronome", reconfigure=False).name == \
            "metronome-noreconf"
        assert Policy("metronome", rotation_joint=False,
                      skip_third_stage=True).name == "metronome-legacyrot-wo3"
        p = Policy("metronome").with_options(a_t=1.05, o_t=3)
        assert p.name == "metronome-a_t=1.05-o_t=3"
        assert Policy("metronome", label="x").name == "x"

    def test_scenario_mode_validated(self):
        with pytest.raises(ValueError, match="mode"):
            Scenario("bad", lambda: (), mode="nope")

    def test_build_arity_validated(self):
        scn = Scenario("bad", lambda: (1,))
        with pytest.raises(ValueError, match="build"):
            scn.materialize()

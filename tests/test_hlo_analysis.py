"""Trip-count-aware HLO roofline accounting (launch/hlo_analysis.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _analyze(fn, *specs):
    hlo = jax.jit(fn).lower(*specs).compile().as_text()
    return H.analyze(hlo)


class TestFlops:
    def test_single_matmul(self):
        a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
        r = _analyze(lambda x, y: x @ y, a, b)
        want = 2 * 128 * 256 * 64
        assert r["flops"] == pytest.approx(want, rel=0.2)

    def test_scan_multiplies_by_trip_count(self):
        w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 128), jnp.float32)

        def f(x, w):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=12)
            return y
        r = _analyze(f, x, w)
        want = 12 * 2 * 8 * 128 * 128
        assert r["flops"] == pytest.approx(want, rel=0.2)
        assert r["n_warnings"] == 0

    def test_nested_scans(self):
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((4, 64), jnp.float32)

        def f(x, w):
            def outer(c, _):
                def inner(c2, _):
                    return c2 @ w, None
                c, _ = jax.lax.scan(inner, c, None, length=5)
                return c, None
            y, _ = jax.lax.scan(outer, x, None, length=3)
            return y
        r = _analyze(f, x, w)
        want = 15 * 2 * 4 * 64 * 64
        assert r["flops"] == pytest.approx(want, rel=0.2)


class TestBytes:
    def test_dynamic_slice_attribution(self):
        """Scanning over stacked weights must charge ONE layer per trip."""
        ws = jax.ShapeDtypeStruct((16, 128, 128), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 128), jnp.float32)

        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, ws)
            return y
        r = _analyze(f, x, ws)
        stack_bytes = 16 * 128 * 128 * 4
        # all 16 layers read once in total: bytes ~ O(stack), NOT O(16*stack)
        assert r["hbm_bytes"] < 6 * stack_bytes, r["hbm_bytes"]

    def test_dynamic_update_slice_write(self):
        """Cache update writes the token, not the whole cache."""
        cache = jax.ShapeDtypeStruct((1024, 128), jnp.float32)
        tok = jax.ShapeDtypeStruct((1, 128), jnp.float32)

        def f(cache, tok):
            return jax.lax.dynamic_update_slice(cache, tok * 2.0, (5, 0))
        r = _analyze(f, cache, tok)
        cache_bytes = 1024 * 128 * 4
        # one full-buffer copy (undonated input->output) is real traffic;
        # the DUS itself must only add the update, not read+write the cache
        assert r["hbm_bytes"] <= cache_bytes * 1.05, r["hbm_bytes"]


class TestCollectives:
    def test_synthetic_all_reduce(self):
        hlo = """
HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (p: f32[1024,256]) -> f32[1024,256] {
  %p = f32[1024,256]{1,0} parameter(0)
  ROOT %ar = f32[1024,256]{1,0} all-reduce(%p), to_apply=%add
}
"""
        r = H.analyze(hlo)
        # wire model: ring all-reduce moves ~2x the buffer
        assert r["collective_bytes"] == 2 * 1024 * 256 * 4
        assert r["per_collective"]["all-reduce"] == 2 * 1024 * 256 * 4

    def test_all_gather_counts_operand_not_result(self):
        hlo = """
HloModule m

ENTRY %main (p: bf16[64,256]) -> bf16[512,256] {
  %p = bf16[64,256]{1,0} parameter(0)
  ROOT %ag = bf16[512,256]{1,0} all-gather(%p), dimensions={0}
}
"""
        r = H.analyze(hlo)
        # wire model: all-gather moves ~the gathered result
        assert r["per_collective"]["all-gather"] == 512 * 256 * 2

    def test_collective_inside_while_scaled(self):
        hlo = """
HloModule m

%body (t: (s32[], f32[128])) -> (s32[], f32[128]) {
  %t = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %x = f32[128]{0} get-tuple-element(%t), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %ar = f32[128]{0} all-reduce(%x), to_apply=%add2
  ROOT %out = (s32[], f32[128]) tuple(%ni, %ar)
}

%add2 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%cond (t: (s32[], f32[128])) -> pred[] {
  %t = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %n = s32[] constant(9)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (p: f32[128]) -> (s32[], f32[128]) {
  %p = f32[128]{0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[128]) tuple(%z, %p)
  ROOT %w = (s32[], f32[128]) while(%init), condition=%cond, body=%body
}
"""
        r = H.analyze(hlo)
        assert r["per_collective"]["all-reduce"] == 2 * 9 * 128 * 4


class TestDryrunResultsIfPresent:
    def test_dryrun_json_sanity(self):
        """If the background sweep has produced cells, sanity-check them."""
        import json, os
        path = os.path.join(os.path.dirname(__file__), "..", "results",
                            "dryrun.json")
        if not os.path.exists(path):
            pytest.skip("no dryrun results yet")
        with open(path) as f:
            results = json.load(f)
        ok = {k: v for k, v in results.items() if v.get("status") == "ok"}
        if not ok:
            pytest.skip("no completed cells yet")
        for cell, info in ok.items():
            assert info["cost"]["flops"] > 0, cell
            assert info["roofline"]["compute_s"] >= 0, cell
            ratio = info.get("model_vs_hlo_flops")
            if ratio is not None and "decode" not in cell and "500k" not in cell:
                # HLO flops within 20x of analytic 6ND (attention + remat
                # overhead push HLO above model flops; never 100x off)
                assert 0.05 < ratio < 20, (cell, ratio)

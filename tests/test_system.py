"""End-to-end system tests: scheduling -> simulation -> training-loop
integration (CommGate + IterationReporter), and a tiny-mesh dry-run."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.metronome_testbed import MODEL_FLEET, make_snapshot
from repro.core.harness import run_experiment
from repro.core.simulator import SimConfig
from repro.core.trace import cluster_load, generate_trace, trace_to_jobs
from repro.optim import AdamWConfig
from repro.runtime.steps import build_train_step, init_train_state


def test_trace_generator_hits_load():
    trace = generate_trace(MODEL_FLEET, duration_s=4 * 3600, total_gpus=13,
                           target_load=0.7, seed=0)
    load = cluster_load(trace, 13, 4 * 3600)
    assert 0.4 < load < 1.2
    jobs = trace_to_jobs(trace, MODEL_FLEET, time_scale=0.05)
    assert all(j.n_iterations >= 1 for j in jobs)


def test_tct_ordering_metronome_vs_default():
    """Fig. 10: Metronome completes the trace no later than Default (online
    arrivals, queueing, eviction — the paper's K8s trace behavior)."""
    from repro.core.harness import run_trace_experiment
    from repro.core.workload import Workload
    trace = generate_trace(MODEL_FLEET, duration_s=1800, total_gpus=13,
                           target_load=0.85, seed=1,
                           job_duration_range_s=(120, 240))[:10]
    cfg = SimConfig(duration_ms=900_000, seed=0, jitter_std=0.01)
    tct = {}
    for sched in ("metronome", "default"):
        cluster, _, _ = make_snapshot("S1")  # reuse testbed cluster
        jobs = trace_to_jobs(trace, MODEL_FLEET, time_scale=1.0)
        wls = [Workload(name=j.name, jobs=[j]) for j in jobs]
        for w in wls:
            for j in w.jobs:
                j.workload = w.name
                for t in j.tasks:
                    t.workload = w.name
        res = run_trace_experiment(sched, cluster, wls, cfg)
        tct[sched] = res.sim.total_completion_ms
    assert tct["metronome"] <= tct["default"] * 1.01


def test_training_loop_with_metronome_gate():
    """The end-to-end integration the paper runs: a training job whose sync
    phase is gated by the controller and which reports iteration times."""
    from repro.core.controller import StopAndWaitController
    from repro.runtime.comm_gate import CommGate, IterationReporter

    cfg = get_smoke_config("llama3_8b")
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0)
    state, _ = init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(cfg, opt_cfg, n_micro=1))

    ctrl = StopAndWaitController()
    clock = {"t": 0.0}
    gate = CommGate(ctrl, "job-a", clock=lambda: clock["t"],
                    sleep=lambda s: clock.__setitem__("t", clock["t"] + s))
    reporter = IterationReporter(ctrl, "job-a", priority=0,
                                 sleep=lambda s: None)

    tokens = jnp.zeros((4, 16), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    for i in range(3):
        gate.wait_for_slot()  # no scheme yet -> no-op
        state, metrics = step(state, batch)
        clock["t"] += 0.05
        reporter.report(0.05)
    assert int(state.step) == 3
    assert gate.total_delay_s == 0.0  # unconstrained job never sleeps


def test_tiny_mesh_train_step_compiles_sharded():
    """A 1x1 mesh exercise of the full sharded train_step path (the 512-dev
    production mesh is exercised by launch/dryrun.py)."""
    from repro.sharding import use_rules
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(1, 1)
    cfg = get_smoke_config("qwen2_moe_a2_7b")
    opt_cfg = AdamWConfig(warmup_steps=0)
    with use_rules(mesh):
        state, specs = init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0))
        step = jax.jit(build_train_step(cfg, opt_cfg, n_micro=2))
        tokens = jnp.zeros((4, 16), jnp.int32)
        batch = {"tokens": tokens, "labels": tokens}
        state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_ablation_hooks():
    """skip_third_stage + monitor=False run end-to-end (benchmark paths)."""
    cluster, wls, bg = make_snapshot("S2", n_iterations=100)
    cfg = SimConfig(duration_ms=30_000, monitor=False)
    res = run_experiment("metronome", cluster, wls, cfg, background=bg,
                         skip_third_stage=True)
    assert res.sim.readjustments == 0  # monitoring off

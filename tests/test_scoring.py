"""Tests for rotation-scheme evaluation + enumeration (III-B / III-C).

The evaluators (Eq. 18 scorer, ranges, banks) live in ``core.scoring``; the
solvers (feasible / optimal / coordinate descent) moved into the fabric-wide
planner ``core.rotation`` and are exercised here against the evaluators."""
import itertools

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import geometry as G
from repro.core import rotation as R
from repro.core import scoring as S


def brute_force_best(patterns, bw, cap, muls, ref_index, n_slots):
    ranges = S.shift_ranges(muls, ref_index, n_slots)
    best = (-1.0, None)
    for combo in itertools.product(*[range(r) for r in ranges]):
        sc = S.score_combos(patterns, np.asarray(bw), cap,
                            np.array([combo]))[0]
        if sc > best[0]:
            best = (sc, np.array(combo))
    return best


class TestScoreCombos:
    def test_matches_geometry_score(self):
        pats = G.pattern_matrix([1, 2], [0.3, 0.2], 72)
        bw = np.array([20.0, 15.0])
        for combo in ([0, 0], [0, 7], [0, 35]):
            got = S.score_combos(pats, bw, 25.0, np.array([combo]))[0]
            want = G.score(pats, bw, np.array(combo), 25.0)
            assert got == pytest.approx(want)

    def test_lex_combos_cover_space(self):
        ranges = [1, 3, 4]
        combos = S.lex_combos(ranges, 0, 12)
        assert combos.shape == (12, 3)
        assert len({tuple(c) for c in combos}) == 12
        assert combos[:, 0].max() == 0


class TestFeasibleRotation:
    def test_finds_perfect_when_exists(self):
        pats = G.pattern_matrix([1, 1], [0.3, 0.3], 72)
        res = R.find_feasible_rotation(pats, [20.0, 20.0], 25.0, [1, 1], 0)
        assert res.perfect
        d = G.demand(pats, np.array([20.0, 20.0]), res.shifts)
        assert d.max() <= 25.0 + 1e-9

    def test_reference_shift_zero(self):
        pats = G.pattern_matrix([1, 1], [0.3, 0.3], 72)
        res = R.find_feasible_rotation(pats, [20.0, 20.0], 25.0, [1, 1], 0)
        assert res.shifts[0] == 0  # Eq. 16

    def test_best_effort_when_impossible(self):
        # combined duty > 1 -> no perfect scheme exists (paper snapshot 0)
        pats = G.pattern_matrix([1, 1], [0.6, 0.6], 72)
        res = R.find_feasible_rotation(pats, [20.0, 20.0], 25.0, [1, 1], 0)
        assert not res.perfect
        bf_score, _ = brute_force_best(pats, [20.0, 20.0], 25.0, [1, 1], 0, 72)
        assert res.score == pytest.approx(bf_score, abs=1e-6)

    def test_first_interval_midpoint(self):
        """The fast path returns the middle of the FIRST perfect run."""
        pats = G.pattern_matrix([1, 1], [0.25, 0.25], 72)
        bw = [20.0, 20.0]
        res = R.find_feasible_rotation(pats, bw, 25.0, [1, 1], 0)
        scores = S.score_combos(pats, np.asarray(bw), 25.0,
                                S.lex_combos([1, 72], 0, 72))
        perfect = scores >= 100.0 - 1e-9
        # first run of perfect scores
        start = int(np.argmax(perfect))
        end = start
        while end + 1 < 72 and perfect[end + 1]:
            end += 1
        assert res.shifts[1] == (start + end) // 2


class TestOptimalRotation:
    def test_psi_maximized_among_perfect(self):
        pats = G.pattern_matrix([1, 1], [0.2, 0.2], 72)
        bw = [20.0, 20.0]
        res = R.find_optimal_rotation(pats, bw, 25.0, [1, 1], 0)
        assert res.perfect
        # stage 3: Psi should be near the theoretical max (bursts
        # antipodal: midpoint distance ~36 slots)
        assert res.psi >= 30.0

    def test_optimal_beats_feasible_on_psi(self):
        pats = G.pattern_matrix([1, 2], [0.3, 0.25], 72)
        bw = [20.0, 18.0]
        fast = R.find_feasible_rotation(pats, bw, 25.0, [1, 2], 0)
        opt = R.find_optimal_rotation(pats, bw, 25.0, [1, 2], 0)
        assert opt.score >= fast.score - 1e-9
        if fast.perfect and opt.perfect:
            assert opt.psi >= fast.psi - 1e-9

    def test_coordinate_descent_on_large_space(self):
        muls = [1, 1, 1, 1, 1]
        pats = G.pattern_matrix(muls, [0.15] * 5, 72)
        bw = [20.0] * 5
        res = R.coordinate_descent_rotation(
            pats, np.asarray(bw), 25.0, muls, 0)
        assert res.perfect  # 5 x 0.15 duty easily interleaves


@given(
    duty_a=st.floats(0.05, 0.45), duty_b=st.floats(0.05, 0.45),
    mul_b=st.integers(1, 4),
)
def test_property_feasible_never_worse_than_zero_shift(duty_a, duty_b, mul_b):
    pats = G.pattern_matrix([1, mul_b], [duty_a, duty_b], 72)
    bw = [20.0, 20.0]
    res = R.find_feasible_rotation(pats, bw, 25.0, [1, mul_b], 0)
    zero = S.score_combos(pats, np.asarray(bw), 25.0,
                          np.zeros((1, 2), dtype=np.int64))[0]
    assert res.score >= zero - 1e-9


def test_pallas_scorer_plugs_into_optimal_rotation():
    """The Pallas pairwise kernel is a drop-in scorer for stage 3."""
    from repro.kernels import ops as kops
    pats = G.pattern_matrix([1, 1], [0.3, 0.25], 72)
    bw = np.array([20.0, 18.0])
    banks = S.rolled_bank(pats, [1, 72])
    base = bw[0] * banks[0][0]
    scores_k = kops.score_pairwise(base, np.zeros((1, 72)),
                                   bw[1] * banks[1], 25.0, interpret=True)
    scores_ref = S.score_combos(pats, bw, 25.0, S.lex_combos([1, 72], 0, 72))
    assert np.allclose(scores_k[0], scores_ref, atol=1e-4)

"""LinkView regression: the unified contention layer must reproduce the
three legacy per-layer link-demand implementations bit-for-bit.

The legacy rules (scheduler ``_node_jobs``/``_uplink_jobs``/
``_traversed_uplinks``, simulator ``_job_links``, controller
``_link_traffic``) were deleted in favor of ``core/contention.LinkView``;
they are re-implemented HERE, verbatim, as the reference oracle, and
compared on the star (S2) and fabric (1:1 "F1" variant, F2, F4) snapshots —
including candidate-pod (extra) placements on every node."""
import pytest

from repro.configs.metronome_testbed import make_fabric_snapshot, make_snapshot
from repro.core.cluster import make_fabric_cluster
from repro.core.contention import LinkView, group_demand_gbps
from repro.core.controller import StopAndWaitController
from repro.core.framework import SchedulingFramework
from repro.core.scheduler import MetronomePlugin
from repro.core.workload import TrafficSpec, make_job


# ---------------------------------------------------------------------------
# Legacy reference implementations (verbatim copies of the pre-refactor code)
# ---------------------------------------------------------------------------

def legacy_node_jobs(cluster, node_name, registry, extra=None):
    groups = {}
    for t in registry.deployed_on(node_name):
        if not t.low_comm:
            groups.setdefault(t.job, []).append(t)
    if extra is not None and not extra.low_comm:
        groups.setdefault(extra.job, []).append(extra)
    return groups


def legacy_uplink_jobs(cluster, leaf, registry, extra=None, extra_node=None):
    topo = cluster.topology
    nodes_by_job = {}
    for t in registry.tasks.values():
        if t.node is not None:
            nodes_by_job.setdefault(t.job, set()).add(t.node)
    if extra is not None and extra_node is not None:
        nodes_by_job.setdefault(extra.job, set()).add(extra_node)
    groups = {}
    for job, nodes in nodes_by_job.items():
        if not topo.spans_leaves(nodes):
            continue
        if not any(topo.leaf_of[n] == leaf for n in nodes):
            continue
        in_leaf = [
            t for t in registry.job_tasks(job)
            if t.node is not None and topo.leaf_of[t.node] == leaf
            and not t.low_comm
        ]
        if (extra is not None and extra_node is not None
                and extra.job == job and not extra.low_comm
                and topo.leaf_of[extra_node] == leaf
                and all(t.uid != extra.uid for t in in_leaf)):
            in_leaf = in_leaf + [extra]
        if in_leaf:
            groups[job] = in_leaf
    return groups


def legacy_traversed_uplinks(cluster, pod, node_name, registry):
    topo = cluster.topology
    if topo.is_star:
        return []
    job_nodes = {t.node for t in registry.job_tasks(pod.job)
                 if t.node is not None}
    job_nodes.add(node_name)
    if not topo.spans_leaves(job_nodes):
        return []
    return sorted({topo.leaf_of[n] for n in job_nodes}
                  & set(topo.uplinks.keys()))


def legacy_job_links(cluster, job):
    nodes = job.nodes_used()
    if len(nodes) <= 1:
        return {}
    out = {}
    for t in job.tasks:
        if t.node is None or t.traffic.bw_gbps <= 0:
            continue
        out[t.node] = out.get(t.node, 0.0) + t.traffic.bw_gbps
    return out


def legacy_link_traffic(registry, sch, cluster, link_id):
    from repro.core.topology import is_uplink
    topo = cluster.topology
    leaf = None
    if is_uplink(link_id):
        for lf, up in topo.uplinks.items():
            if up.id == link_id:
                leaf = lf
                break
    duties, bws = [], []
    for idx, j in enumerate(sch.jobs):
        tasks = registry.job_tasks(j)
        spec = tasks[0].traffic if tasks else TrafficSpec(100.0, 0.3, 1.0)
        eff_period = sch.base_ms / max(int(sch.muls[idx]), 1)
        duties.append(min(1.0, spec.comm_ms / eff_period))
        if leaf is None:
            bws.append(sum(t.traffic.bw_gbps for t in tasks
                           if t.node is not None))
        else:
            bws.append(sum(t.traffic.bw_gbps for t in tasks
                           if t.node is not None and not t.low_comm
                           and topo.leaf_of[t.node] == leaf))
    return duties, bws


# ---------------------------------------------------------------------------
# Scheduled snapshot fixtures
# ---------------------------------------------------------------------------

def scheduled(sid):
    """Schedule a snapshot under Metronome; return (cluster, fw, ctrl, wls)."""
    if sid == "F1":
        # the 1:1-oversubscription fabric variant of F2 (uplinks exist but
        # are as fat as their racks)
        cluster = make_fabric_cluster(n_leaves=2, hosts_per_leaf=2,
                                      bw_gbps=25.0, oversubscription=1.0)
        _, wls, _ = make_fabric_snapshot("F2", n_iterations=50)
    else:
        cluster, wls, _ = make_snapshot(sid, n_iterations=50)
    ctrl = StopAndWaitController()
    fw = SchedulingFramework(cluster, MetronomePlugin(controller=ctrl))
    for wl in wls:
        assert fw.schedule_workload(wl)
    return cluster, fw, ctrl, wls


def same_groups(got, want):
    """Bit-for-bit: same job keys in the same order, same task objects in
    the same order."""
    assert list(got.keys()) == list(want.keys())
    for j in want:
        assert [t.uid for t in got[j]] == [t.uid for t in want[j]]
        assert group_demand_gbps(got[j]) == group_demand_gbps(want[j])


SNAPSHOT_IDS = ["S2", "F1", "F2", "F4"]


class TestPlanningViewMatchesScheduler:
    @pytest.mark.parametrize("sid", SNAPSHOT_IDS)
    def test_host_groups(self, sid):
        cluster, fw, _, _ = scheduled(sid)
        view = LinkView.from_registry(cluster, fw.registry)
        for n in cluster.node_names:
            same_groups(view.host_groups(n),
                        legacy_node_jobs(cluster, n, fw.registry))

    @pytest.mark.parametrize("sid", SNAPSHOT_IDS)
    def test_uplink_groups(self, sid):
        cluster, fw, _, _ = scheduled(sid)
        view = LinkView.from_registry(cluster, fw.registry)
        for leaf in cluster.topology.uplinks:
            same_groups(view.uplink_groups(leaf),
                        legacy_uplink_jobs(cluster, leaf, fw.registry))

    @pytest.mark.parametrize("sid", SNAPSHOT_IDS)
    def test_candidate_pod_groupings(self, sid):
        """The scheduler's Score-phase view: a probe pod provisionally on
        every node must reproduce the legacy extra/extra_node semantics."""
        cluster, fw, _, _ = scheduled(sid)
        probe = make_job("probe", n_tasks=1, period_ms=100.0, duty=0.3,
                         bw_gbps=9.0).tasks[0]
        for node in cluster.node_names:
            view = LinkView.from_registry(cluster, fw.registry, extra=probe,
                                          extra_node=node)
            for n in cluster.node_names:
                same_groups(
                    view.host_groups(n),
                    legacy_node_jobs(cluster, n, fw.registry,
                                     extra=probe if n == node else None))
            for leaf in cluster.topology.uplinks:
                same_groups(
                    view.uplink_groups(leaf),
                    legacy_uplink_jobs(cluster, leaf, fw.registry,
                                       extra=probe, extra_node=node))
            assert (view.traversed_uplinks(probe.job)
                    == legacy_traversed_uplinks(cluster, probe, node,
                                                fw.registry))

    @pytest.mark.parametrize("sid", SNAPSHOT_IDS)
    def test_traversed_uplinks_deployed_jobs(self, sid):
        cluster, fw, _, wls = scheduled(sid)
        view = LinkView.from_registry(cluster, fw.registry)
        for wl in wls:
            for job in wl.jobs:
                pod = job.tasks[0]
                node = pod.node
                got = view.traversed_uplinks(job.name)
                want = legacy_traversed_uplinks(cluster, pod, node,
                                                fw.registry)
                assert got == want


class TestFlowViewMatchesSimulator:
    @pytest.mark.parametrize("sid", SNAPSHOT_IDS)
    def test_flow_specs(self, sid):
        cluster, fw, _, wls = scheduled(sid)
        view = LinkView(cluster)  # the simulator's storeless instance
        for wl in wls:
            for job in wl.jobs:
                flows = view.flows_for(job)
                want = legacy_job_links(cluster, job)
                assert [f.node for f in flows] == list(want.keys())
                assert [f.demand_gbps for f in flows] == list(want.values())
                nodes = job.nodes_used()
                for f in flows:
                    assert f.links == cluster.topology.flow_links(f.node,
                                                                  nodes)

    def test_single_node_job_no_flows(self):
        cluster, _, _ = make_snapshot("S2", n_iterations=10)
        job = make_job("solo", n_tasks=2, period_ms=100.0, duty=0.3,
                       bw_gbps=10.0, spread=0)
        for t in job.tasks:
            t.node = "worker-a30-0"
        assert LinkView(cluster).flows_for(job) == []


class TestRecalcMatchesController:
    @pytest.mark.parametrize("sid", SNAPSHOT_IDS)
    def test_recalc_traffic(self, sid):
        cluster, fw, ctrl, _ = scheduled(sid)
        view = LinkView.from_registry(cluster, fw.registry)
        if sid != "F1":  # 1:1 fabric: nothing contends, no schemes exist
            assert ctrl.links, "snapshots must produce contention schemes"
        for link_id, state in ctrl.links.items():
            sch = state.scheme
            duties, bws = view.recalc_traffic(link_id, sch.jobs, sch.muls,
                                              sch.base_ms)
            ld, lb = legacy_link_traffic(fw.registry, sch, cluster, link_id)
            assert duties == ld
            assert bws == lb


class TestContentionPredicate:
    def test_eq9_pairs(self):
        """Eq. 9: only pairs whose combined demand exceeds the allocatable
        bandwidth contend."""
        cluster, fw, _, _ = scheduled("S2")
        view = LinkView.from_registry(cluster, fw.registry)
        for n in cluster.node_names:
            demands = view.demands(n)
            cap = cluster.link_alloc(n)
            pairs = view.contending_pairs(n)
            jobs = list(demands)
            for i in range(len(jobs)):
                for j in range(i + 1, len(jobs)):
                    a, b = jobs[i], jobs[j]
                    expect = demands[a] + demands[b] > cap
                    assert ((a, b) in pairs) == expect
                    assert view.contends(n, a, b) == expect
        # both 25G jobs share host links on the 25G testbed -> contention
        assert any(view.contending_pairs(n) for n in cluster.node_names)

    def test_planning_links_order(self):
        cluster, fw, _, _ = scheduled("F2")
        view = LinkView.from_registry(cluster, fw.registry)
        assert view.planning_links() == (list(cluster.node_names)
                                         + cluster.topology.uplink_ids)


class TestExpectedIteration:
    def test_no_congestion_equals_period(self):
        cluster, fw, _, wls = scheduled("S2")
        view = LinkView.from_registry(cluster, fw.registry)
        job = wls[0].jobs[0]
        assert view.expected_iteration_ms(job.name) == pytest.approx(
            job.traffic.period_ms)

    def test_allocatable_drop_stretches_comm(self):
        cluster, fw, _, wls = scheduled("S2")
        job = wls[0].jobs[0]
        node = job.tasks[0].node
        cluster.node(node).allocatable_gbps = 12.5  # half of the 25G demand
        view = LinkView.from_registry(cluster, fw.registry)
        spec = job.traffic
        want = spec.compute_ms + spec.comm_ms * (spec.bw_gbps / 12.5)
        assert view.expected_iteration_ms(job.name) == pytest.approx(want)

    def test_unknown_job_is_none(self):
        cluster, fw, _, _ = scheduled("S2")
        view = LinkView.from_registry(cluster, fw.registry)
        assert view.expected_iteration_ms("nope") is None

"""Per-arch smoke tests (reduced configs) + decode/teacher-forcing parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import (decode_step, forward, init_cache, init_model,
                          loss_fn, param_count, prefill)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (b, max(s // cfg.enc_frames_ratio, 1), cfg.d_model),
            jnp.float32)
    if cfg.mrope_sections:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_train_step(arch):
    """One forward/train step on CPU: output shapes + no NaNs (assignment)."""
    cfg = get_smoke_config(arch)
    params, spec = init_model(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = forward(params, cfg, batch["tokens"],
                          positions=batch.get("positions"),
                          frames=batch.get("frames"))
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    # one gradient step must stay finite
    g = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params, _ = init_model(cfg, KEY)
    b = 2
    cache = init_cache(cfg, b, 48)
    if cfg.family == "encdec":
        cache["enc_out"] = jax.random.normal(
            KEY, cache["enc_out"].shape, jnp.float32).astype(cfg.dtype)
    tok = jax.random.randint(KEY, (b, 1), 0, cfg.vocab)
    logits, cache2 = decode_step(params, cfg, cache, tok)
    assert logits.shape == (b, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache2["index"]) == 1


@pytest.mark.parametrize("arch", ["llama3_8b", "qwen2_moe_a2_7b",
                                  "recurrentgemma_2b", "xlstm_125m",
                                  "whisper_small"])
def test_prefill_then_decode_matches_forward(arch):
    """Teacher-forcing parity: logits from (prefill prompt -> decode token)
    must match the training forward at the same position."""
    cfg = get_smoke_config(arch)
    params, _ = init_model(cfg, KEY)
    b, s = 2, 16
    batch = _batch(cfg, b, s + 1)
    tokens = batch["tokens"]
    full_logits, _ = forward(params, cfg, tokens,
                             frames=batch.get("frames"),
                             positions=batch.get("positions"))
    last_logits, cache = prefill(params, cfg, tokens[:, :s],
                                 frames=batch.get("frames"),
                                 positions=(batch["positions"][:, :, :s]
                                            if "positions" in batch else None),
                                 max_len=s + 4)
    # prefill's last-position logits == forward logits at position s-1
    np.testing.assert_allclose(
        np.asarray(last_logits[:, 0]), np.asarray(full_logits[:, s - 1]),
        atol=2e-2, rtol=2e-2)
    if cfg.family in ("dense", "moe", "encdec"):
        # decode one more token and compare against forward position s.
        # (dense-family caches are directly decodable after prefill; the
        # recurrent families are covered by the prefill check above.)
        logits, _ = decode_step(params, cfg, cache, tokens[:, s:s + 1])
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, s]),
            atol=2e-2, rtol=2e-2)


def test_recurrent_decode_continues_prefill():
    """griffin/xlstm: decode after prefill equals forward's next position."""
    for arch in ("recurrentgemma_2b", "xlstm_125m"):
        cfg = get_smoke_config(arch)
        params, _ = init_model(cfg, KEY)
        b, s = 1, 12
        tokens = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab)
        full_logits, _ = forward(params, cfg, tokens)
        _, cache = prefill(params, cfg, tokens[:, :s], max_len=s + 4)
        logits, _ = decode_step(params, cfg, cache, tokens[:, s:s + 1])
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, s]),
            atol=5e-2, rtol=5e-2, err_msg=arch)


def test_moe_aux_loss_nonzero():
    cfg = get_smoke_config("arctic_480b")
    params, _ = init_model(cfg, KEY)
    batch = _batch(cfg)
    _, metrics = loss_fn(params, cfg, batch)
    assert float(metrics["aux"]) > 0.0


def test_mrope_differs_from_text_positions():
    cfg = get_smoke_config("qwen2_vl_72b")
    params, _ = init_model(cfg, KEY)
    b, s = 1, 16
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    text_pos = jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s))
    # image-like positions: h/w streams differ from t
    img_pos = text_pos.at[1].set(text_pos[1] // 4).at[2].set(text_pos[2] % 4)
    l1, _ = forward(params, cfg, tokens, positions=text_pos)
    l2, _ = forward(params, cfg, tokens, positions=img_pos)
    assert float(jnp.abs(l1 - l2).max()) > 1e-4


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    expect = {
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
        "internlm2_20b": (48, 6144, 48, 8, 16384, 92544),
        "qwen3_14b": (40, 5120, 40, 8, 17408, 151936),
        "llama3_8b": (32, 4096, 32, 8, 14336, 128256),
        "starcoder2_15b": (40, 6144, 48, 4, 24576, 49152),
        "qwen2_vl_72b": (80, 8192, 64, 8, 29568, 152064),
        "whisper_small": (12, 768, 12, 12, 3072, 51865),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "xlstm_125m": (12, 768, 4, 4, 0, 50304),
    }
    for arch, (nl, dm, nh, nkv, dff, vocab) in expect.items():
        cfg = get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff,
               cfg.vocab)
        assert got == (nl, dm, nh, nkv, dff, vocab), (arch, got)
    assert get_config("arctic_480b").n_experts == 128
    assert get_config("arctic_480b").top_k == 2
    assert get_config("arctic_480b").dense_residual
    assert get_config("qwen2_moe_a2_7b").n_shared == 4
    assert get_config("qwen2_moe_a2_7b").top_k == 4
    assert get_config("qwen3_14b").qk_norm
    assert get_config("qwen2_vl_72b").mrope_sections == (16, 24, 24)
    assert get_config("recurrentgemma_2b").window == 2048


def test_chunked_attention_vs_naive():
    """The model's chunked online-softmax attention equals the oracle."""
    from repro.models.layers import chunked_attention
    from repro.kernels.ref import attention_ref
    k1, k2, k3 = jax.random.split(KEY, 3)
    b, s, h, kv, d = 2, 128, 4, 2, 32
    q = jax.random.normal(k1, (b, s, h, d), jnp.float32)
    k = jax.random.normal(k2, (b, s, kv, d), jnp.float32)
    v = jax.random.normal(k3, (b, s, kv, d), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, chunk=32)
    want = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), causal=True
                         ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

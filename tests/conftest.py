import os

# Tests must see the single real CPU device (the 512-device override is
# strictly dryrun.py's business).
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import HealthCheck, settings

settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[HealthCheck.too_slow])
settings.load_profile("ci")

import os

# Tests must see the single real CPU device (the 512-device override is
# strictly dryrun.py's business).
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    # Minimal-environment shim: the property-based test modules import
    # ``given``/``settings``/``strategies`` at collection time. Install a
    # stub so the suite still collects and runs; every hypothesis-driven
    # case SKIPs instead of erroring the whole session.
    import sys
    import types

    import pytest

    class _Strategy:
        """Inert stand-in accepted anywhere a SearchStrategy is expected."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    class _StrategiesModule(types.ModuleType):
        def __getattr__(self, name):
            return _Strategy()

    def _given(*_a, **_k):
        def deco(fn):
            # NB: no functools.wraps — it would set __wrapped__ and pytest
            # would unwrap to the original signature, treating strategy
            # parameters as (missing) fixtures. ``self`` must pass through
            # for methods on test classes.
            def wrapper(*args, **kwargs):
                pytest.skip("hypothesis not installed")
            wrapper.__name__ = getattr(fn, "__name__", "test")
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    class _Settings:
        """Usable both as ``@settings(...)`` and for profile registration."""

        def __init__(self, *a, **k):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*a, **k):
            pass

        @staticmethod
        def load_profile(*a, **k):
            pass

    class _HealthCheck:
        def __getattr__(self, name):
            return name

    _hyp = types.ModuleType("hypothesis")
    _st = _StrategiesModule("hypothesis.strategies")
    _hyp.given = _given
    _hyp.settings = _Settings
    _hyp.HealthCheck = _HealthCheck()
    _hyp.assume = lambda *a, **k: True
    _hyp.example = lambda *a, **k: (lambda fn: fn)
    _hyp.note = lambda *a, **k: None
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
else:
    settings.register_profile(
        "ci", deadline=None, max_examples=25,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile("ci")

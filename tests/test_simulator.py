"""Fluid-flow simulator tests + end-to-end paper-claim checks."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.configs.metronome_testbed import make_snapshot
from repro.core.harness import priority_split, run_experiment
from repro.core.simulator import SimConfig, _max_min_fair


class TestMaxMinFair:
    def test_under_capacity_gives_demand(self):
        r = _max_min_fair(np.array([5.0, 10.0]), 25.0)
        assert np.allclose(r, [5.0, 10.0])

    def test_equal_split_when_saturated(self):
        r = _max_min_fair(np.array([20.0, 20.0]), 25.0)
        assert np.allclose(r, [12.5, 12.5])

    def test_water_filling(self):
        r = _max_min_fair(np.array([2.0, 20.0, 20.0]), 25.0)
        assert np.allclose(r, [2.0, 11.5, 11.5])

    @given(st.lists(st.floats(0.1, 40.0), min_size=1, max_size=6),
           st.floats(1.0, 50.0))
    def test_properties(self, demands, cap):
        d = np.array(demands)
        r = _max_min_fair(d, cap)
        assert np.all(r <= d + 1e-9)          # never exceed demand
        assert r.sum() <= cap + 1e-9          # never exceed capacity
        # work conserving: either all demands met or capacity exhausted
        assert (np.allclose(r, d) or r.sum() == pytest.approx(cap))


class TestSimulatorBasics:
    def test_single_job_matches_ideal(self):
        cluster, wls, bg = make_snapshot("S2", n_iterations=200)
        res = run_experiment("ideal", cluster, wls,
                             SimConfig(duration_ms=60_000, jitter_std=0.0))
        # contention-free: iteration == period
        assert res.sim.mean_iter_ms("vgg19-ft") == pytest.approx(96.0, rel=0.01)
        assert res.sim.mean_iter_ms("vgg16-ft") == pytest.approx(90.0, rel=0.01)

    def test_contention_stretches_iterations(self):
        cluster, wls, bg = make_snapshot("S2", n_iterations=200)
        cfg = SimConfig(duration_ms=60_000, jitter_std=0.0)
        de = run_experiment("default", cluster, wls, cfg)
        assert de.sim.mean_iter_ms("vgg19-ft") > 96.0 * 1.05

    def test_utilization_in_bounds(self):
        cluster, wls, bg = make_snapshot("S1", n_iterations=200)
        res = run_experiment("default", cluster, wls,
                             SimConfig(duration_ms=60_000))
        assert 0.0 <= res.sim.avg_bw_utilization <= 1.0
        for u in res.sim.link_utilization.values():
            assert 0.0 <= u <= 1.0

    def test_deterministic_given_seed(self):
        cfg = SimConfig(duration_ms=30_000, seed=7)
        outs = []
        for _ in range(2):
            cluster, wls, bg = make_snapshot("S2", n_iterations=100)
            outs.append(run_experiment("metronome", cluster, wls, cfg,
                                       background=bg))
        a, b = outs
        assert a.sim.time_per_1000_iters_s == b.sim.time_per_1000_iters_s


class TestPaperClaims:
    """The paper's headline behaviors, asserted loosely."""

    CFG = SimConfig(duration_ms=120_000, seed=3, jitter_std=0.01)

    def _run(self, sid, sched):
        cluster, wls, bg = make_snapshot(sid, n_iterations=300)
        return run_experiment(sched, cluster, wls, self.CFG, background=bg), wls

    @pytest.mark.parametrize("sid", ["S1", "S2", "S3", "S4", "S5"])
    def test_metronome_beats_default(self, sid):
        me, wls = self._run(sid, "metronome")
        de, _ = self._run(sid, "default")
        hi, lo = priority_split(wls)
        for j in hi + lo:
            assert (me.sim.time_per_1000_iters_s[j]
                    <= de.sim.time_per_1000_iters_s[j] * 1.02), (sid, j)

    @pytest.mark.parametrize("sid", ["S2", "S4"])
    def test_high_priority_within_2pct_of_ideal(self, sid):
        """Paper section I: 'completion time of high priority jobs deviates
        by no more than 2% from the contention-free ideal'."""
        me, wls = self._run(sid, "metronome")
        id_, _ = self._run(sid, "ideal")
        hi, _ = priority_split(wls)
        for j in hi:
            ratio = (me.sim.time_per_1000_iters_s[j]
                     / id_.sim.time_per_1000_iters_s[j])
            assert ratio < 1.03, (sid, j, ratio)

    def test_s0_incompatible_jobs_isolated(self):
        """Snapshot 0: Metronome places incompatible jobs on disjoint links;
        Default fails to isolate them."""
        me, _ = self._run("S0", "metronome")
        shared_me = set(me.placements["gpt2-0"]) & set(
            me.placements["googlenet-0"])
        assert not shared_me
        de, _ = self._run("S0", "default")
        shared_de = set(de.placements["gpt2-0"]) & set(
            de.placements["googlenet-0"])
        assert shared_de  # default shares a link -> contention

    def test_s4_congestion_avoided(self):
        me, _ = self._run("S4", "metronome")
        assert "worker-a30-2" not in (
            set(me.placements["bert-0"]) | set(me.placements["bert-1"]))

    def test_metronome_improves_bandwidth_utilization(self):
        me, _ = self._run("S2", "metronome")
        de, _ = self._run("S2", "default")
        assert me.sim.avg_bw_utilization > de.sim.avg_bw_utilization

    def test_exclusive_rejects_jobs(self):
        cluster, wls, bg = make_snapshot("S2", n_iterations=100)
        ex = run_experiment("exclusive", cluster, wls, self.CFG, background=bg)
        # per-pod demand == link capacity -> second job rejected somewhere
        assert ex.rejected, "exclusive scheduling should reject jobs"

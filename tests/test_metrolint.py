"""metrolint: per-check fixture snippets (one violating, one clean) on
miniature tmp-dir repos mirroring the real layout, plus the contract that
the committed baseline exactly matches a fresh full-repo run."""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (all_checks, apply_baseline, load_baseline,
                            run_checks)
from repro.analysis.core import BASELINE_NAME

REPO_ROOT = Path(__file__).resolve().parents[1]


def mini_repo(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return tmp_path


def findings_of(root, check):
    return [f for f in run_checks(root, [check]) if f.check == check]


class TestRegistry:
    def test_all_five_checks_registered(self):
        assert {"epoch-soundness", "kernel-parity", "determinism",
                "cache-key-completeness",
                "shared-state-race"} <= set(all_checks())

    def test_unknown_check_rejected(self, tmp_path):
        with pytest.raises(ValueError, match="unknown checks"):
            run_checks(tmp_path, ["no-such-check"])


class TestEpochSoundness:
    VIOLATING = """
        class Framework:
            def drain(self, link):
                link.allocatable_gbps -= 1.0
                return link
        """
    CLEAN = """
        class Framework:
            def drain(self, link):
                link.allocatable_gbps -= 1.0
                self.cluster.bump_epoch()
                return link
        """

    def test_mutation_without_bump_flagged(self, tmp_path):
        root = mini_repo(tmp_path,
                         {"src/repro/core/framework.py": self.VIOLATING})
        found = findings_of(root, "epoch-soundness")
        assert len(found) == 1
        assert found[0].obj == "Framework.drain"
        assert found[0].key == "no-bump"

    def test_mutation_with_bump_clean(self, tmp_path):
        root = mini_repo(tmp_path,
                         {"src/repro/core/framework.py": self.CLEAN})
        assert findings_of(root, "epoch-soundness") == []

    def test_registry_store_mutation_flagged(self, tmp_path):
        root = mini_repo(tmp_path, {"src/repro/core/framework.py": """
            class Framework:
                def admit(self, job):
                    self.registry.jobs[job.name] = job
            """})
        found = findings_of(root, "epoch-soundness")
        assert len(found) == 1 and found[0].obj == "Framework.admit"

    def test_constructors_exempt(self, tmp_path):
        root = mini_repo(tmp_path, {"src/repro/core/cluster.py": """
            class Node:
                def __init__(self):
                    self.allocatable_gbps = 100.0
            """})
        assert findings_of(root, "epoch-soundness") == []


class TestDeterminism:
    def test_set_iteration_flagged(self, tmp_path):
        root = mini_repo(tmp_path, {"src/repro/core/scoring.py": """
            def order(xs):
                pending = set(xs)
                out = []
                for x in pending:
                    out.append(x)
                return out
            """})
        found = findings_of(root, "determinism")
        assert [f.key for f in found] == ["set-iteration:1"]

    def test_sorted_set_clean(self, tmp_path):
        root = mini_repo(tmp_path, {"src/repro/core/scoring.py": """
            def order(xs):
                pending = set(xs)
                out = []
                for x in sorted(pending):
                    out.append(x)
                return out
            """})
        assert findings_of(root, "determinism") == []

    def test_unseeded_random_flagged_seeded_clean(self, tmp_path):
        root = mini_repo(tmp_path, {"src/repro/core/fluid.py": """
            import numpy as np

            def jitter(n):
                return np.random.rand(n)

            def jitter_ok(n, seed):
                return np.random.default_rng(seed).random(n)
            """})
        found = findings_of(root, "determinism")
        assert len(found) == 1
        assert found[0].obj == "jitter"
        assert found[0].key.startswith("unseeded-random")

    def test_float32_flagged_in_pinned_module(self, tmp_path):
        root = mini_repo(tmp_path, {"src/repro/core/rotation.py": """
            import numpy as np

            def pack(x):
                return np.asarray(x, dtype=np.float32)
            """})
        found = findings_of(root, "determinism")
        assert [f.key for f in found] == ["float32"]

    def test_unpinned_module_out_of_scope(self, tmp_path):
        root = mini_repo(tmp_path, {"src/repro/core/workload.py": """
            def order(xs):
                for x in set(xs):
                    yield x
            """})
        assert findings_of(root, "determinism") == []


class TestKernelParity:
    KERNEL = """
        def my_fill(x, interpret=False):
            return x
        """
    OPS = """
        from .mykernel import my_fill

        def fill(x, interpret=None):
            return my_fill(x, interpret=bool(interpret))
        """
    REF = """
        def my_fill_ref(x):
            return x
        """
    PARITY_TEST = """
        from repro.kernels import ops, ref

        def test_fill_parity():
            assert ops.fill(3, interpret=True) == ref.my_fill_ref(3)
        """

    def test_missing_parity_test_flagged(self, tmp_path):
        root = mini_repo(tmp_path, {
            "src/repro/kernels/mykernel.py": self.KERNEL,
            "src/repro/kernels/ops.py": self.OPS,
            "src/repro/kernels/ref.py": self.REF,
        })
        found = findings_of(root, "kernel-parity")
        assert [f.key for f in found] == ["no-parity-test"]
        assert found[0].obj == "my_fill"

    def test_unwired_kernel_flagged(self, tmp_path):
        root = mini_repo(tmp_path, {
            "src/repro/kernels/mykernel.py": self.KERNEL,
            "src/repro/kernels/ops.py": "def other():\n    return 1\n",
            "src/repro/kernels/ref.py": self.REF,
        })
        found = findings_of(root, "kernel-parity")
        assert [f.key for f in found] == ["unwired"]

    def test_wired_and_tested_clean(self, tmp_path):
        root = mini_repo(tmp_path, {
            "src/repro/kernels/mykernel.py": self.KERNEL,
            "src/repro/kernels/ops.py": self.OPS,
            "src/repro/kernels/ref.py": self.REF,
            "tests/test_kernels.py": self.PARITY_TEST,
        })
        assert findings_of(root, "kernel-parity") == []

    def test_smoke_call_without_ref_is_not_parity(self, tmp_path):
        root = mini_repo(tmp_path, {
            "src/repro/kernels/mykernel.py": self.KERNEL,
            "src/repro/kernels/ops.py": self.OPS,
            "src/repro/kernels/ref.py": self.REF,
            "tests/test_kernels.py": """
                from repro.kernels import ops

                def test_fill_smoke():
                    assert ops.fill(3, interpret=True) == 3
                """,
        })
        found = findings_of(root, "kernel-parity")
        assert [f.key for f in found] == ["no-parity-test"]


class TestCacheKeyCompleteness:
    EXPERIMENT = """
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class Scenario:
            name: str
            build: object
            mode: str
            sim_config: object

            @property
            def label(self):
                return self.name

        @dataclasses.dataclass(frozen=True)
        class Policy:
            scheduler: str
            options: dict
        """
    SIMULATOR = """
        import dataclasses

        @dataclasses.dataclass
        class SimConfig:
            seed: int
        """
    CACHE_TMPL = """
        import dataclasses

        def _canon(obj):
            if dataclasses.is_dataclass(obj):
                return {{f.name: getattr(obj, f.name)
                        for f in dataclasses.fields(obj)}}
            return obj

        def fingerprint(scenario, policies, cfg):
            return {{
                "mode": scenario.mode,
                "built": scenario.materialize(),
                "scenario_cfg": _canon(scenario.sim_config),
                "policies": [{policy_expr} for p in policies],
                "cfg": _canon(cfg),
            }}
        """

    def files(self, policy_expr):
        return {
            "src/repro/core/experiment.py": self.EXPERIMENT,
            "src/repro/core/simulator.py": self.SIMULATOR,
            "benchmarks/cache.py": self.CACHE_TMPL.format(
                policy_expr=policy_expr),
        }

    def test_label_keyed_policies_flagged(self, tmp_path):
        root = mini_repo(tmp_path, self.files("p.name"))
        found = [f for f in findings_of(root, "cache-key-completeness")
                 if f.key == "uncovered:policies"]
        assert len(found) == 1
        assert "options" in found[0].message
        assert "scheduler" in found[0].message

    def test_canonicalized_policies_clean(self, tmp_path):
        root = mini_repo(tmp_path, self.files("_canon(p)"))
        assert [f for f in findings_of(root, "cache-key-completeness")
                if f.key.startswith("uncovered")] == []

    def test_missing_knob_in_plan_cache_key_flagged(self, tmp_path):
        root = mini_repo(tmp_path, {"src/repro/core/rotation.py": """
            def solve_link(view, link_id, *, mode="fast",
                           demand="planning", di_pre=16, g_t_ms=5.0,
                           e_t_frac=0.1, rotation_mode="intermediate",
                           cache=None):
                key = ("link", mode, demand, di_pre, g_t_ms, e_t_frac)
                return key
            """})
        found = [f for f in findings_of(root, "cache-key-completeness")
                 if f.obj == "solve_link"]
        assert [f.key for f in found] == ["knobs"]
        assert "rotation_mode" in found[0].message

    def test_renamed_solver_reports_spec_drift(self, tmp_path):
        root = mini_repo(tmp_path, {"src/repro/core/rotation.py": """
            def solve_link_renamed():
                return None
            """})
        found = [f for f in findings_of(root, "cache-key-completeness")
                 if f.obj == "solve_link"]
        assert [f.key for f in found] == ["spec-drift"]


class TestSharedStateRace:
    def test_unlocked_append_flagged(self, tmp_path):
        root = mini_repo(tmp_path, {"benchmarks/common.py": """
            RECORDED: list = []

            def emit(row):
                RECORDED.append(row)
            """})
        found = findings_of(root, "shared-state-race")
        assert [f.key for f in found] == ["unlocked:RECORDED"]
        assert found[0].obj == "emit"

    def test_locked_append_clean(self, tmp_path):
        root = mini_repo(tmp_path, {"benchmarks/common.py": """
            import threading

            _LOCK = threading.Lock()
            RECORDED: list = []

            def emit(row):
                with _LOCK:
                    RECORDED.append(row)
            """})
        assert findings_of(root, "shared-state-race") == []

    def test_dict_slot_assignment_flagged(self, tmp_path):
        root = mini_repo(tmp_path, {"src/repro/core/scoring.py": """
            _CACHE: dict = {}

            def memo(key):
                if key not in _CACHE:
                    _CACHE[key] = expensive(key)
                return _CACHE[key]
            """})
        found = findings_of(root, "shared-state-race")
        assert [f.key for f in found] == ["unlocked:_CACHE"]

    def test_out_of_scope_module_ignored(self, tmp_path):
        root = mini_repo(tmp_path, {"scripts/tool.py": """
            ROWS: list = []

            def emit(row):
                ROWS.append(row)
            """})
        assert findings_of(root, "shared-state-race") == []


class TestBaselineContract:
    def test_committed_baseline_matches_fresh_run(self):
        """The repo must be lint-clean modulo the committed, reason-
        annotated baseline — and the baseline must carry no stale
        entries."""
        findings = run_checks(REPO_ROOT)
        baseline = load_baseline(REPO_ROOT / BASELINE_NAME)
        new, suppressed, stale = apply_baseline(findings, baseline)
        assert new == [], "\n".join(f.render() for f in new)
        assert stale == [], [s.fingerprint for s in stale]
        assert len(suppressed) == len(baseline)

    def test_every_suppression_has_substantive_reason(self):
        baseline = load_baseline(REPO_ROOT / BASELINE_NAME)
        assert baseline, "expected a committed baseline"
        for s in baseline:
            assert len(s.reason) > 20, s.fingerprint
            assert s.reason != "baselined at adoption; triage", \
                s.fingerprint

    def test_reasonless_suppression_rejected(self, tmp_path):
        p = tmp_path / BASELINE_NAME
        p.write_text(json.dumps({"version": 1, "suppressions": [
            {"check": "determinism", "path": "x.py", "obj": "f",
             "key": "float32", "reason": ""}]}))
        with pytest.raises(ValueError, match="no\\s+reason"):
            load_baseline(p)

    def test_fingerprint_is_line_independent(self, tmp_path):
        """Moving a finding within its file must not invalidate its
        suppression."""
        src_v1 = """
            class Framework:
                def drain(self, link):
                    link.allocatable_gbps -= 1.0
            """
        src_v2 = """
            # a comment that shifts every line


            class Framework:
                def drain(self, link):
                    link.allocatable_gbps -= 1.0
            """
        r1 = mini_repo(tmp_path / "a",
                       {"src/repro/core/framework.py": src_v1})
        r2 = mini_repo(tmp_path / "b",
                       {"src/repro/core/framework.py": src_v2})
        f1 = findings_of(r1, "epoch-soundness")
        f2 = findings_of(r2, "epoch-soundness")
        assert f1[0].line != f2[0].line
        assert f1[0].fingerprint == f2[0].fingerprint

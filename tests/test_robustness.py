"""Imperfect-information control plane (DESIGN.md section 19).

Four subsystems under test:

  * the telemetry channel model — ``TelemetryView`` sampling semantics
    (sample-and-hold, staleness, noise, dropout carry), its determinism
    contract (pure function of (link, sample-slot), never query order),
    and the oracle-identity guarantee: a transparent channel is
    bit-for-bit the no-channel path;
  * fault injection — link/host failure+recovery events in BOTH event
    loops (bit-for-bit parity), including same-timestamp stacks,
    zero-capacity links, and flapping trains;
  * graceful-degradation control — the controller's hysteresis gate and
    measured-vs-declared demand reconciliation;
  * event-stream boundary validation — ``strict_events`` raising a
    structured error, default mode warn-once-dropping bad values while
    unknown targets keep the historical fire-time warning.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.configs.metronome_testbed import (FAULT_SNAPSHOTS,
                                             dynamic_scenario, fault_scenario,
                                             make_snapshot)
from repro.core.cluster import Cluster, Node, Resources
from repro.core.controller import StopAndWaitController
from repro.core.events import (BackgroundFlowChange, EventValidationError,
                               HostFailure, HostRecovery, LinkCapacityChange,
                               LinkFailure, LinkRecovery, TrafficChange,
                               UnknownEventTargetWarning, flapping_schedule,
                               validate_stream)
from repro.core.experiment import Policy, Scenario, run
from repro.core.framework import SchedulingFramework
from repro.core.scheduler import MetronomePlugin
from repro.core.simulator import (COMM, DONE, STALLED, ClusterSimulator,
                                  SimConfig)
from repro.core.telemetry import TelemetryChannel, TelemetryView
from repro.core.workload import Workload, make_job
from test_event_loop import sim_equal

CFG = SimConfig(duration_ms=20_000.0, seed=3, jitter_std=0.01)


def small_cluster(n=2, bw=25.0):
    nodes = [Node(f"n{i}", Resources(cpu=32, mem=256, gpu=4), bw_gbps=bw)
             for i in range(n)]
    return Cluster(nodes)


def wl(job):
    return Workload(name=job.name, jobs=[job])


def _job(name="j", **kw):
    kw.setdefault("n_tasks", 2)
    kw.setdefault("period_ms", 100)
    kw.setdefault("duty", 0.4)
    kw.setdefault("bw_gbps", 20.0)
    kw.setdefault("n_iterations", 50)
    return make_job(name, **kw)


def _scheduled(jobs, controller=None):
    cl = small_cluster()
    fw = SchedulingFramework(cl, MetronomePlugin(controller=controller))
    for j in jobs:
        assert fw.schedule_workload(wl(j))
    return cl, fw.registry


def _both_loops(jobs_factory, cfg, **sim_kwargs):
    out = []
    for loop in ("array", "legacy"):
        jobs = jobs_factory()
        cl, registry = _scheduled(jobs)
        sim = ClusterSimulator(
            cl, jobs, dataclasses.replace(cfg, event_loop=loop),
            registry=registry,
            **{k: (v() if callable(v) else v) for k, v in sim_kwargs.items()})
        out.append((sim, sim.run()))
    return out


# ---------------------------------------------------------------------------
# telemetry channel model
# ---------------------------------------------------------------------------

class TestTelemetryChannel:
    def test_defaults_are_benign(self):
        ch = TelemetryChannel()
        assert ch.noise_std == 0.0 and ch.dropout == 0.0

    @pytest.mark.parametrize("kw", [
        dict(sample_period_ms=math.nan),
        dict(noise_std=-0.1), dict(noise_std=math.inf),
        dict(staleness_ms=-1.0), dict(staleness_ms=math.nan),
        dict(dropout=-0.1), dict(dropout=1.0),
    ])
    def test_invalid_params_rejected(self, kw):
        with pytest.raises(ValueError):
            TelemetryChannel(**kw)


class TestTelemetryView:
    def test_sample_and_hold(self):
        cl = small_cluster()
        tv = TelemetryView(cl, TelemetryChannel(sample_period_ms=1000.0),
                           seed=1)
        tv.now_ms = 600.0
        assert tv.link_alloc("n0") == 25.0
        cl.node("n0").allocatable_gbps = 10.0
        tv.record_change(700.0, ["n0"])
        tv.now_ms = 900.0  # still sample slot 0: the change is invisible
        assert tv.link_alloc("n0") == 25.0
        tv.now_ms = 1100.0  # slot 1 samples the truth in force at t=1000
        assert tv.link_alloc("n0") == 10.0

    def test_staleness_pins_older_sample(self):
        cl = small_cluster()
        tv = TelemetryView(
            cl, TelemetryChannel(sample_period_ms=1000.0, staleness_ms=1500.0),
            seed=1)
        cl.node("n0").allocatable_gbps = 10.0
        tv.record_change(700.0, ["n0"])
        tv.now_ms = 1100.0  # t - staleness < 0 -> slot 0 -> pre-change truth
        assert tv.link_alloc("n0") == 25.0
        tv.now_ms = 2600.0  # slot 1 -> truth at t=1000 -> post-change
        assert tv.link_alloc("n0") == 10.0

    def test_continuous_mode_staleness_only(self):
        cl = small_cluster()
        tv = TelemetryView(
            cl, TelemetryChannel(sample_period_ms=0.0, staleness_ms=500.0),
            seed=1)
        cl.node("n0").allocatable_gbps = 10.0
        tv.record_change(700.0, ["n0"])
        tv.now_ms = 1000.0  # sees truth at 500 (pre-change)
        assert tv.link_alloc("n0") == 25.0
        tv.now_ms = 1300.0  # sees truth at 800 (post-change)
        assert tv.link_alloc("n0") == 10.0

    def test_noise_is_seed_deterministic(self):
        ch = TelemetryChannel(sample_period_ms=100.0, noise_std=0.2)

        def observe(seed):
            tv = TelemetryView(small_cluster(), ch, seed=seed)
            out = []
            for k in range(10):
                tv.now_ms = k * 100.0 + 50.0
                out.append(tv.link_alloc("n0"))
            return out

        assert observe(7) == observe(7)
        assert observe(7) != observe(8)
        assert all(v >= 0.0 for v in observe(7))

    def test_dropout_carry_is_query_order_independent(self):
        ch = TelemetryChannel(sample_period_ms=100.0, noise_std=0.1,
                              dropout=0.5)

        def observe(slots):
            tv = TelemetryView(small_cluster(), ch, seed=5)
            out = {}
            for k in slots:
                tv.now_ms = k * 100.0 + 50.0
                out[k] = tv.link_alloc("n0")
            return out

        fwd = observe(range(10))
        rev = observe(list(reversed(range(10))))
        assert fwd == rev

    def test_dropout_carries_previous_sample(self):
        # dropout ~1 => every sample after slot 0 is lost; the slot-0
        # observation is carried forever (sample 0 is never dropped)
        ch = TelemetryChannel(sample_period_ms=100.0, noise_std=0.3,
                              dropout=0.999999)
        cl = small_cluster()
        tv = TelemetryView(cl, ch, seed=5)
        tv.now_ms = 50.0
        first = tv.link_alloc("n0")
        cl.node("n0").allocatable_gbps = 1.0
        tv.record_change(60.0, ["n0"])
        tv.now_ms = 950.0
        assert tv.link_alloc("n0") == first

    def test_unknown_link_raises_like_cluster(self):
        tv = TelemetryView(small_cluster(), TelemetryChannel(), seed=1)
        with pytest.raises(KeyError, match="ghost"):
            tv.link_alloc("ghost")

    def test_delegation_and_truthful_capacity(self):
        cl = small_cluster()
        tv = TelemetryView(
            cl, TelemetryChannel(sample_period_ms=100.0, noise_std=0.5),
            seed=1)
        assert tv.link_capacity("n0") == cl.link_capacity("n0")
        assert tv.node_names == cl.node_names
        tv.bump_epoch()
        assert cl.epoch == 1  # mutations hit the real cluster

    def test_fluctuation_tracks_noise(self):
        ch_noisy = TelemetryChannel(sample_period_ms=100.0, noise_std=0.3)
        ch_clean = TelemetryChannel(sample_period_ms=100.0)

        def fluct(ch):
            tv = TelemetryView(small_cluster(), ch, seed=5)
            for k in range(30):
                tv.now_ms = k * 100.0 + 50.0
                tv.link_alloc("n0")
            return tv.fluctuation("n0")

        assert fluct(ch_noisy) > 0.0
        assert fluct(ch_clean) == 0.0
        tv = TelemetryView(small_cluster(), ch_noisy, seed=5)
        assert tv.fluctuation("n0") == 0.0  # no samples yet


class TestOracleIdentity:
    """A transparent channel must be BIT-FOR-BIT the no-channel path, and
    a noisy channel must be loop-order independent (array == legacy)."""

    @pytest.mark.parametrize("loop", ["array", "legacy"])
    @pytest.mark.parametrize("channel", [
        TelemetryChannel(sample_period_ms=0.0),   # continuous, undistorted
        TelemetryChannel(sample_period_ms=1000.0),  # sampled, undistorted
    ])
    def test_transparent_channel_is_oracle(self, loop, channel):
        scen = dynamic_scenario("D1", n_iterations=30)
        cfg = dataclasses.replace(CFG, event_loop=loop)
        base = run(scen, Policy("metronome"), cfg)
        tel = run(scen, Policy("metronome"),
                  dataclasses.replace(cfg, telemetry=channel))
        sim_equal(base.sim, tel.sim)
        assert base.placements == tel.placements

    def test_noisy_channel_loop_parity(self):
        """The two loops interleave telemetry queries differently; the
        per-(link, slot) RNG contract makes them see identical channels."""
        scen = dynamic_scenario("D1", n_iterations=30)
        chan = TelemetryChannel(sample_period_ms=500.0, noise_std=0.15,
                                staleness_ms=250.0, dropout=0.1)
        cfg = dataclasses.replace(CFG, telemetry=chan)
        arr = run(scen, Policy("metronome"), cfg)
        leg = run(scen, Policy("metronome"),
                  dataclasses.replace(cfg, event_loop="legacy"))
        sim_equal(arr.sim, leg.sim)

    def test_noisy_run_is_seed_deterministic(self):
        scen = dynamic_scenario("D1", n_iterations=30)
        chan = TelemetryChannel(sample_period_ms=500.0, noise_std=0.2,
                                dropout=0.05)
        cfg = dataclasses.replace(CFG, telemetry=chan)
        a = run(scen, Policy("metronome"), cfg)
        b = run(scen, Policy("metronome"), cfg)
        sim_equal(a.sim, b.sim)


# ---------------------------------------------------------------------------
# fault injection: link/host failure + recovery, both loops bit-for-bit
# ---------------------------------------------------------------------------

class TestLinkFailure:
    CFG = SimConfig(duration_ms=10_000.0, seed=0, jitter_std=0.0)

    def test_failure_zeroes_recovery_restores(self):
        evs = [LinkFailure(2_000.0, link="n0"),
               LinkRecovery(4_000.0, link="n0")]
        (sa, ra), (sl, rl) = _both_loops(
            lambda: [_job()], self.CFG, events=lambda: list(evs))
        sim_equal(ra, rl)
        for sim, res in ((sa, ra), (sl, rl)):
            n0 = sim.cluster.node("n0")
            assert n0.bw_gbps == 25.0 and n0.allocatable_gbps is None
            assert res.iterations_done["j"] > 0
            # ~2s of the 10s window was dead: the job finishes later
        clean = _both_loops(lambda: [_job()], self.CFG)[0][1]
        assert ra.finish_times_ms["j"] > clean.finish_times_ms["j"] + 1_000.0

    def test_degraded_recovery(self):
        evs = [LinkFailure(1_000.0, link="n0"),
               LinkRecovery(2_000.0, link="n0", capacity_gbps=10.0)]
        (sa, ra), (sl, rl) = _both_loops(
            lambda: [_job()], self.CFG, events=lambda: list(evs))
        sim_equal(ra, rl)
        for sim in (sa, sl):
            assert sim.cluster.node("n0").bw_gbps == 10.0

    def test_zero_capacity_link_stalls_flows(self):
        """While a traversed link is failed, comm flows have rate 0: the
        job sits mid-comm with no finish event until recovery."""
        evs = [LinkFailure(500.0, link="n0")]
        (sa, ra), (sl, rl) = _both_loops(
            lambda: [_job()], self.CFG, events=lambda: list(evs))
        sim_equal(ra, rl)
        for sim, res in ((sa, ra), (sl, rl)):
            st = sim.jobs["j"]
            assert st.phase == COMM  # stuck mid-comm at the duration cap
            assert math.isnan(res.finish_times_ms["j"])

    def test_same_timestamp_failure_recovery_stack(self):
        """A failure and its recovery at ONE timestamp cancel exactly:
        the run is bit-for-bit an event-free run, in both loops."""
        evs = [LinkFailure(2_000.0, link="n0"),
               LinkRecovery(2_000.0, link="n0")]
        (sa, ra), (sl, rl) = _both_loops(
            lambda: [_job()], self.CFG, events=lambda: list(evs))
        sim_equal(ra, rl)
        (ca, rca), (clg, rcl) = _both_loops(lambda: [_job()], self.CFG)
        sim_equal(ra, rca)

    def test_double_failure_single_recovery(self):
        """Failing a failed link is a no-op; the first recovery restores
        the ORIGINAL pre-failure capacity (flap-overlap semantics)."""
        evs = [LinkFailure(1_000.0, link="n0"),
               LinkFailure(1_500.0, link="n0"),
               LinkRecovery(2_000.0, link="n0"),
               LinkRecovery(2_500.0, link="n0")]  # not failed: no-op
        (sa, ra), (sl, rl) = _both_loops(
            lambda: [_job()], self.CFG, events=lambda: list(evs))
        sim_equal(ra, rl)
        for sim in (sa, sl):
            n0 = sim.cluster.node("n0")
            assert n0.bw_gbps == 25.0 and n0.allocatable_gbps is None

    def test_unknown_link_warns(self):
        evs = [LinkFailure(100.0, link="ghost")]
        with pytest.warns(UnknownEventTargetWarning):
            _both_loops(lambda: [_job()], self.CFG,
                        events=lambda: list(evs))


class TestHostFailure:
    CFG = SimConfig(duration_ms=10_000.0, seed=0, jitter_std=0.0)

    def test_stall_and_recovery(self):
        evs = [HostFailure(2_000.0, host="n0"),
               HostRecovery(5_000.0, host="n0")]
        (sa, ra), (sl, rl) = _both_loops(
            lambda: [_job()], self.CFG, events=lambda: list(evs))
        sim_equal(ra, rl)
        clean = _both_loops(lambda: [_job()], self.CFG)[0][1]
        for sim, res in ((sa, ra), (sl, rl)):
            st = sim.jobs["j"]
            assert st.phase != STALLED  # recovered
            assert not st.stall_hosts
            assert res.iterations_done["j"] > 0
            # the ~3s stall pushes the finish well past the clean run's
            assert (res.finish_times_ms["j"]
                    > clean.finish_times_ms["j"] + 2_000.0)

    def test_unrecovered_host_stalls_to_cap(self):
        evs = [HostFailure(2_000.0, host="n0")]
        (sa, ra), (sl, rl) = _both_loops(
            lambda: [_job(n_iterations=500)], self.CFG,
            events=lambda: list(evs))
        sim_equal(ra, rl)
        for sim, res in ((sa, ra), (sl, rl)):
            st = sim.jobs["j"]
            assert st.phase == STALLED
            assert math.isnan(res.finish_times_ms["j"])
            # iterations froze at the failure: ~2s worth of 100ms periods
            assert res.iterations_done["j"] <= 21

    def test_same_timestamp_host_flap_costs_one_iteration(self):
        """Failure and recovery at ONE timestamp: the host is back
        instantly, but the in-flight iteration was abandoned by the
        failure and restarts from its top — host flaps are destructive
        by design (unlike link flaps, which only gate rates)."""
        evs = [HostFailure(2_000.0, host="n0"),
               HostRecovery(2_000.0, host="n0")]
        (sa, ra), (sl, rl) = _both_loops(
            lambda: [_job()], self.CFG, events=lambda: list(evs))
        sim_equal(ra, rl)
        clean = _both_loops(lambda: [_job()], self.CFG)[0][1]
        assert ra.iterations_done["j"] == clean.iterations_done["j"]
        assert ra.finish_times_ms["j"] == pytest.approx(
            clean.finish_times_ms["j"] + 100.0)  # one redone period
        for sim in (sa, sl):
            assert sim.jobs["j"].phase != STALLED
            assert not sim._failed_hosts and not sim._failed_links

    def test_job_on_other_host_unaffected(self):
        """A job with no task on the failed host keeps running.  Each job
        demands the node's full GPU capacity, pinning one per node."""
        from repro.core.cluster import Resources as Res

        def jobs():
            return [_job("a", n_tasks=1, bw_gbps=5.0, n_iterations=500,
                         resources=Res(cpu=4, mem=16, gpu=4)),
                    _job("b", n_tasks=1, bw_gbps=5.0, n_iterations=500,
                         resources=Res(cpu=4, mem=16, gpu=4))]

        evs = [HostFailure(2_000.0, host="n1")]
        (sa, ra), (sl, rl) = _both_loops(
            jobs, self.CFG, events=lambda: list(evs))
        sim_equal(ra, rl)
        for sim in (sa, sl):
            stalled = [n for n, st in sim.jobs.items()
                       if st.phase == STALLED]
            running = [n for n, st in sim.jobs.items()
                       if st.phase != STALLED]
            assert len(stalled) == 1 and len(running) == 1

    @pytest.mark.parametrize("sid", FAULT_SNAPSHOTS)
    def test_fault_snapshots_loop_parity(self, sid):
        scen = fault_scenario(sid, n_iterations=30, start_ms=3_000.0,
                              period_ms=6_000.0, down_ms=1_000.0, n_cycles=2)
        arr = run(scen, Policy("metronome"), CFG)
        leg = run(scen, Policy("metronome"),
                  dataclasses.replace(CFG, event_loop="legacy"))
        sim_equal(arr.sim, leg.sim)


class TestFlappingSchedule:
    def test_alternating_train(self):
        evs = flapping_schedule("uplink:leaf0", start_ms=1_000.0,
                                period_ms=5_000.0, down_ms=500.0, n_cycles=3)
        assert len(evs) == 6
        assert [type(e).__name__ for e in evs[:2]] == ["LinkFailure",
                                                       "LinkRecovery"]
        assert evs[2].time_ms == 6_000.0 and evs[3].time_ms == 6_500.0

    def test_host_variant(self):
        evs = flapping_schedule("n0", start_ms=0.0, period_ms=100.0,
                                down_ms=10.0, n_cycles=1, host=True)
        assert isinstance(evs[0], HostFailure)
        assert isinstance(evs[1], HostRecovery)

    def test_down_must_fit_period(self):
        with pytest.raises(ValueError, match="down_ms"):
            flapping_schedule("n0", start_ms=0.0, period_ms=100.0,
                              down_ms=100.0, n_cycles=1)


# ---------------------------------------------------------------------------
# event-stream boundary validation
# ---------------------------------------------------------------------------

class TestEventValidation:
    CFG = SimConfig(duration_ms=3_000.0, seed=0, jitter_std=0.0)

    def _sim(self, events, **cfg_kw):
        cfg = dataclasses.replace(self.CFG, **cfg_kw)
        return ClusterSimulator(small_cluster(), [_job(n_iterations=5)],
                                cfg, events=events)

    BAD_VALUE_EVENTS = [
        TrafficChange(100.0, job="j", duty_mult=math.nan),
        TrafficChange(100.0, job="j", duty_mult=-1.0),
        BackgroundFlowChange(100.0, link="n0", rate_gbps=math.nan),
        LinkCapacityChange(100.0, link="n0", allocatable_gbps=-5.0),
        LinkCapacityChange(100.0, link="n0", capacity_gbps=math.inf),
        LinkRecovery(100.0, link="n0", capacity_gbps=-1.0),
        TrafficChange(math.nan, job="j", duty_mult=1.5),
        TrafficChange(-5.0, job="j", duty_mult=1.5),
    ]

    @pytest.mark.parametrize("ev", BAD_VALUE_EVENTS)
    def test_strict_raises_on_bad_values(self, ev):
        with pytest.raises(EventValidationError) as exc:
            self._sim([ev], strict_events=True).run()
        assert exc.value.problems[0].category == "bad-value"

    def test_strict_raises_on_unknown_targets(self):
        with pytest.raises(EventValidationError) as exc:
            self._sim([LinkFailure(100.0, link="ghost")],
                      strict_events=True).run()
        assert exc.value.problems[0].category == "unknown-target"

    def test_strict_reports_all_problems(self):
        evs = [TrafficChange(100.0, job="j", duty_mult=math.nan),
               HostFailure(200.0, host="ghost"),
               BackgroundFlowChange(300.0, link="n0", rate_gbps=math.inf)]
        with pytest.raises(EventValidationError) as exc:
            self._sim(evs, strict_events=True).run()
        assert len(exc.value.problems) == 3

    def test_default_drops_bad_values_with_one_warning(self):
        """Same malformed event twice: ONE warning, both dropped, and the
        run completes as if they were never submitted."""
        evs = [BackgroundFlowChange(100.0, link="n0", rate_gbps=math.nan),
               BackgroundFlowChange(200.0, link="n0", rate_gbps=math.nan)]
        with pytest.warns(UserWarning, match="dropped") as rec:
            sim = self._sim(evs)
            sim.run()
        dropped = [w for w in rec if "dropped" in str(w.message)]
        assert len(dropped) == 1
        assert sim.cluster.node("n0").allocatable_gbps is None

    def test_default_keeps_fire_time_unknown_warning(self):
        """Unknown targets are NOT dropped at the boundary: the historical
        fire-time warning (first offense time) is preserved."""
        evs = [BackgroundFlowChange(100.0, link="ghost", rate_gbps=5.0),
               BackgroundFlowChange(200.0, link="ghost", rate_gbps=9.0)]
        with pytest.warns(UnknownEventTargetWarning) as rec:
            self._sim(evs).run()
        ours = [w for w in rec
                if isinstance(w.message, UnknownEventTargetWarning)]
        assert len(ours) == 1
        assert ours[0].message.time_ms == pytest.approx(100.0)

    def test_validate_stream_clean(self):
        evs = [TrafficChange(100.0, job="j", duty_mult=1.5),
               LinkFailure(200.0, link="n0"),
               HostFailure(300.0, host="n1")]
        assert validate_stream(evs, known_links={"n0", "n1"},
                               known_hosts={"n0", "n1"},
                               known_jobs={"j"}) == []

    def test_strict_in_experiment_config(self):
        """strict_events rides SimConfig through the experiment API."""
        def build():
            cluster, wls, bg = make_snapshot("S2", n_iterations=10)
            return cluster, wls, bg, [TrafficChange(100.0, job="nobody",
                                                    duty_mult=2.0)]

        scen = Scenario(name="bad", build=build)
        cfg = dataclasses.replace(CFG, strict_events=True)
        with pytest.raises(EventValidationError):
            run(scen, Policy("metronome"), cfg)


# ---------------------------------------------------------------------------
# degradation control: hysteresis + reconciliation
# ---------------------------------------------------------------------------

class TestHysteresis:
    CFG = SimConfig(duration_ms=10_000.0, seed=0, jitter_std=0.0)

    def _run(self, events, **ctl_kw):
        controller = StopAndWaitController(**ctl_kw)
        jobs = [_job("a"), _job("b", period_ms=130, duty=0.3,
                                submit_time_s=0.001)]
        cl, registry = _scheduled(jobs, controller=controller)
        sim = ClusterSimulator(cl, jobs, self.CFG, controller=controller,
                               registry=registry, events=events)
        sim.run()
        return controller

    def test_min_interval_suppresses(self):
        evs = [BackgroundFlowChange(1_000.0, link="n0", rate_gbps=5.0),
               BackgroundFlowChange(2_000.0, link="n0", rate_gbps=10.0),
               BackgroundFlowChange(3_000.0, link="n0", rate_gbps=2.0)]
        loose = self._run(list(evs))
        tight = self._run(list(evs), hysteresis_ms=60_000.0)
        assert loose.suppressed_reconf_count == 0
        assert tight.suppressed_reconf_count == 2
        assert tight.reconf_count == 1
        assert tight.reconf_count < loose.reconf_count

    def test_magnitude_gate_suppresses_small_changes(self):
        evs = [BackgroundFlowChange(1_000.0, link="n0", rate_gbps=5.0),
               BackgroundFlowChange(2_000.0, link="n0", rate_gbps=5.2)]
        ctl = self._run(list(evs), hysteresis_frac=0.05)
        # 2nd change moves alloc by 0.2 of 25 (0.8%) < 5% of capacity
        assert ctl.suppressed_reconf_count == 1
        assert ctl.reconf_count == 1
        big = self._run(list(evs[:1]) + [
            BackgroundFlowChange(2_000.0, link="n0", rate_gbps=15.0)],
            hysteresis_frac=0.05)
        assert big.reconf_count == 2

    def test_dead_link_guard(self):
        """A failed (observed-dead) link never replans — there is no
        bandwidth to derive a rotation against; the recovery does."""
        evs = [LinkFailure(1_000.0, link="n0"),
               LinkRecovery(2_000.0, link="n0")]
        ctl = self._run(list(evs))
        assert ctl.reconf_count == 1  # recovery only

    def test_zero_hysteresis_is_seed_behavior(self):
        evs = [BackgroundFlowChange(1_000.0, link="n0", rate_gbps=5.0),
               BackgroundFlowChange(1_500.0, link="n0", rate_gbps=8.0)]
        ctl = self._run(list(evs))
        assert ctl.reconf_count == 2
        assert ctl.suppressed_reconf_count == 0


class TestReconciliation:
    def test_insufficient_evidence_returns_none(self):
        ctl = StopAndWaitController(reconcile=True, reconcile_window=4)
        for _ in range(3):
            assert ctl.reconcile_measurement("j", 80.0, 40.0) is None

    def test_median_deviation_triggers(self):
        ctl = StopAndWaitController(reconcile=True, reconcile_window=4,
                                    reconcile_frac=0.25)
        out = None
        for _ in range(4):
            out = ctl.reconcile_measurement("j", 80.0, 40.0)
        assert out == pytest.approx(80.0)
        assert ctl.reconcile_count == 1
        # evidence cleared after adoption: next report starts fresh
        assert ctl.reconcile_measurement("j", 80.0, 80.0) is None

    def test_within_tolerance_never_triggers(self):
        ctl = StopAndWaitController(reconcile=True, reconcile_window=4,
                                    reconcile_frac=0.25)
        for _ in range(10):
            assert ctl.reconcile_measurement("j", 44.0, 40.0) is None
        assert ctl.reconcile_count == 0

    def test_disabled_returns_none(self):
        ctl = StopAndWaitController()
        for _ in range(10):
            assert ctl.reconcile_measurement("j", 80.0, 40.0) is None

    def test_silent_drift_closed_by_reconciliation(self):
        """declared=False traffic drift: the profile stays stale unless
        the controller reconciles measured comm time against it."""
        def run_one(reconcile):
            controller = StopAndWaitController(reconcile=reconcile)
            jobs = [_job(n_iterations=200)]
            cl, registry = _scheduled(jobs, controller=controller)
            sim = ClusterSimulator(
                cl, jobs, SimConfig(duration_ms=15_000.0, seed=0,
                                    jitter_std=0.0),
                controller=controller, registry=registry,
                events=[TrafficChange(1_000.0, job="j", duty_mult=1.8,
                                      declared=False)])
            sim.run()
            return controller, sim

        stale_ctl, stale_sim = run_one(False)
        assert stale_ctl.reconcile_count == 0
        assert stale_sim.jobs["j"].job.traffic.duty == pytest.approx(0.4)
        assert stale_sim.jobs["j"].drift_mult == pytest.approx(1.8)

        rec_ctl, rec_sim = run_one(True)
        assert rec_ctl.reconcile_count >= 1
        # profile adopted the measurement: duty ~0.72 (0.4 * 1.8)
        assert rec_sim.jobs["j"].job.traffic.duty == pytest.approx(
            0.72, rel=0.1)
        # and the drift bookkeeping re-normalized toward 1
        assert rec_sim.jobs["j"].drift_mult == pytest.approx(1.0, rel=0.1)

    def test_silent_drift_loop_parity(self):
        evs = [TrafficChange(1_000.0, job="j", duty_mult=1.5,
                             declared=False)]
        (sa, ra), (sl, rl) = _both_loops(
            lambda: [_job()], SimConfig(duration_ms=10_000.0, seed=0,
                                        jitter_std=0.0),
            events=lambda: list(evs))
        sim_equal(ra, rl)

"""Fabric-wide joint rotation planner (core/rotation.py) tests.

Three pillars (ISSUE 3 acceptance):

  * star-topology equivalence — the planner must reduce BIT-FOR-BIT to the
    legacy per-link solve + BFS offset merge (oracle: verbatim copy of the
    pre-planner controller's ``_recompute_global_offsets``);
  * the J1 conflict oracle — per-link solves provably conflict; the legacy
    "uplinks win" reconciliation leaves a host link oversubscribed in time
    while the joint solve is feasible on every link;
  * kernel parity — the stacked (L, R, S) multi-link score core matches the
    jnp reference and the per-link numpy min in interpret mode.
"""
import networkx as nx
import numpy as np
import pytest

from repro.configs.metronome_testbed import make_snapshot
from repro.core import geometry, rotation, scoring
from repro.core.contention import LinkView
from repro.core.controller import StopAndWaitController
from repro.core.framework import SchedulingFramework
from repro.core.scheduler import MetronomePlugin
from repro.core.topology import is_uplink


# ---------------------------------------------------------------------------
# Legacy oracle: verbatim copy of the pre-planner controller's offset merge
# (BFS over the affinity graph, add_edge overwrite, uplinks-LAST tie-break)
# ---------------------------------------------------------------------------

def legacy_recompute_global_offsets(links, priorities, di_pre):
    g = nx.Graph()
    link_shift_ms = {}
    ordered = sorted(links.items(),
                     key=lambda kv: (is_uplink(kv[0]), kv[0]))
    for node, state in ordered:
        sch = state.scheme
        delays = geometry.shifts_to_delay_ms(sch.shifts_slots, sch.base_ms,
                                             di_pre)
        for j, d in zip(sch.jobs, delays):
            link_shift_ms[(node, j)] = float(d)
            g.add_node(j)
        for i in range(len(sch.jobs)):
            for k in range(i + 1, len(sch.jobs)):
                a, b = sch.jobs[i], sch.jobs[k]
                rel = link_shift_ms[(node, b)] - link_shift_ms[(node, a)]
                g.add_edge(a, b, rel=rel, src=a)
    offsets = {}
    for comp in nx.connected_components(g):
        comp = list(comp)
        ref = sorted(comp, key=lambda j: (-priorities.get(j, 0), j))[0]
        offsets[ref] = 0.0
        for u, v in nx.bfs_edges(g, ref):
            rel = g[u][v]["rel"]
            if g[u][v]["src"] != u:
                rel = -rel
            offsets[v] = offsets[u] + rel
    return offsets


def schedule_snapshot(sid, joint=True):
    cluster, wls, bg = make_snapshot(sid, n_iterations=100)
    ctrl = StopAndWaitController(joint=joint)
    fw = SchedulingFramework(cluster, MetronomePlugin(controller=ctrl,
                                                      joint=joint))
    for wl in wls:
        fw.schedule_workload(wl)
    return cluster, fw, ctrl


def offsets_implied_scores(cluster, registry, ctrl, demand="planning"):
    """Per-link Eq. 18 score of the controller's FINAL global offsets."""
    view = LinkView.from_registry(cluster, registry)
    out = {}
    for lid, st in ctrl.links.items():
        sch = st.scheme
        duties, rbws = view.recalc_traffic(lid, sch.jobs, sch.muls,
                                           sch.base_ms)
        if demand == "planning":
            groups = view.link_groups(lid)
            bws = [sum(t.traffic.bw_gbps for t in groups.get(j, []))
                   for j in sch.jobs]
        else:
            bws = rbws
        pats = geometry.pattern_matrix(sch.muls, duties, ctrl.di_pre)
        shifts = np.array([
            geometry.delay_to_shift_slots(ctrl.job_offset_ms(j), sch.base_ms,
                                          ctrl.di_pre)
            for j in sch.jobs
        ])
        out[lid] = float(scoring.score_combos(
            pats, np.asarray(bws), cluster.link_alloc(lid),
            shifts[None, :])[0])
    return out


# ---------------------------------------------------------------------------
# Star-topology equivalence (bit-for-bit)
# ---------------------------------------------------------------------------

class TestStarEquivalence:
    @pytest.mark.parametrize("sid", ["S1", "S2", "S4"])
    def test_offsets_match_legacy_oracle(self, sid):
        """The planner's resolution equals the legacy BFS merge bit-for-bit
        on the star snapshots.  S2/S4 components are consistent so the
        joint path never fires; on S1 the three identical jobs produce a
        conflict the legacy merge silently overwrote — the joint re-solve
        lands on the same offsets (symmetric problem), pinning that the
        replacement is behavior-preserving there too."""
        cluster, fw, ctrl = schedule_snapshot(sid)
        want = legacy_recompute_global_offsets(ctrl.links, ctrl._priorities,
                                               ctrl.di_pre)
        assert ctrl.global_offsets_ms == want
        if sid in ("S2", "S4"):
            assert ctrl.joint_resolve_count == 0  # nothing conflicted

    def test_single_link_plan_equals_per_link_solver(self):
        """plan() over one contended link == find_feasible_rotation on it."""
        cluster, fw, ctrl = schedule_snapshot("S2")
        view = LinkView.from_registry(cluster, fw.registry)
        for lid, st in ctrl.links.items():
            score, scheme = rotation.solve_link(view, fw.registry, lid,
                                                mode="fast")
            assert scheme is not None
            res = rotation.plan(view, fw.registry, links=[lid], mode="fast")
            assert np.array_equal(res.schemes[lid].shifts_slots,
                                  scheme.shifts_slots)
            assert res.score == score

    def test_joint_flag_irrelevant_on_star(self):
        """joint=True and joint=False are identical end-to-end on stars."""
        _, fw_a, ctrl_a = schedule_snapshot("S2", joint=True)
        _, fw_b, ctrl_b = schedule_snapshot("S2", joint=False)
        assert ctrl_a.global_offsets_ms == ctrl_b.global_offsets_ms
        for lid in ctrl_a.links:
            assert np.array_equal(ctrl_a.links[lid].scheme.shifts_slots,
                                  ctrl_b.links[lid].scheme.shifts_slots)


# ---------------------------------------------------------------------------
# J1: per-link solves conflict; joint solve feasible, legacy merge not
# ---------------------------------------------------------------------------

class TestJointConflictOracle:
    def test_per_link_solves_conflict(self):
        """Host-optimal relative shift of (hi, lo) is infeasible on the
        shared uplink: the per-link solutions genuinely disagree."""
        cluster, fw, ctrl = schedule_snapshot("J1")
        view = LinkView.from_registry(cluster, fw.registry)
        rels = {}
        for lid in view.planning_links():
            score, scheme = rotation.solve_link(view, fw.registry, lid,
                                                mode="fast")
            if scheme is None or not {"j1-hi", "j1-lo"} <= set(scheme.jobs):
                continue
            d = geometry.shifts_to_delay_ms(scheme.shifts_slots,
                                            scheme.base_ms, ctrl.di_pre)
            rel = (d[scheme.jobs.index("j1-lo")]
                   - d[scheme.jobs.index("j1-hi")])
            rels[lid] = round(float(rel), 6)
        host_rels = {v for k, v in rels.items() if not is_uplink(k)}
        uplink_rels = {v for k, v in rels.items() if is_uplink(k)}
        assert host_rels and uplink_rels
        assert host_rels.isdisjoint(uplink_rels)

    def test_joint_feasible_where_legacy_is_not(self):
        cluster_j, fw_j, ctrl_j = schedule_snapshot("J1", joint=True)
        scores_j = offsets_implied_scores(cluster_j, fw_j.registry, ctrl_j)
        assert ctrl_j.joint_resolve_count >= 1
        assert min(scores_j.values()) == pytest.approx(100.0)

        cluster_l, fw_l, ctrl_l = schedule_snapshot("J1", joint=False)
        scores_l = offsets_implied_scores(cluster_l, fw_l.registry, ctrl_l)
        assert min(scores_l.values()) < 100.0 - 1e-6

    def test_legacy_oracle_matches_joint_false(self):
        """joint=False IS the legacy reconciliation (oracle-pinned)."""
        cluster, fw, ctrl = schedule_snapshot("J1", joint=False)
        want = legacy_recompute_global_offsets(ctrl.links, ctrl._priorities,
                                               ctrl.di_pre)
        assert ctrl.global_offsets_ms == want

    def test_joint_solve_direct(self):
        """joint_solve over the full J1 component: feasible on every link,
        reference pinned at zero (Eq. 16), numpy == kernel backend."""
        cluster, fw, ctrl = schedule_snapshot("J1")
        view = LinkView.from_registry(cluster, fw.registry)
        links = [l for l in view.planning_links()
                 if rotation.solve_link(view, fw.registry, l)[1] is not None]
        res_np = rotation.joint_solve(view, fw.registry, links,
                                      backend="numpy")
        res_k = rotation.joint_solve(view, fw.registry, links,
                                     backend="kernel")
        assert res_np.feasible
        assert res_np.jobs[0] == "j1-hi" and res_np.shifts[0] == 0
        assert np.array_equal(res_np.shifts, res_k.shifts)
        assert res_np.score == pytest.approx(res_k.score, abs=1e-4)


# ---------------------------------------------------------------------------
# Multi-link kernel parity (interpret mode)
# ---------------------------------------------------------------------------

class TestMultilinkKernelParity:
    def _problem(self, seed=0, l=3):
        rng = np.random.default_rng(seed)
        pats = geometry.pattern_matrix([1, 1, 2], [0.3, 0.25, 0.2], 72)
        banks = scoring.rolled_bank(pats, [1, 24, 36])
        bw = rng.uniform(5.0, 20.0, size=(l, 3))
        caps = rng.uniform(18.0, 30.0, size=l)
        base = bw[:, 0:1] * pats[0][None, :]
        bank_a = bw[:, 1, None, None] * banks[1][None]
        bank_b = bw[:, 2, None, None] * banks[2][None]
        return pats, banks, bw, caps, base, bank_a, bank_b

    def test_interpret_matches_ref(self):
        from repro.kernels import ops as kops
        from repro.kernels import ref
        _, _, _, caps, base, bank_a, bank_b = self._problem()
        got = kops.score_multilink(base, bank_a, bank_b, caps,
                                   interpret=True)
        want = np.asarray(ref.metronome_score_multilink_ref(
            base, bank_a, bank_b, caps))
        assert got.shape == (24, 36)
        assert np.allclose(got, want, atol=1e-4)

    def test_ref_matches_per_link_numpy_min(self):
        from repro.kernels import ref
        pats, banks, bw, caps, base, bank_a, bank_b = self._problem(seed=1)
        want = np.asarray(ref.metronome_score_multilink_ref(
            base, bank_a, bank_b, caps)).reshape(-1)
        combos = scoring.lex_combos([1, 24, 36], 0, 24 * 36)
        per = None
        for li in range(len(caps)):
            s = scoring.score_combos(pats, bw[li], float(caps[li]), combos,
                                     banks)
            per = s if per is None else np.minimum(per, s)
        assert np.allclose(want, per, atol=1e-4)

    def test_single_link_reduces_to_pairwise(self):
        from repro.kernels import ops as kops
        _, _, _, caps, base, bank_a, bank_b = self._problem(l=1)
        multi = kops.score_multilink(base, bank_a, bank_b, caps[:1],
                                     interpret=True)
        pair = kops.score_pairwise(base[0], bank_a[0], bank_b[0],
                                   float(caps[0]), interpret=True)
        assert np.allclose(multi, pair, atol=1e-4)

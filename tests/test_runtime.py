"""Runtime substrate: optimizer, grad accumulation, checkpoint, data,
compression, elastic re-mesh, comm gate."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.configs import get_smoke_config
from repro.core.controller import StopAndWaitController
from repro.data import SyntheticLM
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_ef_int8, cosine_schedule, make_ef_state,
                         quantize_int8)
from repro.runtime.comm_gate import CommGate
from repro.runtime.elastic import plan_remesh
from repro.runtime.steps import build_train_step, init_train_state

KEY = jax.random.PRNGKey(0)


class TestAdamW:
    def test_matches_reference_numpy(self):
        cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8,
                          weight_decay=0.0, grad_clip=0.0, warmup_steps=0,
                          total_steps=10, min_lr_frac=1.0)
        p = {"w": jnp.array([1.0, -2.0, 3.0])}
        g = {"w": jnp.array([0.1, 0.2, -0.3])}
        st = adamw_init(cfg, p)
        p1, st1, _ = adamw_update(cfg, p, g, st)
        # closed-form single step: m=0.1g*10... bias-corrected Adam
        m = 0.1 * np.array([0.1, 0.2, -0.3]) / (1 - 0.9)
        v = 0.01 * np.array([0.1, 0.2, -0.3]) ** 2 / (1 - 0.99)
        want = np.array([1.0, -2.0, 3.0]) - 1e-2 * m / (np.sqrt(v) + 1e-8)
        np.testing.assert_allclose(np.asarray(p1["w"]), want, rtol=1e-5)

    def test_grad_clip(self):
        cfg = AdamWConfig(grad_clip=1.0, warmup_steps=0)
        p = {"w": jnp.ones(4)}
        g = {"w": jnp.full(4, 100.0)}
        st = adamw_init(cfg, p)
        _, _, metrics = adamw_update(cfg, p, g, st)
        assert float(metrics["grad_norm"]) == pytest.approx(200.0)

    def test_schedule_warmup_and_decay(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        assert float(cosine_schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
        assert float(cosine_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
        assert float(cosine_schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)

    def test_bf16_moments(self):
        cfg = AdamWConfig(moment_dtype=jnp.bfloat16)
        p = {"w": jnp.ones(4)}
        st = adamw_init(cfg, p)
        assert st["m"]["w"].dtype == jnp.bfloat16


class TestGradAccumulation:
    def test_micro_equivalence(self):
        """n_micro=4 must equal n_micro=1 on the same global batch."""
        cfg = get_smoke_config("llama3_8b")
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0)
        state, _ = init_train_state(cfg, opt_cfg, KEY)
        tokens = jax.random.randint(KEY, (8, 16), 0, cfg.vocab)
        batch = {"tokens": tokens, "labels": tokens}
        s1, m1 = build_train_step(cfg, opt_cfg, n_micro=1)(state, batch)
        state2, _ = init_train_state(cfg, opt_cfg, KEY)
        s4, m4 = build_train_step(cfg, opt_cfg, n_micro=4)(state2, batch)
        assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
            # params are bf16 and Adam's first step is sign-like, so
            # accumulation-order noise can flip near-zero grads: bound the
            # divergence by ~2 x lr rather than exact equality.
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=2.6e-3)

    def test_loss_decreases_over_steps(self):
        cfg = get_smoke_config("llama3_8b")
        opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=0, total_steps=50)
        state, _ = init_train_state(cfg, opt_cfg, KEY)
        step = jax.jit(build_train_step(cfg, opt_cfg, n_micro=1))
        ds = SyntheticLM(cfg.vocab, 16, 8, seed=0)
        losses = []
        for i in range(12):
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.2


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": {"c": jnp.ones(4, jnp.bfloat16)}}
        save_checkpoint(str(tmp_path), 7, tree, {"note": "x"})
        got, step, extra = restore_checkpoint(str(tmp_path), tree)
        assert step == 7 and extra == {"note": "x"}
        np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
        assert got["b"]["c"].dtype == jnp.bfloat16

    def test_corrupt_checkpoint_skipped(self, tmp_path):
        tree = {"a": jnp.ones(3)}
        save_checkpoint(str(tmp_path), 1, tree)
        save_checkpoint(str(tmp_path), 2, tree)
        # corrupt the newest
        os.remove(os.path.join(str(tmp_path), "step_00000002", "manifest.json"))
        assert latest_step(str(tmp_path)) == 1

    def test_keep_n_and_async(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=2, async_save=True)
        tree = {"a": jnp.ones(3)}
        for s in range(5):
            mgr.save(s, tree)
        mgr.wait()
        steps = sorted(n for n in os.listdir(str(tmp_path))
                       if n.startswith("step_"))
        assert steps == ["step_00000003", "step_00000004"]
        got, step, _ = mgr.restore_latest(tree)
        assert step == 4


class TestData:
    def test_deterministic_and_restart_safe(self):
        ds = SyntheticLM(vocab=100, seq_len=8, global_batch=4, seed=3)
        a = ds.batch_at(5)
        b = ds.batch_at(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = ds.batch_at(6)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_labels_are_shifted_tokens(self):
        ds = SyntheticLM(vocab=100, seq_len=8, global_batch=2, seed=0)
        b = ds.batch_at(0)
        assert b["tokens"].shape == b["labels"].shape == (2, 8)
        assert b["tokens"].min() >= 1  # 0 reserved


class TestCompression:
    def test_quantize_error_bound(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=256) * 5)
        q, scale = quantize_int8(x)
        err = jnp.abs(q.astype(jnp.float32) * scale - x).max()
        assert float(err) <= float(scale) / 2 + 1e-6

    def test_error_feedback_reduces_bias(self):
        """EF: accumulated rounding errors are re-injected (mean error of a
        constant gradient stream goes to ~zero over steps)."""
        g = {"w": jnp.full(64, 0.01234)}
        ef = make_ef_state(g)
        total = jnp.zeros(64)
        for _ in range(50):
            qs, ef = compress_ef_int8(g, ef)
            total = total + qs["w"][0].astype(jnp.float32) * qs["w"][1]
        mean = total / 50
        assert float(jnp.abs(mean - 0.01234).max()) < 1e-4


class TestElastic:
    def test_plan_remesh_shrinks_data_axis(self):
        d = plan_remesh(n_healthy=400, model_parallel=16)
        assert d.mesh_shape == (16, 16)  # 256 <= 400 < 512
        d = plan_remesh(n_healthy=511, model_parallel=16)
        assert d.mesh_shape == (16, 16)
        d = plan_remesh(n_healthy=512, model_parallel=16)
        assert d.mesh_shape == (32, 16)

    def test_unrecoverable_below_tp(self):
        assert plan_remesh(8, 16) is None

    def test_failure_recovery_end_to_end(self, tmp_path):
        from repro.runtime.elastic import FaultTolerantRunner
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        state = {"w": jnp.arange(4.0)}
        mgr.save(3, state)
        runner = FaultTolerantRunner(mgr, model_parallel=1)
        mesh, got, step, decision = runner.on_failure(jax.devices(), state)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(state["w"]))


class TestCommGate:
    def test_wait_for_slot_aligns(self):
        """The gate sleeps exactly onto the assigned offset."""
        ctrl = StopAndWaitController()
        # fake link state granting job an offset of 30ms on a 100ms circle
        from repro.core.scheduler import LinkScheme
        from repro.core.controller import LinkState
        import numpy as np
        ctrl.links["n0"] = LinkState(
            scheme=LinkScheme(jobs=["ref", "j"],
                              shifts_slots=np.array([0, 18]), base_ms=100.0,
                              muls=np.array([1, 1]), score=100.0,
                              early_return=False, injected_ms={},
                              ref_job="ref"),
            optimal=True)
        ctrl._priorities = {"ref": 1, "j": 0}
        ctrl._replan_offsets()
        clock = {"t": 0.012}  # 12 ms
        slept = []
        gate = CommGate(ctrl, "j", clock=lambda: clock["t"],
                        sleep=lambda s: slept.append(s))
        delay = gate.wait_for_slot()
        # offset = 18/72*100 = 25ms; now 12ms -> sleep 13ms
        assert delay == pytest.approx(0.013, abs=1e-6)
        assert slept and slept[0] == pytest.approx(0.013, abs=1e-6)

"""Thread-safety regression for the benchmarks.common recorders.

Benches running sweep cells on a thread pool (run.py --workers N) record
rows from worker threads; before _RECORD_LOCK the list appends raced and
rows were lost under interleaving."""
import threading

import benchmarks.common as common


def _drain(lst):
    out = list(lst)
    del lst[:]
    return out


class TestRecorderThreadSafety:
    def test_concurrent_emits_lose_nothing(self):
        saved = _drain(common.RECORDED_EMITS)
        try:
            n_threads, per_thread = 8, 200
            barrier = threading.Barrier(n_threads)

            def worker(tid):
                barrier.wait()
                for i in range(per_thread):
                    common.emit(f"t{tid}-{i}", 1.0, "derived")

            threads = [threading.Thread(target=worker, args=(t,))
                       for t in range(n_threads)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            rows = _drain(common.RECORDED_EMITS)
            assert len(rows) == n_threads * per_thread
            assert len({r["name"] for r in rows}) == n_threads * per_thread
        finally:
            common.RECORDED_EMITS.extend(saved)

    def test_concurrent_trace_and_dynamic_rows(self):
        saved_t = _drain(common.RECORDED_TRACE_ROWS)
        saved_d = _drain(common.RECORDED_DYNAMIC_ROWS)
        try:
            n_threads, per_thread = 6, 150
            barrier = threading.Barrier(n_threads)

            def worker(tid):
                barrier.wait()
                for i in range(per_thread):
                    common.record_trace_row(scheduler=f"t{tid}", snapshot=i)
                    common.record_dynamic_row(scheduler=f"t{tid}", event=i)

            threads = [threading.Thread(target=worker, args=(t,))
                       for t in range(n_threads)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            total = n_threads * per_thread
            assert len(_drain(common.RECORDED_TRACE_ROWS)) == total
            assert len(_drain(common.RECORDED_DYNAMIC_ROWS)) == total
        finally:
            common.RECORDED_TRACE_ROWS.extend(saved_t)
            common.RECORDED_DYNAMIC_ROWS.extend(saved_d)

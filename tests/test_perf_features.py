"""Perf-loop features: EP dispatch, rules presets, phase monitor, flash
byte model, grad-spec constraint."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.dryrun import (RULES_PRESETS, flash_attention_bytes,
                                 model_flops)
from repro.launch.mesh import make_host_mesh
from repro.models import forward, init_model
from repro.models.config import SHAPES
from repro.optim import AdamWConfig
from repro.runtime.steps import build_train_step, init_train_state
from repro.sharding import AxisRules, best_spec, use_rules

KEY = jax.random.PRNGKey(0)


class TestEpDispatch:
    def test_same_outputs_as_baseline(self):
        """EP-consistent dispatch is a sharding annotation — numerics equal."""
        cfg0 = get_smoke_config("arctic_480b")
        cfg1 = dataclasses.replace(cfg0, moe_ep_dispatch=True)
        params, _ = init_model(cfg0, KEY)
        tok = jax.random.randint(KEY, (2, 16), 0, cfg0.vocab)
        l0, _ = forward(params, cfg0, tok)
        l1, _ = forward(params, cfg1, tok)
        np.testing.assert_allclose(np.asarray(l0, np.float32),
                                   np.asarray(l1, np.float32), atol=1e-5)


class TestRulesPresets:
    def test_pure_fsdp_shards_weights_over_all_axes(self):
        mesh = make_host_mesh(1, 1)  # axis sizes 1: spec still resolves
        rules = AxisRules(mesh, RULES_PRESETS["pure_fsdp"])
        spec = best_spec((4096, 128), ("w_embed", "w_heads"), rules)
        assert spec[0] == ("data", "model")
        assert spec[1] is None  # no tensor parallelism

    def test_pure_fsdp_train_step_compiles(self):
        mesh = make_host_mesh(1, 1)
        cfg = get_smoke_config("llama3_8b")
        opt_cfg = AdamWConfig(warmup_steps=0)
        with use_rules(mesh, RULES_PRESETS["pure_fsdp"]):
            state, specs = init_train_state(cfg, opt_cfg, KEY)
            step = jax.jit(build_train_step(cfg, opt_cfg, n_micro=1,
                                            param_specs=specs))
            tokens = jnp.zeros((2, 16), jnp.int32)
            state, metrics = step(state, {"tokens": tokens, "labels": tokens})
        assert np.isfinite(float(metrics["loss"]))


class TestFlashByteModel:
    def test_train_bytes_scale_with_layers(self):
        cfg = get_smoke_config("llama3_8b")
        big = dataclasses.replace(cfg, n_layers=cfg.n_layers * 2)
        mesh = {"data": 16, "model": 16}
        a = flash_attention_bytes(cfg, SHAPES["train_4k"], 8, mesh)
        b = flash_attention_bytes(big, SHAPES["train_4k"], 8, mesh)
        assert b == pytest.approx(2 * a)

    def test_xlstm_has_no_attention(self):
        cfg = get_smoke_config("xlstm_125m")
        assert flash_attention_bytes(cfg, SHAPES["train_4k"], 1,
                                     {"data": 16, "model": 16}) == 0.0

    def test_model_flops_moe_counts_active_only(self):
        from repro.configs import get_config
        arctic = get_config("arctic_480b")
        dense_equiv = dataclasses.replace(
            arctic, n_experts=0, top_k=0, dense_residual=False)
        f_moe = model_flops(arctic, SHAPES["train_4k"])
        f_dense = model_flops(dense_equiv, SHAPES["train_4k"])
        # top-2 of 128 experts + dense residual is far below 128 experts
        # dense-equivalent would be; sanity: active ~ 3x the dense-only net
        assert f_moe < 10 * f_dense


class TestPhaseMonitor:
    def _controller(self, phase_monitor):
        from repro.core.controller import LinkState, StopAndWaitController
        from repro.core.scheduler import LinkScheme
        c = StopAndWaitController(phase_monitor=phase_monitor)
        c.links["n0"] = LinkState(
            scheme=LinkScheme(jobs=["hi", "lo"],
                              shifts_slots=np.array([0, 36]), base_ms=418.0,
                              muls=np.array([1, 1]), score=100.0,
                              early_return=False, injected_ms={},
                              ref_job="hi"), optimal=True)
        c._priorities = {"hi": 1, "lo": 0}
        c._replan_offsets()
        return c

    def test_default_off(self):
        from repro.core.controller import StopAndWaitController
        assert not StopAndWaitController().phase_monitor

    def test_relative_error_triggers_after_debounce(self):
        c = self._controller(True)
        c.report_phase_error("hi", 0.0, 418.0)  # ref on time
        acts = []
        for _ in range(3):
            acts = c.report_phase_error("lo", 60.0, 418.0)
        assert acts and acts[0].job == "lo"
        assert c.readjust_count == 1

    def test_common_mode_drift_ignored(self):
        """Both jobs drifting together must not trigger (the thrash case)."""
        c = self._controller(True)
        for _ in range(10):
            c.report_phase_error("hi", 80.0, 418.0)
            assert not c.report_phase_error("lo", 80.0, 418.0)
        assert c.readjust_count == 0

    def test_off_only_records(self):
        c = self._controller(False)
        for _ in range(10):
            assert not c.report_phase_error("lo", 100.0, 418.0)
        assert c.readjust_count == 0


class TestRealignGuard:
    def test_no_realign_on_imperfect_link(self):
        from repro.core.controller import LinkState, StopAndWaitController
        from repro.core.scheduler import LinkScheme
        c = StopAndWaitController()
        c.links["n0"] = LinkState(
            scheme=LinkScheme(jobs=["hi", "lo"],
                              shifts_slots=np.array([0, 0]), base_ms=100.0,
                              muls=np.array([1, 1]), score=92.0,  # imperfect
                              early_return=False, injected_ms={},
                              ref_job="hi"), optimal=True)
        c._priorities = {"hi": 1, "lo": 0}
        c.set_baseline("lo", 100.0, 0)
        acts = []
        for _ in range(10):
            acts = c.report_iteration("lo", 130.0)
        assert not acts  # pausing cannot fix structural contention


class TestStragglerMonitor:
    def test_trips_on_sustained_slowdown(self):
        from repro.runtime.straggler import StragglerMonitor
        events = []
        mon = StragglerMonitor(a_t=1.3, o_t=5,
                               on_straggler=lambda e: events.append(e))
        for _ in range(20):
            mon.report(0.10)  # healthy baseline
        tripped = False
        for _ in range(10):
            tripped = mon.report(0.20) or tripped  # 2x slowdown
        assert tripped and events

    def test_ignores_transients(self):
        from repro.runtime.straggler import StragglerMonitor
        mon = StragglerMonitor(a_t=1.3, o_t=5)
        for i in range(40):
            t = 0.2 if i % 10 == 0 else 0.1  # occasional spike
            assert not mon.report(t)


class TestCompressedGrads:
    def test_training_still_converges(self):
        from repro.data import SyntheticLM
        cfg = get_smoke_config("llama3_8b")
        opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=0, total_steps=30)
        state, _ = init_train_state(cfg, opt_cfg, KEY)
        step = jax.jit(build_train_step(cfg, opt_cfg, n_micro=1,
                                        compress_grads=True))
        ds = SyntheticLM(cfg.vocab, 16, 8, seed=0)
        losses = []
        for i in range(10):
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.15  # int8 grads still learn

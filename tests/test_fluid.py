"""Backend parity of the fluid rate engine (``core/fluid.py``).

Three layers of evidence that the backend swap is safe:

  * property/seeded-random parity — random star and leaf–spine fill
    problems solved by the python oracle vs the vectorized jnp path vs the
    interpreted Pallas kernel must agree to float32 tolerance;
  * scenario-level parity — the pinned snapshots' actual fill problems
    (``LinkView.fill_problem``) through all three backends;
  * bit-for-bit goldens — ``Policy(sim_backend='python')`` must reproduce
    the default simulation EXACTLY on every pinned scenario (S1–S5, F2,
    F4, J1, D1, D2): the refactor moved the seed's per-flow loop, it must
    not have changed it.

Plus the machinery that rides along: incremental per-component memoization
(``FluidStats``), size-bucketed corpus batching (``fill_corpus``), the
production-trace generator, the ``Policy.sim_backend`` knob, process-mode
sweeps and the content-keyed sweep cache.
"""
import json
import math
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.metronome_testbed import (DYNAMIC_SNAPSHOTS, MODEL_FLEET,
                                             dynamic_scenario, make_snapshot,
                                             snapshot_scenario)
from repro.core import fluid, rotation
from repro.core.contention import LinkView
from repro.core.controller import StopAndWaitController
from repro.core.experiment import Policy, run, sweep
from repro.core.framework import SchedulingFramework
from repro.core.scheduler import MetronomePlugin
from repro.core.simulator import SimConfig
from repro.core.trace import (active_jobs_at, generate_production_trace,
                              TraceJobSpec)

CFG = SimConfig(duration_ms=20_000.0, seed=3, jitter_std=0.01)
N_ITER = 30

# float32 fixed point with FILL_EPS termination: the vectorized backends
# track the float64 oracle to well under a Kbps on Gbps-scale rates
TOL = 5e-3

PINNED = ["S1", "S2", "S3", "S4", "S5", "F2", "F4", "J1"]


def scheduled(sid):
    """Schedule snapshot ``sid`` under Metronome; return (cluster, fw, wls)."""
    cluster, wls, _ = make_snapshot(sid, n_iterations=50)
    fw = SchedulingFramework(
        cluster, MetronomePlugin(controller=StopAndWaitController()))
    for wl in wls:
        assert fw.schedule_workload(wl)
    return cluster, fw, wls


def random_problem(rng, *, fabric):
    """One random fill problem: a star (every path one host link) or a
    2-leaf fabric (spanning flows add their leaf uplink to the path)."""
    n_hosts = int(rng.integers(2, 7))
    n_flows = int(rng.integers(1, 13))
    demands = rng.uniform(0.2, 30.0, size=n_flows)
    caps = {f"h{k}": float(rng.uniform(1.0, 40.0)) for k in range(n_hosts)}
    paths = []
    for _ in range(n_flows):
        h = int(rng.integers(n_hosts))
        path = [f"h{h}"]
        if fabric and rng.random() < 0.5:
            path.append(f"uplink:{h % 2}")
        paths.append(tuple(path))
    if fabric:
        caps["uplink:0"] = float(rng.uniform(2.0, 25.0))
        caps["uplink:1"] = float(rng.uniform(2.0, 25.0))
    return demands, paths, caps


def solve_all_backends(demands, paths, caps):
    """(python, jnp, interpreted-kernel) rate vectors of one problem."""
    golden = fluid.fill_python(np.asarray(demands, dtype=float), paths, caps)
    mat = fluid.problem_matrix(demands, paths, caps)[:3]
    via_jnp = fluid.fill_many([mat], backend="jnp")[0]
    via_kernel = fluid.fill_many([mat], backend="kernel", interpret=True)[0]
    return golden, via_jnp, via_kernel


# ---------------------------------------------------------------------------
# random-problem parity: seeded sweep + hypothesis property
# ---------------------------------------------------------------------------

class TestRandomParity:
    @pytest.mark.parametrize("fabric", [False, True],
                             ids=["star", "fabric"])
    def test_seeded_random_problems(self, fabric):
        """40 seeded random problems per topology family: every backend
        within float32 tolerance of the float64 oracle."""
        rng = np.random.default_rng(20260808 + fabric)
        for _ in range(40):
            demands, paths, caps = random_problem(rng, fabric=fabric)
            golden, via_jnp, via_kernel = solve_all_backends(
                demands, paths, caps)
            np.testing.assert_allclose(via_jnp, golden, atol=TOL, rtol=0)
            np.testing.assert_allclose(via_kernel, golden, atol=TOL, rtol=0)

    def test_rates_feasible_and_demand_capped(self):
        """Vectorized rates never exceed demands or link capacities."""
        rng = np.random.default_rng(7)
        for _ in range(20):
            demands, paths, caps = random_problem(rng, fabric=True)
            mat = fluid.problem_matrix(demands, paths, caps)[:3]
            rates = fluid.fill_many([mat], backend="jnp")[0]
            assert np.all(rates <= np.asarray(demands) + TOL)
            load = {}
            for r, p in zip(rates, paths):
                for l in p:
                    load[l] = load.get(l, 0.0) + r
            for l, used in load.items():
                assert used <= caps[l] + TOL * len(paths)

    @given(data=st.data())
    @settings(max_examples=25, deadline=None)
    def test_property_random_topology(self, data):
        """Hypothesis drives the same generator through a drawn seed and
        topology family (skips when hypothesis is stubbed out)."""
        seed = data.draw(st.integers(min_value=0, max_value=2**31 - 1))
        fabric = data.draw(st.booleans())
        rng = np.random.default_rng(seed)
        demands, paths, caps = random_problem(rng, fabric=fabric)
        golden, via_jnp, via_kernel = solve_all_backends(demands, paths, caps)
        np.testing.assert_allclose(via_jnp, golden, atol=TOL, rtol=0)
        np.testing.assert_allclose(via_kernel, golden, atol=TOL, rtol=0)


# ---------------------------------------------------------------------------
# scenario-level parity on the pinned snapshots
# ---------------------------------------------------------------------------

class TestScenarioParity:
    @pytest.mark.parametrize("sid", ["S2", "S4", "F2", "F4", "J1"])
    def test_pinned_fill_problems(self, sid):
        """The snapshots' real fill problems (post-Metronome placement)
        agree across backends."""
        cluster, fw, wls = scheduled(sid)
        view = LinkView.from_registry(cluster, fw.registry)
        jobs = [j for wl in wls for j in wl.jobs]
        demands, paths, caps = view.fill_problem(jobs)
        assert demands, f"{sid}: no flows — parity test is vacuous"
        golden, via_jnp, via_kernel = solve_all_backends(demands, paths, caps)
        np.testing.assert_allclose(via_jnp, golden, atol=TOL, rtol=0)
        np.testing.assert_allclose(via_kernel, golden, atol=TOL, rtol=0)

    def test_engine_fill_matches_oracle(self):
        """FluidEngine.fill dispatches per backend onto the same problem."""
        cluster, fw, wls = scheduled("F4")
        view = LinkView.from_registry(cluster, fw.registry)
        demands, paths, caps = view.fill_problem(
            [j for wl in wls for j in wl.jobs])
        golden = fluid.FluidEngine("python").fill(demands, paths, caps)
        for backend in ("jnp", "kernel"):
            got = fluid.FluidEngine(backend).fill(demands, paths, caps)
            np.testing.assert_allclose(got, golden, atol=TOL, rtol=0)

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="unknown fluid backend"):
            fluid.FluidEngine("numpy")
        with pytest.raises(ValueError, match="vectorized backend"):
            fluid.fill_many([], backend="python") or fluid.fill_many(
                [(np.zeros(1, np.float32), np.zeros((1, 1), np.float32),
                  np.ones(1, np.float32))], backend="python")


# ---------------------------------------------------------------------------
# bit-for-bit goldens: backend='python' IS the seed path
# ---------------------------------------------------------------------------

def _sim_equal(a, b):
    """Bit-for-bit SimResult equality (NaN-aware float maps)."""
    def eq(x, y):
        if isinstance(x, float) and isinstance(y, float):
            return (math.isnan(x) and math.isnan(y)) or x == y
        return x == y

    def map_eq(x, y):
        return set(x) == set(y) and all(eq(x[k], y[k]) for k in x)

    assert a.durations_ms == b.durations_ms
    assert map_eq(a.time_per_1000_iters_s, b.time_per_1000_iters_s)
    assert map_eq(a.link_utilization, b.link_utilization)
    assert eq(a.avg_bw_utilization, b.avg_bw_utilization)
    assert a.readjustments == b.readjustments
    assert map_eq(a.finish_times_ms, b.finish_times_ms)
    assert eq(a.total_completion_ms, b.total_completion_ms)
    assert a.iterations_done == b.iterations_done
    assert a.reconfigurations == b.reconfigurations


class TestPythonBackendGoldens:
    @pytest.mark.parametrize("sid", PINNED)
    def test_static_snapshots(self, sid):
        scen = snapshot_scenario(sid, n_iterations=N_ITER)
        default = run(scen, Policy("metronome"), CFG)
        explicit = run(scen, Policy("metronome", sim_backend="python"), CFG)
        _sim_equal(default.sim, explicit.sim)
        assert default.accepted == explicit.accepted
        assert default.placements == explicit.placements

    @pytest.mark.parametrize("sid", DYNAMIC_SNAPSHOTS)
    def test_dynamic_snapshots(self, sid):
        scen = dynamic_scenario(sid, n_iterations=N_ITER)
        default = run(scen, Policy("metronome"), CFG)
        explicit = run(scen, Policy("metronome", sim_backend="python"), CFG)
        _sim_equal(default.sim, explicit.sim)
        assert default.accepted == explicit.accepted

    def test_policy_name_encodes_backend(self):
        assert Policy("metronome").name == "metronome"
        assert Policy("metronome", sim_backend="jnp").name == \
            "metronome-fluid=jnp"
        assert Policy("metronome", sim_backend="python").name == \
            "metronome-fluid=python"


# ---------------------------------------------------------------------------
# incremental per-component memoization
# ---------------------------------------------------------------------------

class _Flow:
    def __init__(self, node, demand, links):
        self.node = node
        self.demand_gbps = demand
        self.links = links
        self.rate_gbps = 0.0


class TestIncrementalEngine:
    def _flows(self):
        # two affinity components: {hA} and {hB, uplink:1}
        return [_Flow("hA", 10.0, ("hA",)),
                _Flow("hA", 6.0, ("hA",)),
                _Flow("hB", 8.0, ("hB", "uplink:1")),
                _Flow("hB", 5.0, ("hB",))]

    def test_components(self):
        comps = fluid.affinity_components(
            [f.links for f in self._flows()])
        assert comps == [[0, 1], [2, 3]]

    def test_memo_hits_and_selective_invalidation(self):
        eng = fluid.FluidEngine("python", incremental=True)
        caps = {"hA": 12.0, "hB": 10.0, "uplink:1": 6.0}
        flows = self._flows()
        eng.assign(flows, caps.__getitem__)
        assert (eng.stats.misses, eng.stats.hits) == (2, 0)
        first = [f.rate_gbps for f in flows]

        eng.assign(flows, caps.__getitem__)  # unchanged: both memoized
        assert (eng.stats.misses, eng.stats.hits) == (2, 2)
        assert [f.rate_gbps for f in flows] == first

        caps["uplink:1"] = 3.0  # touches ONLY the {hB} component
        eng.assign(flows, caps.__getitem__)
        assert (eng.stats.misses, eng.stats.hits) == (3, 3)
        assert [f.rate_gbps for f in flows[:2]] == first[:2]
        assert flows[2].rate_gbps < first[2]

    def test_incremental_matches_full_solve(self):
        caps = {"hA": 12.0, "hB": 10.0, "uplink:1": 6.0}
        inc, full = self._flows(), self._flows()
        fluid.FluidEngine("python", incremental=True).assign(
            inc, caps.__getitem__)
        fluid.FluidEngine("python", incremental=False).assign(
            full, caps.__getitem__)
        # disjoint single-link components: per-component == global here
        for a, b in zip(inc, full):
            assert a.rate_gbps == pytest.approx(b.rate_gbps, abs=1e-9)

    def test_backend_defaults(self):
        assert fluid.FluidEngine("python").incremental is False
        assert fluid.FluidEngine("jnp").incremental is True
        assert fluid.FluidEngine("kernel").incremental is True


# ---------------------------------------------------------------------------
# corpus batching
# ---------------------------------------------------------------------------

class TestFillCorpus:
    def test_order_restored_across_buckets(self):
        """fill_corpus sorts by flow count internally; results must come
        back in caller order and equal the one-call fill_many answers."""
        rng = np.random.default_rng(11)
        probs, mats = [], []
        for _ in range(17):
            d, p, c = random_problem(rng, fabric=True)
            probs.append((d, p, c))
            mats.append(fluid.problem_matrix(d, p, c)[:3])
        want = fluid.fill_many(mats, backend="jnp")
        got = fluid.fill_corpus(mats, backend="jnp", chunk=4)
        assert len(got) == len(want)
        for g, w, (d, p, c) in zip(got, want, probs):
            np.testing.assert_allclose(g, w, atol=TOL, rtol=0)
            np.testing.assert_allclose(
                g, fluid.fill_python(np.asarray(d, dtype=float), p, c),
                atol=TOL, rtol=0)

    def test_empty_corpus(self):
        assert fluid.fill_corpus([], backend="jnp") == []


# ---------------------------------------------------------------------------
# production trace generator
# ---------------------------------------------------------------------------

class TestProductionTrace:
    def test_exact_count_and_determinism(self):
        a = generate_production_trace(MODEL_FLEET, n_jobs=500, seed=42)
        b = generate_production_trace(MODEL_FLEET, n_jobs=500, seed=42)
        c = generate_production_trace(MODEL_FLEET, n_jobs=500, seed=43)
        assert len(a) == 500
        assert a == b
        assert a != c

    def test_sorted_and_fields_sane(self):
        trace = generate_production_trace(MODEL_FLEET, n_jobs=400, seed=1)
        times = [s.submit_time_s for s in trace]
        assert times == sorted(times)
        for s in trace:
            assert 60.0 <= s.duration_s <= 6 * 3600.0
            assert s.n_tasks >= 1
            assert s.model in MODEL_FLEET

    def test_diurnal_peak_vs_trough(self):
        """Arrival rate at the 14:00 peak beats the 02:00 trough clearly
        (amplitude 0.6 -> true ratio 4; demand a comfortable 2x)."""
        trace = generate_production_trace(MODEL_FLEET, n_jobs=6000, seed=5)

        def count(center_h):
            lo, hi = (center_h - 2) * 3600.0, (center_h + 2) * 3600.0
            return sum(1 for s in trace if lo <= s.submit_time_s < hi)

        assert count(14.0) > 2 * count(2.0)

    def test_heavy_tail_and_priority_mix(self):
        trace = generate_production_trace(MODEL_FLEET, n_jobs=3000, seed=9)
        durs = np.array([s.duration_s for s in trace])
        assert np.max(durs) > 8 * np.median(durs)  # lognormal right tail
        frac_hi = np.mean([bool(s.priority) for s in trace])
        assert 0.2 < frac_hi < 0.4  # high_priority_frac = 0.3
        mults = {s.n_tasks for s in trace}
        assert len(mults) >= 3  # task multipliers actually mix sizes

    def test_active_jobs_at(self):
        trace = [TraceJobSpec("M", 0.0, 10.0, 0, 1),
                 TraceJobSpec("M", 5.0, 10.0, 0, 1),
                 TraceJobSpec("M", 20.0, 1.0, 0, 1)]
        assert active_jobs_at(trace, 1.0) == [0]
        assert active_jobs_at(trace, 7.0) == [0, 1]
        assert active_jobs_at(trace, 12.0) == [1]
        assert active_jobs_at(trace, 30.0) == []


# ---------------------------------------------------------------------------
# per-family batched link solves (Score phase)
# ---------------------------------------------------------------------------

class TestSolveLinkBatch:
    @pytest.mark.parametrize("sid", ["S2", "F4", "J1"])
    def test_batch_equals_individual(self, sid):
        cluster, fw, _ = scheduled(sid)
        view = LinkView.from_registry(cluster, fw.registry)
        links = sorted(view.planning_links())
        specs = [(view, lid) for lid in links]
        batched = rotation.solve_link_batch(specs, fw.registry, mode="fast")
        for (score, scheme), lid in zip(batched, links):
            want_score, want = rotation.solve_link(view, fw.registry, lid,
                                                   mode="fast")
            assert score == want_score
            assert (scheme is None) == (want is None)
            if scheme is not None:
                assert scheme.jobs == want.jobs
                assert np.array_equal(scheme.shifts_slots, want.shifts_slots)
                assert scheme.base_ms == want.base_ms
                assert scheme.injected_ms == want.injected_ms


# ---------------------------------------------------------------------------
# process-mode sweeps + content-keyed cache
# ---------------------------------------------------------------------------

class TestSweepInfra:
    GRID_CFG = SimConfig(duration_ms=6_000.0, seed=3, jitter_std=0.01)

    def _grid(self):
        return ([snapshot_scenario("S2", n_iterations=10)],
                [Policy("metronome"), Policy("default")])

    @pytest.mark.slow
    def test_process_mode_matches_serial(self):
        scenarios, policies = self._grid()
        serial = sweep(scenarios, policies, self.GRID_CFG)
        procs = sweep(scenarios, policies, self.GRID_CFG,
                      workers=2, mode="process")
        assert serial.to_json_dict(include_durations=True) == \
            procs.to_json_dict(include_durations=True)

    def test_bad_mode_rejected(self):
        with pytest.raises(ValueError, match="thread.*process"):
            sweep(*self._grid(), mode="threads")

    def test_cache_roundtrip_and_keying(self, tmp_path):
        from benchmarks import cache

        scenarios, policies = self._grid()
        key = cache.fingerprint_grid(scenarios, policies, self.GRID_CFG)
        assert key == cache.fingerprint_grid(scenarios, policies,
                                             self.GRID_CFG)
        # a policy knob changes the content key
        assert key != cache.fingerprint_grid(
            scenarios, [Policy("metronome", sim_backend="python")],
            self.GRID_CFG)
        # a sim-config change does too
        assert key != cache.fingerprint_grid(
            scenarios, policies, SimConfig(duration_ms=7_000.0, seed=3))

        assert cache.load(str(tmp_path), key) is None  # cold miss
        res = sweep(scenarios, policies, self.GRID_CFG)
        cache.store(str(tmp_path), key, res)
        back = cache.load(str(tmp_path), key)
        assert back is not None
        assert back.to_json_dict(include_durations=True) == \
            res.to_json_dict(include_durations=True)

        # corrupt entries are a miss, not a crash
        (path,) = [p for p in os.listdir(tmp_path) if key in p]
        with open(tmp_path / path, "w") as f:
            f.write("{not json")
        assert cache.load(str(tmp_path), key) is None


# ---------------------------------------------------------------------------
# diff_bench regression gates (scripts/diff_bench.py)
# ---------------------------------------------------------------------------

def _diff_bench():
    import importlib.util
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        "diff_bench", os.path.join(root, "scripts", "diff_bench.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestDiffBench:
    def test_sweep_gate(self, tmp_path):
        db = _diff_bench()
        doc = {"sweeps": [{"meta": {"origin": "bench_x"}, "cells": [
            {"scenario": "S2", "policy": "metronome", "status": "ok",
             "result": {"jct": 1.0, "samples": [1, 2, 3]}}]}]}
        assert db.diff_sweeps(doc, doc, 1e-6) == []
        drift = json.loads(json.dumps(doc))
        drift["sweeps"][0]["cells"][0]["result"]["jct"] = 1.5
        assert any("jct" in p for p in db.diff_sweeps(doc, drift, 1e-6))
        gone = {"sweeps": []}
        assert any("missing" in p for p in db.diff_sweeps(doc, gone, 1e-6))
        # list leaves compare as lengths only (trajectories are not pinned)
        jig = json.loads(json.dumps(doc))
        jig["sweeps"][0]["cells"][0]["result"]["samples"] = [9, 9, 9]
        assert db.diff_sweeps(doc, jig, 1e-6) == []

    def test_timing_and_trace_gates(self):
        db = _diff_bench()
        base = {"rows": [{"origin": "b", "name": "r", "us_per_call": 10.0}]}
        slow = {"rows": [{"origin": "b", "name": "r", "us_per_call": 900.0}]}
        assert db.diff_timings(base, base, 25.0) == []
        assert any("slower" in p for p in db.diff_timings(base, slow, 25.0))

        trace = {"rows": [
            {"name": "py", "backend": "python", "speedup_vs_python": 1.0},
            {"name": "jnp", "backend": "jnp", "speedup_vs_python": 60.0}]}
        assert db.diff_trace(trace, trace, 50.0) == []
        sagged = json.loads(json.dumps(trace))
        sagged["rows"][1]["speedup_vs_python"] = 8.0
        assert any("speedup" in p for p in db.diff_trace(trace, sagged, 50.0))

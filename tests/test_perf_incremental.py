"""Incremental scheduling hot path (ISSUE 5): epoch-tagged LinkView,
memoized joint planning, one-shot batched candidate scoring.

Four pillars:

  * epoch soundness — every mutation of the demand view (reserve/unreserve,
    dynamic events, capacity/background changes) advances the
    (cluster, registry) epoch, so :class:`repro.core.rotation.PlanCache`
    can never serve a stale result (D1/D2 event streams pinned);
  * memo bit-for-bit — Score with the planner memo enabled equals the
    unmemoized path exactly on every golden snapshot (S1-S5/F2/F4/J1):
    placements, global offsets and per-link shifts;
  * batched joint solving — joint_solve_batch (numpy and the stacked
    (C, L, R, S) kernel dispatch) equals per-problem joint_solve;
  * the timing-artifact schema (BENCH_sched_time.json) round-trips.
"""

import numpy as np
import pytest

from repro.configs.metronome_testbed import (
    dynamic_scenario, make_dynamic_snapshot, make_snapshot, snapshot_scenario)
from repro.core import rotation, scoring, geometry
from repro.core.cluster import Cluster, Node, Resources
from repro.core.contention import LinkView
from repro.core.controller import StopAndWaitController
from repro.core.events import (BackgroundFlowChange, LinkCapacityChange,
                               TrafficChange)
from repro.core.experiment import Policy, run, sweep
from repro.core.framework import SchedulingFramework
from repro.core.results import to_timing_dict, validate_timing_dict
from repro.core.scheduler import MetronomePlugin
from repro.core.simulator import ClusterSimulator, SimConfig
from repro.core.workload import Workload, make_job

GOLDEN_SIDS = ("S1", "S2", "S3", "S4", "S5", "F2", "F4", "J1")


def schedule_snapshot(sid, memo=True):
    cluster, wls, bg = make_snapshot(sid, n_iterations=50)
    ctrl = StopAndWaitController()
    plugin = MetronomePlugin(controller=ctrl, memo=memo)
    fw = SchedulingFramework(cluster, plugin)
    for wl in wls:
        fw.schedule_workload(wl)
    return cluster, fw, ctrl, plugin


# ---------------------------------------------------------------------------
# Epoch tagging and invalidation
# ---------------------------------------------------------------------------

class TestEpochs:
    def _small(self):
        nodes = [Node(f"n{i}", Resources(cpu=64, mem=512, gpu=8),
                      bw_gbps=25.0)
                 for i in range(2)]
        return Cluster(nodes)

    def test_schedule_and_evict_bump_epochs(self):
        cluster = self._small()
        fw = SchedulingFramework(cluster, MetronomePlugin())
        job = make_job("j", n_tasks=2, period_ms=100.0, duty=0.3,
                       bw_gbps=5.0)
        e0 = (cluster.epoch, fw.registry.epoch)
        assert fw.schedule_workload(Workload(name="w", jobs=[job]))
        e1 = (cluster.epoch, fw.registry.epoch)
        assert e1 != e0
        fw.evict_job(job)
        assert (cluster.epoch, fw.registry.epoch) != e1

    def test_view_epoch_capture(self):
        cluster = self._small()
        fw = SchedulingFramework(cluster, MetronomePlugin())
        view = LinkView.from_registry(cluster, fw.registry)
        assert view.epoch == (cluster.epoch, fw.registry.epoch)
        # a raw view (simulator-style) carries no epoch: caches disabled
        assert LinkView(cluster).epoch is None

    @pytest.mark.parametrize("event", [
        LinkCapacityChange(0.0, link="n0", allocatable_gbps=10.0),
        BackgroundFlowChange(0.0, link="n0", rate_gbps=8.0),
        BackgroundFlowChange(0.0, link="n0", rate_gbps=8.0,
                             adjust_allocatable=False),
    ])
    def test_events_bump_cluster_epoch(self, event):
        cluster = self._small()
        fw = SchedulingFramework(cluster, MetronomePlugin())
        sim = ClusterSimulator(cluster, [], SimConfig(duration_ms=1.0),
                               registry=fw.registry)
        before = cluster.epoch
        sim._apply_event(event)
        assert cluster.epoch > before

    def test_traffic_change_bumps_registry_epoch(self):
        cluster = self._small()
        ctrl = StopAndWaitController()
        fw = SchedulingFramework(cluster, MetronomePlugin(controller=ctrl))
        job = make_job("j", n_tasks=2, period_ms=100.0, duty=0.3,
                       bw_gbps=5.0)
        fw.schedule_workload(Workload(name="w", jobs=[job]))
        sim = ClusterSimulator(cluster, [job], SimConfig(duration_ms=1.0),
                               controller=ctrl, registry=fw.registry)
        before = fw.registry.epoch
        sim._apply_event(TrafficChange(0.0, job="j", duty_mult=1.5))
        assert fw.registry.epoch > before

    def test_plan_cache_epoch_scoping(self):
        cache = rotation.PlanCache()
        cache.put((1, 1), "k", "v")
        assert cache.get((1, 1), "k") == "v"
        # ANY epoch advance clears the store: stale reuse is impossible
        assert cache.get((1, 2), "k") is None
        assert cache.get((1, 1), "k") is None  # even going "back"
        # epoch-less views bypass the cache entirely
        cache.put(None, "k", "v")
        assert cache.get(None, "k") is None

    def test_capacity_event_invalidates_scheduler_cache(self):
        """After a LinkCapacityChange the plugin's warmed cache entries are
        unreachable: the epoch moved, so the next Score re-solves against
        the new allocatable bandwidth."""
        cluster = self._small()
        ctrl = StopAndWaitController()
        plugin = MetronomePlugin(controller=ctrl)
        fw = SchedulingFramework(cluster, plugin)
        for i in range(2):
            j = make_job(f"j{i}", n_tasks=2, period_ms=100.0, duty=0.4,
                         bw_gbps=15.0)
            fw.schedule_workload(Workload(name=j.name, jobs=[j]))
        view = LinkView.from_registry(cluster, fw.registry)
        score0, scheme0 = rotation.solve_link(
            view, fw.registry, "n0", cache=plugin.plan_cache)
        assert plugin.plan_cache._store  # warmed
        sim = ClusterSimulator(cluster, [], SimConfig(duration_ms=1.0),
                               controller=ctrl, registry=fw.registry)
        sim._apply_event(LinkCapacityChange(0.0, link="n0",
                                            allocatable_gbps=12.0))
        fresh = LinkView.from_registry(cluster, fw.registry)
        assert fresh.epoch != view.epoch
        assert plugin.plan_cache.get(fresh.epoch, "anything") is None

    def test_cached_scheme_is_mutation_safe(self):
        """Consumers mutate LinkSchemes in place (controller eviction);
        cached copies must stay pristine."""
        cluster = self._small()
        fw = SchedulingFramework(cluster, MetronomePlugin())
        for i in range(2):
            j = make_job(f"j{i}", n_tasks=2, period_ms=100.0, duty=0.4,
                         bw_gbps=15.0)
            fw.schedule_workload(Workload(name=j.name, jobs=[j]))
        cache = rotation.PlanCache()
        view = LinkView.from_registry(cluster, fw.registry)
        _s, first = rotation.solve_link(view, fw.registry, "n0", cache=cache)
        first.jobs.pop()
        first.shifts_slots += 99
        _s, again = rotation.solve_link(view, fw.registry, "n0", cache=cache)
        assert cache.hits >= 1
        assert len(again.jobs) == len(first.jobs) + 1
        assert not np.array_equal(again.shifts_slots, first.shifts_slots)


# ---------------------------------------------------------------------------
# Memoized Score is bit-for-bit the unmemoized Score (goldens)
# ---------------------------------------------------------------------------

class TestMemoBitForBit:
    @pytest.mark.parametrize("sid", GOLDEN_SIDS)
    def test_schedule_identical(self, sid):
        _, fw_m, ctrl_m, plugin_m = schedule_snapshot(sid, memo=True)
        _, fw_n, ctrl_n, _ = schedule_snapshot(sid, memo=False)
        place_m = {uid: t.node for uid, t in fw_m.registry.tasks.items()}
        place_n = {uid: t.node for uid, t in fw_n.registry.tasks.items()}
        assert place_m == place_n
        assert ctrl_m.global_offsets_ms == ctrl_n.global_offsets_ms
        assert set(ctrl_m.links) == set(ctrl_n.links)
        for lid in ctrl_m.links:
            a, b = ctrl_m.links[lid].scheme, ctrl_n.links[lid].scheme
            assert a.jobs == b.jobs
            assert np.array_equal(a.shifts_slots, b.shifts_slots)
            assert a.base_ms == b.base_ms
            assert a.score == b.score
        # the memo actually fired somewhere across the goldens
        if sid in ("S1", "S2", "F2", "F4", "J1"):
            assert plugin_m.plan_cache.hits + plugin_m.plan_cache.misses > 0

    @pytest.mark.parametrize("sid", ("D1", "D2"))
    def test_dynamic_event_stream_identical(self, sid):
        """Full D1/D2 runs (capacity + background fluctuation mid-run) with
        the memo on equal the unmemoized run exactly — if the epoch ever
        failed to advance, a stale scheme would change the realignments and
        the measured durations."""
        results = []
        for memo in (True, False):
            cluster, wls, bg, events = make_dynamic_snapshot(
                sid, n_iterations=60)
            ctrl = StopAndWaitController()
            plugin = MetronomePlugin(controller=ctrl, memo=memo)
            fw = SchedulingFramework(cluster, plugin)
            jobs = []
            for wl in wls:
                assert fw.schedule_workload(wl)
                jobs.extend(wl.jobs)
            ctrl.run_offline_recalculation(fw.registry, cluster)
            sim = ClusterSimulator(
                cluster, jobs, SimConfig(duration_ms=60_000.0, seed=3),
                controller=ctrl, background=bg, registry=fw.registry,
                events=events)
            res = sim.run()
            results.append((res.durations_ms, res.finish_times_ms,
                            res.readjustments, res.reconfigurations,
                            dict(ctrl.global_offsets_ms)))
        assert results[0] == results[1]


# ---------------------------------------------------------------------------
# Batched joint solving == per-problem joint solving
# ---------------------------------------------------------------------------

class TestJointBatch:
    def _j1_specs(self):
        cluster, wls, bg = make_snapshot("J1", n_iterations=50)
        ctrl = StopAndWaitController()
        fw = SchedulingFramework(cluster, MetronomePlugin(controller=ctrl))
        for wl in wls:
            fw.schedule_workload(wl)
        view = LinkView.from_registry(cluster, fw.registry)
        links = [l for l in view.planning_links()
                 if rotation.solve_link(view, fw.registry, l)[1] is not None]
        return view, fw.registry, links

    def test_batch_equals_individual(self):
        view, registry, links = self._j1_specs()
        single = rotation.joint_solve(view, registry, links)
        batch = rotation.joint_solve_batch(
            [(view, links), (view, links)], registry)
        assert len(batch) == 2
        for jr in batch:
            assert jr is not None
            assert jr.jobs == single.jobs
            assert np.array_equal(jr.shifts, single.shifts)
            assert jr.score == single.score
            assert jr.offsets_ms == single.offsets_ms

    def test_batch_warms_cache(self):
        view, registry, links = self._j1_specs()
        cache = rotation.PlanCache()
        rotation.joint_solve_batch([(view, links)], registry, cache=cache)
        hits_before = cache.hits
        again = rotation.joint_solve(view, registry, links, cache=cache)
        assert cache.hits == hits_before + 1
        single = rotation.joint_solve(view, registry, links)
        assert np.array_equal(again.shifts, single.shifts)

    def test_cache_key_includes_solver_selection(self):
        """max_exhaustive selects exhaustive vs coordinate descent, which
        produce different shifts — a cached exhaustive result must never be
        served to a coordinate-descent request under the same epoch."""
        view, registry, links = self._j1_specs()
        cache = rotation.PlanCache()
        rotation.joint_solve(view, registry, links, cache=cache)
        cd_cached = rotation.joint_solve(view, registry, links, cache=cache,
                                         max_exhaustive=0)
        cd_fresh = rotation.joint_solve(view, registry, links,
                                        max_exhaustive=0)
        assert np.array_equal(cd_cached.shifts, cd_fresh.shifts)

    def test_batch_kernel_backend_matches_numpy(self):
        view, registry, links = self._j1_specs()
        res_np = rotation.joint_solve_batch(
            [(view, links)], registry, backend="numpy")[0]
        res_k = rotation.joint_solve_batch(
            [(view, links)], registry, backend="kernel")[0]
        assert np.array_equal(res_np.shifts, res_k.shifts)
        assert res_np.score == pytest.approx(res_k.score, abs=1e-4)


# ---------------------------------------------------------------------------
# Candidate-batched multi-link kernel parity
# ---------------------------------------------------------------------------

class TestBatchKernelParity:
    def _problem(self, seed=0, c=3, l=3):
        rng = np.random.default_rng(seed)
        pats = geometry.pattern_matrix([1, 1, 2], [0.3, 0.25, 0.2], 72)
        banks = scoring.rolled_bank(pats, [1, 24, 36])
        bw = rng.uniform(5.0, 20.0, size=(c, l, 3))
        caps = rng.uniform(18.0, 30.0, size=(c, l))
        base = bw[:, :, 0:1] * pats[0][None, None, :]
        bank_a = bw[:, :, 1, None, None] * banks[1][None, None]
        bank_b = bw[:, :, 2, None, None] * banks[2][None, None]
        return base, bank_a, bank_b, caps

    def test_batch_ref_matches_per_candidate_ref(self):
        from repro.kernels import ref
        base, bank_a, bank_b, caps = self._problem()
        want = np.asarray(ref.metronome_score_multilink_batch_ref(
            base, bank_a, bank_b, caps))
        for ci in range(base.shape[0]):
            per = np.asarray(ref.metronome_score_multilink_ref(
                base[ci], bank_a[ci], bank_b[ci], caps[ci]))
            assert np.allclose(want[ci], per, atol=1e-5)

    def test_interpret_kernel_matches_ref(self):
        from repro.kernels import ops as kops
        from repro.kernels import ref
        base, bank_a, bank_b, caps = self._problem(seed=1)
        got = kops.score_multilink_batch(base, bank_a, bank_b, caps,
                                         interpret=True)
        want = np.asarray(ref.metronome_score_multilink_batch_ref(
            base, bank_a, bank_b, caps))
        assert got.shape == (3, 24, 36)
        assert np.allclose(got, want, atol=1e-4)

    def test_zero_demand_padding_links_are_neutral(self):
        from repro.kernels import ref
        base, bank_a, bank_b, caps = self._problem(seed=2, l=2)
        pad = lambda x: np.concatenate(  # noqa: E731
            [x, np.zeros_like(x[:, :1])], axis=1)
        caps_pad = np.concatenate(
            [caps, np.ones_like(caps[:, :1])], axis=1)
        want = np.asarray(ref.metronome_score_multilink_batch_ref(
            base, bank_a, bank_b, caps))
        got = np.asarray(ref.metronome_score_multilink_batch_ref(
            pad(base), pad(bank_a), pad(bank_b), caps_pad))
        assert np.allclose(got, want, atol=1e-6)


# ---------------------------------------------------------------------------
# Parallel sweep == serial sweep
# ---------------------------------------------------------------------------

class TestParallelSweep:
    CFG = SimConfig(duration_ms=8_000.0, seed=3, jitter_std=0.01)

    def test_workers_identical_to_serial(self):
        scenarios = [snapshot_scenario("S2", n_iterations=20),
                     dynamic_scenario("D1", n_iterations=20)]
        policies = [Policy(scheduler="metronome"),
                    Policy(scheduler="default")]
        serial = sweep(scenarios, policies, self.CFG)
        threaded = sweep(scenarios, policies, self.CFG, workers=3)
        assert serial.to_json_dict() == threaded.to_json_dict()
        # row-major cell order preserved
        order = [(c.scenario, c.policy) for c in threaded.cells]
        assert order == [(s.name, p.name) for s in scenarios
                         for p in policies]

    def test_workers_preserve_error_isolation(self):
        from repro.core.experiment import Scenario

        def boom():
            raise RuntimeError("boom")

        scenarios = [Scenario(name="bad", build=boom),
                     snapshot_scenario("S2", n_iterations=10)]
        policies = [Policy(scheduler="default")]
        res = sweep(scenarios, policies, self.CFG, workers=2)
        assert [c.status for c in res.cells] == ["error", "ok"]
        assert "boom" in res.cells[0].error


# ---------------------------------------------------------------------------
# Timing artifact schema
# ---------------------------------------------------------------------------

class TestTimingArtifact:
    def test_roundtrip_valid(self):
        rows = [{"name": "fig16_sched_metronome_2jobs",
                 "us_per_call": 6400.0, "derived": "ms_per_pod=3.20",
                 "origin": "sched_time"}]
        doc = to_timing_dict(rows, smoke=True)
        assert validate_timing_dict(doc) == []
        assert doc["kind"] == "timing" and doc["smoke"] is True

    def test_validation_catches_drift(self):
        doc = to_timing_dict(
            [{"name": "x", "us_per_call": 1.0, "derived": "", "origin": ""}])
        assert validate_timing_dict({}) != []
        bad = dict(doc)
        bad["rows"] = [{"name": "", "us_per_call": "nope"}]
        problems = validate_timing_dict(bad)
        assert any("name" in p for p in problems)
        assert any("us_per_call" in p for p in problems)
        assert any("derived" in p for p in problems)

    def test_emit_rows_recorded(self):
        import benchmarks.common as common
        before = len(common.RECORDED_EMITS)
        old_origin = common.CURRENT_ORIGIN
        common.CURRENT_ORIGIN = "unit-test"
        try:
            common.emit("unit_row", 12.5, "k=v")
        finally:
            common.CURRENT_ORIGIN = old_origin
        row = common.RECORDED_EMITS[-1]
        assert len(common.RECORDED_EMITS) == before + 1
        assert row == {"name": "unit_row", "us_per_call": 12.5,
                       "derived": "k=v", "origin": "unit-test"}
        doc = to_timing_dict([row])
        assert validate_timing_dict(doc) == []
        common.RECORDED_EMITS.pop()

"""Serving demo: prefill a batch of prompts, then greedy-decode with the
KV cache / recurrent state — the serve_step the dry-run lowers at
(arch x decode_32k) scale.

Run:  PYTHONPATH=src python examples/serve_decode.py --arch llama3-8b
      (smoke-size config on CPU; same code path as the full config)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import init_model, prefill
from repro.runtime.steps import build_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params, _ = init_model(cfg, key)

    b, s = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (b, s), 0, cfg.vocab)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["frames"] = jax.random.normal(
            key, (b, max(s // cfg.enc_frames_ratio, 1), cfg.d_model),
            jnp.float32)

    t0 = time.perf_counter()
    logits, cache = jax.jit(
        lambda p, t: prefill(p, cfg, t, max_len=s + args.gen, **kwargs)
    )(params, prompts)
    print(f"prefill {b}x{s}: {(time.perf_counter()-t0)*1e3:.0f} ms")

    serve = jax.jit(build_serve_step(cfg))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = serve(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.gen - 1} steps x batch {b}: "
          f"{dt / (args.gen - 1) * 1e3:.1f} ms/token/batch")
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()

"""Full-trace cluster simulation: Metronome vs Default vs Diktyo vs Ideal.

Reproduces the paper's Fig. 10 experiment shape: a Gavel-style trace of
training jobs arrives online; each scheduler places (and Metronome
interleaves) them; we report TCT, bandwidth utilization, and per-priority
iteration-time ratios.

Run:  PYTHONPATH=src python examples/cluster_sim.py [--jobs 10] [--seed 1]
"""
import argparse

import numpy as np

from repro.configs.metronome_testbed import MODEL_FLEET, make_snapshot
from repro.core.cluster import make_fabric_cluster
from repro.core.harness import run_trace_experiment
from repro.core.simulator import SimConfig
from repro.core.trace import cluster_load, generate_trace, trace_to_jobs
from repro.core.workload import Workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=10)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--duration-s", type=float, default=1800.0)
    ap.add_argument("--fabric", type=float, default=None, metavar="RATIO",
                    help="run on a 2-leaf fabric with this oversubscription "
                         "ratio instead of the paper's star testbed")
    args = ap.parse_args()

    trace = generate_trace(MODEL_FLEET, duration_s=args.duration_s,
                           total_gpus=13, target_load=0.85, seed=args.seed,
                           job_duration_range_s=(120, 240))[: args.jobs]
    print(f"trace: {len(trace)} jobs, load="
          f"{cluster_load(trace, 13, args.duration_s):.2f}")
    cfg = SimConfig(duration_ms=1_200_000, seed=0, jitter_std=0.01)

    rows = []
    for sched in ("metronome", "default", "diktyo", "ideal"):
        if args.fabric is not None:
            cluster = make_fabric_cluster(n_leaves=2, hosts_per_leaf=2,
                                          oversubscription=args.fabric)
        else:
            cluster, _, _ = make_snapshot("S1")
        jobs = trace_to_jobs(trace, MODEL_FLEET, time_scale=1.0)
        wls = [Workload(name=j.name, jobs=[j]) for j in jobs]
        for w in wls:
            for j in w.jobs:
                j.workload = w.name
                for t in j.tasks:
                    t.workload = w.name
        res = run_trace_experiment(sched, cluster, wls, cfg)
        rows.append((sched, res.sim.total_completion_ms / 1e3,
                     res.sim.avg_bw_utilization, res.sim.readjustments))
    print(f"\n{'scheduler':12s} {'TCT (s)':>10s} {'avg BW util':>12s} "
          f"{'readjusts':>10s}")
    for sched, tct, gamma, readj in rows:
        print(f"{sched:12s} {tct:10.1f} {gamma:12.3f} {readj:10d}")
    me = rows[0][1]
    de = rows[1][1]
    print(f"\nMetronome finishes {de - me:+.1f}s relative to Default "
          f"({100 * (1 - me / de):.1f}% faster)")


if __name__ == "__main__":
    main()

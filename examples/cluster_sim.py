"""Full-trace cluster simulation: Metronome vs Default vs Diktyo vs Ideal.

Reproduces the paper's Fig. 10 experiment shape through the declarative
API: a Gavel-style trace becomes ONE trace-mode Scenario (online arrivals,
queueing, eviction) and the mechanisms are a Policy list — including the
controller ablations that only the new API can apply to trace runs
(``--no-joint`` / ``--no-reconfigure``).

Run:  PYTHONPATH=src python examples/cluster_sim.py [--jobs 10] [--seed 1]
"""
import argparse

from repro.configs.metronome_testbed import MODEL_FLEET, trace_scenario
from repro.core.cluster import make_fabric_cluster
from repro.core.experiment import Policy, sweep
from repro.core.simulator import SimConfig
from repro.core.trace import cluster_load, generate_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=10)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--duration-s", type=float, default=1800.0)
    ap.add_argument("--fabric", type=float, default=None, metavar="RATIO",
                    help="run on a 2-leaf fabric with this oversubscription "
                         "ratio instead of the paper's star testbed")
    ap.add_argument("--no-joint", action="store_true",
                    help="ablate the fabric-wide joint rotation planner "
                         "(legacy uplink-wins tie-break)")
    ap.add_argument("--no-reconfigure", action="store_true",
                    help="ablate the section III-C reconfiguration loop")
    args = ap.parse_args()

    trace = generate_trace(MODEL_FLEET, duration_s=args.duration_s,
                           total_gpus=13, target_load=0.85, seed=args.seed,
                           job_duration_range_s=(120, 240))[: args.jobs]
    print(f"trace: {len(trace)} jobs, load="
          f"{cluster_load(trace, 13, args.duration_s):.2f}")
    cfg = SimConfig(duration_ms=1_200_000, seed=0, jitter_std=0.01)

    cluster_factory = None
    if args.fabric is not None:
        cluster_factory = lambda: make_fabric_cluster(  # noqa: E731
            n_leaves=2, hosts_per_leaf=2, oversubscription=args.fabric)
    scenario = trace_scenario(trace, open_ended=False,
                              cluster_factory=cluster_factory,
                              name="gavel-trace")
    policies = [
        Policy("metronome", rotation_joint=not args.no_joint,
               reconfigure=not args.no_reconfigure, label="metronome"),
        Policy("default"), Policy("diktyo"), Policy("ideal"),
    ]

    grid = sweep([scenario], policies, cfg)
    print(f"\n{'scheduler':12s} {'TCT (s)':>10s} {'avg BW util':>12s} "
          f"{'readjusts':>10s} {'queued':>7s}")
    for pol in policies:
        r = grid.get(scenario.name, pol.name)
        print(f"{pol.name:12s} {r.sim.total_completion_ms / 1e3:10.1f} "
              f"{r.sim.avg_bw_utilization:12.3f} "
              f"{r.sim.readjustments:10d} {len(r.rejected):7d}")
    me = grid.get(scenario.name, "metronome").sim.total_completion_ms / 1e3
    de = grid.get(scenario.name, "default").sim.total_completion_ms / 1e3
    print(f"\nMetronome finishes {de - me:+.1f}s relative to Default "
          f"({100 * (1 - me / de):.1f}% faster)")


if __name__ == "__main__":
    main()

"""End-to-end training driver: the full substrate on one page.

Trains a language model with the production code paths — synthetic data
pipeline, sharded train_step (grad accumulation + remat), AdamW, async
checkpointing with restart, and the Metronome integration (comm gate +
iteration reporting, exactly the paper's modified-DDP hookup).

Default is a ~8M-parameter model so the demo finishes in minutes on CPU;
``--preset 100m`` selects the ~110M-parameter configuration the assignment
names (same code path, bigger shapes — practical on real accelerators).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, latest_step
from repro.core.controller import StopAndWaitController
from repro.data import SyntheticLM
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.runtime.comm_gate import CommGate, IterationReporter
from repro.runtime.steps import build_train_step, init_train_state
from repro.sharding import use_rules
from repro.launch.mesh import make_host_mesh

PRESETS = {
    # ~8M params: fast CPU demo
    "tiny": ModelConfig(name="lm-tiny", family="dense", n_layers=4,
                        d_model=256, n_heads=4, n_kv=2, d_ff=1024,
                        vocab=8192),
    # ~110M params: the assignment's "~100M model" (GPT-2-small-like)
    "100m": ModelConfig(name="lm-100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv=4, d_ff=2048,
                        vocab=32000),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--crash-at", type=int, default=0,
                    help="simulate a failure at this step (restart demo)")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    ds = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=0)
    mesh = make_host_mesh(1, 1)

    # Metronome hookup: in a multi-tenant cluster the scheduler would assign
    # this job an offset; standalone the gate is a no-op but the code path
    # is identical to the gated run.
    controller = StopAndWaitController()
    gate = CommGate(controller, job="train-lm")
    reporter = IterationReporter(controller, "train-lm", priority=1)

    with use_rules(mesh):
        state, _ = init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0))
        from repro.models import param_count
        print(f"model: {cfg.name}  params={param_count(state.params):,}")
        step_fn = jax.jit(build_train_step(cfg, opt_cfg, args.n_micro))

        mgr = CheckpointManager(args.ckpt_dir, keep_n=2)
        start = 0
        if latest_step(args.ckpt_dir) is not None:
            state, start, _ = mgr.restore_latest(state)
            print(f"[fault-tolerance] resumed from checkpoint at step {start}")

        t_last = time.perf_counter()
        for step in range(start, args.steps):
            if args.crash_at and step == args.crash_at:
                print(f"[fault-tolerance] simulated crash at step {step}; "
                      "re-run the same command to resume")
                return
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
            gate.wait_for_slot()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])  # block: honest per-step timing
            dt = time.perf_counter() - t_last
            t_last = time.perf_counter()
            reporter.report(dt)
            if step % 20 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss {loss:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  {dt*1e3:.0f} ms/it",
                      flush=True)
            if (step + 1) % 100 == 0:
                mgr.save(step + 1, state)
        mgr.save(args.steps, state)
        mgr.wait()
    print("done — loss should have dropped by >1 nat from ~ln(vocab)")


if __name__ == "__main__":
    main()

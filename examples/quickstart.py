"""Quickstart: the declarative Scenario/Policy experiment API.

A Scenario says WHAT runs (cluster + workloads + background + events), a
Policy says HOW it is scheduled (mechanism + ablation knobs), and
``run(scenario, policy)`` / ``sweep(scenarios, policies)`` execute the
grid — the shape of the paper's whole evaluation (snapshots x mechanisms).

Shows, in one page: a two-job contention scenario, a policy grid with an
ablation (``rotation_mode='compact'``), the typed per-cell results, and the
JSON round-trip that backs the persisted ``BENCH_sweep.json`` artifact.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import json

from repro.core.cluster import Cluster, Node, Resources
from repro.core.experiment import Policy, Scenario, sweep
from repro.core.results import ExperimentResult
from repro.core.simulator import SimConfig
from repro.core.workload import HIGH, LOW, Workload, make_job


def build():
    """Fresh cluster + workloads per materialization (jobs are mutated by
    scheduling, so every run() cell gets its own copies)."""
    nodes = [Node(f"n{i}", Resources(cpu=32, mem=256, gpu=4), bw_gbps=25.0)
             for i in range(2)]
    cluster = Cluster(nodes)
    hi = make_job("train-hi", n_tasks=2, period_ms=100.0, duty=0.45,
                  bw_gbps=20.0, priority=HIGH, n_iterations=200)
    lo = make_job("train-lo", n_tasks=2, period_ms=100.0, duty=0.45,
                  bw_gbps=20.0, priority=LOW, submit_time_s=0.001,
                  n_iterations=200)
    wls = [Workload(name=j.name, jobs=[j]) for j in (hi, lo)]
    return cluster, wls


def main():
    scenario = Scenario(name="two-job-contention", build=build)
    policies = [
        Policy("metronome"),
        Policy("metronome", rotation_mode="compact", label="metronome-compact"),
        Policy("default"),
        Policy("ideal"),  # dedicated-cluster reference (contention-free bound)
    ]
    cfg = SimConfig(duration_ms=40_000.0, seed=0, jitter_std=0.01)

    grid = sweep([scenario], policies, cfg)
    print(f"{'policy':20s} {'hi s/1000':>10s} {'lo s/1000':>10s} "
          f"{'gamma':>7s} {'readj':>6s}")
    for pol in policies:
        r = grid.get(scenario.name, pol.name)
        print(f"{pol.name:20s} {r.mean_s_per_1000(r.high_priority):10.2f} "
              f"{r.mean_s_per_1000(r.low_priority):10.2f} "
              f"{r.sim.avg_bw_utilization:7.3f} {r.sim.readjustments:6d}")

    me = grid.get(scenario.name, "metronome")
    de = grid.get(scenario.name, "default")
    lo_gain = 100.0 * (1 - me.mean_s_per_1000(me.low_priority)
                       / de.mean_s_per_1000(de.low_priority))
    print(f"\nMetronome low-priority acceleration vs Default: "
          f"{lo_gain:.1f}%")

    # results are schema-versioned JSON: what benchmarks persist in CI
    payload = me.to_json_dict(include_durations=False)
    back = ExperimentResult.from_json_dict(json.loads(json.dumps(payload)))
    print(f"JSON round-trip: policy={back.policy!r}, "
          f"placements={back.placements}")

    # sim_backend swaps the simulator's fluid rate engine per cell
    # (DESIGN.md section 16): the default 'python' is the bit-for-bit
    # seed path; 'jnp' / 'kernel' solve the (flows x links) fixed point
    # vectorized — same rates to float32 tolerance, and the only way to
    # push 10k-job production traces (benchmarks/bench_trace_throughput).
    # The knob encodes itself in the cell name, so ablation grids stay
    # collision-free.
    vec = Policy("metronome", sim_backend="jnp")
    rv = sweep([scenario], [vec], cfg).get(scenario.name, vec.name)
    print(f"{vec.name}: lo s/1000 = "
          f"{rv.mean_s_per_1000(rv.low_priority):.2f} (vs "
          f"{me.mean_s_per_1000(me.low_priority):.2f} under 'python')")


if __name__ == "__main__":
    main()

"""Quickstart: schedule two contending training jobs with Metronome.

Shows the whole mechanism in one page: placement (Algorithm 1), the TDM
circle with assigned rotations, and the resulting interleaved bandwidth
demand (Eq. 4) vs the naive zero-shift overlap.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import geometry
from repro.core.cluster import Cluster, Node, Resources
from repro.core.controller import StopAndWaitController
from repro.core.framework import SchedulingFramework
from repro.core.scheduler import MetronomePlugin
from repro.core.workload import HIGH, LOW, Workload, make_job


def bar(v, cap, width=50):
    n = int(min(v / cap, 2.0) * width / 2)
    mark = "#" * min(n, width // 2) + "!" * max(0, n - width // 2)
    return mark.ljust(width)


def main():
    nodes = [Node(f"n{i}", Resources(cpu=32, mem=256, gpu=4), bw_gbps=25.0)
             for i in range(2)]
    cluster = Cluster(nodes)
    controller = StopAndWaitController()
    fw = SchedulingFramework(cluster, MetronomePlugin(controller=controller))

    hi = make_job("train-hi", n_tasks=2, period_ms=100.0, duty=0.45,
                  bw_gbps=20.0, priority=HIGH)
    lo = make_job("train-lo", n_tasks=2, period_ms=100.0, duty=0.45,
                  bw_gbps=20.0, priority=LOW, submit_time_s=1.0)
    for job in (hi, lo):
        ok = fw.schedule_workload(Workload(name=job.name, jobs=[job]))
        print(f"scheduled {job.name}: {ok}, placement={job.nodes_used()}")
    controller.run_offline_recalculation(fw.registry, cluster)

    print("\nassigned global offsets (ms):")
    for j in ("train-hi", "train-lo"):
        print(f"  {j}: {controller.job_offset_ms(j):.1f}")

    pats = geometry.pattern_matrix([1, 1], [0.45, 0.45], 72)
    bw = np.array([20.0, 20.0])
    shift_lo = geometry.delay_to_shift_slots(
        controller.job_offset_ms("train-lo"), 100.0)
    for title, shifts in (("NAIVE (zero shifts) — contention:", [0, 0]),
                          ("METRONOME (interleaved):", [0, shift_lo])):
        d = geometry.demand(pats, bw, np.array(shifts))
        util = geometry.link_utilization(pats, bw, np.array(shifts), 25.0)
        ex = geometry.excess(pats, bw, np.array(shifts), 25.0)
        print(f"\n{title}  link util={util:.2f}  excess={ex:.0f}")
        print("  circle (72 slots, # = demand, ! = over capacity):")
        for row in range(0, 72, 24):
            line = "".join(
                "!" if d[s] > 25 else ("#" if d[s] > 0 else ".")
                for s in range(row, row + 24))
            print(f"    [{row:2d}-{row+23:2d}] {line}")
    print("\nscore (Eq. 18) naive:",
          geometry.score(pats, bw, np.array([0, 0]), 25.0))
    print("score (Eq. 18) metronome:",
          geometry.score(pats, bw, np.array([0, shift_lo]), 25.0))


if __name__ == "__main__":
    main()

#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md): static gates, then the test suite.
# Usage: scripts/check.sh [extra pytest args]
#
# metrolint (repo-specific invariant checks, src/repro/analysis) always
# runs — it is stdlib-only.  ruff/mypy run only when installed: the
# reference container does not ship them, so locally they are best-effort
# while CI (which pip-installs both) enforces them unconditionally.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== metrolint =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis --root .

if command -v ruff >/dev/null 2>&1; then
  echo "== ruff =="
  ruff check .
else
  echo "== ruff not installed; skipping (CI enforces it) =="
fi

if command -v mypy >/dev/null 2>&1; then
  echo "== mypy =="
  mypy --config-file pyproject.toml
else
  echo "== mypy not installed; skipping (CI enforces it) =="
fi

echo "== pytest =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

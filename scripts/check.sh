#!/usr/bin/env bash
# Tier-1 verify (see ROADMAP.md): run the test suite against src/.
# Usage: scripts/check.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

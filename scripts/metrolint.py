#!/usr/bin/env python3
"""Run metrolint without needing PYTHONPATH=src pre-set.

Equivalent to ``PYTHONPATH=src python -m repro.analysis --root .`` from the
repo root; any CLI flags pass straight through.
"""
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.analysis.cli import main  # noqa: E402

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--root") for a in argv):
        argv = ["--root", str(REPO)] + argv
    sys.exit(main(argv))

#!/usr/bin/env python
"""Validate a BENCH_*.json artifact against its result schema.

Usage:  PYTHONPATH=src python scripts/validate_bench.py BENCH_sweep.json
        PYTHONPATH=src python scripts/validate_bench.py BENCH_sched_time.json

Five payload kinds are recognized: experiment sweeps (``sweeps`` key,
the ``--sweep-out`` artifact), benchmark timing rows (``kind == "timing"``,
the ``--bench-out`` artifact), fluid-engine trace-throughput rows
(``kind == "trace_throughput"``, the ``--trace-out`` artifact),
event-loop dynamic-throughput rows (``kind == "dynamic_throughput"``,
the ``--dynamic-out`` artifact), and graceful-degradation rows
(``kind == "robustness"``, the ``--robustness-out`` artifact).  Exit 0
when the file matches
``repro.core.results.SCHEMA_VERSION``'s schema; exit 1 (listing every
problem) on drift — CI runs this after the benchmark smoke so a
silently-changed result format fails the build.
"""
from __future__ import annotations

import json
import sys


def main(argv) -> int:
    if len(argv) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    path = argv[1]
    from repro.core.results import (validate_bench_dict,
                                    validate_dynamic_throughput_dict,
                                    validate_robustness_dict,
                                    validate_timing_dict,
                                    validate_trace_throughput_dict)

    with open(path) as f:
        doc = json.load(f)
    kind = doc.get("kind") if isinstance(doc, dict) else None
    if kind == "timing":
        problems = validate_timing_dict(doc)
    elif kind == "trace_throughput":
        problems = validate_trace_throughput_dict(doc)
    elif kind == "dynamic_throughput":
        problems = validate_dynamic_throughput_dict(doc)
    elif kind == "robustness":
        problems = validate_robustness_dict(doc)
    else:
        problems = validate_bench_dict(doc)
    if problems:
        print(f"{path}: INVALID ({len(problems)} problems)", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    if kind == "timing":
        rows = doc.get("rows", [])
        origins = sorted({r.get("origin", "") for r in rows})
        print(f"{path}: OK — schema v{doc['schema_version']}, timing, "
              f"{len(rows)} rows from {origins}")
        return 0
    if kind == "trace_throughput":
        rows = doc.get("rows", [])
        best = max((r.get("speedup_vs_python") or 0.0 for r in rows),
                   default=0.0)
        print(f"{path}: OK — schema v{doc['schema_version']}, "
              f"trace_throughput, {len(rows)} rows, best speedup "
              f"{best:.1f}x")
        return 0
    if kind == "dynamic_throughput":
        rows = doc.get("rows", [])
        best = max((r.get("speedup_vs_legacy") or 0.0 for r in rows
                    if r.get("loop") == "array"), default=0.0)
        print(f"{path}: OK — schema v{doc['schema_version']}, "
              f"dynamic_throughput, {len(rows)} rows, best array speedup "
              f"{best:.1f}x")
        return 0
    if kind == "robustness":
        rows = doc.get("rows", [])
        worst = max((r.get("degradation") or 0.0 for r in rows),
                    default=0.0)
        axes = sorted({r.get("axis", "") for r in rows})
        print(f"{path}: OK — schema v{doc['schema_version']}, robustness, "
              f"{len(rows)} rows over axes {axes}, worst degradation "
              f"{worst:.2f}x")
        return 0
    n_sweeps = len(doc.get("sweeps", []))
    n_cells = sum(len(s.get("cells", [])) for s in doc.get("sweeps", []))
    n_err = sum(1 for s in doc.get("sweeps", [])
                for c in s.get("cells", []) if c.get("status") != "ok")
    print(f"{path}: OK — schema v{doc['schema_version']}, {n_sweeps} sweeps, "
          f"{n_cells} cells ({n_err} error cells)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))

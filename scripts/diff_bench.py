#!/usr/bin/env python
"""Cross-commit diff of BENCH_*.json artifacts with regression gates.

Usage:
  PYTHONPATH=src python scripts/diff_bench.py BASELINE CURRENT \
      [--rel-tol 1e-6] [--timing-ratio 25] [--min-speedup 50]

CI uploads the benchmark artifacts on every push but (until now) never
compared them — a silent result regression survived as long as the schema
stayed valid.  This script closes that gap: the tier-1 job diffs the fresh
smoke artifacts against the committed ``benchmarks/baselines/`` copies.

Gates per payload kind (sniffed from the files, which must match):

  * experiment sweeps (``BENCH_sweep.json``): sweeps are seeded and
    deterministic, so every numeric leaf of every cell result must match
    the baseline within ``--rel-tol`` (default 1e-6).  A cell present in
    the baseline but missing from the current run fails; brand-new cells
    (new benches / scenarios) pass with a note.
  * timing rows (``BENCH_sched_time.json``): wall-clock is noisy on shared
    runners, so the gate is loose — a row fails only when it got more than
    ``--timing-ratio`` times slower than baseline (default 25x, i.e. an
    accidental algorithmic blow-up, not jitter).
  * trace throughput (``BENCH_trace_throughput.json``): the vectorized
    backends must keep ``speedup_vs_python >= --min-speedup`` (default 10
    — the committed artifact records ~70x, the acceptance floor is 50x on
    dedicated hardware; CI runners are slower and noisier).
  * dynamic throughput (``BENCH_dynamic_throughput.json``): the
    ``array``/``python`` event-loop row must keep ``speedup_vs_legacy >=
    --min-dyn-speedup`` (default 0.5: the smoke trace is too small for the
    quadratic legacy cost to show; the nightly full-trace job raises it),
    and every row's ``max_abs_err_vs_oracle`` must stay within
    ``--max-abs-err`` (default 1e-6).
  * robustness (``BENCH_robustness.json``): runs are seeded and
    deterministic, so every numeric field of every (axis, scenario,
    policy, x) row must match within ``--rel-tol`` — the committed
    baseline pins the whole graceful-degradation curve, including the
    robust policy's shallower failure-axis slope.

Exit 0 = no regression, 1 = regression(s) listed on stderr, 2 = usage.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, Iterator, List, Tuple


def _kind(doc: Any) -> str:
    if isinstance(doc, dict):
        if doc.get("kind") in ("timing", "trace_throughput",
                               "dynamic_throughput", "robustness"):
            return doc["kind"]
        if "sweeps" in doc:
            return "sweeps"
    return "unknown"


def _numeric_leaves(obj: Any, prefix: str = "") -> Iterator[Tuple[str, Any]]:
    """Yield (dotted.path, value) for every scalar leaf; lists are skipped
    except as lengths (per-iteration duration samples are trajectories we
    deliberately do not pin)."""
    if isinstance(obj, dict):
        for k in sorted(obj):
            yield from _numeric_leaves(obj[k], f"{prefix}.{k}" if prefix else k)
    elif isinstance(obj, list):
        yield f"{prefix}.len", len(obj)
    else:
        yield prefix, obj


def _close(a: Any, b: Any, rel_tol: float) -> bool:
    if a is None or b is None:
        return a is None and b is None  # NaN serializes to null
    if isinstance(a, bool) or isinstance(b, bool) or \
            isinstance(a, str) or isinstance(b, str):
        return a == b
    a, b = float(a), float(b)
    return abs(a - b) <= rel_tol * max(1.0, abs(a), abs(b))


def diff_sweeps(base: Dict, cur: Dict, rel_tol: float) -> List[str]:
    def cells(doc: Dict) -> Dict[Tuple[str, str, str], Dict]:
        out = {}
        for sw in doc.get("sweeps", []):
            origin = str(sw.get("meta", {}).get("origin", ""))
            for c in sw.get("cells", []):
                out[(origin, c.get("scenario"), c.get("policy"))] = c
        return out

    b, c = cells(base), cells(cur)
    problems = []
    for key in sorted(set(b) - set(c)):
        problems.append(f"cell {key} present in baseline, missing now")
    for key in sorted(set(c) - set(b)):
        print(f"note: new cell {key} (no baseline)", file=sys.stderr)
    for key in sorted(set(b) & set(c)):
        cb, cc = b[key], c[key]
        if cb.get("status") != cc.get("status"):
            problems.append(f"cell {key}: status {cb.get('status')!r} -> "
                            f"{cc.get('status')!r}")
            continue
        lb = dict(_numeric_leaves(cb.get("result", {})))
        lc = dict(_numeric_leaves(cc.get("result", {})))
        for path in sorted(set(lb) - set(lc)):
            problems.append(f"cell {key}: field {path} disappeared")
        for path in sorted(set(lb) & set(lc)):
            if not _close(lb[path], lc[path], rel_tol):
                problems.append(f"cell {key}: {path} {lb[path]!r} -> "
                                f"{lc[path]!r} (rel tol {rel_tol})")
    return problems


def diff_timings(base: Dict, cur: Dict, ratio: float) -> List[str]:
    def rows(doc: Dict) -> Dict[Tuple[str, str], float]:
        return {(r.get("origin", ""), r["name"]): r.get("us_per_call")
                for r in doc.get("rows", [])}

    b, c = rows(base), rows(cur)
    problems = []
    for key in sorted(set(b) - set(c)):
        problems.append(f"timing row {key} present in baseline, missing now")
    for key in sorted(set(c) - set(b)):
        print(f"note: new timing row {key} (no baseline)", file=sys.stderr)
    for key in sorted(set(b) & set(c)):
        vb, vc = b[key], c[key]
        if not vb or vc is None:
            continue
        if vc > vb * ratio:
            problems.append(f"timing row {key}: {vb:.1f}us -> {vc:.1f}us "
                            f"(> {ratio}x slower)")
    return problems


def diff_trace(base: Dict, cur: Dict, min_speedup: float) -> List[str]:
    problems = []
    names_cur = {r["name"] for r in cur.get("rows", [])}
    for r in base.get("rows", []):
        if r["name"] not in names_cur:
            problems.append(f"trace row {r['name']!r} present in baseline, "
                            f"missing now")
    for r in cur.get("rows", []):
        if r.get("backend") != "python" and \
                (r.get("speedup_vs_python") or 0.0) < min_speedup:
            problems.append(f"trace row {r['name']!r}: speedup "
                            f"{r.get('speedup_vs_python')} < {min_speedup}x")
    return problems


def diff_dynamic(base: Dict, cur: Dict, min_speedup: float,
                 max_err: float) -> List[str]:
    problems = []
    names_cur = {r["name"] for r in cur.get("rows", [])}
    for r in base.get("rows", []):
        if r["name"] not in names_cur:
            problems.append(f"dynamic row {r['name']!r} present in "
                            f"baseline, missing now")
    for r in cur.get("rows", []):
        if r.get("loop") == "array" and r.get("backend") == "python" and \
                (r.get("speedup_vs_legacy") or 0.0) < min_speedup:
            problems.append(f"dynamic row {r['name']!r}: speedup "
                            f"{r.get('speedup_vs_legacy')} < {min_speedup}x")
        if (r.get("max_abs_err_vs_oracle") or 0.0) > max_err:
            problems.append(f"dynamic row {r['name']!r}: max_abs_err "
                            f"{r.get('max_abs_err_vs_oracle')} > {max_err}")
    return problems


def diff_robustness(base: Dict, cur: Dict, rel_tol: float) -> List[str]:
    def rows(doc: Dict) -> Dict[Tuple[str, str, str, Any], Dict]:
        return {(r.get("axis"), r.get("scenario"), r.get("policy"),
                 r.get("x")): r for r in doc.get("rows", [])}

    b, c = rows(base), rows(cur)
    problems = []
    for key in sorted(set(b) - set(c)):
        problems.append(f"robustness row {key} present in baseline, "
                        f"missing now")
    for key in sorted(set(c) - set(b)):
        print(f"note: new robustness row {key} (no baseline)",
              file=sys.stderr)
    for key in sorted(set(b) & set(c)):
        rb, rc = b[key], c[key]
        for field in sorted(set(rb) | set(rc)):
            if field in ("axis", "scenario", "policy", "origin"):
                continue
            if not _close(rb.get(field), rc.get(field), rel_tol):
                problems.append(f"robustness row {key}: {field} "
                                f"{rb.get(field)!r} -> {rc.get(field)!r} "
                                f"(rel tol {rel_tol})")
    return problems


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument("--rel-tol", type=float, default=1e-6,
                    help="relative tolerance for sweep result fields")
    ap.add_argument("--timing-ratio", type=float, default=25.0,
                    help="fail a timing row slower than baseline by this "
                         "factor")
    ap.add_argument("--min-speedup", type=float, default=10.0,
                    help="minimum speedup_vs_python for vectorized "
                         "trace-throughput rows")
    ap.add_argument("--min-dyn-speedup", type=float, default=0.5,
                    help="minimum speedup_vs_legacy for the array/python "
                         "dynamic-throughput row (nightly full-trace CI "
                         "raises this to the 10x acceptance floor)")
    ap.add_argument("--max-abs-err", type=float, default=1e-6,
                    help="maximum max_abs_err_vs_oracle for "
                         "dynamic-throughput rows")
    args = ap.parse_args(argv[1:])

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.current) as f:
        cur = json.load(f)
    kb, kc = _kind(base), _kind(cur)
    if kb != kc or kb == "unknown":
        print(f"payload kinds differ or unknown: baseline={kb} current={kc}",
              file=sys.stderr)
        return 2
    if base.get("smoke") != cur.get("smoke"):
        print(f"smoke flags differ: baseline={base.get('smoke')} "
              f"current={cur.get('smoke')} — comparing anyway",
              file=sys.stderr)
    if kb == "sweeps":
        problems = diff_sweeps(base, cur, args.rel_tol)
    elif kb == "timing":
        problems = diff_timings(base, cur, args.timing_ratio)
    elif kb == "dynamic_throughput":
        problems = diff_dynamic(base, cur, args.min_dyn_speedup,
                                args.max_abs_err)
    elif kb == "robustness":
        problems = diff_robustness(base, cur, args.rel_tol)
    else:
        problems = diff_trace(base, cur, args.min_speedup)
    if problems:
        print(f"{args.current}: {len(problems)} regression(s) vs "
              f"{args.baseline}", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"{args.current}: no regressions vs {args.baseline} ({kb})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))

"""Render the roofline/dry-run tables of EXPERIMENTS.md from results/*.json."""
import json

d = json.load(open("results/dryrun.json"))


def row(k, v):
    r = v["roofline"]
    rf = v.get("roofline_flash") or {}
    tot = r["compute_s"] + r["memory_s"] + r["collective_s"]
    mem_fl = rf.get("memory_s")
    if mem_fl is not None:
        totf = r["compute_s"] + mem_fl + r["collective_s"]
        fl = f"{mem_fl:.3f}"
        frf = f"{r['compute_s']/totf:.1%}"
    else:
        fl, frf = "—", "—"
    mvh = v.get("model_vs_hlo_flops")
    # perfect-overlap bound: compute / max(terms) — the MFU ceiling if
    # memory and collectives fully hide behind compute (and vice versa)
    mx = max(r["compute_s"], (mem_fl if mem_fl is not None else r["memory_s"]),
             r["collective_s"])
    ovl = f"{r['compute_s']/mx:.1%}" if mx else "—"
    return (f"| {k.replace('|', ' × ')} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{v['bottleneck']} | {mvh:.2f} | {fl} | "
            f"{r['compute_s']/tot:.1%} | {frf} | {ovl} |"
            if mvh is not None else "")


hdr = ("| cell | compute s | memory s | collective s | bottleneck | "
       "6ND/HLO | mem s (flash) | roofline frac | frac (flash) | "
       "overlap bound |\n"
       "|---|---|---|---|---|---|---|---|---|---|")

print("### single-pod baselines (16x16)\n")
print(hdr)
for k in sorted(d):
    v = d[k]
    if v.get("status") != "ok" or "|multi" in k or k.count("|") > 2:
        continue
    print(row(k, v))

print("\n### perf-iteration variants\n")
print(hdr)
for k in sorted(d):
    v = d[k]
    if v.get("status") != "ok" or k.count("|") <= 2:
        continue
    print(row(k, v))

print("\n### multi-pod pass (2x16x16)\n")
n_ok = sum(1 for k, v in d.items()
           if "|multi" in k and v.get("status") == "ok")
n_skip = sum(1 for k, v in d.items()
             if "|multi" in k and v.get("status") == "skipped")
print(f"{n_ok} compiled OK, {n_skip} skipped (long_500k on full-attention).")
print("\n| cell | compute s | memory s | collective s | peak GB/chip |")
print("|---|---|---|---|---|")
for k in sorted(d):
    v = d[k]
    if v.get("status") != "ok" or "|multi" not in k:
        continue
    r = v["roofline"]
    peak = (v["memory"]["peak_bytes"] or 0) / 1e9
    print(f"| {k.replace('|', ' × ')} | {r['compute_s']:.3f} | "
          f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {peak:.1f} |")

print("\n### memory analysis (single-pod, peak bytes/chip)\n")
print("| cell | args GB | temps GB | peak GB | fits 16 GB HBM |")
print("|---|---|---|---|---|")
for k in sorted(d):
    v = d[k]
    if v.get("status") != "ok" or "|multi" in k or k.count("|") > 2:
        continue
    m = v["memory"]
    peak = (m["peak_bytes"] or 0) / 1e9
    print(f"| {k.replace('|', ' × ')} | {(m['argument_bytes'] or 0)/1e9:.1f} | "
          f"{(m['temp_bytes'] or 0)/1e9:.1f} | {peak:.1f} | "
          f"{'yes' if peak <= 16 else 'NO'} |")

"""train_step / serve_step builders with sharding + gradient accumulation.

``build_train_step`` returns a function
    (state, batch) -> (state, metrics)
that microbatches the global batch with a lax.scan (bounded activation
memory), accumulates grads in ``accum_dtype``, and applies AdamW. All
tensors carry logical-axis sharding constraints; the caller wraps the jit
under ``sharding.use_rules(mesh)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import decode_step, init_model, loss_fn, prefill
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         quantize_int8)
from repro.sharding import logical_shard


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Dict
    step: jax.Array


def make_train_state_specs(cfg: ModelConfig, param_specs) -> TrainState:
    """Logical specs for the TrainState (opt moments shard like params)."""
    return TrainState(
        params=param_specs,
        opt={"m": param_specs, "v": param_specs, "step": ()},
        step=(),
    )


def auto_microbatches(cfg: ModelConfig, shape: ShapeConfig,
                      n_data_shards: int) -> int:
    """Pick a microbatch count keeping ~<=2 sequences x 4k tokens per data
    shard per microbatch (activation-memory heuristic; perf loop can tune)."""
    if shape.microbatch:
        return max(1, shape.global_batch // shape.microbatch)
    tokens_per_seq = shape.seq_len
    seqs_per_shard = shape.global_batch / max(n_data_shards, 1)
    budget = max(1.0, 8192.0 / tokens_per_seq)  # seqs per shard per micro
    n_micro = int(max(1, round(seqs_per_shard / budget)))
    # n_micro must divide global batch
    while shape.global_batch % n_micro:
        n_micro += 1
    return n_micro


def build_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    n_micro: int = 1,
    accum_dtype: Any = jnp.float32,
    param_specs: Any = None,
    compress_grads: bool = False,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``param_specs`` (the logical-axis tree from init_model) re-constrains
    per-microbatch gradients to the parameter sharding immediately after
    autodiff, steering GSPMD to reduce-scatter instead of the
    all-reduce+slice it otherwise emits for the FSDP weight-gather
    transpose (see EXPERIMENTS.md section Perf)."""

    def _constrain_grads(grads):
        if param_specs is None:
            return grads
        leaves, treedef = jax.tree.flatten(grads)
        spec_leaves = treedef.flatten_up_to(param_specs)
        out = [logical_shard(g, *sp) for g, sp in zip(leaves, spec_leaves)]
        return treedef.unflatten(out)

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        def constrain(leaf_name, x):
            if x.ndim >= 2:
                return logical_shard(x, *((("batch",) + (None,) * (x.ndim - 1))))
            return x
        batch_c = {k: constrain(k, v) for k, v in batch.items()}

        def micro_slices(i):
            def slc(x):
                if x.ndim == 0:
                    return x
                # positions for mrope have shape (3, B, S): batch on axis 1
                axis = 1 if x.ndim == 3 and x.shape[0] == 3 else 0
                b = x.shape[axis] // n_micro
                return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=axis)
            return {k: slc(v) for k, v in batch_c.items()}

        grad_fn = jax.value_and_grad(
            lambda p, mb: loss_fn(p, cfg, mb), has_aux=True)

        def micro_body(carry, i):
            grads, loss_sum, aux_sum = carry
            (loss, metrics), g = grad_fn(state.params, micro_slices(i))
            g = _constrain_grads(g)
            grads = jax.tree.map(
                lambda a, b: a + b.astype(accum_dtype), grads, g)
            return (grads, loss_sum + loss, aux_sum + metrics["aux"]), None

        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, accum_dtype), state.params)
        if n_micro > 1:
            (grads, loss_sum, aux_sum), _ = jax.lax.scan(
                micro_body, (zero_grads, jnp.zeros((), jnp.float32),
                             jnp.zeros((), jnp.float32)),
                jnp.arange(n_micro))
        else:
            (grads, loss_sum, aux_sum), _ = micro_body(
                (zero_grads, jnp.zeros((), jnp.float32),
                 jnp.zeros((), jnp.float32)), 0)
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        if compress_grads:
            # int8 quantize-dequantize of the accumulated gradients — the
            # numerics of sending the cross-pod (DCN) all-reduce at int8
            # (optim/compression.py provides the error-feedback variant for
            # stateful loops; here the stateless Q/DQ models the wire format)
            def qdq(g):
                q, scale = quantize_int8(g)
                return (q.astype(jnp.float32) * scale).astype(g.dtype)
            grads = jax.tree.map(qdq, grads)
        loss = loss_sum / n_micro

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics = {"loss": loss, "aux": aux_sum / n_micro, **opt_metrics}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def build_serve_step(cfg: ModelConfig) -> Callable:
    """Returns serve_step(params, cache, tokens) -> (logits, cache) — one
    decode step against the KV cache / recurrent state."""

    def serve_step(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens)

    return serve_step


def build_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        return prefill(params, cfg, batch["tokens"],
                       positions=batch.get("positions"),
                       frames=batch.get("frames"))
    return prefill_step


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, key
                     ) -> Tuple[TrainState, Any]:
    params, specs = init_model(cfg, key)
    opt = adamw_init(opt_cfg, params)
    return TrainState(params, opt, jnp.zeros((), jnp.int32)), specs


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt", "step"], meta_fields=[])

from .steps import (TrainState, auto_microbatches, build_serve_step,
                    build_train_step, make_train_state_specs)
from .comm_gate import CommGate, IterationReporter

__all__ = ["TrainState", "auto_microbatches", "build_serve_step",
           "build_train_step", "make_train_state_specs", "CommGate",
           "IterationReporter"]

"""Elastic scaling & fault tolerance: re-mesh on device loss, resume.

On real hardware, device failure surfaces as a collective timeout; here the
manager is driven by an explicit healthy-device list (tests mask devices).
Policy: shrink the data axis to the largest power-of-two that the surviving
device count supports while keeping the model axis intact (tensor-parallel
groups must stay whole), then restore state from the latest checkpoint and
continue — the data pipeline is (seed, step)-deterministic so no data is
replayed or skipped.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import CheckpointManager


@dataclasses.dataclass
class ElasticDecision:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dropped_hosts: int
    global_batch_scale: float  # <1 when the data axis shrank


def plan_remesh(n_healthy: int, model_parallel: int,
                axis_names: Tuple[str, ...] = ("data", "model")
                ) -> Optional[ElasticDecision]:
    """Largest power-of-two data axis that fits the healthy devices."""
    if n_healthy < model_parallel:
        return None  # cannot even form one TP group
    data = 1
    while data * 2 * model_parallel <= n_healthy:
        data *= 2
    return ElasticDecision(
        mesh_shape=(data, model_parallel),
        axis_names=axis_names,
        dropped_hosts=n_healthy - data * model_parallel,
        global_batch_scale=1.0,  # caller rescales batch/n_micro
    )


def build_mesh(devices: Sequence, decision: ElasticDecision) -> Mesh:
    n = int(np.prod(decision.mesh_shape))
    dev = np.asarray(devices[:n]).reshape(decision.mesh_shape)
    return Mesh(dev, decision.axis_names)


class FaultTolerantRunner:
    """Orchestrates detect -> remesh -> restore -> resume."""

    def __init__(self, ckpt: CheckpointManager, model_parallel: int):
        self.ckpt = ckpt
        self.model_parallel = model_parallel
        self.events: List[str] = []

    def on_failure(self, healthy_devices: Sequence, like_state):
        decision = plan_remesh(len(healthy_devices), self.model_parallel)
        if decision is None:
            self.events.append("unrecoverable: not enough devices for TP")
            raise RuntimeError("not enough healthy devices")
        mesh = build_mesh(healthy_devices, decision)
        state, step, extra = self.ckpt.restore_latest(like_state)
        self.events.append(
            f"remeshed to {decision.mesh_shape}, resumed at step {step}")
        return mesh, state, step, decision

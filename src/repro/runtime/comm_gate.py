"""Metronome actuators inside the training loop.

``CommGate`` delays entry into the synchronization (gradient collective)
phase by the job's assigned time-shift — the TPU-side equivalent of the
paper's pod pause (DESIGN.md section 2): a training job cannot be preempted
mid-step cheaply, so TDM alignment is enforced at the step boundary.

``IterationReporter`` is the modified-DDP/DeepSpeed shim: it feeds per-step
wall time to the stop-and-wait controller and applies any realign actions
(pauses) the controller returns.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional

from repro.core.controller import RealignAction, StopAndWaitController


class CommGate:
    """Gates the communication phase of each step to its assigned offset."""

    def __init__(self, controller: Optional[StopAndWaitController], job: str,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep):
        self.controller = controller
        self.job = job
        self.clock = clock
        self.sleep = sleep
        self.total_delay_s = 0.0

    def wait_for_slot(self) -> float:
        """Call immediately before the gradient collective. Sleeps until the
        next assigned communication slot; returns the delay applied (s)."""
        if self.controller is None:
            return 0.0
        align = self.controller.job_alignment(self.job)
        if align is None:
            return 0.0
        offset_ms, period_ms = align
        now_ms = self.clock() * 1e3
        delay_ms = (offset_ms - (now_ms % period_ms)) % period_ms
        # only delay when we're meaningfully off-slot (avoid micro-sleeps)
        if delay_ms > 1.0 and delay_ms < period_ms * 0.95:
            self.sleep(delay_ms / 1e3)
            self.total_delay_s += delay_ms / 1e3
            return delay_ms / 1e3
        return 0.0


class IterationReporter:
    """Reports step wall-times to the controller; applies pause actions."""

    def __init__(self, controller: Optional[StopAndWaitController], job: str,
                 priority: int,
                 sleep: Callable[[float], None] = time.sleep):
        self.controller = controller
        self.job = job
        self.priority = priority
        self.sleep = sleep
        self.pauses_applied = 0
        if controller is not None:
            controller._priorities.setdefault(job, priority)

    def report(self, iter_time_s: float) -> List[RealignAction]:
        if self.controller is None:
            return []
        actions = self.controller.report_iteration(self.job, iter_time_s * 1e3)
        for act in actions:
            if act.job == self.job:
                align = self.controller.job_alignment(self.job)
                if align is not None:
                    _, period_ms = align
                    self.sleep(min(period_ms, 50.0) / 1e3)
                    self.pauses_applied += 1
        return actions

"""Straggler mitigation = the paper's drift monitor applied to step times.

A slow host manifests exactly like communication drift: iteration times
exceed the baseline by a factor. The SAME windowed A_T/O_T rule the
stop-and-wait controller uses for traffic drift (section III-C) doubles as
job-level straggler detection; on trip, the runner triggers the elastic
re-mesh path (runtime/elastic.py) instead of a phase realign.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    iter_time_s: float
    baseline_s: float


class StragglerMonitor:
    """Windowed A_T/O_T rule over training-step wall times.

    Baseline = EMA of healthy steps; a trip requires more than ``o_t`` of
    the last ``window`` steps above ``a_t x baseline`` (the controller's
    MONITOR_WINDOW semantics, section III-C)."""

    def __init__(self, a_t: float = 1.3, o_t: int = 5, window: int = 10,
                 on_straggler: Optional[Callable[[StragglerEvent], None]] = None):
        self.a_t = a_t
        self.o_t = o_t
        self._hist: collections.deque = collections.deque(maxlen=window)
        self._baseline_s: Optional[float] = None
        self._alpha = 0.1  # EMA for the healthy baseline
        self._step = 0
        self.events: List[StragglerEvent] = []
        self.on_straggler = on_straggler

    def report(self, iter_time_s: float) -> bool:
        """Returns True when the straggler rule trips this step."""
        self._step += 1
        if self._baseline_s is None:
            self._baseline_s = iter_time_s
            return False
        if iter_time_s <= self.a_t * self._baseline_s:
            self._baseline_s = ((1 - self._alpha) * self._baseline_s
                                + self._alpha * iter_time_s)
        self._hist.append(iter_time_s)
        n_slow = sum(1 for t in self._hist
                     if t > self.a_t * self._baseline_s)
        if n_slow <= self.o_t:
            return False
        self._hist.clear()
        ev = StragglerEvent(self._step, iter_time_s, self._baseline_s)
        self.events.append(ev)
        if self.on_straggler is not None:
            self.on_straggler(ev)
        return True

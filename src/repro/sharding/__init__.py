from .rules import (AxisRules, best_spec, current_rules, logical_shard,
                    param_spec, use_rules)

__all__ = ["AxisRules", "best_spec", "current_rules", "logical_shard",
           "param_spec", "use_rules"]

"""Logical-axis sharding rules with divisibility-aware axis selection.

The model code annotates tensors with *logical* axes ("batch", "heads",
"mlp", ...). At trace time each logical axis is resolved to the first mesh
axis (or axis tuple) from its candidate list that (a) is not already used in
this spec and (b) divides the dimension size. This makes one model
definition shard correctly across every assigned architecture — including
awkward head counts (qwen3: 40 heads on tp=16 falls back to sequence
sharding; whisper's 51865 vocab stays replicated) — without per-arch special
cases.

Mesh axes (launch/mesh.py):
  pod   — pure data parallelism across pods (cross-pod = DCN)
  data  — within-pod data parallel + FSDP weight sharding (ZeRO-3-like)
  model — tensor parallelism (heads / mlp / vocab / expert-ffn)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisChoice = Union[None, str, Tuple[str, ...]]
Candidates = Sequence[AxisChoice]

# default logical rules: logical axis -> ordered candidate mesh axes
DEFAULT_RULES: Dict[str, Candidates] = {
    # activations
    "batch": [("pod", "data"), "data", None],
    "seq": [None],
    "seq_sharded": ["model", None],        # sequence parallelism fallback
    "embed": [None],
    "heads": ["model", None],
    "kv_heads": ["model", None],
    "kv_seq": ["model", None],             # flash-decoding style cache shard
    "mlp_act": ["model", None],
    "vocab_act": ["model", None],
    "experts_act": ["data", "model", None],
    # weights (FSDP on 'data', TP on 'model')
    "w_embed": ["data", None],
    "w_heads": ["model", None],
    "w_mlp": ["model", None],
    "w_vocab": ["model", None],
    "w_experts": [("pod", "data"), "data", None],
    "w_state": ["model", None],
    "w_replicated": [None],
    "opt_state": [("data", "model"), "data", None],
}


class AxisRules:
    def __init__(self, mesh: Optional[Mesh],
                 rules: Optional[Dict[str, Candidates]] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def axis_size(self, choice: AxisChoice) -> int:
        if choice is None or self.mesh is None:
            return 1
        names = (choice,) if isinstance(choice, str) else choice
        n = 1
        for a in names:
            if a not in self.mesh.shape:
                return 0  # axis not present in this mesh -> unusable
            n *= self.mesh.shape[a]
        return n


_ctx = threading.local()


def current_rules() -> Optional[AxisRules]:
    return getattr(_ctx, "rules", None)


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh], rules: Optional[Dict[str, Candidates]] = None):
    prev = getattr(_ctx, "rules", None)
    _ctx.rules = AxisRules(mesh, rules) if mesh is not None else None
    try:
        yield _ctx.rules
    finally:
        _ctx.rules = prev


def best_spec(shape: Sequence[int], logical: Sequence[Optional[str]],
              rules: Optional[AxisRules] = None) -> P:
    """Resolve logical axes -> PartitionSpec with divisibility checks."""
    rules = rules or current_rules()
    if rules is None or rules.mesh is None:
        return P()
    used: set = set()
    parts: List[AxisChoice] = []
    for dim, name in zip(shape, logical):
        chosen: AxisChoice = None
        if name is not None:
            for cand in rules.rules.get(name, [None]):
                if cand is None:
                    break
                names = (cand,) if isinstance(cand, str) else tuple(cand)
                size = rules.axis_size(cand)
                if size <= 0 or any(a in used for a in names):
                    continue
                if dim % size == 0:
                    chosen = cand
                    used.update(names)
                    break
        parts.append(chosen)
    return P(*parts)


def logical_shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint via logical axes; no-op outside use_rules()."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = best_spec(x.shape, logical, rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def param_spec(shape: Sequence[int], logical: Sequence[Optional[str]],
               rules: Optional[AxisRules] = None) -> P:
    """Spec for a parameter (used to build in_shardings for jit)."""
    return best_spec(shape, logical, rules)


def named_sharding(spec: P, rules: Optional[AxisRules] = None
                   ) -> Optional[NamedSharding]:
    rules = rules or current_rules()
    if rules is None or rules.mesh is None:
        return None
    return NamedSharding(rules.mesh, spec)

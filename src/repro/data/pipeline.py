"""Deterministic synthetic LM data pipeline.

Produces packed next-token batches from a seeded Markov-ish token stream
(deterministic per (seed, step) — a restart resumes exactly where it left
off, which the checkpoint/resume tests rely on). A background thread
prefetches ahead of the training loop.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a given step (restart-safe)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        # zipf-ish marginal + local repetition gives a learnable signal
        base = rng.zipf(1.3, size=(self.global_batch, self.seq_len + 1))
        tokens = (base % (self.vocab - 2)) + 1
        rep = rng.random((self.global_batch, self.seq_len + 1)) < 0.3
        tokens[:, 1:] = np.where(rep[:, 1:], tokens[:, :-1], tokens[:, 1:])
        tokens = tokens.astype(np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:].copy()}


def packed_batch_iterator(ds: SyntheticLM, start_step: int = 0,
                          prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
    """Host-side prefetching iterator."""
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            q.put(ds.batch_at(step))
            step += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                     batch_override: Optional[int] = None) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    This is the single source of truth consumed by the dry-run and the
    serving/training step builders (weak-type-correct, shardable, no device
    allocation).
    """
    import jax
    import jax.numpy as jnp

    b = batch_override or shape.global_batch
    s = shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:  # decode
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.family == "encdec" and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, max(s // cfg.enc_frames_ratio, 1), cfg.d_model), jnp.float32)
    if cfg.mrope_sections and shape.kind != "decode":
        specs["positions"] = jax.ShapeDtypeStruct((3, b, s), i32)
    return specs

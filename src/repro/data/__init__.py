from .pipeline import SyntheticLM, make_batch_specs, packed_batch_iterator

__all__ = ["SyntheticLM", "make_batch_specs", "packed_batch_iterator"]

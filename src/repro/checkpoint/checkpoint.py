"""Fault-tolerant checkpointing: atomic, versioned, keep-N, async.

Layout:  <dir>/step_<N>/{manifest.json, arrays.npz}
Writes go to a tmp dir + os.replace (atomic on POSIX), so a crash mid-save
never corrupts the latest checkpoint; restore skips incomplete steps.
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"
ARRAYS = "arrays.npz"


def _flatten(tree) -> Tuple[List[str], List[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    keys = [f"a{i}" for i in range(len(leaves))]
    out = []
    for x in leaves:
        arr = np.asarray(x)
        if arr.dtype.kind == "V" or arr.dtype.name in (
                "bfloat16", "float8_e4m3fn", "float8_e5m2"):
            # npz cannot store ml_dtypes natively; upcast losslessly to f32
            # (restore casts back to the target tree's dtype)
            arr = arr.astype(np.float32)
        out.append(arr)
    return keys, out, treedef


def save_checkpoint(directory: str, step: int, tree, extra: Optional[Dict] = None
                    ) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    keys, arrays, _ = _flatten(tree)
    np.savez(os.path.join(tmp, ARRAYS), **dict(zip(keys, arrays)))
    manifest = {"step": step, "n_arrays": len(arrays), "extra": extra or {},
                "complete": True}
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            path = os.path.join(directory, name, MANIFEST)
            try:
                with open(path) as f:
                    m = json.load(f)
                if m.get("complete"):
                    steps.append(int(name[5:]))
            except (OSError, ValueError, json.JSONDecodeError):
                continue  # skip corrupt/partial checkpoints
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like_tree, step: Optional[int] = None
                       ) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``like_tree``; returns (tree, step, extra)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, ARRAYS))
    leaves, treedef = jax.tree.flatten(like_tree)
    assert manifest["n_arrays"] == len(leaves), \
        f"checkpoint has {manifest['n_arrays']} arrays, tree expects {len(leaves)}"
    restored = []
    for i, like in enumerate(leaves):
        arr = data[f"a{i}"]
        dtype = like.dtype if hasattr(like, "dtype") else arr.dtype
        restored.append(np.asarray(arr).astype(dtype, copy=False))
    return treedef.unflatten(restored), step, manifest.get("extra", {})


class CheckpointManager:
    """keep-N policy + async (background thread) saving."""

    def __init__(self, directory: str, keep_n: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep_n = keep_n
        self._pool = (concurrent.futures.ThreadPoolExecutor(max_workers=1)
                      if async_save else None)
        self._pending: Optional[concurrent.futures.Future] = None
        self._lock = threading.Lock()

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        # snapshot to host now, write possibly in the background
        host_tree = jax.tree.map(np.asarray, tree)

        def work():
            save_checkpoint(self.directory, step, host_tree, extra)
            self._gc()

        if self._pool is not None:
            self.wait()
            with self._lock:
                self._pending = self._pool.submit(work)
        else:
            work()

    def wait(self) -> None:
        with self._lock:
            pending = self._pending
            self._pending = None
        if pending is not None:
            pending.result()

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(n[5:]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, like_tree):
        self.wait()
        return restore_checkpoint(self.directory, like_tree)

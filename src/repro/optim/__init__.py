from .adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from .compression import (compress_ef_int8, decompress_ef_int8,
                          make_ef_state, quantize_int8)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "compress_ef_int8", "decompress_ef_int8", "make_ef_state",
           "quantize_int8"]

"""AdamW in pure JAX pytree ops, with ZeRO-friendly dtype options.

Moments can be held in bf16 (``moment_dtype``) to fit very large models
(arctic-480b) on v5e HBM; the update math always runs in fp32. Optimizer
state inherits the parameters' sharding (params are already fully sharded
over data x model => ZeRO-3-equivalent footprint).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32  # bf16 for very large models
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(cfg: AdamWConfig, params) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params, grads, state
                 ) -> Tuple[Any, Dict, Dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.asarray(1.0)
    lr = cosine_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p32 = p.astype(jnp.float32) - lr * delta
        return (p32.astype(p.dtype), m32.astype(cfg.moment_dtype),
                v32.astype(cfg.moment_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}

"""Gradient compression for the cross-pod (DCN) all-reduce.

The multi-pod mesh's only cross-pod collective is the data-parallel gradient
all-reduce over the ``pod`` axis (DESIGN.md section 4) — exactly the host-link
traffic Metronome schedules. Two compressors:

  * bf16 reduce — cast-to-bf16 before the collective (2x) — on by default
    when grads are fp32;
  * int8 error-feedback — per-tensor scale quantization with an error
    accumulator (1-bit-Adam-style EF), 4x over fp32; exposed as an optional
    transform since it changes numerics.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def make_ef_state(grads) -> Dict:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_ef_int8(grads, ef_state):
    """Error-feedback int8: compress (g + e), remember the residual."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = quantize_int8(x)
        deq = q.astype(jnp.float32) * scale
        return (q, scale), x - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qs = treedef.unflatten([o[0] for o in out])
    new_e = treedef.unflatten([o[1] for o in out])
    return qs, new_e


def decompress_ef_int8(qs):
    return jax.tree.map(
        lambda q_scale: q_scale[0].astype(jnp.float32) * q_scale[1],
        qs, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)

"""Model assembly: init / train forward / prefill / decode for all families.

Families:
  dense   — pre-norm GQA transformer (llama3/qwen3/internlm2/starcoder2,
            qwen2-vl backbone with M-RoPE)
  moe     — dense skeleton with routed-expert FFN (+ shared experts /
            arctic's parallel dense residual)
  griffin — RecurrentGemma: repeating (RG-LRU, RG-LRU, local attention),
            every temporal block followed by an MLP
  xlstm   — alternating sLSTM / mLSTM blocks (no separate FFN)
  encdec  — whisper backbone: bidirectional encoder over stub frame
            embeddings + causal decoder with cross-attention

Layer stacks are scanned (stacked weights) with jax.checkpoint around the
block body so compiled HLO stays small and activation memory is bounded.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import logical_shard

from . import layers as L
from . import moe as MOE
from . import recurrent as R
from .config import ATTN, RGLRU, ModelConfig

_is_spec = lambda x: isinstance(x, tuple)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _stack_init(init_fn, n: int, key) -> Tuple[Dict, Dict]:
    """vmap the per-layer init over n keys; spec gets a leading None axis."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    spec = _spec_of(init_fn)  # traced, no allocation
    spec = jax.tree.map(lambda s: (None,) + tuple(s), spec, is_leaf=_is_spec)
    return params, spec


def _spec_of(init_fn) -> Dict:
    """Extract the logical-spec tree without allocating parameters."""
    out = {}

    def run(k):
        p, s = init_fn(k)
        out["spec"] = s
        return p

    jax.eval_shape(run, jax.random.PRNGKey(0))
    return out["spec"]


def _dense_layer_init(cfg: ModelConfig):
    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        p, s = {}, {}
        p["ln_attn"], s["ln_attn"] = L.init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["attn"], s["attn"] = L.init_attention(cfg, k1)
        p["ln_mlp"], s["ln_mlp"] = L.init_rmsnorm(cfg.d_model, cfg.param_dtype)
        if cfg.n_experts > 0:
            p["moe"], s["moe"] = MOE.init_moe(cfg, k2)
            if cfg.dense_residual:
                p["mlp"], s["mlp"] = L.init_mlp(cfg, k3)
            if cfg.n_shared > 0:
                p["shared"], s["shared"] = L.init_mlp(
                    cfg, k3, d_ff=cfg.n_shared * (cfg.moe_d_ff or cfg.d_ff))
        else:
            p["mlp"], s["mlp"] = L.init_mlp(cfg, k2)
        return p, s
    return init


def _griffin_sub_init(cfg: ModelConfig, kind: str):
    def init(key):
        k1, k2 = jax.random.split(key)
        p, s = {}, {}
        p["ln"], s["ln"] = L.init_rmsnorm(cfg.d_model, cfg.param_dtype)
        if kind == RGLRU:
            p["block"], s["block"] = R.init_rg_lru(cfg, k1)
        else:
            p["block"], s["block"] = L.init_attention(cfg, k1)
        p["ln_mlp"], s["ln_mlp"] = L.init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["mlp"], s["mlp"] = L.init_mlp(cfg, k2)
        return p, s
    return init


def _griffin_group_init(cfg: ModelConfig):
    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        p, s = {}, {}
        p["rg1"], s["rg1"] = _griffin_sub_init(cfg, RGLRU)(k1)
        p["rg2"], s["rg2"] = _griffin_sub_init(cfg, RGLRU)(k2)
        p["attn"], s["attn"] = _griffin_sub_init(cfg, ATTN)(k3)
        return p, s
    return init


def _xlstm_pair_init(cfg: ModelConfig):
    def init(key):
        k1, k2 = jax.random.split(key)
        p, s = {}, {}
        p["ln_s"], s["ln_s"] = L.init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["slstm"], s["slstm"] = R.init_slstm(cfg, k1)
        p["ln_m"], s["ln_m"] = L.init_rmsnorm(cfg.d_model, cfg.param_dtype)
        p["mlstm"], s["mlstm"] = R.init_mlstm(cfg, k2)
        return p, s
    return init


def _enc_layer_init(cfg: ModelConfig):
    def init(key):
        k1, k2 = jax.random.split(key)
        p, s = {}, {}
        p["ln_attn"], s["ln_attn"] = L.init_layernorm(cfg.d_model, cfg.param_dtype)
        p["attn"], s["attn"] = L.init_attention(cfg, k1)
        p["ln_mlp"], s["ln_mlp"] = L.init_layernorm(cfg.d_model, cfg.param_dtype)
        p["mlp"], s["mlp"] = L.init_mlp(cfg, k2)
        return p, s
    return init


def _dec_layer_init(cfg: ModelConfig):
    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        p, s = {}, {}
        p["ln_self"], s["ln_self"] = L.init_layernorm(cfg.d_model, cfg.param_dtype)
        p["self_attn"], s["self_attn"] = L.init_attention(cfg, k1)
        p["ln_cross"], s["ln_cross"] = L.init_layernorm(cfg.d_model, cfg.param_dtype)
        p["cross_attn"], s["cross_attn"] = L.init_attention(cfg, k2)
        p["ln_mlp"], s["ln_mlp"] = L.init_layernorm(cfg.d_model, cfg.param_dtype)
        p["mlp"], s["mlp"] = L.init_mlp(cfg, k3)
        return p, s
    return init


def init_model(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    """Returns (params, logical_specs) — same tree structure."""
    keys = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    s: Dict[str, Any] = {}
    p["embed"] = L.truncated_normal(keys[0], (cfg.vocab, cfg.d_model),
                                    cfg.param_dtype, 0.02)
    s["embed"] = ("w_vocab", "w_embed")
    p["head"] = L.truncated_normal(keys[1], (cfg.d_model, cfg.vocab),
                                   cfg.param_dtype, 0.02)
    s["head"] = ("w_embed", "w_vocab")
    p["ln_f"], s["ln_f"] = L.init_rmsnorm(cfg.d_model, cfg.param_dtype)

    if cfg.family in ("dense", "moe"):
        p["layers"], s["layers"] = _stack_init(
            _dense_layer_init(cfg), cfg.n_layers, keys[2])
    elif cfg.family == "griffin":
        n_groups = cfg.n_layers // 3
        n_tail = cfg.n_layers - 3 * n_groups
        p["groups"], s["groups"] = _stack_init(
            _griffin_group_init(cfg), n_groups, keys[2])
        if n_tail:
            p["tail"], s["tail"] = _stack_init(
                _griffin_sub_init(cfg, RGLRU), n_tail, keys[3])
    elif cfg.family == "xlstm":
        assert cfg.n_layers % 2 == 0
        p["pairs"], s["pairs"] = _stack_init(
            _xlstm_pair_init(cfg), cfg.n_layers // 2, keys[2])
    elif cfg.family == "encdec":
        p["enc"], s["enc"] = _stack_init(
            _enc_layer_init(cfg), cfg.n_enc_layers, keys[2])
        p["dec"], s["dec"] = _stack_init(
            _dec_layer_init(cfg), cfg.n_layers, keys[3])
        p["ln_enc"], s["ln_enc"] = L.init_layernorm(cfg.d_model, cfg.param_dtype)
    else:
        raise ValueError(cfg.family)
    return p, s


def param_count(params: Dict) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Block bodies shared by training forward and prefill
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if getattr(cfg, "remat_policy", "nothing") == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        return jax.checkpoint(fn, policy=policy)
    return fn


def _dense_block_seq(cfg: ModelConfig, x, lp, positions, cache=None,
                     cache_index=None):
    if cfg.bf16_grad_barrier:
        x = L.grad_bf16_barrier(x)
    h, new_cache = L.attention_layer(
        lp["attn"], cfg, L.rmsnorm(lp["ln_attn"], x, cfg.norm_eps),
        positions=positions, causal=True, cache=cache, cache_index=cache_index)
    x = x + h
    y_in = L.rmsnorm(lp["ln_mlp"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts > 0:
        y, aux = MOE.moe_block(lp["moe"], cfg, y_in)
        if cfg.dense_residual:
            y = y + L.mlp(lp["mlp"], y_in)
        if cfg.n_shared > 0:
            y = y + L.mlp(lp["shared"], y_in)
    else:
        y = L.mlp(lp["mlp"], y_in)
    return x + y, aux, new_cache


def _griffin_sub_seq(cfg: ModelConfig, x, sp, kind, positions, state=None,
                     cache=None, cache_index=None):
    h_in = L.rmsnorm(sp["ln"], x, cfg.norm_eps)
    new_state, new_cache = None, None
    if kind == RGLRU:
        h, new_state = R.griffin_recurrent_block(sp["block"], cfg, h_in, state)
    else:
        h, new_cache = L.attention_layer(
            sp["block"], cfg, h_in, positions=positions, causal=True,
            window=cfg.window, cache=cache, cache_index=cache_index)
    x = x + h
    x = x + L.mlp(sp["mlp"], L.rmsnorm(sp["ln_mlp"], x, cfg.norm_eps))
    return x, new_state, new_cache


def _encoder(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stub frame embeddings (bidirectional)."""
    x = frames.astype(cfg.dtype)
    x = logical_shard(x, "batch", None, None)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

    def body(x, lp):
        h, _ = L.attention_layer(
            lp["attn"], cfg, L.layernorm(lp["ln_attn"], x, cfg.norm_eps),
            positions=pos, causal=False)
        x = x + h
        x = x + L.mlp(lp["mlp"], L.layernorm(lp["ln_mlp"], x, cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc"])
    return L.layernorm(params["ln_enc"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Training forward + loss
# ---------------------------------------------------------------------------

def forward(
    params: Dict,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, S) int32
    *,
    positions: Optional[jax.Array] = None,
    frames: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Training forward (no cache). Returns (logits, moe_aux_loss)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = logical_shard(x, "batch", None, None)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe"):
        def body(carry, lp):
            x, aux = carry
            x, a, _ = _dense_block_seq(cfg, x, lp, positions)
            return (x, aux + a), None
        (x, aux_total), _ = jax.lax.scan(
            _maybe_remat(body, cfg), (x, aux_total), params["layers"])

    elif cfg.family == "griffin":
        def body(x, gp):
            x, _, _ = _griffin_sub_seq(cfg, x, gp["rg1"], RGLRU, positions)
            x, _, _ = _griffin_sub_seq(cfg, x, gp["rg2"], RGLRU, positions)
            x, _, _ = _griffin_sub_seq(cfg, x, gp["attn"], ATTN, positions)
            return x, None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["groups"])
        if "tail" in params:
            def tbody(x, tp):
                x, _, _ = _griffin_sub_seq(cfg, x, tp, RGLRU, positions)
                return x, None
            x, _ = jax.lax.scan(_maybe_remat(tbody, cfg), x, params["tail"])

    elif cfg.family == "xlstm":
        def body(x, pp):
            y, _ = R.slstm_scan(pp["slstm"],
                                L.rmsnorm(pp["ln_s"], x, cfg.norm_eps))
            x = x + y
            x = x + R.mlstm_chunkwise(pp["mlstm"], cfg,
                                      L.rmsnorm(pp["ln_m"], x, cfg.norm_eps))
            return x, None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["pairs"])

    elif cfg.family == "encdec":
        assert frames is not None, "encdec needs stub frame embeddings"
        enc_out = _encoder(params, cfg, frames)
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        def body(x, lp):
            h, _ = L.attention_layer(
                lp["self_attn"], cfg,
                L.layernorm(lp["ln_self"], x, cfg.norm_eps),
                positions=pos, causal=True)
            x = x + h
            h, _ = L.attention_layer(
                lp["cross_attn"], cfg,
                L.layernorm(lp["ln_cross"], x, cfg.norm_eps),
                kv_source=enc_out)
            x = x + h
            x = x + L.mlp(lp["mlp"], L.layernorm(lp["ln_mlp"], x, cfg.norm_eps))
            return x, None
        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["dec"])
    else:
        raise ValueError(cfg.family)

    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(cfg.logit_dtype),
                        params["head"].astype(cfg.logit_dtype))
    logits = logical_shard(logits, "batch", None, "vocab_act")
    return logits, aux_total


def loss_fn(params: Dict, cfg: ModelConfig, batch: Dict,
            aux_weight: float = 0.01) -> Tuple[jax.Array, Dict]:
    """Next-token cross entropy (+ MoE load-balance aux)."""
    logits, aux = forward(
        params, cfg, batch["tokens"],
        positions=batch.get("positions"), frames=batch.get("frames"))
    labels = batch["labels"]
    valid = labels >= 0
    lab = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * valid
    loss = ce.sum() / jnp.maximum(valid.sum(), 1)
    total = loss + aux_weight * aux
    return total, {"ce": loss, "aux": aux, "tokens": valid.sum()}


# ---------------------------------------------------------------------------
# Serving: cache init, prefill, decode step
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               prefill: bool = False) -> Dict:
    """Decode-state pytree per family. Attention caches are bf16.

    For griffin the decode cache is a *ring buffer* of the window size;
    prefill uses a full-length buffer (sequence-sharded) instead.
    """
    hd, kv = cfg.head_dim, cfg.n_kv
    if cfg.family in ("dense", "moe"):
        shape = (cfg.n_layers, batch, max_len, kv, hd)
        return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype),
                "index": jnp.zeros((), jnp.int32)}
    if cfg.family == "griffin":
        n_groups = cfg.n_layers // 3
        n_tail = cfg.n_layers - 3 * n_groups
        win = max_len if prefill else min(cfg.window or max_len, max_len)
        w = cfg.lru_width or cfg.d_model
        cache = {
            "k": jnp.zeros((n_groups, batch, win, kv, hd), cfg.dtype),
            "v": jnp.zeros((n_groups, batch, win, kv, hd), cfg.dtype),
            "conv": jnp.zeros((n_groups, 2, batch, cfg.conv_width - 1, w), cfg.dtype),
            "h": jnp.zeros((n_groups, 2, batch, w), cfg.dtype),
            "index": jnp.zeros((), jnp.int32),
        }
        if n_tail:
            cache["tail_conv"] = jnp.zeros(
                (n_tail, batch, cfg.conv_width - 1, w), cfg.dtype)
            cache["tail_h"] = jnp.zeros((n_tail, batch, w), cfg.dtype)
        return cache
    if cfg.family == "xlstm":
        n_pairs = cfg.n_layers // 2
        nh = cfg.n_heads
        hd2 = cfg.d_model // nh
        d = cfg.d_model
        return {
            "s_c": jnp.zeros((n_pairs, batch, d), jnp.float32),
            "s_n": jnp.zeros((n_pairs, batch, d), jnp.float32),
            "s_m": jnp.full((n_pairs, batch, d), -1e30, jnp.float32),
            "m_C": jnp.zeros((n_pairs, batch, nh, hd2, hd2), jnp.float32),
            "m_n": jnp.zeros((n_pairs, batch, nh, hd2), jnp.float32),
            "m_m": jnp.full((n_pairs, batch, nh), -30.0, jnp.float32),
            "index": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "encdec":
        enc_len = max(max_len // cfg.enc_frames_ratio, 1)
        shape = (cfg.n_layers, batch, max_len, kv, hd)
        return {
            "k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype),
            "enc_out": jnp.zeros((batch, enc_len, cfg.d_model), cfg.dtype),
            "index": jnp.zeros((), jnp.int32),
        }
    raise ValueError(cfg.family)


def prefill(params: Dict, cfg: ModelConfig, tokens: jax.Array,
            *, positions: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None,
            max_len: Optional[int] = None) -> Tuple[jax.Array, Dict]:
    """Process the prompt, build the decode state. Returns (last_logits, cache).

    ``max_len`` reserves cache room beyond the prompt for decoding.
    """
    b, s = tokens.shape
    cache = init_cache(cfg, b, max(max_len or s, s), prefill=True)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = logical_shard(x, "batch", None, None)

    if cfg.family in ("dense", "moe"):
        def body(x, xs):
            lp, ck, cv = xs
            x, _, kv = _dense_block_seq(cfg, x, lp, positions,
                                        cache=(ck, cv), cache_index=0)
            return x, kv
        x, kvs = jax.lax.scan(_maybe_remat(body, cfg), x,
                              (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": kvs[0], "v": kvs[1], "index": jnp.asarray(s, jnp.int32)}

    elif cfg.family == "griffin":
        def body(x, xs):
            gp, ck, cv = xs
            x, s1, _ = _griffin_sub_seq(cfg, x, gp["rg1"], RGLRU, positions)
            x, s2, _ = _griffin_sub_seq(cfg, x, gp["rg2"], RGLRU, positions)
            x, _, kv = _griffin_sub_seq(cfg, x, gp["attn"], ATTN, positions,
                                        cache=(ck, cv), cache_index=0)
            conv = jnp.stack([s1["conv"], s2["conv"]])
            h = jnp.stack([s1["h"], s2["h"]])
            return x, (kv[0], kv[1], conv, h)
        x, outs = jax.lax.scan(_maybe_remat(body, cfg), x,
                               (params["groups"], cache["k"], cache["v"]))
        new_cache = {"k": outs[0], "v": outs[1], "conv": outs[2], "h": outs[3],
                     "index": jnp.asarray(s, jnp.int32)}
        if "tail" in params:
            def tbody(x, tp):
                x, st, _ = _griffin_sub_seq(cfg, x, tp, RGLRU, positions)
                return x, (st["conv"], st["h"])
            x, touts = jax.lax.scan(_maybe_remat(tbody, cfg), x, params["tail"])
            new_cache["tail_conv"], new_cache["tail_h"] = touts

    elif cfg.family == "xlstm":
        def body(x, pp):
            y, s_state = R.slstm_scan(pp["slstm"],
                                      L.rmsnorm(pp["ln_s"], x, cfg.norm_eps))
            x = x + y
            y, m_state = R.mlstm_chunkwise(
                pp["mlstm"], cfg, L.rmsnorm(pp["ln_m"], x, cfg.norm_eps),
                return_state=True)
            x = x + y
            return x, (s_state["c"], s_state["n"], s_state["m"],
                       m_state["C"], m_state["n"], m_state["m"])
        x, outs = jax.lax.scan(_maybe_remat(body, cfg), x, params["pairs"])
        new_cache = {"s_c": outs[0], "s_n": outs[1], "s_m": outs[2],
                     "m_C": outs[3], "m_n": outs[4], "m_m": outs[5],
                     "index": jnp.asarray(s, jnp.int32)}

    elif cfg.family == "encdec":
        assert frames is not None
        enc_out = _encoder(params, cfg, frames)
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

        def body(x, xs):
            lp, ck, cv = xs
            h, kv = L.attention_layer(
                lp["self_attn"], cfg,
                L.layernorm(lp["ln_self"], x, cfg.norm_eps),
                positions=pos, causal=True, cache=(ck, cv), cache_index=0)
            x = x + h
            h, _ = L.attention_layer(
                lp["cross_attn"], cfg,
                L.layernorm(lp["ln_cross"], x, cfg.norm_eps),
                kv_source=enc_out)
            x = x + h
            x = x + L.mlp(lp["mlp"], L.layernorm(lp["ln_mlp"], x, cfg.norm_eps))
            return x, kv
        x, kvs = jax.lax.scan(_maybe_remat(body, cfg), x,
                              (params["dec"], cache["k"], cache["v"]))
        new_cache = {"k": kvs[0], "v": kvs[1], "enc_out": enc_out,
                     "index": jnp.asarray(s, jnp.int32)}
    else:
        raise ValueError(cfg.family)

    x = L.rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(cfg.logit_dtype),
                        params["head"].astype(cfg.logit_dtype))
    return logits, new_cache


def _ring_positions(win: int, index: jax.Array) -> jax.Array:
    """Absolute position stored in each ring-buffer slot at time ``index``."""
    i = jnp.arange(win)
    return index - ((index - i) % win)


def decode_step(params: Dict, cfg: ModelConfig, cache: Dict,
                tokens: jax.Array) -> Tuple[jax.Array, Dict]:
    """One token step. tokens: (B, 1). Returns (logits (B,1,V), new cache)."""
    b = tokens.shape[0]
    index = cache["index"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = logical_shard(x, "batch", None, None)
    pos = jnp.broadcast_to(index[None, None], (b, 1))

    if cfg.family in ("dense", "moe"):
        def body(x, xs):
            lp, ck, cv = xs
            x, _, kv = _dense_block_seq(cfg, x, lp, pos, cache=(ck, cv),
                                        cache_index=index)
            return x, kv
        x, kvs = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": kvs[0], "v": kvs[1], "index": index + 1}

    elif cfg.family == "griffin":
        win = cache["k"].shape[2]
        slot = index % win
        kpos = _ring_positions(win, index)

        def attn_ring(sp, x_in, ck, cv):
            h_in = L.rmsnorm(sp["ln"], x_in, cfg.norm_eps)
            hd, h_, n_kv = cfg.head_dim, cfg.n_heads, cfg.n_kv
            ap = sp["block"]
            q = jnp.einsum("bsd,dh->bsh", h_in, ap["wq"]).reshape(b, 1, h_, hd)
            k = jnp.einsum("bsd,dh->bsh", h_in, ap["wk"]).reshape(b, 1, n_kv, hd)
            v = jnp.einsum("bsd,dh->bsh", h_in, ap["wv"]).reshape(b, 1, n_kv, hd)
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, slot, 0, 0))
            valid = (kpos <= index) & (index - kpos < win) & (kpos >= 0)
            sc = jnp.einsum(
                "bqkgd,bckd->bkgqc",
                q.reshape(b, 1, n_kv, h_ // n_kv, hd).astype(jnp.float32),
                ck.astype(jnp.float32)) / math.sqrt(hd)
            sc = jnp.where(valid[None, None, None, None, :], sc, L.NEG_INF)
            w = jax.nn.softmax(sc, axis=-1)
            o = jnp.einsum("bkgqc,bckd->bkgqd", w, cv.astype(jnp.float32))
            o = jnp.moveaxis(o, 3, 1).reshape(b, 1, h_ * hd).astype(x_in.dtype)
            out = jnp.einsum("bsh,hd->bsd", o, ap["wo"])
            x_new = x_in + out
            x_new = x_new + L.mlp(sp["mlp"],
                                  L.rmsnorm(sp["ln_mlp"], x_new, cfg.norm_eps))
            return x_new, ck, cv

        def body(x, xs):
            gp, ck, cv, conv, hstate = xs
            st1 = {"conv": conv[0], "h": hstate[0]}
            x, s1, _ = _griffin_sub_seq(cfg, x, gp["rg1"], RGLRU, pos, state=st1)
            st2 = {"conv": conv[1], "h": hstate[1]}
            x, s2, _ = _griffin_sub_seq(cfg, x, gp["rg2"], RGLRU, pos, state=st2)
            x, ck, cv = attn_ring(gp["attn"], x, ck, cv)
            conv_new = jnp.stack([s1["conv"], s2["conv"]])
            h_new = jnp.stack([s1["h"], s2["h"]])
            return x, (ck, cv, conv_new, h_new)

        x, outs = jax.lax.scan(
            body, x, (params["groups"], cache["k"], cache["v"],
                      cache["conv"], cache["h"]))
        new_cache = {"k": outs[0], "v": outs[1], "conv": outs[2], "h": outs[3],
                     "index": index + 1}
        if "tail" in params:
            def tbody(x, xs):
                tp, conv, hstate = xs
                st = {"conv": conv, "h": hstate}
                x, s_new, _ = _griffin_sub_seq(cfg, x, tp, RGLRU, pos, state=st)
                return x, (s_new["conv"], s_new["h"])
            x, touts = jax.lax.scan(
                tbody, x, (params["tail"], cache["tail_conv"], cache["tail_h"]))
            new_cache["tail_conv"], new_cache["tail_h"] = touts

    elif cfg.family == "xlstm":
        def body(x, xs):
            pp, sc, sn, sm, mC, mn, mm = xs
            y, s_new = R.slstm_scan(pp["slstm"],
                                    L.rmsnorm(pp["ln_s"], x, cfg.norm_eps),
                                    state={"c": sc, "n": sn, "m": sm})
            x = x + y
            y, m_new = R.mlstm_step(pp["mlstm"], cfg,
                                    L.rmsnorm(pp["ln_m"], x, cfg.norm_eps),
                                    {"C": mC, "n": mn, "m": mm})
            x = x + y
            return x, (s_new["c"], s_new["n"], s_new["m"],
                       m_new["C"], m_new["n"], m_new["m"])
        x, outs = jax.lax.scan(
            body, x, (params["pairs"], cache["s_c"], cache["s_n"], cache["s_m"],
                      cache["m_C"], cache["m_n"], cache["m_m"]))
        new_cache = {"s_c": outs[0], "s_n": outs[1], "s_m": outs[2],
                     "m_C": outs[3], "m_n": outs[4], "m_m": outs[5],
                     "index": index + 1}

    elif cfg.family == "encdec":
        enc_out = cache["enc_out"]

        def body(x, xs):
            lp, ck, cv = xs
            h, kv = L.attention_layer(
                lp["self_attn"], cfg,
                L.layernorm(lp["ln_self"], x, cfg.norm_eps),
                positions=pos, causal=True, cache=(ck, cv), cache_index=index)
            x = x + h
            h, _ = L.attention_layer(
                lp["cross_attn"], cfg,
                L.layernorm(lp["ln_cross"], x, cfg.norm_eps),
                kv_source=enc_out)
            x = x + h
            x = x + L.mlp(lp["mlp"], L.layernorm(lp["ln_mlp"], x, cfg.norm_eps))
            return x, kv
        x, kvs = jax.lax.scan(body, x, (params["dec"], cache["k"], cache["v"]))
        new_cache = {"k": kvs[0], "v": kvs[1], "enc_out": enc_out,
                     "index": index + 1}
    else:
        raise ValueError(cfg.family)

    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x.astype(cfg.logit_dtype),
                        params["head"].astype(cfg.logit_dtype))
    logits = logical_shard(logits, "batch", None, "vocab_act")
    return logits, new_cache

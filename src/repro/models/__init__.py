from .config import SHAPES, ModelConfig, ShapeConfig
from .model import (decode_step, forward, init_cache, init_model, loss_fn,
                    param_count, prefill)

__all__ = ["SHAPES", "ModelConfig", "ShapeConfig", "decode_step", "forward",
           "init_cache", "init_model", "loss_fn", "param_count", "prefill"]

"""Mixture-of-Experts with scatter-based capacity dispatch.

Design notes (DESIGN.md section 4):
  * tokens are grouped PER BATCH ROW so the position-in-expert cumsum never
    crosses a data shard (no sequential cross-shard dependency);
  * dispatch uses scatter-add into an (B, E, C, D) buffer instead of the
    GShard one-hot einsum — the (tokens, E, C) one-hot blow-up never
    materializes (at 32k x 32 x 128e that tensor would be ~10 TB);
  * expert weights are sharded E->'data' (expert parallelism) with the FFN
    dim on 'model'; XLA inserts the token all-to-all from the sharding
    constraints;
  * qwen2-moe style shared experts run as a parallel dense SwiGLU; arctic's
    dense residual branch likewise.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import current_rules, logical_shard

from .config import ModelConfig
from .layers import truncated_normal


def init_moe(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    d, f, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = 0.02
    p = {
        "router": truncated_normal(k1, (d, e), jnp.float32, std),
        "w_gate": truncated_normal(k2, (e, d, f), cfg.param_dtype, std),
        "w_up": truncated_normal(k3, (e, d, f), cfg.param_dtype, std),
        "w_down": truncated_normal(k4, (e, f, d), cfg.param_dtype,
                                   std / math.sqrt(2 * cfg.n_layers)),
    }
    s = {
        # The router is tiny (d_model x E ~ a few MB): REPLICATE it. FSDP-
        # sharding its d_model dim makes the backward emit a full fp32 dx
        # all-reduce over the data axis per layer per micro (~1.3 TB/step
        # for arctic) — see EXPERIMENTS.md section Perf, arctic iteration 3.
        "router": (None, None),
        "w_gate": ("w_experts", None, "w_mlp"),
        "w_up": ("w_experts", None, "w_mlp"),
        "w_down": ("w_experts", "w_mlp", None),
    }
    return p, s


def _buf_axes(cfg: ModelConfig):
    """Dispatch-buffer sharding. EP mode aligns the buffer's expert axis
    with the expert-sharded weights (token all-to-all, expert grads stay
    local — no cross-data grad all-reduce for expert weights); fallback is
    batch sharding when the expert count doesn't divide the data axis."""
    rules = current_rules()
    if cfg.moe_ep_dispatch and rules is not None and rules.mesh is not None:
        dp = rules.mesh.shape.get("data", 1)
        if cfg.n_experts % max(dp, 1) == 0:
            return (None, "w_experts", None, None)
    return ("batch", "experts_act", None, None)


def moe_capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(tokens_per_group * cfg.top_k / cfg.n_experts
                      * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8, min 8


def moe_block(p: Dict, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss). Router in fp32.

    Returns the load-balancing auxiliary loss (Switch-style) alongside the
    output so the training loop can add it.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = moe_capacity(s, cfg)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (B,S,E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    # Switch aux loss: mean(prob per expert) * mean(assignment per expert) * E
    me = probs.mean(axis=(0, 1))  # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (b * s * k))
    aux = jnp.sum(me * ce) * e

    # position-in-expert within each batch row (group)
    flat_e = expert_idx.reshape(b, s * k)  # (B, S*k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (B, S*k, E)
    pos = jnp.cumsum(onehot, axis=1) - 1  # (B, S*k, E)
    pos = jnp.take_along_axis(pos, flat_e[..., None], axis=-1)[..., 0]  # (B,S*k)
    keep = (pos < c).astype(x.dtype)  # dropped beyond capacity

    # scatter tokens into the (B, E, C, D) dispatch buffer
    tok = jnp.repeat(x, k, axis=1)  # (B, S*k, D) token per assignment slot
    w = keep * gate_vals.reshape(b, s * k).astype(x.dtype)
    pos_c = jnp.minimum(pos, c - 1)
    buf = jnp.zeros((b, e, c, d), dtype=x.dtype)
    bidx = jnp.arange(b)[:, None]
    buf = buf.at[bidx, flat_e, pos_c].add(tok * keep[..., None])
    buf = logical_shard(buf, *_buf_axes(cfg))

    # expert FFN (SwiGLU), E-sharded
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"]))
    h = h * jnp.einsum("becd,edf->becf", buf, p["w_up"])
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])
    out_buf = logical_shard(out_buf, *_buf_axes(cfg))

    # gather back and combine with gate weights
    y_slots = out_buf[bidx, flat_e, pos_c]  # (B, S*k, D)
    y = (y_slots * w[..., None]).reshape(b, s, k, d).sum(axis=2)
    y = logical_shard(y, "batch", None, None)
    return y.astype(x.dtype), aux

"""Core layers: norms, RoPE/M-RoPE, chunked (flash-style) attention, MLP.

Everything is pure JAX (no flax). Parameters are nested dicts; each ``init_*``
returns (params, spec) where spec mirrors the params tree with logical-axis
tuples consumed by repro.sharding.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import logical_shard

from .config import ModelConfig


def truncated_normal(key, shape, dtype, std):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


@jax.custom_vjp
def grad_bf16_barrier(x):
    """Identity with a bf16 cotangent cast.

    The f32 logits/loss head makes every residual-stream cotangent f32; XLA
    then promotes the tensor-parallel psums in the backward pass to f32
    (2x wire bytes + 2x bwd activation traffic). Casting the cotangent back
    to bf16 at block boundaries keeps the backward collectives in bf16 —
    the standard mixed-precision training contract."""
    return x


def _gbb_fwd(x):
    return x, None


def _gbb_bwd_cast(_, g):
    return (g.astype(jnp.bfloat16),)


grad_bf16_barrier.defvjp(_gbb_fwd, _gbb_bwd_cast)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Tuple[Dict, Dict]:
    return {"scale": jnp.ones((d,), dtype=dtype)}, {"scale": (None,)}


def rmsnorm(params: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def init_layernorm(d: int, dtype) -> Tuple[Dict, Dict]:
    return (
        {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)},
        {"scale": (None,), "bias": (None,)},
    )


def layernorm(params: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    sin, cos = jnp.sin(angles)[..., None, :], jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions: (3, B, S) — temporal / height / width position streams.
    sections: per-stream number of (pair) frequencies, summing to D/2.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)  # (D/2,)
    # select the position stream per frequency band
    sec_id = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections),
                        total_repeat_length=d // 2)  # (D/2,)
    # angles[b, s, f] = positions[sec_id[f], b, s] * freqs[f]
    angles = jnp.einsum("tbs,tf->bsf", positions.astype(jnp.float32),
                        jax.nn.one_hot(sec_id, len(sections), dtype=jnp.float32).T
                        * freqs[None, :])
    sin, cos = jnp.sin(angles)[..., None, :], jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (chunked online-softmax; GQA grouped; causal / window / bidir)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def chunked_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, Kv, D)
    v: jax.Array,  # (B, Sk, Kv, D)
    *,
    causal: bool,
    q_offset: Any = 0,  # scalar or (B,) start position of q within kv timeline
    window: int = 0,
    kv_len: Optional[jax.Array] = None,  # (B,) valid kv length (decode)
    chunk: int = 1024,
) -> jax.Array:
    """Flash-style attention: scan over KV chunks with online softmax.

    Peak memory is O(Sq * chunk) per head group instead of O(Sq * Sk). The
    Pallas kernel (repro.kernels.flash_attention) implements the same
    contract for TPU; this is the XLA reference path used by the dry-run.
    """
    with jax.named_scope("chunked_attention"):
        return _chunked_attention_impl(q, k, v, causal=causal,
                                       q_offset=q_offset, window=window,
                                       kv_len=kv_len, chunk=chunk)


def _chunked_attention_impl(q, k, v, *, causal, q_offset, window, kv_len,
                            chunk):
    b, sq, h, d = q.shape
    _, sk, n_kv, _ = k.shape
    g = h // n_kv
    qg = q.reshape(b, sq, n_kv, g, d)
    scale = 1.0 / math.sqrt(d)

    chunk = min(chunk, sk)
    n_chunks = sk // chunk
    assert sk % chunk == 0, (sk, chunk)
    kc = k.reshape(b, n_chunks, chunk, n_kv, d)
    vc = v.reshape(b, n_chunks, chunk, n_kv, d)

    q_pos = jnp.asarray(q_offset)[..., None] + jnp.arange(sq)  # (B?, Sq)
    q_pos = jnp.broadcast_to(q_pos, (b, sq))

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, c_idx = xs
        k_pos = c_idx * chunk + jnp.arange(chunk)  # (chunk,)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
        mask = jnp.ones((b, sq, chunk), dtype=bool)
        if causal:
            mask &= q_pos[:, :, None] >= k_pos[None, None, :]
        if window > 0:
            mask &= (q_pos[:, :, None] - k_pos[None, None, :]) < window
        if kv_len is not None:
            mask &= k_pos[None, None, :] < kv_len[:, None, None]
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, n_kv, g, sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, sq), dtype=jnp.float32)
    acc0 = jnp.zeros((b, n_kv, g, sq, d), dtype=jnp.float32)
    idx = jnp.arange(n_chunks)
    kcs = jnp.moveaxis(kc, 1, 0)  # (C, B, chunk, Kv, D)
    vcs = jnp.moveaxis(vc, 1, 0)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kcs, vcs, idx))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, d)  # (B,Sq,Kv,G,D)->(B,Sq,H,D)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention layer (projections + rope + cache handling)
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key, d_model: Optional[int] = None,
                   cross: bool = False) -> Tuple[Dict, Dict]:
    d = d_model or cfg.d_model
    hd, h, kv = cfg.head_dim, cfg.n_heads, cfg.n_kv
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = 0.02
    p = {
        "wq": truncated_normal(k1, (d, h * hd), cfg.param_dtype, std),
        "wk": truncated_normal(k2, (d, kv * hd), cfg.param_dtype, std),
        "wv": truncated_normal(k3, (d, kv * hd), cfg.param_dtype, std),
        "wo": truncated_normal(k4, (h * hd, d), cfg.param_dtype, std / math.sqrt(2 * cfg.n_layers)),
    }
    s = {
        "wq": ("w_embed", "w_heads"),
        "wk": ("w_embed", "w_heads"),
        "wv": ("w_embed", "w_heads"),
        "wo": ("w_heads", "w_embed"),
    }
    if cfg.qk_norm:
        qp, qs = init_rmsnorm(hd, cfg.param_dtype)
        kp, ks = init_rmsnorm(hd, cfg.param_dtype)
        p["q_norm"], p["k_norm"] = qp, kp
        s["q_norm"], s["k_norm"] = qs, ks
    return p, s


def attention_layer(
    p: Dict,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, D)
    *,
    positions: Optional[jax.Array] = None,  # (B,S) or (3,B,S) for mrope
    causal: bool = True,
    window: int = 0,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (B, Smax, Kv, D) x2
    cache_index: Optional[jax.Array] = None,  # scalar current length
    kv_source: Optional[jax.Array] = None,  # cross attention source
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    b, s, d = x.shape
    hd, h, n_kv = cfg.head_dim, cfg.n_heads, cfg.n_kv
    src = kv_source if kv_source is not None else x

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,dh->bsh", src, p["wk"]).reshape(b, src.shape[1], n_kv, hd)
    v = jnp.einsum("bsd,dh->bsh", src, p["wv"]).reshape(b, src.shape[1], n_kv, hd)
    q = logical_shard(q, "batch", None, "heads", None)
    k = logical_shard(k, "batch", None, "kv_heads", None)
    v = logical_shard(v, "batch", None, "kv_heads", None)

    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)

    if kv_source is None:  # self-attention: rotary embedding
        if positions is None:
            base = cache_index if cache_index is not None else 0
            positions = jnp.arange(s)[None, :] + base
            positions = jnp.broadcast_to(positions, (b, s))
        if cfg.mrope_sections:
            if positions.ndim == 2:  # text-only fallback: same stream x3
                positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
            q_offset = positions[0, :, 0]
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            q_offset = positions[:, 0]
    else:
        q_offset = jnp.zeros((b,), dtype=jnp.int32)

    new_cache = None
    if cache is not None:
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_index, 0, 0))
        ck = logical_shard(ck, "batch", "kv_seq", None, None)
        cv = logical_shard(cv, "batch", "kv_seq", None, None)
        new_cache = (ck, cv)
        k, v = ck, cv
        kv_len = jnp.full((b,), cache_index + s, dtype=jnp.int32)
    else:
        kv_len = None

    out = chunked_attention(
        q, k, v, causal=causal and kv_source is None, q_offset=q_offset,
        window=window, kv_len=kv_len, chunk=cfg.attn_chunk,
    )
    out = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, h * hd), p["wo"])
    out = logical_shard(out, "batch", None, None)
    return out, new_cache


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Tuple[Dict, Dict]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_gate": truncated_normal(k1, (d, f), cfg.param_dtype, 0.02),
        "w_up": truncated_normal(k2, (d, f), cfg.param_dtype, 0.02),
        "w_down": truncated_normal(k3, (f, d), cfg.param_dtype,
                                   0.02 / math.sqrt(2 * cfg.n_layers)),
    }
    s = {"w_gate": ("w_embed", "w_mlp"), "w_up": ("w_embed", "w_mlp"),
         "w_down": ("w_mlp", "w_embed")}
    return p, s


def mlp(p: Dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = logical_shard(h, "batch", None, "mlp_act")
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return logical_shard(out, "batch", None, None)

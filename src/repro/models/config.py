"""Model configuration for every assigned architecture family."""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax.numpy as jnp

# block kinds used in hybrid layer patterns
ATTN = "attn"
RGLRU = "rglru"
SLSTM = "slstm"
MLSTM = "mlstm"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | griffin | xlstm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    moe_ep_dispatch: bool = False  # EP-consistent dispatch (see moe._buf_axes)

    # attention details
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t,h,w)
    window: int = 0  # sliding-window size (griffin local attention)

    # griffin / recurrent
    lru_width: int = 0
    conv_width: int = 4

    # encoder-decoder (whisper): encoder layer count; frontend is a stub
    n_enc_layers: int = 0
    enc_frames_ratio: int = 4  # encoder frames = seq_len // ratio

    # numerics & runtime
    bf16_grad_barrier: bool = False  # bf16 backward collectives (see layers)
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    attn_chunk: int = 1024
    remat: bool = True
    # 'nothing' recomputes everything (min memory, recomputes TP psums in
    # the backward); 'dots' saves matmul outputs (no psum recompute, more
    # memory) -- see EXPERIMENTS.md section Perf, arctic iteration 4
    remat_policy: str = "nothing"
    scan_layers: bool = True
    # lm-head logits are computed in f32 for loss stability
    logit_dtype: Any = jnp.float32

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv

    def pattern(self) -> Tuple[str, ...]:
        """Per-layer temporal-mixing kind."""
        if self.family == "griffin":
            # Griffin: repeating (recurrent, recurrent, local attention)
            out = []
            for i in range(self.n_layers):
                out.append(ATTN if i % 3 == 2 else RGLRU)
            return tuple(out)
        if self.family == "xlstm":
            # alternating sLSTM / mLSTM blocks
            return tuple(SLSTM if i % 2 == 0 else MLSTM
                         for i in range(self.n_layers))
        return tuple(ATTN for _ in range(self.n_layers))

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can decode at 500k context (SSM/hybrid/linear)."""
        return self.family in ("griffin", "xlstm")


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'
    microbatch: int = 0  # global microbatch for grad accumulation (0 = auto)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

"""Recurrent temporal-mixing blocks: RG-LRU (Griffin) and xLSTM cells.

All three support two execution modes:
  * sequence mode (training / prefill): associative-scan (RG-LRU, sLSTM) or
    chunkwise-parallel (mLSTM) over the time axis — sub-quadratic, bounded
    memory;
  * step mode (decode): O(1) recurrent state update.

DESIGN.md records one simplification: sLSTM gates are computed from the
input only (no R_h recurrence), which makes the cell an input-gated linear
recurrence and therefore associative-scannable; this matches the
"parallelizable" xLSTM ablation.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding import logical_shard

from .config import ModelConfig
from .layers import truncated_normal


# ---------------------------------------------------------------------------
# RG-LRU (Real-Gated Linear Recurrent Unit) — Griffin / RecurrentGemma
# ---------------------------------------------------------------------------

def init_rg_lru(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    keys = jax.random.split(key, 6)
    std = 0.02
    p = {
        # input / gate projections (the Griffin recurrent block)
        "w_x": truncated_normal(keys[0], (d, w), cfg.param_dtype, std),
        "w_gate": truncated_normal(keys[1], (d, w), cfg.param_dtype, std),
        "w_out": truncated_normal(keys[2], (w, d), cfg.param_dtype,
                                  std / math.sqrt(2 * cfg.n_layers)),
        # rg-lru gates
        "w_a": truncated_normal(keys[3], (w, w), cfg.param_dtype, std),
        "w_i": truncated_normal(keys[4], (w, w), cfg.param_dtype, std),
        # Lambda parametrized so a = sigmoid(lam)^(8*sigmoid(r)) starts ~0.95
        "lam": jnp.full((w,), 3.0, dtype=jnp.float32),
        # short conv (Griffin conv1d width 4)
        "conv": truncated_normal(keys[5], (cfg.conv_width, w), cfg.param_dtype, std),
    }
    s = {
        "w_x": ("w_embed", "w_state"), "w_gate": ("w_embed", "w_state"),
        "w_out": ("w_state", "w_embed"), "w_a": ("w_state", None),
        "w_i": ("w_state", None), "lam": (None,), "conv": (None, "w_state"),
    }
    return p, s


def _rg_gates(p: Dict, u: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """a_t (decay) and gated input multiplier, both fp32. u: (..., W)."""
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", u, p["w_i"]).astype(jnp.float32))
    log_a = 8.0 * r * jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    return a, i


def rg_lru_scan(p: Dict, u: jax.Array,
                h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """u: (B, S, W) gated input. Returns (y (B,S,W), h_final (B,W)).

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)  — associative scan.
    """
    b, s, w = u.shape
    a, i = _rg_gates(p, u)
    x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-6)) * (i * u.astype(jnp.float32))
    if h0 is not None:
        # fold the carried state into the first step
        x = x.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 * a2, a2 * x1 + x2

    aa, yy = jax.lax.associative_scan(combine, (a, x), axis=1)
    return yy.astype(u.dtype), yy[:, -1]


def rg_lru_step(p: Dict, u: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One decode step. u: (B, 1, W), h: (B, W)."""
    a, i = _rg_gates(p, u[:, 0])
    x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-6)) * (i * u[:, 0].astype(jnp.float32))
    h_new = a * h.astype(jnp.float32) + x
    return h_new.astype(u.dtype)[:, None], h_new.astype(u.dtype)


def causal_conv1d(p_conv: jax.Array, x: jax.Array,
                  state: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: (B,S,W); state: (B, width-1, W)."""
    width = p_conv.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), dtype=x.dtype)
    xt = jnp.concatenate([state, x], axis=1)
    out = sum(xt[:, i:i + x.shape[1]] * p_conv[i] for i in range(width))
    new_state = xt[:, -(width - 1):] if width > 1 else state
    return out.astype(x.dtype), new_state


def griffin_recurrent_block(p: Dict, cfg: ModelConfig, x: jax.Array,
                            state: Optional[Dict] = None
                            ) -> Tuple[jax.Array, Optional[Dict]]:
    """The Griffin recurrent temporal block: (conv -> RG-LRU) x gelu gate."""
    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"])
    u = logical_shard(u, "batch", None, "w_state")
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"]))
    if state is None or u.shape[1] > 1:  # sequence mode (train / prefill)
        conv_in = None if state is None else state["conv"]
        u, conv_state = causal_conv1d(p["conv"], u, conv_in)
        y, h = rg_lru_scan(p, u, None if state is None else state["h"])
        new_state = {"conv": conv_state, "h": h.astype(u.dtype)}
    else:
        u, conv_state = causal_conv1d(p["conv"], u, state["conv"])
        y, h = rg_lru_step(p, u, state["h"])
        new_state = {"conv": conv_state, "h": h}
    out = jnp.einsum("bsw,wd->bsd", y * gate, p["w_out"])
    return logical_shard(out, "batch", None, None), new_state


def init_griffin_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype=dtype),
        "h": jnp.zeros((batch, w), dtype=dtype),
    }


# ---------------------------------------------------------------------------
# xLSTM: sLSTM block (scalar memory) and mLSTM block (matrix memory)
# ---------------------------------------------------------------------------

def init_slstm(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    keys = jax.random.split(key, 5)
    std = 0.02
    p = {
        "w_z": truncated_normal(keys[0], (d, d), cfg.param_dtype, std),
        "w_i": truncated_normal(keys[1], (d, d), cfg.param_dtype, std),
        "w_f": truncated_normal(keys[2], (d, d), cfg.param_dtype, std),
        "w_o": truncated_normal(keys[3], (d, d), cfg.param_dtype, std),
        "w_out": truncated_normal(keys[4], (d, d), cfg.param_dtype,
                                  std / math.sqrt(2 * cfg.n_layers)),
    }
    s = {k: ("w_embed", "w_state") for k in p}
    return p, s


def slstm_scan(p: Dict, x: jax.Array, state: Optional[Dict] = None,
               ) -> Tuple[jax.Array, Dict]:
    """sLSTM with exponential gating (input-conditioned gates; see module
    docstring). x: (B, S, D).

    c_t = f_t c_{t-1} + i_t z_t ;  n_t = f_t n_{t-1} + i_t ;  h = o * c/n
    with log-space stabilizer m_t = max(log f_t + m_{t-1}, log i_t).
    """
    b, s, d = x.shape
    z = jnp.tanh(jnp.einsum("bsd,de->bse", x, p["w_z"]).astype(jnp.float32))
    log_i = jnp.einsum("bsd,de->bse", x, p["w_i"]).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bsd,de->bse", x, p["w_f"]).astype(jnp.float32))
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["w_o"]).astype(jnp.float32))

    # stabilized exponential gating as an associative scan over
    # (cumulative log f, stabilized c, stabilized n, running max m)
    def combine(c1, c2):
        f1, m1, cc1, nn1 = c1
        f2, m2, cc2, nn2 = c2
        m = jnp.maximum(m1 + f2, m2)
        scale1 = jnp.exp(m1 + f2 - m)
        scale2 = jnp.exp(m2 - m)
        return f1 + f2, m, cc1 * scale1 + cc2 * scale2, nn1 * scale1 + nn2 * scale2

    m0 = log_i  # per-step stabilizer
    c_elems = (log_f, m0, jnp.exp(log_i - m0) * z, jnp.exp(log_i - m0))
    if state is not None:
        # fold carried (c, n, m) into step 0
        f0, mm0, cc0, nn0 = (log_f[:, 0], m0[:, 0], c_elems[2][:, 0], c_elems[3][:, 0])
        m_in = state["m"].astype(jnp.float32)
        mm = jnp.maximum(m_in + f0, mm0)
        cc = state["c"].astype(jnp.float32) * jnp.exp(m_in + f0 - mm) + cc0 * jnp.exp(mm0 - mm)
        nn = state["n"].astype(jnp.float32) * jnp.exp(m_in + f0 - mm) + nn0 * jnp.exp(mm0 - mm)
        c_elems = (
            c_elems[0], c_elems[1].at[:, 0].set(mm),
            c_elems[2].at[:, 0].set(cc), c_elems[3].at[:, 0].set(nn),
        )
    _, m, c, n = jax.lax.associative_scan(combine, c_elems, axis=1)
    h = o * (c / jnp.maximum(jnp.abs(n), 1.0))
    y = jnp.einsum("bse,ed->bsd", h.astype(x.dtype), p["w_out"])
    new_state = {"c": c[:, -1], "n": n[:, -1], "m": m[:, -1]}
    return logical_shard(y, "batch", None, None), new_state


def init_slstm_state(cfg: ModelConfig, batch: int) -> Dict:
    d = cfg.d_model
    z = jnp.zeros((batch, d), dtype=jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, d), -1e30, dtype=jnp.float32)}


def init_mlstm(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    keys = jax.random.split(key, 6)
    std = 0.02
    p = {
        "w_q": truncated_normal(keys[0], (d, d), cfg.param_dtype, std),
        "w_k": truncated_normal(keys[1], (d, d), cfg.param_dtype, std),
        "w_v": truncated_normal(keys[2], (d, d), cfg.param_dtype, std),
        "w_i": truncated_normal(keys[3], (d, h), cfg.param_dtype, std),
        "w_f": truncated_normal(keys[4], (d, h), cfg.param_dtype, std),
        "w_out": truncated_normal(keys[5], (d, d), cfg.param_dtype,
                                  std / math.sqrt(2 * cfg.n_layers)),
    }
    s = {"w_q": ("w_embed", "w_heads"), "w_k": ("w_embed", "w_heads"),
         "w_v": ("w_embed", "w_heads"), "w_i": ("w_embed", None),
         "w_f": ("w_embed", None), "w_out": ("w_heads", "w_embed")}
    return p, s


def mlstm_chunkwise(p: Dict, cfg: ModelConfig, x: jax.Array,
                    chunk: int = 256,
                    state: Optional[Dict] = None,
                    return_state: bool = False):
    """Chunkwise-parallel mLSTM (matrix memory): intra-chunk quadratic with
    decay mask + inter-chunk carried (C, n) state. x: (B, S, D).

    NOTE on prefill->decode handoff: the chunkwise form carries an
    unstabilized (C, n); the returned state therefore has m = 0 (identity
    scale), which the step form consumes directly."""
    b, s, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    def heads(w):
        return jnp.einsum("bsd,de->bse", x, w).reshape(b, s, nh, hd)

    q = heads(p["w_q"]).astype(jnp.float32) / math.sqrt(hd)
    k = heads(p["w_k"]).astype(jnp.float32) / math.sqrt(hd)
    v = heads(p["w_v"]).astype(jnp.float32)
    log_i = jnp.einsum("bsd,dh->bsh", x, p["w_i"]).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", x, p["w_f"]).astype(jnp.float32))

    rs = lambda t: jnp.moveaxis(t.reshape(b, nc, chunk, *t.shape[2:]), 1, 0)
    qc, kc, vc, ic, fc = map(rs, (q, k, v, log_i, log_f))

    def step(carry, xs):
        C, n = carry  # C: (B,H,hd,hd), n: (B,H,hd)
        qb, kb, vb, ib, fb = xs  # (B, chunk, H, ...)
        f_cum = jnp.cumsum(fb, axis=1)  # (B,chunk,H)
        f_tot = f_cum[:, -1]
        # intra-chunk decay matrix D[t, t'] = exp(f_cum_t - f_cum_t' + i_t')
        logD = (f_cum[:, :, None, :] - f_cum[:, None, :, :]
                + ib[:, None, :, :])  # (B,t,t',H)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        logD = jnp.where(mask[None, :, :, None], logD, -jnp.inf)
        # stabilizer per query step
        m_intra = jnp.max(logD, axis=2)  # (B,t,H)
        m_inter = f_cum  # decay applied to carried state
        m = jnp.maximum(m_intra, m_inter)
        Dm = jnp.exp(logD - m[:, :, None, :])
        s_qk = jnp.einsum("bthd,bshd->btsh", qb, kb) * Dm
        intra = jnp.einsum("btsh,bshd->bthd", s_qk, vb)
        inter_scale = jnp.exp(m_inter - m)  # (B,t,H)
        inter = jnp.einsum("bthd,bhde->bthe", qb, C) * inter_scale[..., None]
        num = intra + inter
        den_intra = s_qk.sum(axis=2)  # (B,t,H)
        den_inter = jnp.einsum("bthd,bhd->bth", qb, n) * inter_scale
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m))
        h = num / den[..., None]
        # update carried state: C' = exp(f_tot) C + sum_t exp(f_tot - f_cum_t + i_t) k_t v_t^T
        w_t = jnp.exp(f_tot[:, None, :] - f_cum + ib)  # (B,chunk,H)
        C_new = jnp.exp(f_tot)[:, :, None, None] * C + jnp.einsum(
            "bthd,bthe->bhde", kb * w_t[..., None], vb)
        n_new = jnp.exp(f_tot)[:, :, None] * n + jnp.einsum(
            "bthd,bth->bhd", kb, w_t)
        return (C_new, n_new), h

    if state is not None:
        # fold a stabilized decode state back to raw scale (exp(m))
        scale = jnp.exp(state["m"].astype(jnp.float32))
        C0 = state["C"].astype(jnp.float32) * scale[..., None, None]
        n0 = state["n"].astype(jnp.float32) * scale[..., None]
    else:
        C0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, nh, hd), jnp.float32)
    (Cf, nf), hs = jax.lax.scan(step, (C0, n0), (qc, kc, vc, ic, fc))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, nh * hd)
    y = jnp.einsum("bse,ed->bsd", h.astype(x.dtype), p["w_out"])
    y = logical_shard(y, "batch", None, None)
    if return_state:
        final = {"C": Cf, "n": nf, "m": jnp.zeros((b, nh), jnp.float32)}
        return y, final
    return y


def mlstm_step(p: Dict, cfg: ModelConfig, x: jax.Array, state: Dict
               ) -> Tuple[jax.Array, Dict]:
    """One decode step with matrix memory. x: (B, 1, D)."""
    b, _, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    xt = x[:, 0]
    q = (xt @ p["w_q"]).reshape(b, nh, hd).astype(jnp.float32) / math.sqrt(hd)
    k = (xt @ p["w_k"]).reshape(b, nh, hd).astype(jnp.float32) / math.sqrt(hd)
    v = (xt @ p["w_v"]).reshape(b, nh, hd).astype(jnp.float32)
    log_i = (xt @ p["w_i"]).astype(jnp.float32)  # (B,H)
    log_f = jax.nn.log_sigmoid((xt @ p["w_f"]).astype(jnp.float32))
    m_prev = state["m"]
    m = jnp.maximum(log_f + m_prev, log_i)
    f_s = jnp.exp(log_f + m_prev - m)[..., None]
    i_s = jnp.exp(log_i - m)[..., None]
    C = f_s[..., None] * state["C"] + i_s[..., None] * (k[..., :, None] * v[..., None, :])
    n = f_s * state["n"] + i_s * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m))
    h = (num / den[..., None]).reshape(b, 1, nh * hd)
    y = jnp.einsum("bse,ed->bsd", h.astype(x.dtype), p["w_out"])
    return y, {"C": C, "n": n, "m": m}


def init_mlstm_state(cfg: ModelConfig, batch: int) -> Dict:
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    return {
        "C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), -30.0, jnp.float32),
    }

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init). Everything below is ordinary.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build the real train/prefill/serve step, lower it with
ShapeDtypeStruct inputs (no allocation), compile for the production mesh,
and record memory_analysis / cost_analysis / per-collective byte counts —
the inputs to EXPERIMENTS.md sections Dry-run and Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single --out results/dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as config_registry
from repro.data.pipeline import make_batch_specs
from repro.models import init_cache, init_model
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.optim import AdamWConfig, adamw_init
from repro.runtime.steps import (TrainState, auto_microbatches,
                                 build_prefill_step, build_serve_step,
                                 build_train_step)
from repro.sharding import AxisRules, best_spec, use_rules
from repro.launch.mesh import make_production_mesh

_is_spec = lambda x: isinstance(x, tuple)

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------

def param_shardings(mesh, shapes_tree, spec_tree, rules=None):
    rules = rules or AxisRules(mesh)
    leaves, treedef = jax.tree.flatten(shapes_tree)
    spec_leaves = treedef.flatten_up_to(spec_tree)
    out = [NamedSharding(mesh, best_spec(l.shape, s, rules))
           for l, s in zip(leaves, spec_leaves)]
    return treedef.unflatten(out)


def batch_shardings(mesh, batch_specs, rules=None):
    rules = rules or AxisRules(mesh)
    out = {}
    for k, v in batch_specs.items():
        if k == "positions":  # (3, B, S)
            logical = (None, "batch", None)
        else:
            logical = ("batch",) + (None,) * (v.ndim - 1)
        out[k] = NamedSharding(mesh, best_spec(v.shape, logical, rules))
    return out


def cache_logical(cfg: ModelConfig, head_sharded: bool = False
                  ) -> Dict[str, Tuple]:
    """Logical axes for each decode-state leaf.

    Default is seq-sharded cache (flash-decoding style — works for every
    kv count). ``head_sharded`` prefers the kv-head axis (no cross-shard
    softmax combine) and is valid when n_kv % tp == 0 (perf lever for
    qwen2-moe/whisper-class archs)."""
    if cfg.family in ("dense", "moe"):
        kv = ((None, "batch", None, "kv_heads", None) if head_sharded
              else (None, "batch", "kv_seq", "kv_heads", None))
        return {"k": kv, "v": kv, "index": ()}
    if cfg.family == "griffin":
        kv = (None, "batch", "kv_seq", "kv_heads", None)
        d = {
            "k": kv, "v": kv,
            "conv": (None, None, "batch", None, "w_state"),
            "h": (None, None, "batch", "w_state"),
            "index": (),
        }
        n_tail = cfg.n_layers - 3 * (cfg.n_layers // 3)
        if n_tail:
            d["tail_conv"] = (None, "batch", None, "w_state")
            d["tail_h"] = (None, "batch", "w_state")
        return d
    if cfg.family == "xlstm":
        return {
            "s_c": (None, "batch", "w_state"), "s_n": (None, "batch", "w_state"),
            "s_m": (None, "batch", "w_state"),
            "m_C": (None, "batch", "heads", None, None),
            "m_n": (None, "batch", "heads", None),
            "m_m": (None, "batch", "heads"),
            "index": (),
        }
    if cfg.family == "encdec":
        kv = (None, "batch", "kv_seq", "kv_heads", None)
        return {"k": kv, "v": kv, "enc_out": ("batch", None, None), "index": ()}
    raise ValueError(cfg.family)


def input_specs(arch: str, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = config_registry.get_config(arch)
    shape = SHAPES[shape_name]
    return make_batch_specs(cfg, shape)


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

# Alternative sharding layouts for the perf loop (section Perf):
# pure_fsdp — no tensor parallelism; weights fully sharded over every mesh
# axis and gathered layer-wise (right-sizes small-dense models where TP
# activation psums dominate the collective term).
RULES_PRESETS = {
    # pod axis used as additional FSDP for weights/optimizer (instead of
    # pure DP) — the 1000+-node memory story for the giants
    "pod_fsdp": {
        "w_embed": [("pod", "data"), "data", None],
        "w_vocab": ["model", None],
    },
    "pure_fsdp": {
        "batch": [("pod", "data", "model"), ("data", "model"), None],
        "heads": [None], "kv_heads": [None],
        "mlp_act": [None], "vocab_act": [None], "experts_act": [None],
        "w_embed": [("data", "model"), "data", None],
        "w_heads": [None], "w_mlp": [None],
        "w_vocab": [("data", "model"), "data", None],
        "w_state": [None],
    },
}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               opt_overrides: Optional[Dict] = None) -> Dict[str, Any]:
    import dataclasses as _dc
    cfg = config_registry.get_config(arch)
    if opt_overrides and opt_overrides.get("cfg_replace"):
        cfg = _dc.replace(cfg, **opt_overrides["cfg_replace"])
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return {"status": "skipped",
                "reason": "full-attention arch at 524k context (see DESIGN.md)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules_over = None
    if opt_overrides and opt_overrides.get("rules_preset"):
        rules_over = RULES_PRESETS[opt_overrides["rules_preset"]]
    rules = AxisRules(mesh, rules_over)
    n_data = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    if rules_over is not None:
        n_data *= mesh.shape.get("model", 1)  # batch spans every axis
    key = jax.random.PRNGKey(0)

    t0 = time.time()
    # shapes + logical specs without allocating anything
    spec_box: Dict[str, Any] = {}

    def _init(k):
        p, s = init_model(cfg, k)
        spec_box["s"] = s
        return p

    param_shapes = jax.eval_shape(_init, key)
    logical = spec_box["s"]
    p_shard = param_shardings(mesh, param_shapes, logical, rules)

    batch_specs = make_batch_specs(cfg, shape)
    b_shard = batch_shardings(mesh, batch_specs, rules)

    info: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "params": int(sum(np.prod(l.shape) for l in jax.tree.leaves(param_shapes))),
    }

    with use_rules(mesh, rules_over):
        if shape.kind == "train":
            opt_cfg = AdamWConfig(
                moment_dtype=jnp.bfloat16 if info["params"] > 1e11 else jnp.float32)
            n_micro = auto_microbatches(cfg, shape, n_data)
            accum = jnp.bfloat16 if info["params"] > 1e11 else jnp.float32
            if opt_overrides:
                n_micro = opt_overrides.get("n_micro", n_micro)
            specs_for_grads = logical if (
                opt_overrides and opt_overrides.get("grad_rs")) else None
            step_fn = build_train_step(cfg, opt_cfg, n_micro,
                                       accum_dtype=accum,
                                       param_specs=specs_for_grads)
            opt_shapes = jax.eval_shape(
                lambda p: adamw_init(opt_cfg, p), param_shapes)
            opt_shard = {
                "m": p_shard, "v": p_shard,
                "step": NamedSharding(mesh, P()),
            }
            state_shapes = TrainState(
                param_shapes, opt_shapes,
                jax.ShapeDtypeStruct((), jnp.int32))
            state_shard = TrainState(p_shard, opt_shard, NamedSharding(mesh, P()))
            info["n_micro"] = n_micro
            jitted = jax.jit(step_fn, in_shardings=(state_shard, b_shard),
                             out_shardings=(state_shard, None))
            lowered = jitted.lower(state_shapes, batch_specs)
        elif shape.kind == "prefill":
            step_fn = build_prefill_step(cfg)
            jitted = jax.jit(step_fn, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(param_shapes, batch_specs)
        else:  # decode
            step_fn = build_serve_step(cfg)
            cache_shapes = jax.eval_shape(
                lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
            c_logical = cache_logical(
                cfg, head_sharded=bool(opt_overrides
                                       and opt_overrides.get("kv_head_shard")))
            c_shard = {
                k: NamedSharding(mesh, best_spec(v.shape, c_logical[k], rules))
                for k, v in cache_shapes.items()
            }
            tok_shard = b_shard["tokens"]
            jitted = jax.jit(step_fn,
                             in_shardings=(p_shard, c_shard, tok_shard),
                             out_shardings=(None, c_shard))
            lowered = jitted.lower(param_shapes, cache_shapes,
                                   batch_specs["tokens"])

    info["lower_s"] = round(time.time() - t0, 1)
    t1 = time.time()
    compiled = lowered.compile()
    info["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    info["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
        + (getattr(mem, "temp_size_in_bytes", 0) or 0),
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    # raw cost_analysis is loop-UNAWARE (scan bodies counted once) — kept
    # for reference; the roofline uses the trip-count-aware HLO analysis.
    info["cost_raw"] = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
    }
    hlo = compiled.as_text()
    if opt_overrides is None or opt_overrides.get("dump_hlo", True):
        import gzip
        os.makedirs("results/hlo", exist_ok=True)
        tag = (opt_overrides or {}).get("tag", "")
        cell_id = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
        if tag:
            cell_id += f"__{tag}"
        with gzip.open(f"results/hlo/{cell_id}.txt.gz", "wt") as f:
            f.write(hlo)
    from repro.launch import hlo_analysis
    hc = hlo_analysis.analyze(hlo)
    info["cost"] = {"flops": hc["flops"], "bytes": hc["hbm_bytes"]}
    info["attention_hbm_bytes"] = hc["attention_hbm_bytes"]
    info["collectives"] = hc["per_collective"]
    info["collective_bytes_total"] = int(hc["collective_bytes"])
    info["hlo_warnings"] = hc["n_warnings"]
    info["status"] = "ok"

    # roofline terms (per chip program; see EXPERIMENTS.md section Roofline)
    chips = int(np.prod(list(mesh.shape.values())))
    info["chips"] = chips
    info["roofline"] = {
        "compute_s": info["cost"]["flops"] / PEAK_FLOPS,
        "memory_s": info["cost"]["bytes"] / HBM_BW,
        "collective_s": info["collective_bytes_total"] / ICI_BW,
    }
    dom = max(info["roofline"], key=info["roofline"].get)
    info["bottleneck"] = dom.replace("_s", "")
    info["model_flops_global"] = model_flops(cfg, shape)
    per_chip = info["model_flops_global"] / chips
    info["model_vs_hlo_flops"] = (per_chip / info["cost"]["flops"]
                                  if info["cost"]["flops"] else None)
    info["roofline_flash"] = optimized_roofline(info, cfg, shape)
    return info


def flash_attention_bytes(cfg: ModelConfig, shape: ShapeConfig,
                          n_micro: int, mesh_shape: Dict[str, int]) -> float:
    """Per-chip HBM traffic of attention under the Pallas flash kernel:
    q, k, v read + o written per pass; scores never leave VMEM.

    Training runs ~3 passes (fwd + remat-fwd + bwd reading q,k,v,o,do);
    prefill 1. Used to model the TPU-target roofline where the kernel
    replaces the XLA chunked path (see EXPERIMENTS.md section Perf).
    """
    if cfg.family in ("xlstm",):
        return 0.0  # no softmax attention
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    tp = mesh_shape.get("model", 1)
    b_local = max(shape.global_batch / dp, 1.0)
    s = shape.seq_len
    hd = cfg.head_dim
    h_local = max(cfg.n_heads / tp, 1.0)
    kv_local = max(cfg.n_kv / tp, 1.0)
    per_layer = 2.0 * (b_local * s * hd) * (2 * h_local + 2 * kv_local)
    if shape.kind == "train":
        passes = 3.0
        per_micro = per_layer / n_micro * passes
        n_layers = cfg.n_layers + cfg.n_enc_layers
        if cfg.family == "griffin":
            n_layers = cfg.n_layers // 3  # only the local-attention blocks
        return per_micro * n_micro * n_layers
    if shape.kind == "prefill":
        n_layers = cfg.n_layers + cfg.n_enc_layers
        if cfg.family == "griffin":
            n_layers = cfg.n_layers // 3
        return per_layer * n_layers
    return 0.0  # decode attention is cache-read dominated; no substitution


def optimized_roofline(info: Dict[str, Any], cfg: ModelConfig,
                       shape: ShapeConfig) -> Optional[Dict[str, float]]:
    """TPU-target roofline with the Pallas flash-attention substitution."""
    att = info.get("attention_hbm_bytes")
    if not att:
        return None
    mesh_shape = ({"pod": 2, "data": 16, "model": 16}
                  if info.get("mesh") == "2x16x16"
                  else {"data": 16, "model": 16})
    flash = flash_attention_bytes(cfg, shape, info.get("n_micro", 1),
                                  mesh_shape)
    mem = max(info["cost"]["bytes"] - att + flash, 0.0)
    return {
        "compute_s": info["roofline"]["compute_s"],
        "memory_s": mem / HBM_BW,
        "collective_s": info["roofline"]["collective_s"],
        "attention_bytes_removed": att,
        "flash_bytes_added": flash,
    }


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) per step (global).

    For prefill we count 2*N*D (forward only); decode counts one new token
    per sequence.
    """
    n_active = _active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n_active * tokens


def _active_params(cfg: ModelConfig) -> float:
    """Parameters touched per token (MoE: top_k + shared + dense residual)."""
    d, hd = cfg.d_model, cfg.head_dim
    attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv) + cfg.n_heads * hd * d
    if cfg.family == "griffin":
        w = cfg.lru_width or d
        rec = 2 * d * w + w * d + 2 * w * w  # in/gate/out + a/i gates
        per_group = 2 * (rec + 3 * d * cfg.d_ff) + attn + 3 * d * cfg.d_ff
        n_groups = cfg.n_layers // 3
        tail = (cfg.n_layers - 3 * n_groups) * (rec + 3 * d * cfg.d_ff)
        body = per_group * n_groups + tail
    elif cfg.family == "xlstm":
        per_pair = 5 * d * d + (3 * d * d + 2 * d * cfg.n_heads + d * d)
        body = per_pair * (cfg.n_layers // 2)
    elif cfg.family == "encdec":
        enc = cfg.n_enc_layers * (attn + 3 * d * cfg.d_ff)
        dec = cfg.n_layers * (2 * attn + 3 * d * cfg.d_ff)
        body = enc + dec
    else:
        ff_active = 0.0
        if cfg.n_experts > 0:
            f = cfg.moe_d_ff or cfg.d_ff
            ff_active = 3 * d * f * cfg.top_k
            if cfg.dense_residual:
                ff_active += 3 * d * cfg.d_ff
            if cfg.n_shared:
                ff_active += 3 * d * f * cfg.n_shared
            ff_active += d * cfg.n_experts  # router
        else:
            ff_active = 3 * d * cfg.d_ff
        body = cfg.n_layers * (attn + ff_active)
    head = 2 * d * cfg.vocab  # embed + lm head
    return body + head


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--overrides", default=None,
                    help="JSON opt overrides, e.g. "
                         "'{\"grad_rs\":true,\"n_micro\":2}'")
    ap.add_argument("--tag", default=None,
                    help="suffix for the result key (perf iterations)")
    args = ap.parse_args()
    overrides = json.loads(args.overrides) if args.overrides else None

    archs = config_registry.ARCHS if (args.all or not args.arch) \
        else [config_registry.canonical(args.arch)]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results: Dict[str, Any] = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cell = f"{arch}|{shape}|{'multi' if mp else 'single'}"
                if args.tag:
                    cell = f"{cell}|{args.tag}"
                if cell in results and results[cell].get("status") in (
                        "ok", "skipped") and not args.force:
                    print(f"[skip cached] {cell}")
                    continue
                print(f"[lowering] {cell}", flush=True)
                try:
                    ov = dict(overrides) if overrides else None
                    if ov is not None and args.tag:
                        ov["tag"] = args.tag
                    info = lower_cell(arch, shape, mp, opt_overrides=ov)
                except Exception as e:  # noqa: BLE001 — record and continue
                    info = {"status": "error", "error": f"{type(e).__name__}: {e}",
                            "traceback": traceback.format_exc()[-2000:]}
                    print(f"[ERROR] {cell}: {info['error']}", flush=True)
                results[cell] = info
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                if info.get("status") == "ok":
                    r = info["roofline"]
                    print(f"[ok] {cell} compile={info['compile_s']}s "
                          f"flops={info['cost']['flops']:.3e} "
                          f"comp={r['compute_s']:.4f}s mem={r['memory_s']:.4f}s "
                          f"coll={r['collective_s']:.4f}s -> {info['bottleneck']}",
                          flush=True)
    n_ok = sum(1 for v in results.values() if v.get("status") == "ok")
    n_skip = sum(1 for v in results.values() if v.get("status") == "skipped")
    n_err = sum(1 for v in results.values() if v.get("status") == "error")
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors")


if __name__ == "__main__":
    main()

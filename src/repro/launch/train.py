"""Training driver: ``python -m repro.launch.train --arch llama3-8b ...``

Single-process end-to-end training with the full substrate: synthetic data
pipeline, AdamW, checkpointing/restart, Metronome comm-gating + iteration
reporting. On a CPU container this runs the reduced (smoke) configs; on real
hardware pass --full and a device mesh materializes via make_production_mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as config_registry
from repro.checkpoint import CheckpointManager, latest_step
from repro.core.controller import StopAndWaitController
from repro.data import SyntheticLM
from repro.optim import AdamWConfig
from repro.runtime.comm_gate import CommGate, IterationReporter
from repro.runtime.steps import build_train_step, init_train_state
from repro.sharding import use_rules
from repro.launch.mesh import make_host_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full", action="store_true",
                    help="full-size config on the production mesh (TPU)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.full:
        cfg = config_registry.get_config(args.arch)
        mesh = make_production_mesh()
    else:
        cfg = config_registry.get_smoke_config(args.arch)
        mesh = make_host_mesh(1, 1)

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    ds = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=0)
    controller = StopAndWaitController()
    gate = CommGate(controller, job=f"train-{args.arch}")
    reporter = IterationReporter(controller, f"train-{args.arch}", priority=1)

    with use_rules(mesh):
        state, _ = init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0))
        step_fn = jax.jit(build_train_step(cfg, opt_cfg, args.n_micro))

        start = 0
        mgr = None
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir, keep_n=3)
            if latest_step(args.ckpt_dir) is not None:
                state, start, _ = mgr.restore_latest(state)
                print(f"resumed from step {start}")

        t_last = time.perf_counter()
        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
            gate.wait_for_slot()  # Metronome TDM actuator (no-op standalone)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t_last
            t_last = time.perf_counter()
            reporter.report(dt)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f} ms/it", flush=True)
            if mgr is not None and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, state)
        if mgr is not None:
            mgr.save(args.steps, state)
            mgr.wait()
    print("done")


if __name__ == "__main__":
    main()

"""Serving driver: continuous batched decoding with Metronome reporting.

``python -m repro.launch.serve --arch llama3-8b --requests 16``

A minimal production serving loop: a request queue is admitted in batches,
prefilled once, then decoded step-by-step with the KV cache / recurrent
state; per-token latencies are reported to the stop-and-wait controller the
same way training steps are (serving jobs are periodic-traffic jobs too —
their decode steps synchronize across model shards every token).
"""
from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as config_registry
from repro.core.controller import StopAndWaitController
from repro.models import init_model, prefill
from repro.runtime.comm_gate import IterationReporter
from repro.runtime.steps import build_serve_step
from repro.sharding import use_rules
from repro.launch.mesh import make_host_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    if args.full:
        cfg = config_registry.get_config(args.arch)
        mesh = make_production_mesh()
    else:
        cfg = config_registry.get_smoke_config(args.arch)
        mesh = make_host_mesh(1, 1)

    key = jax.random.PRNGKey(0)
    controller = StopAndWaitController()
    reporter = IterationReporter(controller, f"serve-{args.arch}", priority=1)

    with use_rules(mesh):
        params, _ = init_model(cfg, key)
        serve = jax.jit(build_serve_step(cfg))
        max_len = args.prompt_len + args.gen

        pending = list(range(args.requests))
        done_tokens: List[np.ndarray] = []
        t_start = time.perf_counter()
        while pending:
            batch_ids = pending[: args.batch]
            pending = pending[args.batch:]
            prompts = jax.random.randint(
                jax.random.fold_in(key, batch_ids[0]),
                (len(batch_ids), args.prompt_len), 0, cfg.vocab)
            kwargs = {}
            if cfg.family == "encdec":
                kwargs["frames"] = jax.random.normal(
                    key, (len(batch_ids),
                          max(args.prompt_len // cfg.enc_frames_ratio, 1),
                          cfg.d_model), jnp.float32)
            logits, cache = prefill(params, cfg, prompts, max_len=max_len,
                                    **kwargs)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            outs = [tok]
            for _ in range(args.gen - 1):
                t0 = time.perf_counter()
                logits, cache = serve(params, cache, tok)
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
                reporter.report(time.perf_counter() - t0)
                outs.append(tok)
            done_tokens.append(np.concatenate(
                [np.asarray(t) for t in outs], axis=1))
            print(f"batch of {len(batch_ids)} done "
                  f"({len(done_tokens) * args.batch}/{args.requests})",
                  flush=True)
        dt = time.perf_counter() - t_start
        n_tok = sum(t.size for t in done_tokens)
        print(f"served {args.requests} requests, {n_tok} tokens in {dt:.1f}s "
              f"({n_tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()

"""Trip-count-aware roofline accounting over optimized (SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` visits every computation ONCE —
a lax.scan over 48 layers or 8 microbatches contributes a single body's
FLOPs, undercounting by orders of magnitude. This module re-derives the
three roofline inputs directly from ``compiled.as_text()``:

  * flops            — 2*M*N*K for every dot (+ 1 flop/elt for arithmetic
                       elementwise ops), weighted by while-loop trip counts;
  * hbm_bytes        — per *fusion boundary* (operands + result), since
                       fused internals never touch HBM; weighted by trips;
  * collective_bytes — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       weighted by trips, also broken out per op kind.

Trip counts come from each while condition's ``compare(iter, constant)``.
Unresolvable trips fall back to 1 and are reported in ``warnings``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs",
    "logistic", "cosine", "sine", "floor", "ceil", "round-nearest-afz",
    "exponential-minus-one", "log-plus-one", "select", "compare", "and",
    "or", "not", "xor",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            total += _shape_elems(dims) * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    return _shape_elems(m.group(2)) if m else 0


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    by_name: Dict[str, Op]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    attention_hbm_bytes: float = 0.0  # subset of hbm_bytes in attention scope
    collective_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.attention_hbm_bytes += other.attention_hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v * mult


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        # computation header: `%name (args) -> type {` or `ENTRY %name ...{`
        if stripped.endswith("{") and ("(" in stripped) and "=" not in stripped.split("(")[0]:
            header = stripped.split("(")[0].strip()
            header = header.replace("ENTRY", "").strip()
            name = header.lstrip("%").strip()
            cur = Computation(name, [], {})
            comps[name] = cur
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        op = Op(name=m.group(1), type_str=m.group(2), opcode=m.group(3),
                line=stripped)
        cur.ops.append(op)
        cur.by_name[op.name] = op
    return comps


def _operand_names(op: Op) -> List[str]:
    """Names referenced inside the op's parens (before attribute list)."""
    try:
        inner = op.line.split(op.opcode + "(", 1)[1]
    except IndexError:
        return []
    # cut at the matching close paren (attributes follow after `), `)
    depth = 1
    for i, ch in enumerate(inner):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                inner = inner[:i]
                break
    return _OPERAND_RE.findall(inner)


def _dot_flops(op: Op, comp: Computation) -> float:
    """2 * prod(out) * prod(contracting dims of lhs)."""
    out_elems = _type_elems(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    operands = _operand_names(op)
    if not operands:
        return 0.0
    lhs = comp.by_name.get(operands[0])
    if lhs is None or m is None:
        return 2.0 * out_elems  # degenerate fallback
    sm = _SHAPE_RE.search(lhs.type_str)
    if sm is None:
        return 2.0 * out_elems
    dims = [int(d) for d in sm.group(2).split(",") if d]
    k = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(dims):
            k *= dims[int(idx)]
    return 2.0 * out_elems * k


def _trip_count(cond: Computation) -> Optional[int]:
    """lax loops: condition is compare(iter, constant, LT)."""
    consts: Dict[str, int] = {}
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?[0-9]+)\)", op.line)
            if m and op.type_str.startswith(("s32[]", "s64[]", "u32[]", "u64[]")):
                consts[op.name] = int(m.group(1))
    for op in cond.ops:
        if op.opcode == "compare" and "direction=LT" in op.line:
            for nm in _operand_names(op):
                if nm in consts:
                    return max(consts[nm], 0)
    # GE/GT countdown loops
    for op in cond.ops:
        if op.opcode == "compare":
            for nm in _operand_names(op):
                if nm in consts:
                    return max(consts[nm], 0)
    return None


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")


def _fusion_read_bytes(op: Op, operand_names: List[str], comp: Computation,
                       called: Optional[Computation]) -> float:
    """Operand bytes with dynamic-slice attribution.

    If a fusion parameter is consumed ONLY by (dynamic-)slice ops inside the
    fused computation, the HBM read is the slice output, not the whole
    operand (the common scan-over-stacked-weights pattern)."""
    param_by_idx: Dict[int, Op] = {}
    users: Dict[str, List[Op]] = {}
    if called is not None:
        for o in called.ops:
            if o.opcode == "parameter":
                mm = _PARAM_IDX_RE.search(o.line)
                if mm:
                    param_by_idx[int(mm.group(1))] = o
        for o in called.ops:
            for nm in _operand_names(o):
                users.setdefault(nm, []).append(o)

    total = 0.0
    for i, nm in enumerate(operand_names):
        src = comp.by_name.get(nm)
        if src is None:
            continue
        full = _type_bytes(src.type_str)
        eff = full
        p = param_by_idx.get(i)
        if p is not None:
            uses = users.get(p.name, [])
            if uses and all(u.opcode in ("dynamic-slice", "slice")
                            for u in uses):
                eff = sum(_type_bytes(u.type_str) for u in uses)
            elif uses and all(
                    u.opcode == "dynamic-update-slice"
                    and _operand_names(u)[:1] == [p.name] for u in uses):
                # buffer only updated in place (aliased) — never read
                eff = 0.0
        total += min(eff, full)
    return total


def _fusion_write_bytes(op: Op, called: Optional[Computation]) -> float:
    """Result bytes with dynamic-update-slice attribution: an in-place
    cache/carry update only writes the update tensor."""
    full = _type_bytes(op.type_str)
    if called is None:
        return full
    root = None
    for o in called.ops:
        if o.line.startswith("ROOT"):
            root = o
            break
    if root is None:
        return full
    if root.opcode == "dynamic-update-slice":
        ops_n = _operand_names(root)
        if len(ops_n) >= 2 and ops_n[1] in called.by_name:
            return min(full, _type_bytes(called.by_name[ops_n[1]].type_str))
    if root.opcode == "tuple":
        b = 0.0
        for nm in _operand_names(root):
            src = called.by_name.get(nm)
            if src is None:
                continue
            if src.opcode == "dynamic-update-slice":
                sub = _operand_names(src)
                if len(sub) >= 2 and sub[1] in called.by_name:
                    b += _type_bytes(called.by_name[sub[1]].type_str)
                    continue
            b += _type_bytes(src.type_str)
        return min(b, full)
    return full


_ATTN_MARK = "chunked_attention"


def _in_attention_scope(op: Op, called: Optional[Computation]) -> bool:
    """True if the op (or its fused computation) carries the model's
    attention scope marker in its op_name metadata."""
    if _ATTN_MARK in op.line:
        return True
    if called is not None:
        return any(_ATTN_MARK in o.line for o in called.ops)
    return False


def analyze(hlo: str) -> Dict:
    comps = parse_computations(hlo)
    entry = None
    for raw in hlo.splitlines():
        if raw.strip().startswith("ENTRY"):
            name = raw.strip().split("(")[0].replace("ENTRY", "").strip()
            entry = name.lstrip("%")
            break
    if entry is None or entry not in comps:
        # fall back: first computation containing a root tuple
        entry = next(iter(comps))

    warnings: List[str] = []
    memo: Dict[str, Cost] = {}
    visiting: set = set()

    def cost_of(name: str) -> Cost:
        if name in memo:
            return memo[name]
        if name in visiting or name not in comps:
            return Cost()
        visiting.add(name)
        comp = comps[name]
        total = Cost()
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                body_m = _BODY_RE.search(op.line)
                cond_m = _COND_RE.search(op.line)
                trips = None
                # XLA annotates resolved loops: known_trip_count:{"n":"7"}
                tm = re.search(r'known_trip_count[^0-9]*([0-9]+)', op.line)
                if tm:
                    trips = int(tm.group(1))
                if trips is None and cond_m and cond_m.group(1) in comps:
                    trips = _trip_count(comps[cond_m.group(1)])
                if trips is None:
                    trips = 1
                    warnings.append(f"unresolved trip count for {op.name}")
                if body_m:
                    total.add(cost_of(body_m.group(1)), float(trips))
                continue
            if oc in ("fusion", "call", "map", "reduce", "reduce-window",
                      "sort", "scatter", "select-and-scatter"):
                # hbm traffic at the fusion boundary, with slice-aware
                # attribution: a fusion that dynamic-slices one layer out of
                # an (L, ...) stacked weight only reads that layer, and a
                # fused dynamic-update-slice only writes the update.
                m = _CALLS_RE.search(op.line)
                called = comps.get(m.group(1)) if m else None
                opnds = _operand_names(op)
                b = _fusion_read_bytes(op, opnds, comp, called)
                b += _fusion_write_bytes(op, called)
                total.hbm_bytes += b
                if _in_attention_scope(op, called):
                    total.attention_hbm_bytes += b
                if called is not None:
                    sub = cost_of(called.name)
                    total.flops += sub.flops
                    total.attention_hbm_bytes += sub.attention_hbm_bytes
                    total.collective_bytes += sub.collective_bytes
                    for k, v in sub.per_collective.items():
                        total.per_collective[k] = total.per_collective.get(k, 0) + v
                continue
            if oc == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}", op.line)
                if branches:
                    names = [b.strip().lstrip("%") for b in branches[0].split(",")]
                    subs = [cost_of(n) for n in names if n in comps]
                    if subs:
                        worst = max(subs, key=lambda c: c.flops)
                        total.add(worst)
                continue
            if oc == "dot":
                total.flops += _dot_flops(op, comp)
                b = _type_bytes(op.type_str)
                for nm in _operand_names(op):
                    src = comp.by_name.get(nm)
                    if src is not None:
                        b += _type_bytes(src.type_str)
                total.hbm_bytes += b
                if _ATTN_MARK in op.line:
                    total.attention_hbm_bytes += b
                continue
            if oc == "convolution":
                # 2 * out_elems * kernel_elems_per_output (approx)
                out_elems = _type_elems(op.type_str)
                opnds = _operand_names(op)
                kb = 1.0
                if len(opnds) > 1 and opnds[1] in comp.by_name:
                    kb = max(1.0, _type_elems(comp.by_name[opnds[1]].type_str)
                             / max(out_elems, 1))
                total.flops += 2.0 * out_elems * kb
                total.hbm_bytes += _type_bytes(op.type_str)
                continue
            base = oc[:-6] if oc.endswith("-start") else oc
            if base in _COLLECTIVES:
                b = 0
                for nm in _operand_names(op):
                    src = comp.by_name.get(nm)
                    if src is not None:
                        b += _type_bytes(src.type_str)
                if b == 0:  # operands not found: use result size
                    b = _type_bytes(op.type_str)
                # wire-bytes model (ring algorithms, (n-1)/n ~ 1):
                #   all-reduce: 2x operand; all-gather: result size;
                #   reduce-scatter / all-to-all / permute: operand size
                # XLA:CPU promotes bf16 all-reduces to f32 ("..._promoted"
                # reducer); the TPU target keeps them bf16 -> halve.
                if base == "all-reduce":
                    if "promoted" in op.line and "f32[" in op.type_str:
                        b *= 0.5
                    wire = 2.0 * b
                elif base == "all-gather":
                    wire = max(b, _type_bytes(op.type_str))
                else:
                    wire = b
                total.collective_bytes += wire
                total.per_collective[base] = (
                    total.per_collective.get(base, 0) + wire)
                total.hbm_bytes += b
                continue
            if oc in _ELEMENTWISE:
                n = _type_elems(op.type_str)
                total.flops += n
                continue
            if oc == "dynamic-update-slice":
                ops_n = _operand_names(op)
                if len(ops_n) >= 2 and ops_n[1] in comp.by_name:
                    total.hbm_bytes += _type_bytes(
                        comp.by_name[ops_n[1]].type_str)
                else:
                    total.hbm_bytes += _type_bytes(op.type_str)
                continue
            if oc in ("copy", "copy-start", "transpose", "broadcast",
                      "dynamic-slice", "gather",
                      "concatenate", "slice", "pad", "reverse", "iota"):
                # data movement at top level (outside fusions)
                total.hbm_bytes += _type_bytes(op.type_str)
                if _ATTN_MARK in op.line:
                    total.attention_hbm_bytes += _type_bytes(op.type_str)
                continue
        visiting.discard(name)
        memo[name] = total
        return total

    c = cost_of(entry)
    return {
        "flops": c.flops,
        "hbm_bytes": c.hbm_bytes,
        "attention_hbm_bytes": c.attention_hbm_bytes,
        "collective_bytes": c.collective_bytes,
        "per_collective": {k: int(v) for k, v in c.per_collective.items()},
        "warnings": warnings[:20],
        "n_warnings": len(warnings),
    }

"""Re-run the roofline analysis over dumped HLO (no recompilation).

PYTHONPATH=src python -m repro.launch.reanalyze --hlo results/hlo \
    --out results/dryrun.json
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro import configs as config_registry
from repro.launch import hlo_analysis
from repro.launch.dryrun import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                 optimized_roofline)
from repro.models.config import SHAPES


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo", default="results/hlo")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    with open(args.out) as f:
        results = json.load(f)
    for path in sorted(glob.glob(os.path.join(args.hlo, "*.txt.gz"))):
        cell_id = os.path.basename(path)[:-7]
        parts = cell_id.split("__")
        arch, shape, mesh = parts[:3]
        key = "|".join([arch, shape, mesh] + parts[3:])
        if key not in results:
            continue
        with gzip.open(path, "rt") as f:
            hlo = f.read()
        hc = hlo_analysis.analyze(hlo)
        info = results[key]
        info["cost"] = {"flops": hc["flops"], "bytes": hc["hbm_bytes"]}
        info["attention_hbm_bytes"] = hc["attention_hbm_bytes"]
        info["collectives"] = hc["per_collective"]
        info["collective_bytes_total"] = int(hc["collective_bytes"])
        info["hlo_warnings"] = hc["n_warnings"]
        info["roofline"] = {
            "compute_s": hc["flops"] / PEAK_FLOPS,
            "memory_s": hc["hbm_bytes"] / HBM_BW,
            "collective_s": hc["collective_bytes"] / ICI_BW,
        }
        info["bottleneck"] = max(
            info["roofline"], key=info["roofline"].get).replace("_s", "")
        if info.get("model_flops_global") and hc["flops"]:
            info["model_vs_hlo_flops"] = (
                info["model_flops_global"] / info["chips"] / hc["flops"])
        try:
            cfg = config_registry.get_config(arch)
            info["roofline_flash"] = optimized_roofline(
                info, cfg, SHAPES[shape])
        except KeyError:
            pass
        r = info["roofline"]
        print(f"{key}: comp={r['compute_s']:.4f} mem={r['memory_s']:.4f} "
              f"coll={r['collective_s']:.4f} -> {info['bottleneck']}")
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()

"""Production meshes (v5e): single-pod 16x16 and 2-pod 2x16x16.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    return jax.make_mesh((data, model), ("data", "model"))

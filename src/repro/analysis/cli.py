"""metrolint CLI.

Exit status is the contract CI relies on:

  * ``0`` — no findings outside the baseline, no stale suppressions;
  * ``1`` — new findings (fix them or suppress WITH A REASON), or stale
    suppressions (the finding is gone — delete its baseline entry);
  * ``2`` — usage errors / unreadable baseline.

``--write-baseline`` rewrites the baseline to exactly the current finding
set, preserving reasons of entries that survive; fresh entries get a
placeholder reason that a human must replace before committing.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from . import checks as _checks  # noqa: F401  (registers the checks)
from .core import (BASELINE_NAME, all_checks, apply_baseline, load_baseline,
                   run_checks, write_baseline)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.analysis",
        description="metrolint: repo-specific static invariant checks")
    p.add_argument("--root", default=".",
                   help="repo root to scan (default: cwd)")
    p.add_argument("--baseline", default=None,
                   help=f"baseline path (default: <root>/{BASELINE_NAME})")
    p.add_argument("--checks", default=None,
                   help="comma-separated subset of check ids")
    p.add_argument("--list-checks", action="store_true",
                   help="list registered checks and exit")
    p.add_argument("--write-baseline", action="store_true",
                   help="rewrite the baseline to the current finding set")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit machine-readable JSON instead of text")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_checks:
        for cid, doc in all_checks().items():
            print(f"{cid}: {doc}")
        return 0

    root = Path(args.root).resolve()
    if not root.is_dir():
        print(f"metrolint: root {root} is not a directory", file=sys.stderr)
        return 2
    baseline_path = (Path(args.baseline) if args.baseline
                     else root / BASELINE_NAME)
    selected = ([c.strip() for c in args.checks.split(",") if c.strip()]
                if args.checks else None)

    try:
        findings = run_checks(root, selected)
    except ValueError as e:
        print(f"metrolint: {e}", file=sys.stderr)
        return 2

    try:
        baseline = load_baseline(baseline_path)
    except (ValueError, KeyError, json.JSONDecodeError) as e:
        print(f"metrolint: bad baseline: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        write_baseline(baseline_path, findings, existing=baseline)
        print(f"metrolint: wrote {len(findings)} suppression(s) to "
              f"{baseline_path}")
        return 0

    new, suppressed, stale = apply_baseline(findings, baseline)

    if args.as_json:
        print(json.dumps({
            "new": [vars(f) for f in new],
            "suppressed": [vars(f) for f in suppressed],
            "stale": [vars(s) for s in stale],
        }, indent=1))
        return 1 if (new or stale) else 0

    for f in new:
        print(f.render())
    for s in stale:
        print(f"stale suppression: {s.fingerprint} (reason was: "
              f"{s.reason!r}) — the finding is gone, delete the entry")
    summary = (f"metrolint: {len(new)} new finding(s), "
               f"{len(suppressed)} suppressed, {len(stale)} stale")
    print(summary, file=sys.stderr if (new or stale) else sys.stdout)
    return 1 if (new or stale) else 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())

"""metrolint core: findings, repo loading, check registry, baseline.

Checks are plain functions ``check(repo) -> List[Finding]`` registered via
:func:`register_check`.  A :class:`Repo` lazily parses every tracked Python
file once and hands the same ASTs to all checks; checks locate their scope
by *path suffix* (``core/simulator.py``, ``kernels/ops.py``), so the
fixture tests can exercise them on miniature tmp-dir repos with the same
layout as the real tree.

Baseline discipline: a finding's :attr:`Finding.fingerprint` deliberately
excludes the line number (moves must not invalidate suppressions) and
instead keys on ``(check, path, obj, key)`` where ``obj`` is the enclosing
scope's qualname and ``key`` a per-check stable discriminator.
"""
from __future__ import annotations

import ast
import dataclasses
import json
from pathlib import Path
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

BASELINE_NAME = "metrolint.baseline.json"
BASELINE_VERSION = 1

# directories never scanned (vendored/generated/VCS content)
_SKIP_DIRS = {".git", "__pycache__", ".bench_cache", "node_modules",
              ".pytest_cache", ".ruff_cache", ".mypy_cache"}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation at ``path:line``."""

    check: str
    path: str  # repo-relative posix path
    line: int
    obj: str  # qualname of the enclosing scope ('' = module level)
    key: str  # stable discriminator within (check, path, obj)
    message: str

    @property
    def fingerprint(self) -> str:
        """Line-independent identity used for baseline matching."""
        return f"{self.check}::{self.path}::{self.obj}::{self.key}"

    def render(self) -> str:
        where = f" [{self.obj}]" if self.obj else ""
        return f"{self.path}:{self.line}: {self.check}:{where} {self.message}"


class Module:
    """One parsed source file (AST parsed lazily, cached)."""

    def __init__(self, root: Path, path: Path) -> None:
        self.abspath = path
        self.relpath = path.relative_to(root).as_posix()
        self._source: Optional[str] = None
        self._tree: Optional[ast.Module] = None
        self._error: Optional[SyntaxError] = None

    @property
    def source(self) -> str:
        if self._source is None:
            self._source = self.abspath.read_text()
        return self._source

    @property
    def tree(self) -> Optional[ast.Module]:
        """Parsed AST, or None when the file does not parse (the syntax
        error is surfaced as its own finding by :func:`run_checks`)."""
        if self._tree is None and self._error is None:
            try:
                self._tree = ast.parse(self.source)
            except SyntaxError as e:  # pragma: no cover - defensive
                self._error = e
        return self._tree

    @property
    def syntax_error(self) -> Optional[SyntaxError]:
        self.tree
        return self._error


class Repo:
    """All Python files under one root, parsed once and shared by checks."""

    def __init__(self, root: Path) -> None:
        self.root = Path(root).resolve()
        self._modules: Optional[List[Module]] = None

    def modules(self) -> List[Module]:
        if self._modules is None:
            out = []
            for p in sorted(self.root.rglob("*.py")):
                rel = p.relative_to(self.root).parts
                if any(part in _SKIP_DIRS for part in rel):
                    continue
                out.append(Module(self.root, p))
            self._modules = out
        return self._modules

    def ending_with(self, *suffixes: str) -> List[Module]:
        """Modules whose repo-relative path ends with any given suffix."""
        return [m for m in self.modules()
                if any(m.relpath.endswith(s) for s in suffixes)]

    def under(self, prefix: str) -> List[Module]:
        """Modules whose repo-relative path starts with ``prefix``."""
        return [m for m in self.modules() if m.relpath.startswith(prefix)]

    def get(self, suffix: str) -> Optional[Module]:
        mods = self.ending_with(suffix)
        return mods[0] if mods else None


# --------------------------------------------------------------- AST helpers
def iter_scopes(tree: ast.Module) -> Iterator[Tuple[str, ast.AST]]:
    """Yield ``(qualname, node)`` for every function/method, walking into
    classes (``Cls.meth``) but not into nested functions (a nested def is
    analyzed as part of its enclosing scope)."""

    def walk(body: Sequence[ast.stmt], prefix: str) -> Iterator:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield prefix + node.name, node
            elif isinstance(node, ast.ClassDef):
                yield from walk(node.body, prefix + node.name + ".")

    yield from walk(tree.body, "")


def attr_chain(node: ast.AST) -> List[str]:
    """``a.b.c`` -> ``['a', 'b', 'c']``; empty when the base is dynamic."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return []


def find_scope(tree: ast.Module, qualname: str) -> Optional[ast.AST]:
    for name, node in iter_scopes(tree):
        if name == qualname:
            return node
    return None


# ------------------------------------------------------------ check registry
CheckFn = Callable[[Repo], List[Finding]]
_CHECKS: Dict[str, CheckFn] = {}
_CHECK_DOCS: Dict[str, str] = {}


def register_check(check_id: str, doc: str) -> Callable[[CheckFn], CheckFn]:
    def deco(fn: CheckFn) -> CheckFn:
        if check_id in _CHECKS:
            raise ValueError(f"duplicate check id {check_id!r}")
        _CHECKS[check_id] = fn
        _CHECK_DOCS[check_id] = doc
        return fn

    return deco


def all_checks() -> Dict[str, str]:
    """check id -> one-line description, in registration order."""
    return dict(_CHECK_DOCS)


def run_checks(root: Path,
               checks: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the selected (default: all) checks over the repo at ``root``."""
    repo = Repo(Path(root))
    selected = list(checks) if checks else list(_CHECKS)
    unknown = [c for c in selected if c not in _CHECKS]
    if unknown:
        raise ValueError(f"unknown checks {unknown}; have {sorted(_CHECKS)}")
    findings: List[Finding] = []
    for m in repo.modules():
        err = m.syntax_error
        if err is not None:
            findings.append(Finding(
                check="parse", path=m.relpath, line=err.lineno or 1,
                obj="", key="syntax-error", message=f"does not parse: {err}"))
    for cid in selected:
        findings.extend(_CHECKS[cid](repo))
    findings.sort(key=lambda f: (f.path, f.line, f.check, f.key))
    return findings


# ------------------------------------------------------------------ baseline
@dataclasses.dataclass(frozen=True)
class Suppression:
    check: str
    path: str
    obj: str
    key: str
    reason: str

    @property
    def fingerprint(self) -> str:
        return f"{self.check}::{self.path}::{self.obj}::{self.key}"


def load_baseline(path: Path) -> List[Suppression]:
    """Parse the committed baseline; every entry must carry a reason."""
    if not Path(path).exists():
        return []
    doc = json.loads(Path(path).read_text())
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(f"baseline {path}: unsupported version "
                         f"{doc.get('version')!r}")
    out = []
    for i, e in enumerate(doc.get("suppressions", [])):
        reason = str(e.get("reason", "")).strip()
        if not reason:
            raise ValueError(f"baseline {path}: suppression #{i} has no "
                             "reason — every deliberate deviation must say "
                             "why it is deliberate")
        out.append(Suppression(check=e["check"], path=e["path"],
                               obj=e.get("obj", ""), key=e.get("key", ""),
                               reason=reason))
    return out


def apply_baseline(findings: Sequence[Finding],
                   baseline: Sequence[Suppression]
                   ) -> Tuple[List[Finding], List[Finding],
                              List[Suppression]]:
    """Split into (new, suppressed, stale-suppressions).

    Stale entries — suppressions matching no current finding — are
    reported (and fail the CLI) so the baseline shrinks as findings are
    actually fixed instead of fossilizing."""
    by_fp = {s.fingerprint: s for s in baseline}
    new, suppressed = [], []
    hit = set()
    for f in findings:
        if f.fingerprint in by_fp:
            suppressed.append(f)
            hit.add(f.fingerprint)
        else:
            new.append(f)
    stale = [s for s in baseline if s.fingerprint not in hit]
    return new, suppressed, stale


def write_baseline(path: Path, findings: Sequence[Finding],
                   existing: Sequence[Suppression] = (),
                   default_reason: str = "baselined at adoption; triage"
                   ) -> None:
    """Write a baseline covering ``findings``, preserving the reasons of
    entries already present."""
    reasons = {s.fingerprint: s.reason for s in existing}
    entries = []
    for f in findings:
        entries.append({
            "check": f.check, "path": f.path, "obj": f.obj, "key": f.key,
            "reason": reasons.get(f.fingerprint, default_reason),
        })
    doc = {"version": BASELINE_VERSION, "suppressions": entries}
    Path(path).write_text(json.dumps(doc, indent=1) + "\n")

"""determinism: no nondeterminism hazards in the bit-for-bit-pinned modules.

The seed goldens (S1-S5/F2/F4/J1/D1/D2) and the backend-parity suites pin
``scoring.py``, ``rotation.py``, ``fluid.py`` and ``simulator.py``
bit-for-bit on the python oracle paths.  Three hazard classes break that
silently:

  * **set-order iteration** — iterating a set (or anything derived from
    one without ``sorted()``) makes result order depend on hash seeding;
  * **unseeded randomness** — module-level ``np.random.*`` / ``random.*``
    draws bypass the simulator's seeded ``default_rng``;
  * **float32 literals** — the pinned oracle paths are float64; a float32
    cast inside them truncates the goldens.  (The vectorized fluid
    backends are float32 BY DESIGN — those functions are suppressed in
    the baseline with that reason.)
"""
from __future__ import annotations

import ast
from typing import Dict, List

from ..core import Finding, Repo, iter_scopes, register_check

SCOPE = ("core/scoring.py", "core/rotation.py", "core/fluid.py",
         "core/simulator.py")

# np.random attributes that are fine (seeded constructors / types)
SEEDED_OK = {"default_rng", "RandomState", "SeedSequence", "Generator",
             "PRNGKey", "seed"}
F32_NAMES = {"float32"}
_WRAPPERS = {"list", "tuple", "enumerate", "reversed", "iter"}


def _set_locals(func: ast.AST) -> Dict[str, int]:
    """Local names assigned a set-valued expression exactly once."""
    counts: Dict[str, int] = {}
    setlike: Dict[str, int] = {}
    for node in ast.walk(func):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            counts[name] = counts.get(name, 0) + 1
            if _is_set_expr(node.value):
                setlike[name] = node.value.lineno
    return {n: ln for n, ln in setlike.items() if counts.get(n) == 1}


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("set", "frozenset")
    if isinstance(node, ast.BinOp) \
            and isinstance(node.op, (ast.BitAnd, ast.BitOr, ast.Sub,
                                     ast.BitXor)):
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


def _iter_hazard(it: ast.AST, sets: Dict[str, int]) -> bool:
    """True when the iterable of a for/comprehension is set-ordered."""
    if isinstance(it, ast.Call) and isinstance(it.func, ast.Name):
        if it.func.id == "sorted":
            return False
        if it.func.id in _WRAPPERS and it.args:
            return _iter_hazard(it.args[0], sets)
    if _is_set_expr(it):
        return True
    return isinstance(it, ast.Name) and it.id in sets


def _iterables(func: ast.AST):
    for node in ast.walk(func):
        if isinstance(node, (ast.For, ast.AsyncFor)):
            yield node.iter
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                yield gen.iter


@register_check(
    "determinism",
    "no set-order iteration / unseeded randomness / float32 literals in "
    "the bit-for-bit-pinned modules")
def check(repo: Repo) -> List[Finding]:
    out: List[Finding] = []
    for mod in repo.ending_with(*SCOPE):
        tree = mod.tree
        if tree is None:
            continue
        for qualname, func in iter_scopes(tree):
            sets = _set_locals(func)
            n_set = 0
            for it in _iterables(func):
                if _iter_hazard(it, sets):
                    n_set += 1
                    out.append(Finding(
                        check="determinism", path=mod.relpath,
                        line=it.lineno, obj=qualname,
                        key=f"set-iteration:{n_set}",
                        message="iterates in set order — wrap in sorted() "
                                "or use an insertion-ordered container "
                                "(goldens pin this module bit-for-bit)"))
            n_rand = 0
            f32_line = 0
            for node in ast.walk(func):
                if isinstance(node, ast.Attribute):
                    chain_ok = (isinstance(node.value, ast.Attribute)
                                and node.value.attr == "random") or \
                               (isinstance(node.value, ast.Name)
                                and node.value.id == "random")
                    if chain_ok and node.attr not in SEEDED_OK:
                        n_rand += 1
                        out.append(Finding(
                            check="determinism", path=mod.relpath,
                            line=node.lineno, obj=qualname,
                            key=f"unseeded-random:{n_rand}",
                            message=f"np.random.{node.attr}/random."
                                    f"{node.attr} draws from global "
                                    "unseeded state — thread the seeded "
                                    "rng through instead"))
                    if node.attr in F32_NAMES and not f32_line:
                        f32_line = node.lineno
                if isinstance(node, ast.Constant) \
                        and node.value == "float32" and not f32_line:
                    f32_line = node.lineno
            if f32_line:
                out.append(Finding(
                    check="determinism", path=mod.relpath, line=f32_line,
                    obj=qualname, key="float32",
                    message="float32 literal in a module the goldens pin "
                            "bit-for-bit (float64) — keep the oracle path "
                            "float64 or baseline the vectorized-backend "
                            "function with a reason"))
    return out

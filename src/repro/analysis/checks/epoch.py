"""epoch-soundness: demand/capacity mutations must bump a mutation epoch.

The planner memo (``rotation.PlanCache``) and the fluid engine's
per-component refill memo are only sound because EVERY mutation of
scheduler-visible link state advances ``Cluster.epoch`` or
``TaskRegistry.epoch`` (DESIGN.md section 15).  A mutation path that
forgets the bump silently serves stale plans — exactly the class of bug
bisection found twice while PR 5 landed.

Rule: in the epoch-bearing core modules, any function that mutates a
tracked demand/capacity attribute, calls the ``allocate``/``release``
primitives, or mutates a registry store (``registry.tasks`` /
``.jobs`` / ``.workloads``) must ALSO contain a reachable epoch advance
(``bump_epoch()`` / ``bump()`` / ``<x>.epoch += 1``) in the same function
scope.  Constructors, ``copy()`` factories, the bump definitions
themselves, and the ``Node.allocate``/``Node.release`` primitives (whose
CALLERS own the bump) are exempt.
"""
from __future__ import annotations

import ast
from typing import List

from ..core import Finding, Repo, attr_chain, iter_scopes, register_check

# the modules that own epoch-tagged state (path suffixes)
SCOPE = ("core/framework.py", "core/simulator.py", "core/controller.py",
         "core/events.py", "core/cluster.py")

# attributes whose assignment changes what schedulers/planners see
TRACKED_ATTRS = {"allocatable_gbps", "capacity_gbps", "bw_gbps", "traffic",
                 "allocated", "background", "latency"}
# method calls that mutate demand state on whatever object they hit
MUTATING_CALLS = {"allocate", "release"}
# registry stores: mutation of registry.<store> must bump
REGISTRY_STORES = {"tasks", "jobs", "workloads"}
STORE_MUTATORS = {"pop", "clear", "update", "setdefault", "popitem"}

BUMP_CALLS = {"bump_epoch", "bump"}
# functions that may mutate without bumping
EXEMPT_NAMES = {"__init__", "__post_init__", "copy"}
EXEMPT_QUALNAMES = {"Node.allocate", "Node.release"}


def _is_registry_store(node: ast.AST) -> bool:
    """True for attribute chains like ``self.registry.tasks`` /
    ``registry.jobs`` — a store access rooted at a registry object."""
    chain = attr_chain(node)
    return (len(chain) >= 2 and chain[-1] in REGISTRY_STORES
            and "registry" in chain[:-1])


def _mutations(func: ast.AST):
    """Yield ``(line, description)`` for every tracked mutation."""
    for node in ast.walk(func):
        targets = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for t in targets:
            for el in (t.elts if isinstance(t, ast.Tuple) else [t]):
                sub = el
                if isinstance(sub, ast.Subscript):
                    sub = sub.value
                if isinstance(sub, ast.Attribute):
                    if sub.attr in TRACKED_ATTRS:
                        yield el.lineno, f"writes .{sub.attr}"
                    elif isinstance(el, ast.Subscript) \
                            and _is_registry_store(sub):
                        yield el.lineno, f"writes registry.{sub.attr}[...]"
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id == "setattr" \
                    and len(node.args) >= 2 \
                    and isinstance(node.args[1], ast.Constant) \
                    and node.args[1].value in TRACKED_ATTRS:
                yield node.lineno, f"calls setattr(.., {node.args[1].value!r})"
            elif isinstance(fn, ast.Attribute):
                if fn.attr in MUTATING_CALLS:
                    yield node.lineno, f"calls .{fn.attr}()"
                elif (fn.attr in STORE_MUTATORS
                      and _is_registry_store(fn.value)):
                    chain = attr_chain(fn.value)
                    yield node.lineno, (f"calls {'.'.join(chain)}"
                                        f".{fn.attr}()")


def _has_bump(func: ast.AST) -> bool:
    for node in ast.walk(func):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in BUMP_CALLS:
            return True
        if isinstance(node, ast.AugAssign) \
                and isinstance(node.target, ast.Attribute) \
                and node.target.attr == "epoch":
            return True
    return False


@register_check(
    "epoch-soundness",
    "demand/capacity mutations must advance Cluster/TaskRegistry epochs")
def check(repo: Repo) -> List[Finding]:
    out: List[Finding] = []
    for mod in repo.ending_with(*SCOPE):
        tree = mod.tree
        if tree is None:
            continue
        for qualname, func in iter_scopes(tree):
            short = qualname.rsplit(".", 1)[-1]
            if short in EXEMPT_NAMES or short in BUMP_CALLS \
                    or qualname in EXEMPT_QUALNAMES:
                continue
            muts = list(_mutations(func))
            if not muts or _has_bump(func):
                continue
            line, what = muts[0]
            extra = f" (+{len(muts) - 1} more)" if len(muts) > 1 else ""
            out.append(Finding(
                check="epoch-soundness", path=mod.relpath, line=line,
                obj=qualname, key="no-bump",
                message=f"{what}{extra} without a reachable bump_epoch()/"
                        "bump()/epoch increment in the same mutation scope "
                        "— epoch-scoped planner caches would serve stale "
                        "plans"))
    return out

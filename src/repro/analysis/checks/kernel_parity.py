"""kernel-parity: every Pallas kernel needs ops wiring, a ref oracle and
an interpret-mode parity test.

The dispatch contract (``kernels/ops.py``): real TPU -> compiled Pallas;
anything else -> interpret mode or the jit'd jnp reference from
``kernels/ref.py``.  This container never runs compiled Pallas, so the
ONLY thing standing between a kernel edit and silently-wrong TPU behavior
is the interpret-mode parity test against the ref oracle.  Three rules per
public kernel function in ``kernels/*.py`` (excluding ``ops.py`` /
``ref.py``):

  1. **wired** — some ``ops.py`` function references it (otherwise the
     kernel is dead code the dispatch contract never covers);
  2. **ref twin** — ``kernels/ref.py`` exists and exports oracles;
  3. **parity test** — some test function under ``tests/`` calls one of
     the kernel's dispatchers with ``interpret=True`` (keyword, or the
     positional-``True`` idiom of the flash tests) AND references the
     ``ref`` module in the same function — i.e. an actual interpret-vs-
     oracle comparison, not just a smoke call.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from ..core import Finding, Module, Repo, iter_scopes, register_check

_EXCLUDE = ("ops.py", "ref.py", "__init__.py")


def _kernel_modules(repo: Repo) -> List[Module]:
    return [m for m in repo.modules()
            if "kernels/" in m.relpath
            and not m.relpath.endswith(_EXCLUDE)]


def _public_defs(mod: Module) -> List[ast.FunctionDef]:
    if mod.tree is None:
        return []
    return [n for n in mod.tree.body
            if isinstance(n, ast.FunctionDef)
            and not n.name.startswith("_")]


def _names_used(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _ops_reference_map(ops: Module) -> Dict[str, Set[str]]:
    """ops function name -> every Name it references, with module-level
    ``X = jax.jit(ref.Y)`` aliases resolved one hop and ``D.defvjp(f, b)``
    fwd/bwd bodies merged into ``D`` (the flash custom_vjp idiom)."""
    tree = ops.tree
    if tree is None:
        return {}
    alias_refs: Dict[str, Set[str]] = {}
    fn_refs: Dict[str, Set[str]] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            alias_refs[node.targets[0].id] = _names_used(node.value) | {
                a.attr for a in ast.walk(node.value)
                if isinstance(a, ast.Attribute)}
        elif isinstance(node, ast.FunctionDef):
            fn_refs[node.name] = _names_used(node)
    # defvjp merge
    for node in tree.body:
        if isinstance(node, ast.Expr) and isinstance(node.value, ast.Call):
            call = node.value
            if isinstance(call.func, ast.Attribute) \
                    and call.func.attr == "defvjp" \
                    and isinstance(call.func.value, ast.Name):
                owner = call.func.value.id
                for arg in call.args:
                    if isinstance(arg, ast.Name) and arg.id in fn_refs:
                        fn_refs.setdefault(owner, set()).update(
                            fn_refs[arg.id])
    # one-hop alias resolution: a function referencing _x_jit inherits the
    # names of the module-level assignment that defined it
    for name, refs in fn_refs.items():
        for a, arefs in alias_refs.items():
            if a in refs:
                refs.update(arefs)
    return fn_refs


def _ref_aliases(tree: ast.Module) -> Set[str]:
    """Names in a test file that are bound to ``kernels.ref`` (module
    aliases AND directly-imported oracle functions)."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            if node.module.endswith("kernels"):
                for a in node.names:
                    if a.name == "ref":
                        out.add(a.asname or a.name)
            elif node.module.endswith("kernels.ref"):
                for a in node.names:
                    out.add(a.asname or a.name)
    return out


def _is_parity_call(call: ast.Call, dispatchers: Set[str]) -> bool:
    fn = call.func
    name = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else "")
    if name not in dispatchers:
        return False
    for kw in call.keywords:
        if kw.arg == "interpret" and isinstance(kw.value, ast.Constant) \
                and kw.value.value is True:
            return True
    return any(isinstance(a, ast.Constant) and a.value is True
               for a in call.args)


def _has_parity_test(repo: Repo, dispatchers: Set[str]) -> bool:
    for mod in repo.under("tests/"):
        tree = mod.tree
        if tree is None:
            continue
        refs = _ref_aliases(tree)
        for _qual, func in iter_scopes(tree):
            local_refs = refs | _ref_aliases_from(func)
            has_call = any(
                isinstance(n, ast.Call) and _is_parity_call(n, dispatchers)
                for n in ast.walk(func))
            if not has_call:
                continue
            uses_ref = any(
                isinstance(n, ast.Name) and n.id in local_refs
                for n in ast.walk(func))
            if uses_ref:
                return True
    return False


def _ref_aliases_from(func: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, ast.ImportFrom) and node.module:
            if node.module.endswith("kernels"):
                out.update(a.asname or a.name for a in node.names
                           if a.name == "ref")
            elif node.module.endswith("kernels.ref"):
                out.update(a.asname or a.name for a in node.names)
    return out


@register_check(
    "kernel-parity",
    "every public Pallas kernel is wired in ops.py, has a ref.py oracle "
    "and an interpret-mode parity test under tests/")
def check(repo: Repo) -> List[Finding]:
    out: List[Finding] = []
    kmods = _kernel_modules(repo)
    if not kmods:
        return out
    ops = repo.get("kernels/ops.py")
    ref = repo.get("kernels/ref.py")
    ops_refs = _ops_reference_map(ops) if ops is not None else {}
    for mod in kmods:
        for fn in _public_defs(mod):
            if ref is None:
                out.append(Finding(
                    check="kernel-parity", path=mod.relpath, line=fn.lineno,
                    obj=fn.name, key="no-ref-module",
                    message="kernels/ref.py is missing — every kernel "
                            "needs a pure-jnp oracle twin"))
                continue
            dispatchers = {name for name, refs in ops_refs.items()
                           if fn.name in refs and not name.startswith("_")}
            if not dispatchers:
                out.append(Finding(
                    check="kernel-parity", path=mod.relpath, line=fn.lineno,
                    obj=fn.name, key="unwired",
                    message=f"public kernel {fn.name!r} is not referenced "
                            "by any ops.py dispatcher — the TPU/interpret/"
                            "jnp dispatch contract never covers it"))
                continue
            if not _has_parity_test(repo, dispatchers | {fn.name}):
                out.append(Finding(
                    check="kernel-parity", path=mod.relpath, line=fn.lineno,
                    obj=fn.name, key="no-parity-test",
                    message=f"no interpret-mode parity test for kernel "
                            f"{fn.name!r}: no test function calls "
                            f"{sorted(dispatchers)} with interpret=True "
                            "and compares against kernels.ref"))
    return out

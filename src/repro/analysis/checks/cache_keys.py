"""cache-key completeness: content-addressed caches must key on ALL inputs.

Two cache families carry correctness weight here:

  * ``benchmarks/cache.py::fingerprint`` — the nightly sweep cache.  A
    result-relevant input that is missing from the fingerprint silently
    serves stale sweep results.  The rule is *field coverage*: for each
    parameter bound to a known dataclass, every dataclass field must be
    covered by the fingerprint — either accessed directly (``.mode``),
    through a declared alias (``materialize()`` consumes ``build``), or by
    handing the whole object to a canonicalizing helper (one that walks
    ``dataclasses.fields``/``asdict``).  Property accesses are deliberately
    NOT coverage: a derived human label (``p.name``) can collide across
    distinct configurations, which is exactly the bug class this catches.

  * ``rotation.PlanCache`` memo keys — ``solve_link`` / ``solve_link_batch``
    / ``_build_joint_problem`` build ``key = (...)`` tuples that must
    mention every solver knob in the signature (``mode``, ``demand``,
    ``rotation_mode``, ``di_pre``, ``g_t_ms``, ``e_t_frac``, and for the
    joint path ``backend`` / ``max_exhaustive``).  A knob missing from the
    key makes two different solves share one memo slot.

Specs skip silently when their target *file* is absent (fixture mini-repos
only materialize what they test) but report drift when the file exists and
the expected function has disappeared — a rename must not silently disable
the check.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from ..core import Finding, Module, Repo, find_scope, register_check


# --------------------------------------------------- fingerprint field specs
class Binding:
    """One fingerprint parameter bound to a dataclass whose fields must
    all be covered."""

    def __init__(self, param: str, dc_suffix: str, dc_name: str,
                 aliases: Optional[Dict[str, Set[str]]] = None,
                 ignore: Optional[Dict[str, str]] = None) -> None:
        self.param = param
        self.dc_suffix = dc_suffix  # path suffix of the defining module
        self.dc_name = dc_name
        self.aliases = aliases or {}  # accessed attr -> fields it covers
        self.ignore = ignore or {}  # field -> why it is excluded by design


FINGERPRINT_SPECS = [
    ("benchmarks/cache.py", "fingerprint", [
        Binding("scenario", "core/experiment.py", "Scenario",
                aliases={"materialize": {"build"}},
                ignore={"name": "human-readable label; content is hashed "
                                "via materialize()"}),
        Binding("policies", "core/experiment.py", "Policy"),
        Binding("cfg", "core/simulator.py", "SimConfig"),
        # SimConfig.telemetry nests the observation-channel dataclass;
        # its distortion knobs are result-relevant and must be hashed by
        # content too (a dataclasses-walking canonicalizer recurses)
        Binding("cfg", "core/telemetry.py", "TelemetryChannel"),
    ]),
]

# ------------------------------------------------------ PlanCache knob specs
_SOLVER_KNOBS = {"mode", "demand", "rotation_mode", "di_pre", "g_t_ms",
                 "e_t_frac"}
# link capacity is a mutable input since fault injection (LinkFailure
# zeroes it mid-run, recovery restores it): a memo key omitting it would
# serve a pre-failure scheme on the post-failure link
KNOB_SPECS = [
    ("core/rotation.py", "solve_link", _SOLVER_KNOBS | {"cap"}),
    ("core/rotation.py", "solve_link_batch", _SOLVER_KNOBS | {"cap"}),
    ("core/rotation.py", "_build_joint_problem",
     _SOLVER_KNOBS | {"backend", "max_exhaustive", "caps", "bw_lp"}),
]


def _dataclass_fields(mod: Module, cls: str) -> Optional[Set[str]]:
    tree = mod.tree
    if tree is None:
        return None
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == cls:
            out = set()
            for stmt in node.body:
                if isinstance(stmt, ast.AnnAssign) \
                        and isinstance(stmt.target, ast.Name):
                    ann = ast.dump(stmt.annotation)
                    if "ClassVar" not in ann:
                        out.add(stmt.target.id)
            return out
    return None


def _covering_helpers(mod: Module) -> Set[str]:
    """Module-level functions that canonicalize whole dataclasses (walk
    ``dataclasses.fields``/``asdict``), plus one hop of helpers that call
    them (``_cluster_canon`` -> ``_canon``)."""
    tree = mod.tree
    if tree is None:
        return set()
    direct: Set[str] = set()
    calls: Dict[str, Set[str]] = {}
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        names = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Attribute):
                names.add(sub.attr)
            elif isinstance(sub, ast.Name):
                names.add(sub.id)
        if names & {"fields", "asdict"}:
            direct.add(node.name)
        calls[node.name] = names
    # fixpoint over call-through (helpers delegating to covering helpers)
    changed = True
    while changed:
        changed = False
        for fn, names in calls.items():
            if fn not in direct and names & direct:
                direct.add(fn)
                changed = True
    return direct


def _tracked_names(func: ast.AST, param: str) -> Set[str]:
    """``param`` plus loop/comprehension variables iterating over it."""
    tracked = {param}
    changed = True

    def unwrap(it: ast.AST) -> Optional[str]:
        while isinstance(it, ast.Call) and isinstance(it.func, ast.Name) \
                and it.func.id in ("sorted", "list", "tuple", "enumerate",
                                   "reversed") and it.args:
            it = it.args[0]
        return it.id if isinstance(it, ast.Name) else None

    def targets_of(t: ast.AST) -> List[str]:
        if isinstance(t, ast.Name):
            return [t.id]
        if isinstance(t, ast.Tuple):
            return [e.id for e in t.elts if isinstance(e, ast.Name)]
        return []

    while changed:
        changed = False
        for node in ast.walk(func):
            pairs = []
            if isinstance(node, (ast.For, ast.AsyncFor)):
                pairs.append((node.iter, node.target))
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                pairs.extend((g.iter, g.target) for g in node.generators)
            for it, tgt in pairs:
                src = unwrap(it)
                if src in tracked:
                    for name in targets_of(tgt):
                        if name not in tracked:
                            tracked.add(name)
                            changed = True
    return tracked


def _coverage(func: ast.AST, binding: Binding, fields: Set[str],
              helpers: Set[str]) -> Set[str]:
    tracked = _tracked_names(func, binding.param)
    covered: Set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, ast.Attribute) \
                and isinstance(node.value, ast.Name) \
                and node.value.id in tracked:
            if node.attr in fields:
                covered.add(node.attr)
            covered.update(binding.aliases.get(node.attr, ()))
        elif isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in helpers:
            if any(isinstance(a, ast.Name) and a.id in tracked
                   for a in node.args):
                return set(fields)
    return covered


@register_check(
    "cache-key-completeness",
    "content caches (bench fingerprint, PlanCache memo keys) must cover "
    "every result-relevant input")
def check(repo: Repo) -> List[Finding]:
    out: List[Finding] = []

    for suffix, qualname, bindings in FINGERPRINT_SPECS:
        mod = repo.get(suffix)
        if mod is None or mod.tree is None:
            continue
        func = find_scope(mod.tree, qualname)
        if func is None:
            out.append(Finding(
                check="cache-key-completeness", path=mod.relpath, line=1,
                obj=qualname, key="spec-drift",
                message=f"expected fingerprint function {qualname!r} not "
                        "found — update the cache-key spec alongside the "
                        "rename"))
            continue
        helpers = _covering_helpers(mod)
        for b in bindings:
            dc_mod = repo.get(b.dc_suffix)
            if dc_mod is None:
                continue
            fields = _dataclass_fields(dc_mod, b.dc_name)
            if fields is None:
                out.append(Finding(
                    check="cache-key-completeness", path=mod.relpath,
                    line=func.lineno, obj=qualname,
                    key=f"spec-drift:{b.dc_name}",
                    message=f"dataclass {b.dc_name!r} not found in "
                            f"{b.dc_suffix} — update the cache-key spec"))
                continue
            covered = _coverage(func, b, fields, helpers)
            missing = sorted(fields - covered - set(b.ignore))
            if missing:
                out.append(Finding(
                    check="cache-key-completeness", path=mod.relpath,
                    line=func.lineno, obj=qualname,
                    key=f"uncovered:{b.param}",
                    message=f"{b.dc_name} fields {missing} of parameter "
                            f"{b.param!r} never reach the fingerprint — "
                            "hash content (e.g. via a dataclasses.fields "
                            "canonicalizer), not derived labels"))

    for suffix, qualname, required in KNOB_SPECS:
        mod = repo.get(suffix)
        if mod is None or mod.tree is None:
            continue
        func = find_scope(mod.tree, qualname)
        if func is None:
            out.append(Finding(
                check="cache-key-completeness", path=mod.relpath, line=1,
                obj=qualname, key="spec-drift",
                message=f"expected solver {qualname!r} not found — update "
                        "the cache-key spec alongside the rename"))
            continue
        key_names: Set[str] = set()
        key_line = 0
        for node in ast.walk(func):
            if isinstance(node, ast.Assign) \
                    and any(isinstance(t, ast.Name) and t.id == "key"
                            for t in node.targets) \
                    and isinstance(node.value, ast.Tuple):
                key_line = key_line or node.lineno
                key_names.update(n.id for n in ast.walk(node.value)
                                 if isinstance(n, ast.Name))
        if not key_names:
            out.append(Finding(
                check="cache-key-completeness", path=mod.relpath,
                line=func.lineno, obj=qualname, key="no-key",
                message="no `key = (...)` memo-key tuple found — the "
                        "PlanCache contract requires a content key"))
            continue
        missing = sorted(required - key_names)
        if missing:
            out.append(Finding(
                check="cache-key-completeness", path=mod.relpath,
                line=key_line, obj=qualname, key="knobs",
                message=f"memo key omits solver knobs {missing} — two "
                        "solves differing only in them would share a "
                        "PlanCache slot"))
    return out

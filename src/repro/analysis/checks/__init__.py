"""Check modules; importing the package registers every check."""
from . import (cache_keys, determinism, epoch, kernel_parity,  # noqa: F401
               shared_state)

"""shared-state race: module-level mutable containers mutated without a lock.

``experiment.sweep(workers=N, mode="thread")`` runs simulations on a
thread pool, so any module-level list/dict/set that worker-path code
mutates is a data race.  CPython's GIL makes single ``append``s atomic,
but read-modify-write patterns (``if k not in cache: cache[k] = ...``,
``list.extend`` of interleaved rows, clear-then-refill) interleave and
corrupt — the ``benchmarks.common.RECORDED_*`` recorders were the live
instance of this.

Rule: in the thread-reachable modules (``src/repro/core/`` and
``benchmarks/``), every function-scope mutation of a module-level mutable
container must sit inside a ``with <lock>:`` block, where the lock is a
module-level ``threading.Lock()``/``RLock()`` (or any context-manager
variable whose name contains "lock").  Deliberately unlocked state —
import-time registries, content-keyed pure memo caches where a race only
duplicates work — is suppressed in the baseline *with the reason stated*.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from ..core import Finding, Repo, attr_chain, iter_scopes, register_check

_MUTATORS = {"append", "add", "update", "setdefault", "extend", "insert",
             "pop", "popitem", "clear", "remove", "discard"}
_CONTAINER_CALLS = {"list", "dict", "set", "defaultdict", "OrderedDict",
                    "deque", "Counter"}


def _in_scope(relpath: str) -> bool:
    return "/core/" in relpath or relpath.startswith("benchmarks/")


def _module_state(tree: ast.Module) -> Tuple[Dict[str, int], Set[str]]:
    """(mutable module-level containers -> def line, lock names)."""
    mutables: Dict[str, int] = {}
    locks: Set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name, val = node.targets[0].id, node.value
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name) \
                and node.value is not None:
            name, val = node.target.id, node.value
        else:
            continue
        if isinstance(val, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                            ast.DictComp, ast.SetComp)):
            mutables[name] = node.lineno
        elif isinstance(val, ast.Call):
            chain = attr_chain(val.func)
            leaf = chain[-1] if chain else ""
            if leaf in _CONTAINER_CALLS:
                mutables[name] = node.lineno
            elif leaf in ("Lock", "RLock"):
                locks.add(name)
    return mutables, locks


def _is_lock_name(expr: ast.AST, locks: Set[str]) -> bool:
    chain = attr_chain(expr)
    if not chain:
        return False
    leaf = chain[-1]
    return leaf in locks or "lock" in leaf.lower()


def _locked_node_ids(func: ast.AST, locks: Set[str]) -> Set[int]:
    """ids of AST nodes lexically inside a ``with <lock>:`` body."""
    out: Set[int] = set()
    for node in ast.walk(func):
        if isinstance(node, (ast.With, ast.AsyncWith)) and any(
                _is_lock_name(item.context_expr, locks)
                for item in node.items):
            for stmt in node.body:
                for sub in ast.walk(stmt):
                    out.add(id(sub))
    return out


def _mutations(func: ast.AST, mutables: Dict[str, int]):
    """Yield ``(node, global_name, what)`` for each mutation of a tracked
    module-level container."""
    declared_global: Set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, ast.Global):
            declared_global.update(node.names)
    for node in ast.walk(func):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id in mutables \
                and node.func.attr in _MUTATORS:
            yield node, node.func.value.id, f".{node.func.attr}()"
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, ast.AugAssign):
            targets = [node.target]
        for t in targets:
            for el in (t.elts if isinstance(t, ast.Tuple) else [t]):
                if isinstance(el, ast.Subscript) \
                        and isinstance(el.value, ast.Name) \
                        and el.value.id in mutables:
                    yield el, el.value.id, "[...] assignment"
                elif isinstance(el, ast.Name) and el.id in mutables \
                        and el.id in declared_global:
                    yield el, el.id, "rebinding (global)"


@register_check(
    "shared-state-race",
    "module-level mutable containers in thread-reachable code must be "
    "mutated under a lock")
def check(repo: Repo) -> List[Finding]:
    out: List[Finding] = []
    for mod in repo.modules():
        if not _in_scope(mod.relpath):
            continue
        tree = mod.tree
        if tree is None:
            continue
        mutables, locks = _module_state(tree)
        if not mutables:
            continue
        for qualname, func in iter_scopes(tree):
            locked = _locked_node_ids(func, locks)
            unlocked: Dict[str, List[Tuple[int, str]]] = {}
            for node, gname, what in _mutations(func, mutables):
                if id(node) not in locked:
                    unlocked.setdefault(gname, []).append(
                        (node.lineno, what))
            for gname, sites in sorted(unlocked.items()):
                line, what = sites[0]
                extra = (f" (+{len(sites) - 1} more)"
                         if len(sites) > 1 else "")
                out.append(Finding(
                    check="shared-state-race", path=mod.relpath, line=line,
                    obj=qualname, key=f"unlocked:{gname}",
                    message=f"mutates module-level {gname!r} via {what}"
                            f"{extra} outside any lock — thread sweeps "
                            "(sweep(mode='thread')) interleave here"))
    return out

"""metrolint — repo-specific static invariant checks (DESIGN.md section 18).

PRs 5-7 made correctness hinge on contracts nothing enforced mechanically:
epoch counters that must advance on every demand/capacity mutation, Pallas
kernels that must keep a ``ref.py`` oracle and an interpret-parity test,
modules the test suite pins bit-for-bit that must stay free of
nondeterminism hazards, content-keyed caches whose key functions must cover
every input field, and module-level state reachable from ``sweep(workers=
N)`` worker threads.  This package machine-checks those invariants on every
commit (``scripts/check.sh`` and CI run ``python -m repro.analysis``).

Deliberate deviations are recorded in ``metrolint.baseline.json`` at the
repo root; every suppression carries a reason and the CLI fails on any
finding not in the baseline (and on baseline entries that no longer match
anything, so the file cannot rot).
"""
from .core import (Finding, Repo, all_checks, apply_baseline, load_baseline,
                   run_checks, write_baseline)

# the check modules self-register on import
from . import checks as _checks  # noqa: F401  (import-time registration)

__all__ = [
    "Finding",
    "Repo",
    "all_checks",
    "apply_baseline",
    "load_baseline",
    "run_checks",
    "write_baseline",
]

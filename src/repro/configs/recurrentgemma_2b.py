"""RecurrentGemma-2B (Griffin): RG-LRU + local attention 1:2, MQA
[arXiv:2402.19427]. Sub-quadratic -> runs long_500k."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='recurrentgemma-2b',
        family='griffin',
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv=1,
        d_ff=7680,
        vocab=256000,
        window=2048,
        lru_width=2560,
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return ModelConfig(
        name='recurrentgemma-2b-smoke',
        family='griffin',
        n_layers=6,
        d_model=64,
        n_heads=4,
        n_kv=1,
        d_ff=128,
        vocab=512,
        window=16,
        lru_width=64,
    )

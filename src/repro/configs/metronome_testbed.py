"""The paper's testbed fleet and snapshots (sections IV-A, Table III/IV).

Traffic parameters (period / duty / bandwidth) are calibrated against the
paper's own measurements where the text pins them down:

  * Table VI gives Metronome's (near-ideal) time per 1,000 iterations per
    snapshot: S1 ~ 422 s, S2 ~ 88/99 s, S3 ~ 124/103 s, S4 ~ 533 s,
    S5 ~ 112/430 s  -> ideal iteration times in ms below.
  * section IV-D: in S3, after period doubling of VGG19, WideResNet101 is
    35 ms shorter; G_T = 5 ms, E_T = 10 %.
  * snapshot 0 (GPT-2 + GoogLeNet) is INCOMPATIBLE: the summed communication
    phases exceed the LCM period.

Where the paper gives no number we use plausible values for A30-class DP/MP
training on 25 GbE (duty cycles 0.2-0.6, bandwidth demand 8-24 Gbps).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.cluster import Cluster, make_fabric_cluster, make_testbed_cluster
from repro.core.events import (BackgroundFlowChange, Event,
                               LinkCapacityChange, flapping_schedule)
from repro.core.experiment import Scenario
from repro.core.simulator import BackgroundFlow, SimConfig
from repro.core.topology import uplink_id
from repro.core.trace import (TraceJobSpec, trace_departure_events,
                              trace_to_jobs)
from repro.core.workload import HIGH, LOW, Job, Workload, make_job

# model -> traffic; period_ms = ideal iteration time (contention free)
MODEL_FLEET: Dict[str, dict] = {
    "VGG11":          dict(period_ms=80.0,  duty=0.40, bw_gbps=20.0, n_tasks=2),
    # FT-VGG16 period chosen so S2 exercises the E_T idle-injection path:
    # 96 - 90 = 6 ms mismatch (> G_T = 5, <= E_T = 10% of 90) -> inject 6 ms
    "FT-VGG16":       dict(period_ms=90.0,  duty=0.48, bw_gbps=25.0, n_tasks=2),
    "FT-VGG19":       dict(period_ms=96.0,  duty=0.48, bw_gbps=25.0, n_tasks=2),
    "FT-VGG19-S3":    dict(period_ms=245.0, duty=0.30, bw_gbps=22.0, n_tasks=2),
    "Pre-VGG19":      dict(period_ms=418.0, duty=0.30, bw_gbps=22.0, n_tasks=2),
    "ResNet18":       dict(period_ms=60.0,  duty=0.25, bw_gbps=12.0, n_tasks=2),
    "ResNet50":       dict(period_ms=120.0, duty=0.30, bw_gbps=15.0, n_tasks=2),
    "FT-ResNet152":   dict(period_ms=110.0, duty=0.25, bw_gbps=18.0, n_tasks=2),
    "FT-WideResNet101": dict(period_ms=120.0, duty=0.35, bw_gbps=20.0, n_tasks=2),
    "GoogLeNet":      dict(period_ms=70.0,  duty=0.20, bw_gbps=8.0,  n_tasks=2),
    "GoogLeNet-S0":   dict(period_ms=70.0,  duty=0.60, bw_gbps=10.0, n_tasks=2),
    "DenseNet201":    dict(period_ms=160.0, duty=0.25, bw_gbps=12.0, n_tasks=2),
    "AlexNet":        dict(period_ms=45.0,  duty=0.50, bw_gbps=24.0, n_tasks=2),
    "GPT-1":          dict(period_ms=424.0, duty=0.17, bw_gbps=20.0, n_tasks=2),
    "GPT-2":          dict(period_ms=600.0, duty=0.50, bw_gbps=22.0, n_tasks=2),
    # BERT's per-pod demand (10G) fits one 25G link twice -> the S4 pair is
    # "strongly compatible" (paper IV-C); congestion avoidance is the gain.
    "BERT":           dict(period_ms=527.0, duty=0.40, bw_gbps=10.0, n_tasks=2),
}

# 13 "real" models of Table III (the -S0/-S3 variants are batch variants)
TABLE_III_MODELS: List[str] = [
    "VGG11", "FT-VGG16", "FT-VGG19", "ResNet18", "ResNet50", "FT-ResNet152",
    "FT-WideResNet101", "GoogLeNet", "DenseNet201", "AlexNet",
    "GPT-1", "GPT-2", "BERT",
]


def _wl(name: str, jobs: List[Job]) -> Workload:
    for j in jobs:
        j.workload = name
        for t in j.tasks:
            t.workload = name
    return Workload(name=name, jobs=jobs)


def make_snapshot(sid: str, n_iterations: int = 400
                  ) -> Tuple[Cluster, List[Workload], List[BackgroundFlow]]:
    """Snapshot compositions of Table IV.  '*' jobs are high priority;
    otherwise earlier-deployed jobs are higher priority (paper note)."""
    cluster = make_testbed_cluster()
    bg: List[BackgroundFlow] = []

    def job(name, model, prio, submit=0.0):
        f = MODEL_FLEET[model]
        return make_job(name, n_tasks=f["n_tasks"], period_ms=f["period_ms"],
                        duty=f["duty"], bw_gbps=f["bw_gbps"], priority=prio,
                        n_iterations=n_iterations, submit_time_s=submit,
                        model=model)

    if sid == "S0":  # incompatible pair (section IV-B1, last paragraph)
        wls = [
            _wl("wl-gpt2", [job("gpt2-0", "GPT-2", HIGH)]),
            _wl("wl-googlenet", [job("googlenet-0", "GoogLeNet-S0", LOW, 0.001)]),
        ]
    elif sid == "S1":  # DP HPO training job x3 (same workload)
        wls = [_wl("wl-hpo-vgg19", [
            job("vgg19-hpo-0", "Pre-VGG19", HIGH),
            job("vgg19-hpo-1", "Pre-VGG19", LOW, 0.001),
            job("vgg19-hpo-2", "Pre-VGG19", LOW, 0.002),
        ])]
    elif sid == "S2":  # FT-VGG16 + FT-VGG19*
        wls = [
            _wl("wl-vgg19", [job("vgg19-ft", "FT-VGG19", HIGH)]),
            _wl("wl-vgg16", [job("vgg16-ft", "FT-VGG16", LOW, 0.001)]),
        ]
    elif sid == "S3":  # FT-WideResNet101 + FT-VGG19*, 2:1 period ratio
        wls = [
            _wl("wl-vgg19s3", [job("vgg19-ft", "FT-VGG19-S3", HIGH)]),
            _wl("wl-wrn", [job("wrn101-ft", "FT-WideResNet101", LOW, 0.001)]),
        ]
    elif sid == "S4":  # Pre-BERT x2 with a congested link
        wls = [_wl("wl-hpo-bert", [
            job("bert-0", "BERT", HIGH),
            job("bert-1", "BERT", LOW, 0.001),
        ])]
        _congest(cluster, bg, "worker-a30-2", iperf_gbps=16.0, tau_ms=40.0)
    elif sid == "S5":  # FT-ResNet152 + Pre-GPT-1*, congested link, DP + MP
        wls = [
            _wl("wl-gpt1", [job("gpt1-pre", "GPT-1", HIGH)]),
            _wl("wl-rn152", [job("rn152-ft", "FT-ResNet152", LOW, 0.001)]),
        ]
        _congest(cluster, bg, "worker-a30-2", iperf_gbps=16.0, tau_ms=40.0)
    elif sid in ("F2", "F4", "J1"):
        return make_fabric_snapshot(sid, n_iterations=n_iterations)
    else:
        raise ValueError(f"unknown snapshot {sid!r}")
    return cluster, wls, bg


def make_fabric_snapshot(sid: str, n_iterations: int = 400
                         ) -> Tuple[Cluster, List[Workload], List[BackgroundFlow]]:
    """Beyond-paper fabric snapshots on an oversubscribed leaf–spine fabric.

    These scenarios are invisible to the seed's host-link-only model: host
    links stay under capacity while the spine uplinks contend, so the only
    scheduler that separates the jobs in time is the one that models the
    uplink (Metronome post-fabric-refactor).

      F2: 2 leaves x 2 hosts @25G, 2:1 oversubscription (25G uplinks).
          Two 4-task jobs span both leaves; per-host demand 12+12 = 24G
          < 25G (no host contention) but each job pushes 24G through each
          uplink -> 48G >> 25G when overlapped.
      F4: 2 leaves x 4 hosts @25G, 4:1 oversubscription (25G uplinks).
          Three 8-task jobs (1 HIGH + 2 LOW) span both leaves; per-host
          demand 3x6 = 18G < 25G, per-uplink 3x24G vs 25G.
      J1: 2 leaves x 2 hosts @25G, 4:1 oversubscription (12.5G uplinks) —
          the joint-rotation oracle snapshot: per-link rotation solves
          PROVABLY conflict.  Two 4-task spanning jobs (hi*/lo, 5G each)
          contend only on the uplinks (in-leaf 10G vs 12.5G; pair 20G);
          an intra-leaf 2-task job (24G, pinned to one rack because 24G
          exceeds the uplink's 12.5G — Eq. 14) contends with both on the
          leaf0 host links (24+5 > 25G).  The host-link solve puts hi/lo
          adjacent (their pair fits a host link, so only the intra-leaf
          job needs separating) while the uplink solve must spread hi/lo
          apart — the host-optimal relative shift is infeasible on the
          shared uplink.  The legacy "uplinks win" reconciliation then
          lands the intra-leaf job on top of the spanning LOW job
          (29G > 25G sustained); the joint planner picks the one region
          where all three constraints hold (bench_rotation.py,
          tests/test_rotation.py).
    """
    def job(name, prio, submit, *, n_tasks, period_ms, duty, bw_gbps):
        return make_job(name, n_tasks=n_tasks, period_ms=period_ms, duty=duty,
                        bw_gbps=bw_gbps, priority=prio,
                        n_iterations=n_iterations, submit_time_s=submit)

    bg: List[BackgroundFlow] = []
    if sid == "F2":
        cluster = make_fabric_cluster(n_leaves=2, hosts_per_leaf=2,
                                      bw_gbps=25.0, oversubscription=2.0)
        spec = dict(n_tasks=4, period_ms=100.0, duty=0.35, bw_gbps=12.0)
        wls = [
            _wl("wl-f2-hi", [job("f2-hi", HIGH, 0.0, **spec)]),
            _wl("wl-f2-lo", [job("f2-lo", LOW, 0.001, **spec)]),
        ]
    elif sid == "F4":
        cluster = make_fabric_cluster(n_leaves=2, hosts_per_leaf=4,
                                      bw_gbps=25.0, oversubscription=4.0)
        spec = dict(n_tasks=8, period_ms=120.0, duty=0.30, bw_gbps=6.0)
        wls = [
            _wl("wl-f4-hi", [job("f4-hi", HIGH, 0.0, **spec)]),
            _wl("wl-f4-lo0", [job("f4-lo0", LOW, 0.001, **spec)]),
            _wl("wl-f4-lo1", [job("f4-lo1", LOW, 0.002, **spec)]),
        ]
    elif sid == "J1":
        cluster = make_fabric_cluster(n_leaves=2, hosts_per_leaf=2,
                                      bw_gbps=25.0, oversubscription=4.0)
        span = dict(n_tasks=4, period_ms=100.0, duty=0.30, bw_gbps=5.0)
        wls = [
            _wl("wl-j1-hi", [job("j1-hi", HIGH, 0.0, **span)]),
            _wl("wl-j1-lo", [job("j1-lo", LOW, 0.001, **span)]),
            _wl("wl-j1-local", [job("j1-local", LOW, 0.002, n_tasks=2,
                                    period_ms=100.0, duty=0.35,
                                    bw_gbps=24.0)]),
        ]
    else:
        raise ValueError(f"unknown fabric snapshot {sid!r}")
    return cluster, wls, bg


def _congest(cluster: Cluster, bg: List[BackgroundFlow], node: str,
             iperf_gbps: float, tau_ms: float) -> None:
    """iPerf3-style congestion (section IV-A 'Traces'): an unregulated flow
    occupies the node's host link; the cluster manager lowers the node's
    ALLOCATABLE bandwidth accordingly (NodeBandwidth CR, section III-A) and
    the latency monitor reports a high tau to that node."""
    bg.append(BackgroundFlow(node=node, rate_gbps=iperf_gbps))
    n = cluster.node(node)
    n.allocatable_gbps = max(0.0, n.bw_gbps - iperf_gbps)
    for other in cluster.node_names:
        if other != node:
            cluster.set_latency(node, other, tau_ms)


def make_dynamic_snapshot(
    sid: str, n_iterations: int = 400, amplitude: float = 0.3,
    t_on_ms: float = 15_000.0, t_off_ms: float = 45_000.0,
) -> Tuple[Cluster, List[Workload], List[BackgroundFlow], List[Event]]:
    """Beyond-paper dynamic snapshots: a static snapshot plus a mid-run
    environment fluctuation (returns an extra event list for the
    simulator's ``events=`` stream — see ``core/events.py``).

      D1 (bandwidth fluctuation): the S2 pair (FT-VGG19* + FT-VGG16) with an
         iPerf3-style background flow ramping on ``worker-a30-0`` — a host
         link every scheduler co-locates both jobs on — between ``t_on`` and
         ``t_off``.  Rate = ``amplitude`` x the 25G link.  The NodeBandwidth
         CR lowers the allocatable share while the flow runs, so the
         controller's reconfiguration loop re-derives the rotation and
         re-baselines the monitor.

      D2 (fabric): the F4 trio (1 HIGH + 2 LOW spanning two leaves at 4:1
         oversubscription) with both spine uplinks dropping to
         ``(1 - amplitude)`` of their capacity (allocatable AND physical —
         a degraded/partitioned spine) between ``t_on`` and ``t_off``,
         forcing uplink-scheme reconfiguration.
    """
    if sid == "D1":
        cluster, wls, bg = make_snapshot("S2", n_iterations=n_iterations)
        link = "worker-a30-0"
        rate = amplitude * cluster.node(link).bw_gbps
        events: List[Event] = [
            BackgroundFlowChange(t_on_ms, link=link, rate_gbps=rate),
            BackgroundFlowChange(t_off_ms, link=link, rate_gbps=0.0),
        ]
    elif sid == "D2":
        cluster, wls, bg = make_snapshot("F4", n_iterations=n_iterations)
        events = []
        for leaf in cluster.topology.uplinks:
            cap = cluster.topology.uplinks[leaf].capacity_gbps
            low = (1.0 - amplitude) * cap
            events.append(LinkCapacityChange(
                t_on_ms, link=uplink_id(leaf),
                allocatable_gbps=low, capacity_gbps=low))
            events.append(LinkCapacityChange(
                t_off_ms, link=uplink_id(leaf),
                allocatable_gbps=cap, capacity_gbps=cap))
    else:
        raise ValueError(f"unknown dynamic snapshot {sid!r}")
    return cluster, wls, bg, events


def make_fault_snapshot(
    sid: str, n_iterations: int = 400, start_ms: float = 15_000.0,
    period_ms: float = 20_000.0, down_ms: float = 2_000.0, n_cycles: int = 3,
) -> Tuple[Cluster, List[Workload], List[BackgroundFlow], List[Event]]:
    """Fault-injection snapshots (DESIGN.md section 19): a static snapshot
    plus an alternating failure/recovery train (:func:`flapping_schedule`).

      R1 (flapping uplink): the F4 trio with spine uplink ``uplink:leaf0``
         failing outright (capacity AND allocatable -> 0) ``n_cycles``
         times for ``down_ms`` each, one failure every ``period_ms``.
         Cross-leaf flows stall on the dead uplink until recovery; the
         controller must re-derive uplink schemes on every transition
         (or, with hysteresis, sit the flap out).

      R2 (flapping host): the S2 pair with ``worker-a30-1`` dying on the
         same schedule — every job with a task on it stalls (flows
         dropped, iteration abandoned) and restarts on recovery.
    """
    if sid == "R1":
        cluster, wls, bg = make_snapshot("F4", n_iterations=n_iterations)
        events = flapping_schedule(
            uplink_id("leaf0"), start_ms=start_ms, period_ms=period_ms,
            down_ms=down_ms, n_cycles=n_cycles)
    elif sid == "R2":
        cluster, wls, bg = make_snapshot("S2", n_iterations=n_iterations)
        events = flapping_schedule(
            "worker-a30-1", start_ms=start_ms, period_ms=period_ms,
            down_ms=down_ms, n_cycles=n_cycles, host=True)
    else:
        raise ValueError(f"unknown fault snapshot {sid!r}")
    return cluster, wls, bg, events


# -------------------------------------------------- declarative scenarios
# (Scenario/Policy experiment API, DESIGN.md section 14): the snapshot
# builders above stay the single source of truth for compositions; these
# wrap them as Scenario instances whose build() returns FRESH objects per
# materialization — exactly what the benchmarks' per-scheduler regeneration
# loop used to do by hand.

# The build callables are module-level dataclass instances, not closures:
# process-mode sweeps (``experiment.sweep(mode='process')``) pickle each
# cell's Scenario into spawned workers, and a closure cannot cross that
# boundary.  ``__call__`` keeps them drop-in where a plain function went.

@dataclasses.dataclass(frozen=True)
class SnapshotBuild:
    """Picklable ``Scenario.build`` of one Table IV / fabric snapshot."""

    sid: str
    n_iterations: int = 400

    def __call__(self):
        cluster, wls, bg = make_snapshot(self.sid,
                                         n_iterations=self.n_iterations)
        return cluster, wls, bg


@dataclasses.dataclass(frozen=True)
class DynamicBuild:
    """Picklable ``Scenario.build`` of one dynamic snapshot (D1/D2)."""

    sid: str
    n_iterations: int = 400
    amplitude: float = 0.3
    t_on_ms: float = 15_000.0
    t_off_ms: float = 45_000.0

    def __call__(self):
        return make_dynamic_snapshot(
            self.sid, n_iterations=self.n_iterations,
            amplitude=self.amplitude, t_on_ms=self.t_on_ms,
            t_off_ms=self.t_off_ms)


@dataclasses.dataclass(frozen=True)
class FaultBuild:
    """Picklable ``Scenario.build`` of one fault snapshot (R1/R2)."""

    sid: str
    n_iterations: int = 400
    start_ms: float = 15_000.0
    period_ms: float = 20_000.0
    down_ms: float = 2_000.0
    n_cycles: int = 3

    def __call__(self):
        return make_fault_snapshot(
            self.sid, n_iterations=self.n_iterations,
            start_ms=self.start_ms, period_ms=self.period_ms,
            down_ms=self.down_ms, n_cycles=self.n_cycles)


@dataclasses.dataclass(frozen=True)
class TraceBuild:
    """Picklable ``Scenario.build`` of a Gavel-style trace scenario.

    ``cluster_factory=None`` means the testbed cluster; a non-None factory
    must itself be picklable (a module-level function or dataclass) for
    process-mode sweeps."""

    trace: Tuple[TraceJobSpec, ...]
    time_scale: float = 1.0
    open_ended: bool = True
    cluster_factory: Optional[Callable[[], Cluster]] = None

    def __call__(self):
        cluster = (self.cluster_factory()
                   if self.cluster_factory is not None
                   else make_testbed_cluster())
        jobs = trace_to_jobs(list(self.trace), MODEL_FLEET,
                             time_scale=self.time_scale,
                             open_ended=self.open_ended)
        wls = []
        for j in jobs:
            wl = Workload(name=j.name, jobs=[j])
            j.workload = wl.name
            for t in j.tasks:
                t.workload = wl.name
            wls.append(wl)
        events = (trace_departure_events(list(self.trace),
                                         time_scale=self.time_scale)
                  if self.open_ended else ())
        return cluster, wls, (), events


def snapshot_scenario(sid: str, n_iterations: int = 400,
                      sim_config: Optional[SimConfig] = None) -> Scenario:
    """The Table IV snapshot (or fabric/joint snapshot) ``sid`` as an
    offline Scenario."""
    return Scenario(name=sid, build=SnapshotBuild(sid, n_iterations),
                    sim_config=sim_config)


def dynamic_scenario(sid: str, n_iterations: int = 400,
                     amplitude: float = 0.3, t_on_ms: float = 15_000.0,
                     t_off_ms: float = 45_000.0,
                     sim_config: Optional[SimConfig] = None) -> Scenario:
    """Dynamic snapshot ``sid`` (D1/D2) with its fluctuation event stream as
    an offline Scenario (the events fire mid-run on the simulator clock)."""
    return Scenario(
        name=sid,
        build=DynamicBuild(sid, n_iterations, amplitude, t_on_ms, t_off_ms),
        sim_config=sim_config)


def fault_scenario(sid: str, n_iterations: int = 400,
                   start_ms: float = 15_000.0, period_ms: float = 20_000.0,
                   down_ms: float = 2_000.0, n_cycles: int = 3,
                   sim_config: Optional[SimConfig] = None) -> Scenario:
    """Fault snapshot ``sid`` (R1/R2) with its failure/recovery train as an
    offline Scenario (events fire mid-run on the simulator clock)."""
    return Scenario(
        name=sid,
        build=FaultBuild(sid, n_iterations, start_ms, period_ms, down_ms,
                         n_cycles),
        sim_config=sim_config)


def trace_scenario(trace: List[TraceJobSpec], *, time_scale: float = 1.0,
                   open_ended: bool = True,
                   cluster_factory: Optional[Callable[[], Cluster]] = None,
                   name: str = "trace",
                   sim_config: Optional[SimConfig] = None) -> Scenario:
    """A Gavel-style trace as a trace-mode Scenario (online arrivals,
    queueing, eviction — the paper's Fig. 10 K8s behavior).

    ``open_ended=True`` truncates jobs by :class:`JobDeparture` events
    instead of an iteration cap (a contended job does FEWER iterations in
    its window; never-admitted jobs depart from the pending queue).  Use
    ``open_ended=False`` for the 'ideal' reference, which ignores the event
    stream and needs the static iteration caps."""
    return Scenario.trace(
        name=name,
        build=TraceBuild(tuple(trace), time_scale=time_scale,
                         open_ended=open_ended,
                         cluster_factory=cluster_factory),
        sim_config=sim_config)


SNAPSHOTS = ("S1", "S2", "S3", "S4", "S5")
# beyond-paper leaf–spine snapshots (oversubscribed fabric; bench_fabric.py)
FABRIC_SNAPSHOTS = ("F2", "F4")
# joint-rotation oracle snapshot (per-link solves conflict; bench_rotation.py)
JOINT_SNAPSHOTS = ("J1",)
# beyond-paper dynamic snapshots (mid-run fluctuation; bench_dynamic.py)
DYNAMIC_SNAPSHOTS = ("D1", "D2")
# fault-injection snapshots (failure/recovery trains; bench_robustness.py)
FAULT_SNAPSHOTS = ("R1", "R2")

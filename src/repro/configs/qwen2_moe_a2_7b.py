"""Qwen1.5-MoE-A2.7B: 4 shared + 60 routed experts, top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='qwen2-moe-a2.7b',
        family='moe',
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv=16,
        d_ff=1408,
        vocab=151936,
        n_experts=60,
        top_k=4,
        n_shared=4,
        moe_d_ff=1408,
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return ModelConfig(
        name='qwen2-moe-a2.7b-smoke',
        family='moe',
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=4,
        d_ff=64,
        vocab=512,
        n_experts=6,
        top_k=2,
        n_shared=2,
        moe_d_ff=64,
    )

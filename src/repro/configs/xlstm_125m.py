"""xLSTM-125M: alternating sLSTM / mLSTM blocks [arXiv:2405.04517].
Sub-quadratic -> runs long_500k."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='xlstm-125m',
        family='xlstm',
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv=4,
        d_ff=0,
        vocab=50304,
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return ModelConfig(
        name='xlstm-125m-smoke',
        family='xlstm',
        n_layers=4,
        d_model=64,
        n_heads=2,
        n_kv=2,
        d_ff=0,
        vocab=512,
    )

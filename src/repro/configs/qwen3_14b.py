"""Qwen3-14B dense GQA with qk-norm [hf:Qwen/Qwen3-8B family]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='qwen3-14b',
        family='dense',
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv=8,
        d_ff=17408,
        vocab=151936,
        qk_norm=True,
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return ModelConfig(
        name='qwen3-14b-smoke',
        family='dense',
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        d_ff=128,
        vocab=512,
        qk_norm=True,
    )

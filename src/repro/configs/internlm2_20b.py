"""InternLM2-20B dense GQA [arXiv:2403.17297]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='internlm2-20b',
        family='dense',
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv=8,
        d_ff=16384,
        vocab=92544,
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return ModelConfig(
        name='internlm2-20b-smoke',
        family='dense',
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        d_ff=128,
        vocab=512,
    )

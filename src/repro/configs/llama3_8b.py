"""Llama-3-8B dense GQA, 128k vocab [arXiv:2407.21783]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='llama3-8b',
        family='dense',
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv=8,
        d_ff=14336,
        vocab=128256,
        rope_theta=500000.0,
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return ModelConfig(
        name='llama3-8b-smoke',
        family='dense',
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        d_ff=128,
        vocab=512,
        rope_theta=500000.0,
    )

"""Architecture configs (one module per assigned arch) + the paper testbed."""
from __future__ import annotations

import importlib
from typing import Dict, List

ARCHS: List[str] = [
    "arctic_480b",
    "qwen2_moe_a2_7b",
    "internlm2_20b",
    "qwen3_14b",
    "llama3_8b",
    "starcoder2_15b",
    "qwen2_vl_72b",
    "whisper_small",
    "recurrentgemma_2b",
    "xlstm_125m",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}
_ALIAS.update({a: a for a in ARCHS})
# assignment ids use dashes/dots
_ALIAS.update({
    "arctic-480b": "arctic_480b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "internlm2-20b": "internlm2_20b",
    "qwen3-14b": "qwen3_14b",
    "llama3-8b": "llama3_8b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "whisper-small": "whisper_small",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "xlstm-125m": "xlstm_125m",
})


def get_config(arch: str):
    """Load the full-size ModelConfig for an architecture id."""
    mod = importlib.import_module(f"repro.configs.{_ALIAS[arch]}")
    return mod.config()


def get_smoke_config(arch: str):
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_ALIAS[arch]}")
    return mod.smoke_config()


def canonical(arch: str) -> str:
    return _ALIAS[arch]

"""Snowflake Arctic: 128-expert top-2 MoE with a parallel dense residual FFN
[hf:Snowflake/snowflake-arctic-base]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='arctic-480b',
        family='moe',
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv=8,
        d_ff=4864,
        vocab=32000,
        n_experts=128,
        top_k=2,
        moe_d_ff=4864,
        dense_residual=True,
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return ModelConfig(
        name='arctic-480b-smoke',
        family='moe',
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        d_ff=96,
        vocab=512,
        n_experts=8,
        top_k=2,
        moe_d_ff=96,
        dense_residual=True,
    )

"""StarCoder2-15B dense GQA (kv=4), RoPE [arXiv:2402.19173]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='starcoder2-15b',
        family='dense',
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv=4,
        d_ff=24576,
        vocab=49152,
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return ModelConfig(
        name='starcoder2-15b-smoke',
        family='dense',
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        d_ff=128,
        vocab=512,
    )

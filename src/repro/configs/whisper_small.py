"""Whisper-small backbone: bidirectional encoder over STUB frame embeddings
(conv frontend stubbed per assignment) + causal decoder w/ cross-attention
[arXiv:2212.04356]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='whisper-small',
        family='encdec',
        n_layers=12,
        n_enc_layers=12,
        d_model=768,
        n_heads=12,
        n_kv=12,
        d_ff=3072,
        vocab=51865,
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return ModelConfig(
        name='whisper-small-smoke',
        family='encdec',
        n_layers=2,
        n_enc_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=4,
        d_ff=128,
        vocab=512,
    )

"""Qwen2-VL-72B language backbone with M-RoPE (t/h/w sections); the vision
patch frontend is a STUB — input_specs() supplies patch position ids
[arXiv:2409.12191]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name='qwen2-vl-72b',
        family='dense',
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv=8,
        d_ff=29568,
        vocab=152064,
        mrope_sections=(16, 24, 24),
    )


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return ModelConfig(
        name='qwen2-vl-72b-smoke',
        family='dense',
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        d_ff=128,
        vocab=512,
        mrope_sections=(4, 2, 2),
    )

"""Typed, serializable experiment results (DESIGN.md section 14).

The paper's evaluation is a grid — snapshots x scenarios x mechanisms — so
results are grid-shaped too:

  * :class:`ExperimentResult` — one ``run(scenario, policy)`` outcome: the
    simulator measurements plus the admission split and the priority split
    (the latter replaces the benchmarks' old ``"_workloads"`` magic key).
  * :class:`SweepCell` / :class:`SweepResult` — one grid cell / the whole
    grid.  A cell that raised carries ``status="error"`` and the traceback
    instead of poisoning its neighbours (per-cell error isolation).

Everything serializes to schema-versioned JSON (``SCHEMA_VERSION``):
benchmarks write their sweeps as ``BENCH_sweep.json`` (``to_bench_dict``)
and CI validates the artifact with :func:`validate_bench_dict` so
result-format drift fails the build instead of rotting silently.  NaN is
mapped to JSON ``null`` on the way out (strict parsers choke on bare NaN)
and restored on the way back.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, List, Mapping, Optional, Sequence

import numpy as np

from .simulator import SimResult

SCHEMA_VERSION = 1


def _f(v: Optional[float]) -> Optional[float]:
    """float -> JSON-safe float (NaN/inf -> None)."""
    if v is None:
        return None
    v = float(v)
    return None if not math.isfinite(v) else v


def _unf(v: Optional[float]) -> float:
    return math.nan if v is None else float(v)


def _fmap(d: Mapping[str, float]) -> Dict[str, Optional[float]]:
    return {k: _f(v) for k, v in d.items()}


def _unfmap(d: Mapping[str, Optional[float]]) -> Dict[str, float]:
    return {k: _unf(v) for k, v in d.items()}


def sim_to_dict(sim: SimResult, include_durations: bool = True) -> Dict[str, Any]:
    """JSON-safe dict of a :class:`SimResult`.

    ``include_durations=False`` drops the per-iteration duration lists (the
    bulky part) but always keeps the derived per-job mean so compact
    artifacts stay analyzable."""
    d: Dict[str, Any] = {
        "time_per_1000_iters_s": _fmap(sim.time_per_1000_iters_s),
        "link_utilization": _fmap(sim.link_utilization),
        "avg_bw_utilization": _f(sim.avg_bw_utilization),
        "readjustments": int(sim.readjustments),
        "finish_times_ms": _fmap(sim.finish_times_ms),
        "total_completion_ms": _f(sim.total_completion_ms),
        "iterations_done": {k: int(v) for k, v in sim.iterations_done.items()},
        "reconfigurations": int(sim.reconfigurations),
        "suppressed_reconfigurations": int(sim.suppressed_reconfigurations),
        "reconciliations": int(sim.reconciliations),
        "mean_iter_ms": {j: _f(sim.mean_iter_ms(j)) for j in sim.durations_ms},
    }
    if include_durations:
        d["durations_ms"] = {k: [_f(x) for x in v]
                             for k, v in sim.durations_ms.items()}
    return d


def sim_from_dict(d: Mapping[str, Any]) -> SimResult:
    durations = d.get("durations_ms")
    if durations is None:  # compact artifact: jobs known, samples dropped
        durations = {k: [] for k in d.get("iterations_done", {})}
    return SimResult(
        durations_ms={k: [_unf(x) for x in v] for k, v in durations.items()},
        time_per_1000_iters_s=_unfmap(d["time_per_1000_iters_s"]),
        link_utilization=_unfmap(d["link_utilization"]),
        avg_bw_utilization=_unf(d["avg_bw_utilization"]),
        readjustments=int(d["readjustments"]),
        finish_times_ms=_unfmap(d["finish_times_ms"]),
        total_completion_ms=_unf(d["total_completion_ms"]),
        iterations_done={k: int(v) for k, v in d["iterations_done"].items()},
        reconfigurations=int(d.get("reconfigurations", 0)),
        suppressed_reconfigurations=int(
            d.get("suppressed_reconfigurations", 0)),
        reconciliations=int(d.get("reconciliations", 0)),
    )


@dataclasses.dataclass
class ExperimentResult:
    """One ``run(scenario, policy)`` outcome.

    ``high_priority`` / ``low_priority`` name every job of the scenario's
    workloads split by priority (including rejected jobs) — the typed
    replacement for re-deriving the split from a workload list."""

    scenario: str
    policy: str
    scheduler: str
    accepted: List[str]
    rejected: List[str]
    placements: Dict[str, List[str]]
    high_priority: List[str]
    low_priority: List[str]
    sim: SimResult

    # ------------------------------------------------------------ aggregates
    def mean_s_per_1000(self, jobs: Optional[Sequence[str]] = None) -> float:
        """Mean time-per-1000-iterations (s) over ``jobs`` (default: every
        measured job), skipping jobs without samples."""
        if jobs is None:
            jobs = list(self.sim.time_per_1000_iters_s)
        vals = [self.sim.time_per_1000_iters_s[j] for j in jobs
                if j in self.sim.time_per_1000_iters_s
                and not math.isnan(self.sim.time_per_1000_iters_s[j])]
        return float(np.mean(vals)) if vals else math.nan

    def mean_jct_ms(self, jobs: Optional[Sequence[str]] = None) -> float:
        """Mean finish time (ms) over ``jobs`` that finished."""
        if jobs is None:
            jobs = list(self.sim.finish_times_ms)
        vals = [self.sim.finish_times_ms[j] for j in jobs
                if j in self.sim.finish_times_ms
                and not math.isnan(self.sim.finish_times_ms[j])]
        return float(np.mean(vals)) if vals else math.nan

    # ----------------------------------------------------------------- (de)ser
    def to_json_dict(self, include_durations: bool = True) -> Dict[str, Any]:
        return {
            "scenario": self.scenario,
            "policy": self.policy,
            "scheduler": self.scheduler,
            "accepted": list(self.accepted),
            "rejected": list(self.rejected),
            "placements": {k: list(v) for k, v in self.placements.items()},
            "high_priority": list(self.high_priority),
            "low_priority": list(self.low_priority),
            "sim": sim_to_dict(self.sim, include_durations=include_durations),
        }

    @classmethod
    def from_json_dict(cls, d: Mapping[str, Any]) -> "ExperimentResult":
        return cls(
            scenario=d["scenario"],
            policy=d["policy"],
            scheduler=d["scheduler"],
            accepted=list(d["accepted"]),
            rejected=list(d["rejected"]),
            placements={k: list(v) for k, v in d["placements"].items()},
            high_priority=list(d["high_priority"]),
            low_priority=list(d["low_priority"]),
            sim=sim_from_dict(d["sim"]),
        )


@dataclasses.dataclass
class SweepCell:
    """One (scenario, policy) grid cell: a result or an isolated failure."""

    scenario: str
    policy: str
    status: str  # "ok" | "error"
    result: Optional[ExperimentResult] = None
    error: Optional[str] = None  # traceback text when status == "error"

    def to_json_dict(self, include_durations: bool = True) -> Dict[str, Any]:
        d: Dict[str, Any] = {"scenario": self.scenario, "policy": self.policy,
                             "status": self.status}
        if self.result is not None:
            d["result"] = self.result.to_json_dict(
                include_durations=include_durations)
        if self.error is not None:
            d["error"] = self.error
        return d

    @classmethod
    def from_json_dict(cls, d: Mapping[str, Any]) -> "SweepCell":
        res = d.get("result")
        return cls(scenario=d["scenario"], policy=d["policy"],
                   status=d["status"],
                   result=ExperimentResult.from_json_dict(res)
                   if res is not None else None,
                   error=d.get("error"))


@dataclasses.dataclass
class SweepResult:
    """A full scenario x policy grid (row-major over scenarios)."""

    cells: List[SweepCell]
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    schema_version: int = SCHEMA_VERSION

    # ------------------------------------------------------------------ access
    def cell(self, scenario: str, policy: str) -> SweepCell:
        for c in self.cells:
            if c.scenario == scenario and c.policy == policy:
                return c
        raise KeyError(f"no cell ({scenario!r}, {policy!r}); have "
                       f"{[(c.scenario, c.policy) for c in self.cells]}")

    def get(self, scenario: str, policy: str) -> ExperimentResult:
        """The cell's result; raises if the cell failed (use :meth:`cell`
        to inspect the captured traceback instead)."""
        c = self.cell(scenario, policy)
        if c.status != "ok" or c.result is None:
            raise RuntimeError(
                f"cell ({scenario!r}, {policy!r}) failed:\n{c.error}")
        return c.result

    @property
    def errors(self) -> List[SweepCell]:
        return [c for c in self.cells if c.status != "ok"]

    def scenario_results(self, scenario: str) -> Dict[str, ExperimentResult]:
        """policy name -> result for every OK cell of one scenario."""
        return {c.policy: c.result for c in self.cells
                if c.scenario == scenario and c.status == "ok"
                and c.result is not None}

    # ----------------------------------------------------------------- (de)ser
    def to_json_dict(self, include_durations: bool = True) -> Dict[str, Any]:
        return {
            "schema_version": self.schema_version,
            "meta": dict(self.meta),
            "cells": [c.to_json_dict(include_durations=include_durations)
                      for c in self.cells],
        }

    @classmethod
    def from_json_dict(cls, d: Mapping[str, Any]) -> "SweepResult":
        version = int(d.get("schema_version", -1))
        if version != SCHEMA_VERSION:
            raise ValueError(f"sweep schema version {version} != "
                             f"supported {SCHEMA_VERSION}")
        return cls(cells=[SweepCell.from_json_dict(c) for c in d["cells"]],
                   meta=dict(d.get("meta", {})),
                   schema_version=version)

    def save(self, path: str, include_durations: bool = True) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json_dict(include_durations=include_durations),
                      f, indent=1, allow_nan=False)

    @classmethod
    def load(cls, path: str) -> "SweepResult":
        with open(path) as f:
            return cls.from_json_dict(json.load(f))


# --------------------------------------------------------------- BENCH file
def to_bench_dict(sweeps: Sequence[SweepResult], *,
                  smoke: bool = False,
                  include_durations: bool = False) -> Dict[str, Any]:
    """The ``BENCH_sweep.json`` payload: every sweep the bench harness ran,
    compact by default (per-iteration samples dropped, derived means kept)."""
    return {
        "schema_version": SCHEMA_VERSION,
        "generated_by": "benchmarks.run",
        "smoke": bool(smoke),
        "sweeps": [s.to_json_dict(include_durations=include_durations)
                   for s in sweeps],
    }


# ------------------------------------------------------------ timing BENCH
# The second artifact family: benchmark timing rows (``common.emit``'s
# ``name,us_per_call,derived`` contract) persisted as schema-versioned JSON
# (``BENCH_sched_time.json``) so scheduler-latency regressions are a
# machine-readable trajectory instead of stdout-only CSV.

def to_timing_dict(rows: Sequence[Mapping[str, Any]], *,
                   smoke: bool = False) -> Dict[str, Any]:
    """The ``BENCH_sched_time.json`` payload: every ``emit`` row the bench
    harness produced, each ``{name, us_per_call, derived, origin}``."""
    return {
        "schema_version": SCHEMA_VERSION,
        "generated_by": "benchmarks.run",
        "kind": "timing",
        "smoke": bool(smoke),
        "rows": [
            {"name": str(r["name"]),
             "us_per_call": _f(float(r["us_per_call"])),
             "derived": str(r.get("derived", "")),
             "origin": str(r.get("origin", ""))}
            for r in rows
        ],
    }


def validate_timing_dict(doc: Mapping[str, Any]) -> List[str]:
    """Schema check of a timing-rows payload; empty list == valid."""
    problems: List[str] = []
    if not isinstance(doc, Mapping):
        return ["top level is not an object"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        problems.append(f"schema_version {doc.get('schema_version')!r} != "
                        f"{SCHEMA_VERSION}")
    if doc.get("kind") != "timing":
        problems.append(f"kind {doc.get('kind')!r} != 'timing'")
    rows = doc.get("rows")
    if not isinstance(rows, list):
        problems.append("'rows' missing or not a list")
        return problems
    if not rows:
        problems.append("'rows' is empty — no benchmark emitted a timing")
    for ri, row in enumerate(rows):
        where = f"rows[{ri}]"
        if not isinstance(row, Mapping):
            problems.append(f"{where} is not an object")
            continue
        if not isinstance(row.get("name"), str) or not row.get("name"):
            problems.append(f"{where}.name missing or not a string")
        us = row.get("us_per_call")
        if not isinstance(us, (int, float)) or isinstance(us, bool):
            problems.append(f"{where}.us_per_call missing or not a number")
        for key in ("derived", "origin"):
            if not isinstance(row.get(key), str):
                problems.append(f"{where}.{key} missing or not a string")
    return problems


def to_trace_throughput_dict(rows: Sequence[Mapping[str, Any]], *,
                             smoke: bool = False) -> Dict[str, Any]:
    """The ``BENCH_trace_throughput.json`` payload: one row per fluid-rate
    backend timed over the production-trace fill-problem corpus
    (``benchmarks/bench_trace_throughput.py``).  ``speedup_vs_python`` on
    the vectorized rows is the acceptance metric the CI gate reads."""
    return {
        "schema_version": SCHEMA_VERSION,
        "generated_by": "benchmarks.run",
        "kind": "trace_throughput",
        "smoke": bool(smoke),
        "rows": [
            {"name": str(r["name"]),
             "backend": str(r["backend"]),
             "n_jobs": int(r["n_jobs"]),
             "n_problems": int(r["n_problems"]),
             "n_flows": int(r["n_flows"]),
             "seconds": _f(float(r["seconds"])),
             "problems_per_s": _f(float(r["problems_per_s"])),
             "flows_per_s": _f(float(r["flows_per_s"])),
             "speedup_vs_python": _f(float(r["speedup_vs_python"])),
             "max_abs_err_vs_python": _f(float(r["max_abs_err_vs_python"])),
             "origin": str(r.get("origin", ""))}
            for r in rows
        ],
    }


def validate_trace_throughput_dict(doc: Mapping[str, Any]) -> List[str]:
    """Schema check of a trace-throughput payload; empty list == valid."""
    problems: List[str] = []
    if not isinstance(doc, Mapping):
        return ["top level is not an object"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        problems.append(f"schema_version {doc.get('schema_version')!r} != "
                        f"{SCHEMA_VERSION}")
    if doc.get("kind") != "trace_throughput":
        problems.append(f"kind {doc.get('kind')!r} != 'trace_throughput'")
    rows = doc.get("rows")
    if not isinstance(rows, list):
        problems.append("'rows' missing or not a list")
        return problems
    if not rows:
        problems.append("'rows' is empty — no backend was benchmarked")
    backends = set()
    for ri, row in enumerate(rows):
        where = f"rows[{ri}]"
        if not isinstance(row, Mapping):
            problems.append(f"{where} is not an object")
            continue
        for key in ("name", "backend", "origin"):
            if not isinstance(row.get(key), str) or row.get(key) is None:
                problems.append(f"{where}.{key} missing or not a string")
        for key in ("n_jobs", "n_problems", "n_flows"):
            v = row.get(key)
            if not isinstance(v, int) or isinstance(v, bool):
                problems.append(f"{where}.{key} missing or not an int")
        for key in ("seconds", "problems_per_s", "flows_per_s",
                    "speedup_vs_python", "max_abs_err_vs_python"):
            v = row.get(key)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                problems.append(f"{where}.{key} missing or not a number")
        backends.add(row.get("backend"))
    if rows and "python" not in backends:
        problems.append("no 'python' baseline row — speedups are unanchored")
    return problems


def to_dynamic_throughput_dict(rows: Sequence[Mapping[str, Any]], *,
                               smoke: bool = False) -> Dict[str, Any]:
    """The ``BENCH_dynamic_throughput.json`` payload: one row per
    (event-loop, fluid-backend) combination driving the full dynamic event
    loop over the 10k-job production trace
    (``benchmarks/bench_dynamic_throughput.py``).

    ``speedup_vs_legacy`` on the array rows is the acceptance metric the
    CI gate reads (>= 10x end-to-end on the non-smoke trace);
    ``max_abs_err_vs_oracle`` audits sampled in-loop solves of vectorized
    backends against ``fill_python`` re-solves (0 for the python oracle,
    which is instead bit-for-bit by construction — pinned in
    ``tests/test_event_loop.py``).  ``profile`` carries the per-phase
    counters/timings of ``SimConfig.profile``; ``corpus`` the
    ``fluid.CorpusStats`` bucket occupancy, so batch-padding waste is in
    the artifact rather than silent."""
    out = []
    for r in rows:
        profile = r.get("profile")
        corpus = r.get("corpus")
        out.append(
            {"name": str(r["name"]),
             "loop": str(r["loop"]),
             "backend": str(r["backend"]),
             "n_jobs": int(r["n_jobs"]),
             "n_events": int(r["n_events"]),
             "ticks": int(r["ticks"]),
             "seconds": _f(float(r["seconds"])),
             "speedup_vs_legacy": _f(float(r["speedup_vs_legacy"])),
             "max_abs_err_vs_oracle": _f(float(r["max_abs_err_vs_oracle"])),
             "profile": dict(profile) if profile is not None else None,
             "corpus": dict(corpus) if corpus is not None else None,
             "origin": str(r.get("origin", ""))})
    return {
        "schema_version": SCHEMA_VERSION,
        "generated_by": "benchmarks.run",
        "kind": "dynamic_throughput",
        "smoke": bool(smoke),
        "rows": out,
    }


def validate_dynamic_throughput_dict(doc: Mapping[str, Any]) -> List[str]:
    """Schema check of a dynamic-throughput payload; empty list == valid."""
    problems: List[str] = []
    if not isinstance(doc, Mapping):
        return ["top level is not an object"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        problems.append(f"schema_version {doc.get('schema_version')!r} != "
                        f"{SCHEMA_VERSION}")
    if doc.get("kind") != "dynamic_throughput":
        problems.append(f"kind {doc.get('kind')!r} != 'dynamic_throughput'")
    rows = doc.get("rows")
    if not isinstance(rows, list):
        problems.append("'rows' missing or not a list")
        return problems
    if not rows:
        problems.append("'rows' is empty — no loop/backend was benchmarked")
    loops = set()
    for ri, row in enumerate(rows):
        where = f"rows[{ri}]"
        if not isinstance(row, Mapping):
            problems.append(f"{where} is not an object")
            continue
        for key in ("name", "loop", "backend", "origin"):
            if not isinstance(row.get(key), str):
                problems.append(f"{where}.{key} missing or not a string")
        for key in ("n_jobs", "n_events", "ticks"):
            v = row.get(key)
            if not isinstance(v, int) or isinstance(v, bool):
                problems.append(f"{where}.{key} missing or not an int")
        for key in ("seconds", "speedup_vs_legacy", "max_abs_err_vs_oracle"):
            v = row.get(key)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                problems.append(f"{where}.{key} missing or not a number")
        for key in ("profile", "corpus"):
            v = row.get(key)
            if v is not None and not isinstance(v, Mapping):
                problems.append(f"{where}.{key} neither null nor an object")
        loops.add(row.get("loop"))
    if rows and "legacy" not in loops:
        problems.append("no 'legacy' baseline row — speedups are unanchored")
    if rows and "array" not in loops:
        problems.append("no 'array' row — the optimized loop was not timed")
    return problems


ROBUSTNESS_AXES = ("noise", "staleness", "failure", "trace")


def to_robustness_dict(rows: Sequence[Mapping[str, Any]], *,
                       smoke: bool = False) -> Dict[str, Any]:
    """The ``BENCH_robustness.json`` payload: graceful-degradation curves
    under an imperfect-information control plane
    (``benchmarks/bench_robustness.py``, DESIGN.md section 19).

    One row per (axis, scenario, policy, x) point, seed-averaged.  ``axis``
    names the swept distortion (``noise`` = telemetry noise_std,
    ``staleness`` = telemetry staleness_ms, ``failure`` = flapping-cycle
    count, ``trace`` = noise_std on an online trace); ``x`` its value.
    ``degradation`` is the job-mean time-per-1000-iterations ratio against
    the same (axis, scenario, policy) group's ``x == 0`` anchor — 1.0 at
    the anchor by construction, and the acceptance criterion is that the
    robust policy's curve stays monotone-ish and SHALLOWER than the
    oracle-assuming ablation's.  The controller diagnostics
    (``readjustments``/``reconfigurations``/``suppressed_reconfigurations``/
    ``reconciliations``) record WHY: suppressed replans and adopted
    reconciliations are the degradation-control machinery firing."""
    out = []
    for r in rows:
        out.append(
            {"axis": str(r["axis"]),
             "scenario": str(r["scenario"]),
             "policy": str(r["policy"]),
             "x": _f(float(r["x"])),
             "seeds": int(r["seeds"]),
             "t1000_mean_s": _f(float(r["t1000_mean_s"])),
             "t1000_hi_s": _f(float(r["t1000_hi_s"])),
             "t1000_lo_s": _f(float(r["t1000_lo_s"])),
             "degradation": _f(float(r["degradation"])),
             "readjustments": _f(float(r["readjustments"])),
             "reconfigurations": _f(float(r["reconfigurations"])),
             "suppressed_reconfigurations": _f(
                 float(r["suppressed_reconfigurations"])),
             "reconciliations": _f(float(r["reconciliations"])),
             "origin": str(r.get("origin", ""))})
    return {
        "schema_version": SCHEMA_VERSION,
        "generated_by": "benchmarks.run",
        "kind": "robustness",
        "smoke": bool(smoke),
        "rows": out,
    }


def validate_robustness_dict(doc: Mapping[str, Any]) -> List[str]:
    """Schema check of a robustness payload; empty list == valid."""
    problems: List[str] = []
    if not isinstance(doc, Mapping):
        return ["top level is not an object"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        problems.append(f"schema_version {doc.get('schema_version')!r} != "
                        f"{SCHEMA_VERSION}")
    if doc.get("kind") != "robustness":
        problems.append(f"kind {doc.get('kind')!r} != 'robustness'")
    rows = doc.get("rows")
    if not isinstance(rows, list):
        problems.append("'rows' missing or not a list")
        return problems
    if not rows:
        problems.append("'rows' is empty — no degradation curve was run")
    policies = set()
    anchors = set()
    groups = set()
    for ri, row in enumerate(rows):
        where = f"rows[{ri}]"
        if not isinstance(row, Mapping):
            problems.append(f"{where} is not an object")
            continue
        for key in ("axis", "scenario", "policy", "origin"):
            if not isinstance(row.get(key), str):
                problems.append(f"{where}.{key} missing or not a string")
        if row.get("axis") not in ROBUSTNESS_AXES:
            problems.append(f"{where}.axis {row.get('axis')!r} not in "
                            f"{ROBUSTNESS_AXES}")
        v = row.get("seeds")
        if not isinstance(v, int) or isinstance(v, bool) or v < 1:
            problems.append(f"{where}.seeds missing or not a positive int")
        for key in ("x", "t1000_mean_s", "degradation", "readjustments",
                    "reconfigurations", "suppressed_reconfigurations",
                    "reconciliations"):
            v = row.get(key)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                problems.append(f"{where}.{key} missing or not a number")
        for key in ("t1000_hi_s", "t1000_lo_s"):
            # null (NaN) is legitimate: a scenario may have no jobs of
            # that priority class with measured iterations
            v = row.get(key)
            if v is not None and (not isinstance(v, (int, float))
                                  or isinstance(v, bool)):
                problems.append(f"{where}.{key} not a number or null")
        policies.add(row.get("policy"))
        group = (row.get("axis"), row.get("scenario"), row.get("policy"))
        groups.add(group)
        if row.get("x") == 0.0:
            anchors.add(group)
            deg = row.get("degradation")
            if isinstance(deg, (int, float)) and abs(deg - 1.0) > 1e-9:
                problems.append(
                    f"{where}: x == 0 anchor has degradation {deg!r} != 1.0")
    if rows and len(policies) < 2:
        problems.append("fewer than 2 policies — the degradation curve has "
                        "no ablation to compare against")
    for g in sorted(groups - anchors):
        problems.append(f"group {g} has no x == 0 anchor row — its "
                        "degradation ratios are unanchored")
    return problems


_CELL_RESULT_KEYS = ("scenario", "policy", "scheduler", "accepted",
                     "rejected", "placements", "high_priority",
                     "low_priority", "sim")
_SIM_KEYS = ("time_per_1000_iters_s", "link_utilization",
             "avg_bw_utilization", "readjustments", "finish_times_ms",
             "total_completion_ms", "iterations_done", "reconfigurations",
             "suppressed_reconfigurations", "reconciliations",
             "mean_iter_ms")


def validate_bench_dict(doc: Mapping[str, Any]) -> List[str]:
    """Schema check of a ``BENCH_sweep.json`` payload.

    Returns a list of human-readable problems; empty list == valid.  Used
    by ``scripts/validate_bench.py`` in CI so format drift fails the build."""
    problems: List[str] = []
    if not isinstance(doc, Mapping):
        return ["top level is not an object"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        problems.append(f"schema_version {doc.get('schema_version')!r} != "
                        f"{SCHEMA_VERSION}")
    sweeps = doc.get("sweeps")
    if not isinstance(sweeps, list):
        problems.append("'sweeps' missing or not a list")
        return problems
    if not sweeps:
        problems.append("'sweeps' is empty — no benchmark recorded a sweep")
    for si, sw in enumerate(sweeps):
        where = f"sweeps[{si}]"
        if not isinstance(sw, Mapping):
            problems.append(f"{where} is not an object")
            continue
        if sw.get("schema_version") != SCHEMA_VERSION:
            problems.append(f"{where}.schema_version != {SCHEMA_VERSION}")
        cells = sw.get("cells")
        if not isinstance(cells, list) or not cells:
            problems.append(f"{where}.cells missing or empty")
            continue
        for ci, cell in enumerate(cells):
            cw = f"{where}.cells[{ci}]"
            if not isinstance(cell, Mapping):
                problems.append(f"{cw} is not an object")
                continue
            for key in ("scenario", "policy", "status"):
                if not isinstance(cell.get(key), str):
                    problems.append(f"{cw}.{key} missing or not a string")
            status = cell.get("status")
            if status == "ok":
                res = cell.get("result")
                if not isinstance(res, Mapping):
                    problems.append(f"{cw}.result missing on an ok cell")
                    continue
                for key in _CELL_RESULT_KEYS:
                    if key not in res:
                        problems.append(f"{cw}.result.{key} missing")
                sim = res.get("sim")
                if isinstance(sim, Mapping):
                    for key in _SIM_KEYS:
                        if key not in sim:
                            problems.append(f"{cw}.result.sim.{key} missing")
                else:
                    problems.append(f"{cw}.result.sim missing")
            elif status == "error":
                if not isinstance(cell.get("error"), str):
                    problems.append(f"{cw}.error missing on an error cell")
            else:
                problems.append(f"{cw}.status {status!r} not ok|error")
    return problems

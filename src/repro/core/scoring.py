"""Rotation-scheme enumeration and scoring — paper sections III-B / III-C.

Two entry points mirroring the paper's split between the scheduler and the
stop-and-wait controller:

  * :func:`find_feasible_rotation` — the Score-phase fast path: traverse
    rotation schemes in lexicographic order until the *first* interval of
    perfect scores, return its middle index ("locally optimal feasible
    solution", section III-B).

  * :func:`find_optimal_rotation` — the offline recalculation (3rd stage):
    enumerate all schemes, restrict to middle indices of perfect-score
    intervals, and among those maximize the minimum communication interval
    Psi (Eq. 9), section III-C.

Combo spaces are the Cartesian product of per-task shift ranges
``[0, S/mul_p)`` (Eq. 15) with the highest-priority reference task pinned to
0 (Eq. 16). When the product is too large for exhaustive enumeration we use
the paper's own reduction argument (hold all but one pod fixed) as
coordinate descent.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from . import geometry
from .geometry import DI_PRE

PERFECT = 100.0
_EPS = 1e-9


@dataclasses.dataclass
class RotationResult:
    score: float
    shifts: np.ndarray  # (P,) integer slot shifts theta_{l,p}
    perfect: bool
    psi: float = 0.0  # min communication interval of the chosen scheme
    n_evaluated: int = 0


def shift_ranges(muls: Sequence[int], ref_index: int, n_slots: int = DI_PRE) -> List[int]:
    """Per-task rotation search-space sizes: S // mul_p (Eq. 15), ref pinned."""
    out = []
    for i, m in enumerate(muls):
        if i == ref_index:
            out.append(1)
        else:
            out.append(max(1, n_slots // int(m)))
    return out


def _rolled_bank(patterns: np.ndarray, ranges: Sequence[int]) -> List[np.ndarray]:
    """bank[p][r] = pattern p rolled by r slots, for r in [0, ranges[p])."""
    p, s = patterns.shape
    bank = []
    for i in range(p):
        idx = (np.arange(s)[None, :] - np.arange(ranges[i])[:, None]) % s
        bank.append(patterns[i][idx])  # (ranges[i], S)
    return bank


def score_combos(
    patterns: np.ndarray,
    bw: np.ndarray,
    capacity: float,
    combos: np.ndarray,
    bank: Optional[List[np.ndarray]] = None,
) -> np.ndarray:
    """Vectorized Eq. (18) score for a (K, P) array of shift combos."""
    p, s = patterns.shape
    k = combos.shape[0]
    total = np.zeros((k, s), dtype=np.float64)
    for i in range(p):
        if bank is not None:
            rolled = bank[i][combos[:, i]]  # (K, S)
        else:
            idx = (np.arange(s)[None, :] - combos[:, i][:, None]) % s
            rolled = patterns[i][idx]
        total += bw[i] * rolled
    ex = np.sum(np.maximum(total - capacity, 0.0), axis=1)
    return np.maximum(0.0, 100.0 * (1.0 - ex / (capacity * s)))


def _lex_combos(ranges: Sequence[int], start: int, count: int) -> np.ndarray:
    """Decode lexicographic combo indices [start, start+count) -> (count, P)."""
    idx = np.arange(start, start + count, dtype=np.int64)
    p = len(ranges)
    out = np.zeros((len(idx), p), dtype=np.int64)
    for i in range(p - 1, -1, -1):
        out[:, i] = idx % ranges[i]
        idx = idx // ranges[i]
    return out


def total_combos(ranges: Sequence[int]) -> int:
    n = 1
    for r in ranges:
        n *= r
    return n


def find_feasible_rotation(
    patterns: np.ndarray,
    bw: Sequence[float],
    capacity: float,
    muls: Sequence[int],
    ref_index: int = 0,
    n_slots: int = DI_PRE,
    chunk: int = 4096,
    max_exhaustive: int = 1 << 22,
    mode: str = "intermediate",
) -> RotationResult:
    """Score-phase fast path (Algorithm 1, Score extension point).

    Traverses combos lexicographically and stops at the first maximal run of
    perfect scores, returning the scheme at the run's middle index. Falls
    back to the best seen score when no perfect combo exists.

    ``mode='compact'`` is the paper's 3rd-stage ABLATION (section IV-C):
    take the first index of the perfect run (comm phases packed
    back-to-back, no cushion slots) instead of the middle.
    """
    bw = np.asarray(bw, dtype=np.float64)
    ranges = shift_ranges(muls, ref_index, n_slots)
    n_total = total_combos(ranges)
    if n_total > max_exhaustive:
        return coordinate_descent_rotation(
            patterns, bw, capacity, muls, ref_index, n_slots
        )
    bank = _rolled_bank(patterns, ranges)

    best_score = -1.0
    best_combo = np.zeros(len(ranges), dtype=np.int64)
    run_start = None  # start index of the current perfect run
    n_eval = 0
    pos = 0
    while pos < n_total:
        cnt = min(chunk, n_total - pos)
        combos = _lex_combos(ranges, pos, cnt)
        scores = score_combos(patterns, bw, capacity, combos, bank)
        n_eval += cnt
        is_perfect = scores >= PERFECT - _EPS
        for j in range(cnt):
            if is_perfect[j]:
                if run_start is None:
                    run_start = pos + j
            else:
                if run_start is not None:
                    # first perfect run ended at pos+j-1 -> return middle
                    # (or the run's edge in the no-cushion ablation)
                    mid = (run_start if mode == "compact"
                           else (run_start + pos + j - 1) // 2)
                    shifts = _lex_combos(ranges, mid, 1)[0]
                    return RotationResult(PERFECT, shifts, True,
                                          _psi(patterns, bw, capacity, muls, shifts, n_slots),
                                          n_eval)
                if scores[j] > best_score:
                    best_score = float(scores[j])
                    best_combo = combos[j]
        pos += cnt
    if run_start is not None:  # perfect run extends to the end
        mid = (run_start if mode == "compact"
               else (run_start + n_total - 1) // 2)
        shifts = _lex_combos(ranges, mid, 1)[0]
        return RotationResult(PERFECT, shifts, True,
                              _psi(patterns, bw, capacity, muls, shifts, n_slots), n_eval)
    return RotationResult(best_score, best_combo, False,
                          _psi(patterns, bw, capacity, muls, best_combo, n_slots), n_eval)


def _psi(patterns, bw, capacity, muls, shifts, n_slots) -> float:
    # duty w.r.t. the base circle = total comm slots / n_slots; Eq. 9 midpoints
    # need the per-task duty cycle (per-burst arc = duty * n_slots / mul).
    duties = [float(patterns[i].sum() / n_slots) for i in range(len(muls))]
    return geometry.min_comm_interval(muls, duties, bw, shifts, capacity, n_slots)


def find_optimal_rotation(
    patterns: np.ndarray,
    bw: Sequence[float],
    capacity: float,
    muls: Sequence[int],
    ref_index: int = 0,
    n_slots: int = DI_PRE,
    chunk: int = 8192,
    max_exhaustive: int = 1 << 22,
    scorer: Optional[Callable[[np.ndarray], np.ndarray]] = None,
) -> RotationResult:
    """Offline recalculation (3rd optimization stage), section III-C.

    Enumerates all rotation schemes; candidate set = middle indices of all
    perfect-score runs (the paper's search-space narrowing); among candidates
    maximizes Psi (Eq. 9). ``scorer`` may override the combo scorer (used to
    plug in the Pallas kernel).
    """
    bw = np.asarray(bw, dtype=np.float64)
    ranges = shift_ranges(muls, ref_index, n_slots)
    n_total = total_combos(ranges)
    if n_total > max_exhaustive:
        return coordinate_descent_rotation(
            patterns, bw, capacity, muls, ref_index, n_slots, optimize_psi=True
        )
    bank = _rolled_bank(patterns, ranges)

    candidates: List[int] = []
    best_score = -1.0
    best_idx = 0
    run_start = None
    prev_perfect_end = None
    pos = 0
    while pos < n_total:
        cnt = min(chunk, n_total - pos)
        combos = _lex_combos(ranges, pos, cnt)
        if scorer is not None:
            scores = np.asarray(scorer(combos))
        else:
            scores = score_combos(patterns, bw, capacity, combos, bank)
        is_perfect = scores >= PERFECT - _EPS
        for j in range(cnt):
            gi = pos + j
            if is_perfect[j]:
                if run_start is None:
                    run_start = gi
            else:
                if run_start is not None:
                    candidates.append((run_start + gi - 1) // 2)
                    run_start = None
                if scores[j] > best_score:
                    best_score = float(scores[j])
                    best_idx = gi
        pos += cnt
    if run_start is not None:
        candidates.append((run_start + n_total - 1) // 2)

    if not candidates:
        shifts = _lex_combos(ranges, best_idx, 1)[0]
        return RotationResult(best_score, shifts, False,
                              _psi(patterns, bw, capacity, muls, shifts, n_slots), n_total)

    # stage 3: among perfect-run midpoints maximize Psi
    best_psi = -1.0
    best_shifts = None
    for c in candidates:
        shifts = _lex_combos(ranges, c, 1)[0]
        psi = _psi(patterns, bw, capacity, muls, shifts, n_slots)
        if psi > best_psi:
            best_psi = psi
            best_shifts = shifts
    return RotationResult(PERFECT, best_shifts, True, best_psi, n_total)


def coordinate_descent_rotation(
    patterns: np.ndarray,
    bw: np.ndarray,
    capacity: float,
    muls: Sequence[int],
    ref_index: int,
    n_slots: int = DI_PRE,
    optimize_psi: bool = False,
    sweeps: int = 4,
) -> RotationResult:
    """Large combo spaces: hold all but one pod fixed (paper's reduction)."""
    bw = np.asarray(bw, dtype=np.float64)
    p = patterns.shape[0]
    ranges = shift_ranges(muls, ref_index, n_slots)
    shifts = np.zeros(p, dtype=np.int64)
    n_eval = 0
    for _ in range(sweeps):
        changed = False
        for i in range(p):
            if i == ref_index or ranges[i] <= 1:
                continue
            cands = np.tile(shifts, (ranges[i], 1))
            cands[:, i] = np.arange(ranges[i])
            scores = score_combos(patterns, bw, capacity, cands)
            n_eval += ranges[i]
            best = scores.max()
            mask = scores >= best - _EPS
            if optimize_psi and best >= PERFECT - _EPS:
                # pick the perfect shift maximizing Psi
                idxs = np.nonzero(mask)[0]
                psis = [
                    _psi(patterns, bw, capacity, muls, cands[k], n_slots) for k in idxs
                ]
                pick = int(idxs[int(np.argmax(psis))])
            else:
                # middle of the first perfect/best run
                idxs = np.nonzero(mask)[0]
                runs = np.split(idxs, np.where(np.diff(idxs) != 1)[0] + 1)
                pick = int(runs[0][len(runs[0]) // 2])
            if pick != shifts[i]:
                shifts[i] = pick
                changed = True
        if not changed:
            break
    final = score_combos(patterns, bw, capacity, shifts[None, :])[0]
    return RotationResult(float(final), shifts, final >= PERFECT - _EPS,
                          _psi(patterns, bw, capacity, muls, shifts, n_slots), n_eval)

"""Per-candidate rotation-scheme evaluators — paper section III-B (Eq. 18).

This module holds the *evaluation* primitives of the rotation search: the
per-task shift ranges of Eq. 15, lexicographic combo decoding, rolled
demand banks, the vectorized Eq. 18 scorer, and the Psi (Eq. 9) metric of a
chosen scheme.  The *search* itself — per-link solvers, the fabric-wide
joint solve, and global-offset resolution — lives in
:mod:`repro.core.rotation`, the single producer of rotation schemes.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence

import numpy as np

from . import geometry
from .geometry import DI_PRE

PERFECT = 100.0
_EPS = 1e-9


def shift_ranges(muls: Sequence[int], ref_index: int, n_slots: int = DI_PRE) -> List[int]:
    """Per-task rotation search-space sizes: S // mul_p (Eq. 15), ref pinned."""
    out = []
    for i, m in enumerate(muls):
        if i == ref_index:
            out.append(1)
        else:
            out.append(max(1, n_slots // int(m)))
    return out


def rolled_bank(patterns: np.ndarray, ranges: Sequence[int]) -> List[np.ndarray]:
    """bank[p][r] = pattern p rolled by r slots, for r in [0, ranges[p])."""
    p, s = patterns.shape
    bank = []
    for i in range(p):
        idx = (np.arange(s)[None, :] - np.arange(ranges[i])[:, None]) % s
        bank.append(patterns[i][idx])  # (ranges[i], S)
    return bank


def score_combos(
    patterns: np.ndarray,
    bw: np.ndarray,
    capacity: float,
    combos: np.ndarray,
    bank: Optional[List[np.ndarray]] = None,
) -> np.ndarray:
    """Vectorized Eq. (18) score for a (K, P) array of shift combos."""
    p, s = patterns.shape
    k = combos.shape[0]
    total = np.zeros((k, s), dtype=np.float64)
    for i in range(p):
        if bank is not None:
            rolled = bank[i][combos[:, i]]  # (K, S)
        else:
            idx = (np.arange(s)[None, :] - combos[:, i][:, None]) % s
            rolled = patterns[i][idx]
        total += bw[i] * rolled
    ex = np.sum(np.maximum(total - capacity, 0.0), axis=1)
    return np.maximum(0.0, 100.0 * (1.0 - ex / (capacity * s)))


def lex_combos(ranges: Sequence[int], start: int, count: int) -> np.ndarray:
    """Decode lexicographic combo indices [start, start+count) -> (count, P)."""
    idx = np.arange(start, start + count, dtype=np.int64)
    p = len(ranges)
    out = np.zeros((len(idx), p), dtype=np.int64)
    for i in range(p - 1, -1, -1):
        out[:, i] = idx % ranges[i]
        idx = idx // ranges[i]
    return out


def total_combos(ranges: Sequence[int]) -> int:
    n = 1
    for r in ranges:
        n *= r
    return n


def scheme_psi(patterns, bw, capacity, muls, shifts, n_slots=DI_PRE) -> float:
    """Psi (Eq. 9) of one chosen scheme.

    The duty w.r.t. the base circle = total comm slots / n_slots; Eq. 9
    midpoints need the per-task duty cycle (per-burst arc =
    duty * n_slots / mul)."""
    duties = [float(patterns[i].sum() / n_slots) for i in range(len(muls))]
    return geometry.min_comm_interval(muls, duties, bw, shifts, capacity,
                                      n_slots)

"""Per-candidate rotation-scheme evaluators — paper section III-B (Eq. 18).

This module holds the *evaluation* primitives of the rotation search: the
per-task shift ranges of Eq. 15, lexicographic combo decoding, rolled
demand banks, the vectorized Eq. 18 scorer, and the Psi (Eq. 9) metric of a
chosen scheme.  The *search* itself — per-link solvers, the fabric-wide
joint solve, and global-offset resolution — lives in
:mod:`repro.core.rotation`, the single producer of rotation schemes.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from . import geometry
from .geometry import DI_PRE

PERFECT = 100.0
_EPS = 1e-9

# (n_slots,) -> the full (S, S) roll-index matrix ROLL[r, j] = (j - r) % S,
# shared by rolled_bank (sliced to the first ``ranges[p]`` rows) and the
# bank-less score_combos fallback (gathered by combo column) instead of
# reallocating the arange outer difference on every call.
_ROLL_IDX: dict = {}
# (patterns bytes, shape, ranges) -> rolled bank; patterns are tiny (P x S)
# so the content key is cheap, and the Score phase re-derives the SAME bank
# for every candidate node of a pod (see repro.core.rotation).
_BANK_CACHE: dict = {}
_BANK_CACHE_MAX = 128


def roll_index(n_slots: int) -> np.ndarray:
    """The (S, S) matrix of roll gather indices: row r = (arange(S) - r) % S."""
    idx = _ROLL_IDX.get(n_slots)
    if idx is None:
        ar = np.arange(n_slots)
        idx = (ar[None, :] - ar[:, None]) % n_slots
        _ROLL_IDX[n_slots] = idx
    return idx


def shift_ranges(muls: Sequence[int], ref_index: int, n_slots: int = DI_PRE) -> List[int]:
    """Per-task rotation search-space sizes: S // mul_p (Eq. 15), ref pinned."""
    out = []
    for i, m in enumerate(muls):
        if i == ref_index:
            out.append(1)
        else:
            out.append(max(1, n_slots // int(m)))
    return out


def rolled_bank(patterns: np.ndarray, ranges: Sequence[int]) -> List[np.ndarray]:
    """bank[p][r] = pattern p rolled by r slots, for r in [0, ranges[p]).

    Content-cached: the bank is a pure function of (patterns, ranges) and the
    scheduler re-requests identical banks for every candidate node of a pod.
    Callers must treat the returned arrays as read-only."""
    p, s = patterns.shape
    key = (patterns.tobytes(), patterns.shape, tuple(int(r) for r in ranges))
    bank = _BANK_CACHE.get(key)
    if bank is None:
        idx = roll_index(s)
        bank = [patterns[i][idx[: ranges[i]]] for i in range(p)]
        if len(_BANK_CACHE) >= _BANK_CACHE_MAX:
            _BANK_CACHE.clear()
        _BANK_CACHE[key] = bank
    return bank


def score_combos(
    patterns: np.ndarray,
    bw: np.ndarray,
    capacity: float,
    combos: np.ndarray,
    bank: Optional[List[np.ndarray]] = None,
) -> np.ndarray:
    """Vectorized Eq. (18) score for a (K, P) array of shift combos."""
    p, s = patterns.shape
    k = combos.shape[0]
    total = np.zeros((k, s), dtype=np.float64)
    for i in range(p):
        if bank is not None:
            rolled = bank[i][combos[:, i]]  # (K, S)
        else:
            rolled = patterns[i][roll_index(s)[combos[:, i] % s]]
        total += bw[i] * rolled
    ex = np.sum(np.maximum(total - capacity, 0.0), axis=1)
    if capacity <= 0.0:
        # a dead link (fault injection, DESIGN.md section 19) admits
        # nothing: every scheme scores 0, and 0/0 must not leak NaN
        return np.zeros(k, dtype=np.float64)
    return np.maximum(0.0, 100.0 * (1.0 - ex / (capacity * s)))


def lex_block_scores(
    patterns: np.ndarray,
    bw_rows: np.ndarray,
    capacities: np.ndarray,
    ranges: Sequence[int],
    bank: List[np.ndarray],
    major_start: int,
    major_count: int,
) -> np.ndarray:
    """Eq. (18) scores of a contiguous lexicographic combo span, batched over
    M (bandwidth, capacity) rows — shape (M, major_count * minor_product).

    The span covers every combo whose MOST SIGNIFICANT free digit (the lowest
    pattern index with ``ranges > 1``) lies in
    ``[major_start, major_start + major_count)`` with all lower digits
    enumerated — exactly rows ``[major_start * minor, ...)`` of the
    lexicographic order that :func:`lex_combos` decodes.

    Instead of gathering a rolled row per combo (a (K, S) gather per pattern,
    the old hot path), the demand tensor is built by broadcasting each free
    pattern's bank along its own axis.  Per element the accumulation performs
    the IDENTICAL float64 operation sequence as :func:`score_combos`
    (``total += bw[p] * rolled_p`` in ascending pattern order), so the result
    is bit-for-bit equal to calling ``score_combos`` row by row."""
    p, s = patterns.shape
    bw_rows = np.asarray(bw_rows, dtype=np.float64)
    capacities = np.asarray(capacities, dtype=np.float64)
    squeeze = bw_rows.ndim == 1
    if squeeze:
        bw_rows = bw_rows[None, :]
        capacities = capacities.reshape(1)
    m = bw_rows.shape[0]
    free = [i for i in range(p) if ranges[i] > 1]
    nfree = len(free)
    full = [m] + [1] * nfree + [s]
    for fi, i in enumerate(free):
        full[1 + fi] = (major_count if fi == 0 else ranges[i])
    # one full-shape buffer, accumulated IN PLACE with broadcasting: per
    # element the partial-sum sequence (ascending pattern index) is the
    # same as score_combos', so results stay bit-identical while the big
    # tensor is traversed once per pattern instead of re-allocated.  The
    # first pattern is written by assignment (0.0 + x == x bit-exactly for
    # the non-negative bw*pattern contributions), skipping the zero fill.
    total = np.empty(full, dtype=np.float64)
    for i in range(p):
        if ranges[i] <= 1:
            rows = bank[i][0:1]  # digit pinned at 0
            shape = [1] * nfree + [s]
        else:
            fi = free.index(i)
            if fi == 0:
                rows = bank[i][major_start:major_start + major_count]
            else:
                rows = bank[i]
            shape = [1] * nfree + [s]
            shape[fi] = rows.shape[0]
        contrib = (bw_rows[:, i].reshape((m,) + (1,) * (nfree + 1))
                   * rows.reshape([1] + shape))
        if i == 0:
            total[...] = contrib
        else:
            total += contrib
    total -= capacities.reshape((m,) + (1,) * (nfree + 1))
    np.maximum(total, 0.0, out=total)
    ex = np.sum(total, axis=-1).reshape(m, -1)
    with np.errstate(divide="ignore", invalid="ignore"):
        scores = np.maximum(0.0,
                            100.0 * (1.0 - ex / (capacities[:, None] * s)))
    # dead links (capacity 0, fault injection) score 0, not inf/NaN
    scores = np.where(capacities[:, None] > 0.0, scores, 0.0)
    return scores[0] if squeeze else scores


def minor_product(ranges: Sequence[int]) -> int:
    """Product of every free range BELOW the most significant free digit —
    the span granularity of :func:`lex_block_scores` (1 when <= 1 free)."""
    free = [int(r) for r in ranges if r > 1]
    n = 1
    for r in free[1:]:
        n *= r
    return n


def lex_combos(ranges: Sequence[int], start: int, count: int) -> np.ndarray:
    """Decode lexicographic combo indices [start, start+count) -> (count, P)."""
    idx = np.arange(start, start + count, dtype=np.int64)
    p = len(ranges)
    out = np.zeros((len(idx), p), dtype=np.int64)
    for i in range(p - 1, -1, -1):
        out[:, i] = idx % ranges[i]
        idx = idx // ranges[i]
    return out


def total_combos(ranges: Sequence[int]) -> int:
    n = 1
    for r in ranges:
        n *= r
    return n


def scheme_psi(patterns, bw, capacity, muls, shifts, n_slots=DI_PRE) -> float:
    """Psi (Eq. 9) of one chosen scheme.

    The duty w.r.t. the base circle = total comm slots / n_slots; Eq. 9
    midpoints need the per-task duty cycle (per-burst arc =
    duty * n_slots / mul)."""
    duties = [float(patterns[i].sum() / n_slots) for i in range(len(muls))]
    return geometry.min_comm_interval(muls, duties, bw, shifts, capacity,
                                      n_slots)

"""Fabric-wide joint rotation planner — the single producer of rotation
schemes (paper sections III-B / III-C generalized to multi-tier fabrics).

The paper's offline recalculation (Eqs. 15-18) solves rotation *per link*;
since the fabric refactor a job can traverse a host link **and** a leaf
uplink, and reconciling conflicting per-link shifts with a BFS +
"uplinks take precedence" tie-break (the pre-planner controller) can leave
a link oversubscribed in time even though each per-link solve was perfect.
This module replaces that heuristic with one global solve in the spirit of
CASSINI's affinity-graph formulation: every job receives a **single** circle
offset that is evaluated simultaneously on every link it traverses.

Layering:

  * :func:`find_feasible_rotation` / :func:`find_optimal_rotation` /
    :func:`coordinate_descent_rotation` — the per-link solvers (moved here
    from ``scoring.py``, which now only holds the per-candidate evaluators).
  * :func:`solve_link` — one link's rotation problem from a
    :class:`~repro.core.contention.LinkView` (the legacy Score-phase
    ``_score_link`` generalized over demand conventions).
  * :func:`joint_solve` — the fabric-wide solve of one affinity component:
    periods unified over *all* component jobs, per-job shift ranges from
    Eq. 15 (one range per job — intersecting the per-link ranges of Eq. 15
    degenerates to the global ``S // mul_p`` once the base circle is
    shared), reference pinned per Eq. 16, Eq. 18 scored on every link at
    once (min over links), and Psi (Eq. 9) minimized over links as the
    multi-link tie-break.  Falls back to coordinate descent over jobs when
    the joint product space is too large (the paper's own reduction
    argument).
  * :func:`resolve` — global-offset resolution over a set of per-link
    schemes: consistent components keep the per-link solutions and the
    legacy BFS traversal **bit-for-bit** (star topologies always land
    here); components whose per-link solutions conflict are re-solved
    jointly.  ``joint=False`` preserves the legacy last-link-wins
    reconciliation (uplinks last in the canonical order) as an ablation.
  * :func:`plan` — the scheduler/controller entry point: per-link solve +
    conflict resolution in one call.

The joint evaluation is batched: every link's demand bank shares the
component's pattern matrix and differs only in per-job bandwidth and link
capacity, which is exactly the stacked ``(L, R, S)`` layout of the
``kernels.metronome_score`` multi-link core (``backend='kernel'``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import networkx as nx
import numpy as np

from . import geometry, scoring
from .contention import LinkView, group_demand_gbps
from .geometry import DI_PRE
from .topology import is_uplink

PERFECT = 100.0
_EPS = 1e-9
# per-link relative shifts (ms) closer than this are "the same solution"
REL_TOL_MS = 1e-6


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RotationResult:
    score: float
    shifts: np.ndarray  # (P,) integer slot shifts theta_{l,p}
    perfect: bool
    psi: float = 0.0  # min communication interval of the chosen scheme
    n_evaluated: int = 0


@dataclasses.dataclass
class LinkScheme:
    """Rotation scheme of one fabric link (host link id == node name,
    uplinks ``uplink:<leaf>``)."""

    jobs: List[str]  # job order used in the rotation problem
    shifts_slots: np.ndarray  # theta per job (slots)
    base_ms: float
    muls: np.ndarray
    score: float
    early_return: bool
    injected_ms: Dict[str, float]  # E_T idle injection per job
    ref_job: str = ""


@dataclasses.dataclass
class PlanResult:
    """Output of :func:`plan` / :func:`resolve`.

    ``schemes`` maps every contended link to its scheme (per-link solution
    for consistent components, the joint solution restricted to the link's
    jobs otherwise); ``offsets_ms`` is the global circle offset per job;
    ``score`` the worst per-link Eq. 18 score; ``joint_links`` which links
    were re-solved jointly (empty whenever the per-link solutions already
    agree — always on star topologies)."""

    schemes: Dict[str, LinkScheme]
    offsets_ms: Dict[str, float]
    score: float
    feasible: bool
    joint_links: List[str]
    n_evaluated: int = 0


def priority_order(registry, jobs: Sequence[str]) -> List[str]:
    """Jobs by (priority desc, deployment order asc) — Eq. 16's reference
    semantics; index 0 is the pinned reference."""
    def key(j: str):
        job = registry.jobs.get(j)
        prio = job.priority if job else 0
        sub = job.submit_time_s if job else 0.0
        return (-prio, sub, j)
    return sorted(jobs, key=key)


# ---------------------------------------------------------------------------
# Per-link solvers (section III-B / III-C, single link)
# ---------------------------------------------------------------------------

def find_feasible_rotation(
    patterns: np.ndarray,
    bw: Sequence[float],
    capacity: float,
    muls: Sequence[int],
    ref_index: int = 0,
    n_slots: int = DI_PRE,
    chunk: int = 4096,
    max_exhaustive: int = 1 << 22,
    mode: str = "intermediate",
) -> RotationResult:
    """Score-phase fast path (Algorithm 1, Score extension point).

    Traverses combos lexicographically and stops at the first maximal run of
    perfect scores, returning the scheme at the run's middle index. Falls
    back to the best seen score when no perfect combo exists.

    ``mode='compact'`` is the paper's 3rd-stage ABLATION (section IV-C):
    take the first index of the perfect run (comm phases packed
    back-to-back, no cushion slots) instead of the middle.
    """
    bw = np.asarray(bw, dtype=np.float64)
    ranges = scoring.shift_ranges(muls, ref_index, n_slots)
    n_total = scoring.total_combos(ranges)
    if n_total > max_exhaustive:
        return coordinate_descent_rotation(
            patterns, bw, capacity, muls, ref_index, n_slots
        )
    bank = scoring.rolled_bank(patterns, ranges)

    best_score = -1.0
    best_combo = np.zeros(len(ranges), dtype=np.int64)
    run_start = None  # start index of the current perfect run
    n_eval = 0
    pos = 0
    while pos < n_total:
        cnt = min(chunk, n_total - pos)
        combos = scoring.lex_combos(ranges, pos, cnt)
        scores = scoring.score_combos(patterns, bw, capacity, combos, bank)
        n_eval += cnt
        is_perfect = scores >= PERFECT - _EPS
        for j in range(cnt):
            if is_perfect[j]:
                if run_start is None:
                    run_start = pos + j
            else:
                if run_start is not None:
                    # first perfect run ended at pos+j-1 -> return middle
                    # (or the run's edge in the no-cushion ablation)
                    mid = (run_start if mode == "compact"
                           else (run_start + pos + j - 1) // 2)
                    shifts = scoring.lex_combos(ranges, mid, 1)[0]
                    return RotationResult(
                        PERFECT, shifts, True,
                        scoring.scheme_psi(patterns, bw, capacity, muls,
                                           shifts, n_slots),
                        n_eval)
                if scores[j] > best_score:
                    best_score = float(scores[j])
                    best_combo = combos[j]
        pos += cnt
    if run_start is not None:  # perfect run extends to the end
        mid = (run_start if mode == "compact"
               else (run_start + n_total - 1) // 2)
        shifts = scoring.lex_combos(ranges, mid, 1)[0]
        return RotationResult(
            PERFECT, shifts, True,
            scoring.scheme_psi(patterns, bw, capacity, muls, shifts, n_slots),
            n_eval)
    return RotationResult(
        best_score, best_combo, False,
        scoring.scheme_psi(patterns, bw, capacity, muls, best_combo, n_slots),
        n_eval)


def find_optimal_rotation(
    patterns: np.ndarray,
    bw: Sequence[float],
    capacity: float,
    muls: Sequence[int],
    ref_index: int = 0,
    n_slots: int = DI_PRE,
    chunk: int = 8192,
    max_exhaustive: int = 1 << 22,
    scorer: Optional[Callable[[np.ndarray], np.ndarray]] = None,
) -> RotationResult:
    """Offline recalculation (3rd optimization stage), section III-C.

    Enumerates all rotation schemes; candidate set = middle indices of all
    perfect-score runs (the paper's search-space narrowing); among candidates
    maximizes Psi (Eq. 9). ``scorer`` may override the combo scorer (used to
    plug in the Pallas kernel).
    """
    bw = np.asarray(bw, dtype=np.float64)
    ranges = scoring.shift_ranges(muls, ref_index, n_slots)
    n_total = scoring.total_combos(ranges)
    if n_total > max_exhaustive:
        return coordinate_descent_rotation(
            patterns, bw, capacity, muls, ref_index, n_slots, optimize_psi=True
        )
    bank = scoring.rolled_bank(patterns, ranges)

    candidates: List[int] = []
    best_score = -1.0
    best_idx = 0
    run_start = None
    pos = 0
    while pos < n_total:
        cnt = min(chunk, n_total - pos)
        combos = scoring.lex_combos(ranges, pos, cnt)
        if scorer is not None:
            scores = np.asarray(scorer(combos))
        else:
            scores = scoring.score_combos(patterns, bw, capacity, combos, bank)
        is_perfect = scores >= PERFECT - _EPS
        for j in range(cnt):
            gi = pos + j
            if is_perfect[j]:
                if run_start is None:
                    run_start = gi
            else:
                if run_start is not None:
                    candidates.append((run_start + gi - 1) // 2)
                    run_start = None
                if scores[j] > best_score:
                    best_score = float(scores[j])
                    best_idx = gi
        pos += cnt
    if run_start is not None:
        candidates.append((run_start + n_total - 1) // 2)

    if not candidates:
        shifts = scoring.lex_combos(ranges, best_idx, 1)[0]
        return RotationResult(
            best_score, shifts, False,
            scoring.scheme_psi(patterns, bw, capacity, muls, shifts, n_slots),
            n_total)

    # stage 3: among perfect-run midpoints maximize Psi
    best_psi = -1.0
    best_shifts = None
    for c in candidates:
        shifts = scoring.lex_combos(ranges, c, 1)[0]
        psi = scoring.scheme_psi(patterns, bw, capacity, muls, shifts, n_slots)
        if psi > best_psi:
            best_psi = psi
            best_shifts = shifts
    return RotationResult(PERFECT, best_shifts, True, best_psi, n_total)


def coordinate_descent_rotation(
    patterns: np.ndarray,
    bw: np.ndarray,
    capacity: float,
    muls: Sequence[int],
    ref_index: int,
    n_slots: int = DI_PRE,
    optimize_psi: bool = False,
    sweeps: int = 4,
) -> RotationResult:
    """Large combo spaces: hold all but one pod fixed (paper's reduction)."""
    bw = np.asarray(bw, dtype=np.float64)
    p = patterns.shape[0]
    ranges = scoring.shift_ranges(muls, ref_index, n_slots)
    shifts = np.zeros(p, dtype=np.int64)
    n_eval = 0
    for _ in range(sweeps):
        changed = False
        for i in range(p):
            if i == ref_index or ranges[i] <= 1:
                continue
            cands = np.tile(shifts, (ranges[i], 1))
            cands[:, i] = np.arange(ranges[i])
            scores = scoring.score_combos(patterns, bw, capacity, cands)
            n_eval += ranges[i]
            best = scores.max()
            mask = scores >= best - _EPS
            if optimize_psi and best >= PERFECT - _EPS:
                # pick the perfect shift maximizing Psi
                idxs = np.nonzero(mask)[0]
                psis = [
                    scoring.scheme_psi(patterns, bw, capacity, muls, cands[k],
                                       n_slots)
                    for k in idxs
                ]
                pick = int(idxs[int(np.argmax(psis))])
            else:
                # middle of the first perfect/best run
                idxs = np.nonzero(mask)[0]
                runs = np.split(idxs, np.where(np.diff(idxs) != 1)[0] + 1)
                pick = int(runs[0][len(runs[0]) // 2])
            if pick != shifts[i]:
                shifts[i] = pick
                changed = True
        if not changed:
            break
    final = scoring.score_combos(patterns, bw, capacity, shifts[None, :])[0]
    return RotationResult(
        float(final), shifts, final >= PERFECT - _EPS,
        scoring.scheme_psi(patterns, bw, capacity, muls, shifts, n_slots),
        n_eval)


# ---------------------------------------------------------------------------
# One link's rotation problem from the LinkView
# ---------------------------------------------------------------------------

def _link_demands(view: LinkView, link_id: str, jobs: Sequence[str],
                  demand: str) -> List[float]:
    """Per-job demand on one link under the named convention.

    ``'planning'`` — the Score-phase view (the link's grouped tasks);
    ``'recalc'``  — the controller's offline-recalculation view (whole-job
    demand on host links; see :meth:`LinkView.recalc_demands`)."""
    if demand == "recalc":
        return view.recalc_demands(link_id, jobs)
    groups = view.link_groups(link_id)
    return [group_demand_gbps(groups.get(j, [])) for j in jobs]


def solve_link(
    view: LinkView,
    registry,
    link_id: str,
    *,
    self_job: Optional[str] = None,
    mode: str = "fast",
    demand: str = "planning",
    di_pre: int = DI_PRE,
    g_t_ms: float = 5.0,
    e_t_frac: float = 0.10,
    rotation_mode: str = "intermediate",
) -> Tuple[float, Optional[LinkScheme]]:
    """One link's rotation problem. Returns (score, scheme); scheme is None
    on the early-return paths (empty link, only the candidate's own job, or
    aggregate demand within capacity — no contention to solve)."""
    groups = view.link_groups(link_id)
    cap = view.cluster.link_alloc(link_id)
    total_bw = sum(group_demand_gbps(ts) for ts in groups.values())
    only_self = self_job is not None and list(groups.keys()) == [self_job]
    if not groups or only_self or total_bw <= cap:
        return PERFECT, None

    # --- two-dimensional bandwidth scheduling: interleave phases -----------
    jobs = priority_order(registry, groups.keys())
    ref_index = 0  # highest priority (ties: earliest) — Eq. 16
    periods = []
    prios = []
    for j in jobs:
        ts = groups[j]
        periods.append(ts[0].traffic.period_ms)
        job = registry.jobs.get(j)
        prios.append(job.priority if job else 0)
    unified = geometry.unify_periods(
        periods, prios, g_t_ms=g_t_ms, e_t_frac=e_t_frac
    )
    duties = []
    for idx, j in enumerate(jobs):
        spec = groups[j][0].traffic
        # idle injection stretches the period -> duty shrinks (comm time
        # m_p is unchanged); this is the E_T mechanism's second insight.
        duties.append(min(1.0, spec.comm_ms / unified.periods_ms[idx]))
    bws = _link_demands(view, link_id, jobs, demand)
    patterns = geometry.pattern_matrix(unified.muls, duties, di_pre)
    if mode == "optimal":
        result = find_optimal_rotation(patterns, bws, cap, unified.muls,
                                       ref_index, di_pre)
    else:
        result = find_feasible_rotation(patterns, bws, cap, unified.muls,
                                        ref_index, di_pre,
                                        mode=rotation_mode)
    scheme = LinkScheme(
        jobs=jobs,
        shifts_slots=result.shifts,
        base_ms=unified.base_ms,
        muls=unified.muls,
        score=float(result.score),
        early_return=False,
        injected_ms={j: float(unified.injected_ms[i])
                     for i, j in enumerate(jobs)},
        ref_job=jobs[ref_index],
    )
    return float(result.score), scheme


def replan_link(view: LinkView, link_id: str, scheme: LinkScheme,
                capacity: float, di_pre: int = DI_PRE) -> RotationResult:
    """Offline 3rd-stage re-solve of one EXISTING scheme (the controller's
    pending-recalc path): keep the scheme's job order / unified base, re-read
    demand from the live view under the recalc convention, maximize Psi."""
    duties, bws = view.recalc_traffic(link_id, scheme.jobs, scheme.muls,
                                      scheme.base_ms)
    patterns = geometry.pattern_matrix(scheme.muls, duties, di_pre)
    ref_index = (scheme.jobs.index(scheme.ref_job)
                 if scheme.ref_job in scheme.jobs else 0)
    return find_optimal_rotation(patterns, bws, capacity, scheme.muls,
                                 ref_index, di_pre)


# ---------------------------------------------------------------------------
# Joint multi-link solve (one affinity component)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JointResult:
    jobs: List[str]
    shifts: np.ndarray  # (P,) global slot shifts
    base_ms: float
    muls: np.ndarray
    schemes: Dict[str, LinkScheme]  # per link, restricted to its jobs
    offsets_ms: Dict[str, float]
    score: float  # min over links
    psi: float  # min over links (Eq. 9)
    feasible: bool
    n_evaluated: int = 0


def _min_link_scores(patterns: np.ndarray, bw_lp: np.ndarray,
                     caps: np.ndarray, combos: np.ndarray,
                     banks) -> np.ndarray:
    """(K,) joint score: Eq. 18 evaluated on every link, min over links."""
    out = None
    for li in range(len(caps)):
        s = scoring.score_combos(patterns, bw_lp[li], float(caps[li]),
                                 combos, banks)
        out = s if out is None else np.minimum(out, s)
    return out


def _kernel_joint_scores(patterns: np.ndarray, bw_lp: np.ndarray,
                         caps: np.ndarray, ranges: Sequence[int],
                         banks) -> Optional[np.ndarray]:
    """Batched multi-link evaluation of the FULL combo space via the
    stacked (L, R, S) kernel; None when the space has != 2 free jobs
    (the pairwise product layout does not apply)."""
    free = [i for i, r in enumerate(ranges) if r > 1]
    if len(free) != 2:
        return None
    from repro.kernels import ops as kops  # deferred: jax import is heavy
    pa, pb = free
    l, p = bw_lp.shape
    s = patterns.shape[1]
    base = np.zeros((l, s))
    for i in range(p):
        if i not in (pa, pb):
            base += bw_lp[:, i:i + 1] * patterns[i][None, :]
    bank_a = bw_lp[:, pa, None, None] * banks[pa][None, :, :]  # (L, Ra, S)
    bank_b = bw_lp[:, pb, None, None] * banks[pb][None, :, :]  # (L, Rb, S)
    scores = kops.score_multilink(base, bank_a, bank_b, np.asarray(caps))
    # C-order flatten == lexicographic combo order (free job a is the more
    # significant digit; every other range is 1)
    return np.asarray(scores).reshape(-1)


def _perfect_runs(perfect: np.ndarray) -> List[Tuple[int, int]]:
    """[(start, end)] of every maximal run of True, vectorized."""
    idx = np.flatnonzero(perfect)
    if idx.size == 0:
        return []
    brk = np.flatnonzero(np.diff(idx) != 1)
    starts = np.concatenate(([idx[0]], idx[brk + 1]))
    ends = np.concatenate((idx[brk], [idx[-1]]))
    return list(zip(starts.tolist(), ends.tolist()))


def joint_solve(
    view: LinkView,
    registry,
    links: Sequence[str],
    *,
    jobs: Optional[Sequence[str]] = None,
    mode: str = "fast",
    demand: str = "planning",
    rotation_mode: str = "intermediate",
    di_pre: int = DI_PRE,
    g_t_ms: float = 5.0,
    e_t_frac: float = 0.10,
    backend: str = "numpy",
    max_exhaustive: int = 1 << 22,
    chunk: int = 8192,
) -> Optional[JointResult]:
    """Solve one affinity component jointly over every link it touches.

    One global shift per job; Eq. 18 evaluated simultaneously on all links
    (min over links), Eq. 15 ranges on the shared base circle, Eq. 16
    reference pinned, Eq. 9 Psi (min over links) as the tie-break among
    perfect-run midpoints in ``mode='optimal'``; ``mode='fast'`` returns the
    middle of the first jointly perfect run (``rotation_mode='compact'`` is
    the no-cushion ablation).  Returns None when a job has no tasks in the
    view (stale scheme — the caller falls back to the BFS merge)."""
    groups_by_link = {l: view.link_groups(l) for l in links}
    if jobs is None:
        seen: Dict[str, None] = {}
        for l in links:
            for j in groups_by_link[l]:
                seen[j] = None
        jobs = list(seen)
    jobs = priority_order(registry, jobs)
    if not jobs:
        return None
    specs = []
    for j in jobs:
        ts = view.job_tasks(j)
        if not ts:
            return None
        specs.append(ts[0].traffic)
    prios = []
    for j in jobs:
        job = registry.jobs.get(j)
        prios.append(job.priority if job else 0)
    unified = geometry.unify_periods([s.period_ms for s in specs], prios,
                                     g_t_ms=g_t_ms, e_t_frac=e_t_frac)
    duties = [min(1.0, specs[i].comm_ms / unified.periods_ms[i])
              for i in range(len(jobs))]
    patterns = geometry.pattern_matrix(unified.muls, duties, di_pre)
    ranges = scoring.shift_ranges(unified.muls, 0, di_pre)
    caps = np.array([view.cluster.link_alloc(l) for l in links])
    bw_lp = np.zeros((len(links), len(jobs)))
    for li, l in enumerate(links):
        dmds = _link_demands(view, l, jobs, demand)
        present = groups_by_link[l]
        for pi, j in enumerate(jobs):
            bw_lp[li, pi] = dmds[pi] if j in present else 0.0

    n_total = scoring.total_combos(ranges)
    banks = scoring.rolled_bank(patterns, ranges)

    def psi_of(shifts: np.ndarray) -> float:
        return min(
            scoring.scheme_psi(patterns, bw_lp[li], float(caps[li]),
                               unified.muls, shifts, di_pre)
            for li in range(len(links))
        )

    if n_total > max_exhaustive:
        result = _joint_coordinate_descent(
            patterns, bw_lp, caps, unified.muls, ranges, psi_of,
            optimize_psi=(mode == "optimal"))
    else:
        result = _joint_exhaustive(
            patterns, bw_lp, caps, ranges, banks, psi_of,
            mode=mode, rotation_mode=rotation_mode,
            backend=backend, chunk=chunk)

    shifts = result.shifts
    delays = geometry.shifts_to_delay_ms(shifts, unified.base_ms, di_pre)
    offsets = {j: float(d) for j, d in zip(jobs, delays)}
    schemes: Dict[str, LinkScheme] = {}
    link_scores: List[float] = []
    for li, l in enumerate(links):
        on_link = [pi for pi, j in enumerate(jobs) if j in groups_by_link[l]]
        sc = float(scoring.score_combos(
            patterns, bw_lp[li], float(caps[li]), shifts[None, :])[0])
        link_scores.append(sc)
        link_jobs = [jobs[pi] for pi in on_link]
        ref = link_jobs[0] if link_jobs else ""
        schemes[l] = LinkScheme(
            jobs=link_jobs,
            shifts_slots=shifts[on_link].copy(),
            base_ms=float(unified.base_ms),
            muls=unified.muls[on_link].copy(),
            score=sc,
            early_return=False,
            injected_ms={jobs[pi]: float(unified.injected_ms[pi])
                         for pi in on_link},
            ref_job=ref,
        )
    worst = min(link_scores) if link_scores else PERFECT
    return JointResult(
        jobs=list(jobs), shifts=shifts, base_ms=float(unified.base_ms),
        muls=unified.muls, schemes=schemes, offsets_ms=offsets,
        score=worst, psi=result.psi, feasible=worst >= PERFECT - _EPS,
        n_evaluated=result.n_evaluated,
    )


def _joint_exhaustive(patterns, bw_lp, caps, ranges, banks, psi_of, *,
                      mode, rotation_mode, backend, chunk) -> RotationResult:
    n_total = scoring.total_combos(ranges)
    joint_all = None
    if backend == "kernel":
        joint_all = _kernel_joint_scores(patterns, bw_lp, caps, ranges, banks)

    candidates: List[int] = []
    best_score = -1.0
    best_idx = 0
    run_start: Optional[int] = None  # global start of an open perfect run
    n_eval = 0

    def _close(start: int, end: int) -> Optional[RotationResult]:
        """A maximal perfect run [start, end] is complete (global indices)."""
        if mode == "fast":
            mid = (start if rotation_mode == "compact"
                   else (start + end) // 2)
            shifts = scoring.lex_combos(ranges, mid, 1)[0]
            return RotationResult(PERFECT, shifts, True, psi_of(shifts),
                                  n_eval)
        candidates.append((start + end) // 2)
        return None

    pos = 0
    while pos < n_total:
        cnt = n_total if joint_all is not None else min(chunk, n_total - pos)
        if joint_all is not None:
            js = joint_all
        else:
            combos = scoring.lex_combos(ranges, pos, cnt)
            js = _min_link_scores(patterns, bw_lp, caps, combos, banks)
        n_eval += cnt * len(caps)
        perfect = js >= PERFECT - _EPS
        # vectorized run scan (replaces the per-combo Python loop of the
        # per-link solvers — see benchmarks/bench_rotation.py)
        runs = _perfect_runs(perfect)
        if run_start is not None:
            if runs and runs[0][0] == 0:
                start0, end0 = runs.pop(0)
                if end0 == cnt - 1 and pos + cnt < n_total:
                    pass  # run still open into the next chunk
                else:
                    done = _close(run_start, pos + end0)
                    if done is not None:
                        return done
                    run_start = None
            else:
                done = _close(run_start, pos - 1)
                if done is not None:
                    return done
                run_start = None
        for start, end in runs:
            if end == cnt - 1 and pos + cnt < n_total:
                run_start = pos + start  # continues into the next chunk
            else:
                done = _close(pos + start, pos + end)
                if done is not None:
                    return done
        imperfect = ~perfect
        if imperfect.any():
            local_best = int(np.argmax(np.where(imperfect, js, -np.inf)))
            if js[local_best] > best_score:
                best_score = float(js[local_best])
                best_idx = pos + local_best
        pos += cnt
    if run_start is not None:
        done = _close(run_start, n_total - 1)
        if done is not None:
            return done

    if mode == "optimal" and candidates:
        best_psi = -1.0
        best_shifts = None
        for c in candidates:
            shifts = scoring.lex_combos(ranges, c, 1)[0]
            psi = psi_of(shifts)
            if psi > best_psi:
                best_psi = psi
                best_shifts = shifts
        return RotationResult(PERFECT, best_shifts, True, best_psi, n_eval)
    shifts = scoring.lex_combos(ranges, best_idx, 1)[0]
    return RotationResult(best_score, shifts, False, psi_of(shifts), n_eval)


def _joint_coordinate_descent(patterns, bw_lp, caps, muls, ranges, psi_of, *,
                              optimize_psi, sweeps: int = 4) -> RotationResult:
    """Coordinate descent over jobs with the joint (min-over-links) score."""
    p = patterns.shape[0]
    shifts = np.zeros(p, dtype=np.int64)
    n_eval = 0
    for _ in range(sweeps):
        changed = False
        for i in range(p):
            if ranges[i] <= 1:
                continue
            cands = np.tile(shifts, (ranges[i], 1))
            cands[:, i] = np.arange(ranges[i])
            js = _min_link_scores(patterns, bw_lp, caps, cands, None)
            n_eval += ranges[i] * len(caps)
            best = js.max()
            mask = js >= best - _EPS
            idxs = np.nonzero(mask)[0]
            if optimize_psi and best >= PERFECT - _EPS:
                psis = [psi_of(cands[k]) for k in idxs]
                pick = int(idxs[int(np.argmax(psis))])
            else:
                runs = np.split(idxs, np.where(np.diff(idxs) != 1)[0] + 1)
                pick = int(runs[0][len(runs[0]) // 2])
            if pick != shifts[i]:
                shifts[i] = pick
                changed = True
        if not changed:
            break
    final = _min_link_scores(patterns, bw_lp, caps, shifts[None, :], None)[0]
    return RotationResult(float(final), shifts, final >= PERFECT - _EPS,
                          psi_of(shifts), n_eval)


# ---------------------------------------------------------------------------
# Global resolution: consistent BFS merge or joint re-solve per component
# ---------------------------------------------------------------------------

def resolve(
    schemes: Dict[str, LinkScheme],
    priorities: Dict[str, int],
    view: Optional[LinkView],
    registry=None,
    *,
    di_pre: int = DI_PRE,
    mode: str = "fast",
    demand: str = "planning",
    g_t_ms: float = 5.0,
    e_t_frac: float = 0.10,
    rotation_mode: str = "intermediate",
    joint: bool = True,
    backend: str = "numpy",
) -> PlanResult:
    """Assign each job one global circle offset from a set of per-link
    schemes (Cassini-style affinity graph anchored at the highest-priority
    job — the paper's difference vs Cassini's random reference, Eq. 16).

    Components whose per-link relative shifts all agree keep their schemes
    and the BFS traversal of the pre-planner controller bit-for-bit.  A
    component with CONFLICTING per-link shifts is re-solved jointly from the
    live ``view`` (``joint=True``); with ``joint=False`` — or when no view
    is available — the legacy reconciliation applies: links are traversed
    in canonical order (host links sorted, uplinks LAST) and the last
    writer wins, i.e. the most oversubscribed tier takes precedence."""
    g = nx.Graph()
    link_shift_ms: Dict[Tuple[str, str], float] = {}
    # canonical deterministic construction order (sorted hosts, uplinks
    # last): for consistent components any order gives the same offsets;
    # for the joint=False ablation it reproduces the legacy tie-break.
    ordered = sorted(schemes.items(), key=lambda kv: (is_uplink(kv[0]), kv[0]))
    for link_id, sch in ordered:
        delays = geometry.shifts_to_delay_ms(sch.shifts_slots, sch.base_ms,
                                             di_pre)
        for j, d in zip(sch.jobs, delays):
            link_shift_ms[(link_id, j)] = float(d)
            g.add_node(j)
        for i in range(len(sch.jobs)):
            for k in range(i + 1, len(sch.jobs)):
                a, b = sch.jobs[i], sch.jobs[k]
                rel = (link_shift_ms[(link_id, b)]
                       - link_shift_ms[(link_id, a)])
                if g.has_edge(a, b):
                    if g[a][b]["src"] != a:
                        rel = -rel
                    g[a][b]["rels"].append(rel)
                else:
                    g.add_edge(a, b, rels=[rel], src=a)

    offsets: Dict[str, float] = {}
    joint_links: List[str] = []
    new_schemes: Dict[str, LinkScheme] = dict(schemes)
    n_eval = 0
    for comp in nx.connected_components(g):
        comp = set(comp)
        sub = g.subgraph(comp)
        conflicted = any(
            max(d["rels"]) - min(d["rels"]) > REL_TOL_MS
            for _, _, d in sub.edges(data=True)
        )
        if conflicted and joint and view is not None and registry is not None:
            comp_links = [lid for lid, sch in schemes.items()
                          if any(j in comp for j in sch.jobs)]
            jr = joint_solve(
                view, registry, comp_links, mode=mode, demand=demand,
                rotation_mode=rotation_mode, di_pre=di_pre, g_t_ms=g_t_ms,
                e_t_frac=e_t_frac, backend=backend,
            )
            if jr is not None:
                offsets.update(jr.offsets_ms)
                new_schemes.update(jr.schemes)
                joint_links.extend(comp_links)
                n_eval += jr.n_evaluated
                continue
        # consistent component (or legacy fallback): BFS from the
        # highest-priority reference; the last rel in canonical order is
        # the edge value (== the only value when consistent).
        comp_list = list(comp)
        ref = sorted(comp_list,
                     key=lambda j: (-priorities.get(j, 0), j))[0]
        offsets[ref] = 0.0
        for u, v in nx.bfs_edges(g, ref):
            rel = g[u][v]["rels"][-1]
            if g[u][v]["src"] != u:
                rel = -rel
            offsets[v] = offsets[u] + rel

    scores = [sch.score for sch in new_schemes.values()]
    worst = min(scores) if scores else PERFECT
    return PlanResult(
        schemes=new_schemes, offsets_ms=offsets, score=worst,
        feasible=worst >= PERFECT - _EPS, joint_links=joint_links,
        n_evaluated=n_eval,
    )


# ---------------------------------------------------------------------------
# Top-level: per-link solve + conflict resolution in one call
# ---------------------------------------------------------------------------

def plan(
    view: LinkView,
    registry,
    *,
    links: Optional[Sequence[str]] = None,
    self_job: Optional[str] = None,
    mode: str = "fast",
    demand: str = "planning",
    di_pre: int = DI_PRE,
    g_t_ms: float = 5.0,
    e_t_frac: float = 0.10,
    rotation_mode: str = "intermediate",
    joint: bool = True,
    backend: str = "numpy",
) -> PlanResult:
    """The planner entry point: solve every (given or contended) link, then
    resolve the per-link solutions into one consistent set of global
    offsets.  On star topologies — or whenever the per-link solutions
    already agree — this reduces bit-for-bit to the per-link solve."""
    link_ids = list(links) if links is not None else view.planning_links()
    schemes: Dict[str, LinkScheme] = {}
    worst = PERFECT
    for lid in link_ids:
        score, scheme = solve_link(
            view, registry, lid, self_job=self_job, mode=mode, demand=demand,
            di_pre=di_pre, g_t_ms=g_t_ms, e_t_frac=e_t_frac,
            rotation_mode=rotation_mode,
        )
        worst = min(worst, score)
        if scheme is not None:
            schemes[lid] = scheme
    if not schemes:
        return PlanResult(schemes={}, offsets_ms={}, score=worst,
                          feasible=worst >= PERFECT - _EPS, joint_links=[])
    if len(schemes) == 1:
        # single contended link: nothing to resolve — offsets are the
        # scheme's own delays (BFS from the priority-0 reference would
        # yield exactly these, ref delay being 0 per Eq. 16)
        (lid, sch), = schemes.items()
        delays = geometry.shifts_to_delay_ms(sch.shifts_slots, sch.base_ms,
                                             di_pre)
        return PlanResult(
            schemes=schemes,
            offsets_ms={j: float(d) for j, d in zip(sch.jobs, delays)},
            score=worst, feasible=worst >= PERFECT - _EPS, joint_links=[])
    priorities = {j: (registry.jobs[j].priority if j in registry.jobs else 0)
                  for sch in schemes.values() for j in sch.jobs}
    res = resolve(
        schemes, priorities, view, registry, di_pre=di_pre, mode=mode,
        demand=demand, g_t_ms=g_t_ms, e_t_frac=e_t_frac,
        rotation_mode=rotation_mode, joint=joint, backend=backend,
    )
    # resolve()'s schemes carry the FINAL per-link scores (a jointly
    # re-solved component replaces the stale per-link ones); early-return
    # links contribute exactly PERFECT and cannot lower the worst score
    return res

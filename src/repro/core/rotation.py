"""Fabric-wide joint rotation planner — the single producer of rotation
schemes (paper sections III-B / III-C generalized to multi-tier fabrics).

The paper's offline recalculation (Eqs. 15-18) solves rotation *per link*;
since the fabric refactor a job can traverse a host link **and** a leaf
uplink, and reconciling conflicting per-link shifts with a BFS +
"uplinks take precedence" tie-break (the pre-planner controller) can leave
a link oversubscribed in time even though each per-link solve was perfect.
This module replaces that heuristic with one global solve in the spirit of
CASSINI's affinity-graph formulation: every job receives a **single** circle
offset that is evaluated simultaneously on every link it traverses.

Layering:

  * :func:`find_feasible_rotation` / :func:`find_optimal_rotation` /
    :func:`coordinate_descent_rotation` — the per-link solvers (moved here
    from ``scoring.py``, which now only holds the per-candidate evaluators).
  * :func:`solve_link` — one link's rotation problem from a
    :class:`~repro.core.contention.LinkView` (the legacy Score-phase
    ``_score_link`` generalized over demand conventions).
  * :func:`joint_solve` — the fabric-wide solve of one affinity component:
    periods unified over *all* component jobs, per-job shift ranges from
    Eq. 15 (one range per job — intersecting the per-link ranges of Eq. 15
    degenerates to the global ``S // mul_p`` once the base circle is
    shared), reference pinned per Eq. 16, Eq. 18 scored on every link at
    once (min over links), and Psi (Eq. 9) minimized over links as the
    multi-link tie-break.  Falls back to coordinate descent over jobs when
    the joint product space is too large (the paper's own reduction
    argument).
  * :func:`resolve` — global-offset resolution over a set of per-link
    schemes: consistent components keep the per-link solutions and the
    legacy BFS traversal **bit-for-bit** (star topologies always land
    here); components whose per-link solutions conflict are re-solved
    jointly.  ``joint=False`` preserves the legacy last-link-wins
    reconciliation (uplinks last in the canonical order) as an ablation.
  * :func:`plan` — the scheduler/controller entry point: per-link solve +
    conflict resolution in one call.

The joint evaluation is batched: every link's demand bank shares the
component's pattern matrix and differs only in per-job bandwidth and link
capacity, which is exactly the stacked ``(L, R, S)`` layout of the
``kernels.metronome_score`` multi-link core (``backend='kernel'``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import networkx as nx
import numpy as np

from . import geometry, scoring
from .contention import LinkView, group_demand_gbps
from .geometry import DI_PRE
from .topology import is_uplink

PERFECT = 100.0
_EPS = 1e-9
# per-link relative shifts (ms) closer than this are "the same solution"
REL_TOL_MS = 1e-6


# ---------------------------------------------------------------------------
# Epoch-scoped planner memo (DESIGN.md section 15)
# ---------------------------------------------------------------------------

class PlanCache:
    """Epoch-scoped, content-keyed memo for planner results.

    Entries live only within one ``(cluster.epoch, registry.epoch)`` epoch:
    ANY mutation of the demand view (reserve/unreserve, dynamic events,
    capacity/background changes) advances an epoch, and the first lookup
    under the new epoch clears the store wholesale — stale reuse across a
    mutation is structurally impossible.  Keys additionally capture the full
    numeric problem content (job order, demands, capacities, periods,
    priorities, solver knobs), so within an epoch the N candidate nodes of
    one Score phase share every solve whose inputs coincide.

    Views built without an epoch (``LinkView(cluster, ...)`` directly,
    ``epoch=None``) bypass the cache entirely.
    """

    def __init__(self, maxsize: int = 512) -> None:
        self.maxsize = maxsize
        self._epoch: Optional[Tuple] = None
        self._store: Dict[Tuple, Tuple] = {}
        self.hits = 0
        self.misses = 0

    def _sync(self, epoch) -> None:
        if epoch != self._epoch:
            self._store.clear()
            self._epoch = epoch

    def get(self, epoch, key):
        if epoch is None:
            return None
        self._sync(epoch)
        value = self._store.get(key)
        if value is None:
            self.misses += 1
        else:
            self.hits += 1
        return value

    def put(self, epoch, key, value) -> None:
        if epoch is None:
            return
        self._sync(epoch)
        if len(self._store) >= self.maxsize:
            self._store.clear()
        self._store[key] = value

    def clear(self) -> None:
        self._store.clear()
        self._epoch = None


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RotationResult:
    score: float
    shifts: np.ndarray  # (P,) integer slot shifts theta_{l,p}
    perfect: bool
    psi: float = 0.0  # min communication interval of the chosen scheme
    n_evaluated: int = 0


@dataclasses.dataclass
class LinkScheme:
    """Rotation scheme of one fabric link (host link id == node name,
    uplinks ``uplink:<leaf>``)."""

    jobs: List[str]  # job order used in the rotation problem
    shifts_slots: np.ndarray  # theta per job (slots)
    base_ms: float
    muls: np.ndarray
    score: float
    early_return: bool
    injected_ms: Dict[str, float]  # E_T idle injection per job
    ref_job: str = ""


@dataclasses.dataclass
class PlanResult:
    """Output of :func:`plan` / :func:`resolve`.

    ``schemes`` maps every contended link to its scheme (per-link solution
    for consistent components, the joint solution restricted to the link's
    jobs otherwise); ``offsets_ms`` is the global circle offset per job;
    ``score`` the worst per-link Eq. 18 score; ``joint_links`` which links
    were re-solved jointly (empty whenever the per-link solutions already
    agree — always on star topologies)."""

    schemes: Dict[str, LinkScheme]
    offsets_ms: Dict[str, float]
    score: float
    feasible: bool
    joint_links: List[str]
    n_evaluated: int = 0


def _copy_scheme(sch: "LinkScheme") -> "LinkScheme":
    """Defensive deep copy: cached schemes must never alias consumer-mutated
    state (the controller edits jobs/shifts/muls in place on eviction and
    offline recalculation)."""
    return LinkScheme(
        jobs=list(sch.jobs),
        shifts_slots=np.array(sch.shifts_slots, copy=True),
        base_ms=sch.base_ms,
        muls=np.array(sch.muls, copy=True),
        score=sch.score,
        early_return=sch.early_return,
        injected_ms=dict(sch.injected_ms),
        ref_job=sch.ref_job,
    )


def priority_order(registry, jobs: Sequence[str]) -> List[str]:
    """Jobs by (priority desc, deployment order asc) — Eq. 16's reference
    semantics; index 0 is the pinned reference."""
    def key(j: str):
        job = registry.jobs.get(j)
        prio = job.priority if job else 0
        sub = job.submit_time_s if job else 0.0
        return (-prio, sub, j)
    return sorted(jobs, key=key)


# ---------------------------------------------------------------------------
# Chunked lexicographic scan (shared by the per-link and joint solvers)
# ---------------------------------------------------------------------------

def _perfect_runs(perfect: np.ndarray) -> List[Tuple[int, int]]:
    """[(start, end)] of every maximal run of True, vectorized."""
    idx = np.flatnonzero(perfect)
    if idx.size == 0:
        return []
    brk = np.flatnonzero(np.diff(idx) != 1)
    starts = np.concatenate(([idx[0]], idx[brk + 1]))
    ends = np.concatenate((idx[brk], [idx[-1]]))
    return list(zip(starts.tolist(), ends.tolist()))


class _RunScan:
    """Incremental perfect-run scanner over consecutive score chunks.

    Replicates the historical per-combo traversal semantics exactly:

      * ``mode='fast'`` — finish at the END of the FIRST maximal perfect
        run, returning its middle index (or its start under the
        ``rotation_mode='compact'`` no-cushion ablation);
      * ``mode='optimal'`` — collect every maximal run's midpoint as a Psi
        candidate, then maximize Psi among them (the 3rd stage);
      * no perfect combo — the first strict argmax over all scores wins.

    Chunk boundaries are invisible to the result: runs spanning chunks are
    stitched, so any chunking (including the one-shot batched kernel path)
    yields identical shifts.  ``eval_scale`` multiplies the per-chunk combo
    count into ``n_evaluated`` (the joint solver counts combos x links).
    """

    def __init__(self, ranges: Sequence[int], n_total: int, *, mode: str,
                 rotation_mode: str,
                 psi_of: Callable[[np.ndarray], float],
                 eval_scale: int = 1) -> None:
        self.ranges = list(ranges)
        self.n_total = n_total
        self.mode = mode
        self.rotation_mode = rotation_mode
        self.psi_of = psi_of
        self.eval_scale = eval_scale
        self.candidates: List[int] = []
        self.best_score = -1.0
        self.best_idx = 0
        self.n_eval = 0
        self.result: Optional[RotationResult] = None
        self._run_start: Optional[int] = None

    def _close(self, start: int, end: int) -> bool:
        """A maximal perfect run [start, end] completed (global indices)."""
        if self.mode == "fast":
            mid = (start if self.rotation_mode == "compact"
                   else (start + end) // 2)
            shifts = scoring.lex_combos(self.ranges, mid, 1)[0]
            self.result = RotationResult(PERFECT, shifts, True,
                                         self.psi_of(shifts), self.n_eval)
            return True
        self.candidates.append((start + end) // 2)
        return False

    def feed(self, pos: int, scores: np.ndarray) -> bool:
        """Consume the chunk starting at global index ``pos``; True once the
        scan is resolved (fast mode found its run)."""
        if self.result is not None:
            return True
        cnt = len(scores)
        self.n_eval += cnt * self.eval_scale
        perfect = scores >= PERFECT - _EPS
        runs = _perfect_runs(perfect)
        if self._run_start is not None:
            if runs and runs[0][0] == 0:
                start0, end0 = runs.pop(0)
                if end0 == cnt - 1 and pos + cnt < self.n_total:
                    pass  # run still open into the next chunk
                else:
                    if self._close(self._run_start, pos + end0):
                        return True
                    self._run_start = None
            else:
                if self._close(self._run_start, pos - 1):
                    return True
                self._run_start = None
        for start, end in runs:
            if end == cnt - 1 and pos + cnt < self.n_total:
                self._run_start = pos + start  # continues into the next chunk
            else:
                if self._close(pos + start, pos + end):
                    return True
        imperfect = ~perfect
        if imperfect.any():
            local = int(np.argmax(np.where(imperfect, scores, -np.inf)))
            if scores[local] > self.best_score:
                self.best_score = float(scores[local])
                self.best_idx = pos + local
        return False

    def finish(self, n_eval: Optional[int] = None) -> RotationResult:
        """Resolve after the last chunk; ``n_eval`` overrides the combo
        count (find_optimal_rotation historically reported n_total)."""
        if self.result is not None:
            return self.result
        if self._run_start is not None:
            if self._close(self._run_start, self.n_total - 1):
                return self.result
            self._run_start = None
        reported = self.n_eval if n_eval is None else n_eval
        if self.mode == "optimal" and self.candidates:
            best_psi = -1.0
            best_shifts = None
            for c in self.candidates:
                shifts = scoring.lex_combos(self.ranges, c, 1)[0]
                psi = self.psi_of(shifts)
                if psi > best_psi:
                    best_psi = psi
                    best_shifts = shifts
            self.result = RotationResult(PERFECT, best_shifts, True, best_psi,
                                         reported)
            return self.result
        shifts = scoring.lex_combos(self.ranges, self.best_idx, 1)[0]
        self.result = RotationResult(self.best_score, shifts, False,
                                     self.psi_of(shifts), reported)
        return self.result


def _lex_spans(ranges: Sequence[int], chunk: int):
    """Yield (global_pos, major_start, major_count, span_size) covering the
    whole combo space in lexicographic order, aligned on the most
    significant free digit (the :func:`scoring.lex_block_scores` layout), or
    None when the minor product is too large to materialize (fall back to
    the gather-based path)."""
    free = [i for i, r in enumerate(ranges) if r > 1]
    minor = scoring.minor_product(ranges)
    if not free or minor > max(int(chunk), 1):
        return None
    major_r = ranges[free[0]]
    step = max(1, int(chunk) // minor)
    spans = []
    a = 0
    while a < major_r:
        cnt = min(step, major_r - a)
        spans.append((a * minor, a, cnt, cnt * minor))
        a += cnt
    return spans


def _score_chunks(patterns: np.ndarray, bw_rows: np.ndarray,
                  caps: np.ndarray, ranges: Sequence[int], bank,
                  chunk: int):
    """Generator of (pos, (M, K) scores) chunks over the full lex space.

    Uses the broadcast block evaluator (no per-combo gathers) whenever the
    minor product fits the chunk budget; otherwise decodes combos and calls
    :func:`scoring.score_combos` per row — both bit-identical to the
    historical row-by-row scoring."""
    bw_rows = np.asarray(bw_rows, dtype=np.float64)
    caps = np.asarray(caps, dtype=np.float64).reshape(-1)
    n_total = scoring.total_combos(ranges)
    spans = _lex_spans(ranges, chunk)
    if spans is not None:
        for pos, a, cnt, _size in spans:
            yield pos, scoring.lex_block_scores(patterns, bw_rows, caps,
                                                ranges, bank, a, cnt)
        return
    pos = 0
    while pos < n_total:
        cnt = min(int(chunk), n_total - pos)
        combos = scoring.lex_combos(ranges, pos, cnt)
        out = np.empty((bw_rows.shape[0], cnt), dtype=np.float64)
        for m in range(bw_rows.shape[0]):
            out[m] = scoring.score_combos(patterns, bw_rows[m],
                                          float(caps[m]), combos, bank)
        yield pos, out
        pos += cnt


# ---------------------------------------------------------------------------
# Per-link solvers (section III-B / III-C, single link)
# ---------------------------------------------------------------------------

def find_feasible_rotation(
    patterns: np.ndarray,
    bw: Sequence[float],
    capacity: float,
    muls: Sequence[int],
    ref_index: int = 0,
    n_slots: int = DI_PRE,
    chunk: int = 8192,
    max_exhaustive: int = 1 << 22,
    mode: str = "intermediate",
) -> RotationResult:
    """Score-phase fast path (Algorithm 1, Score extension point).

    Traverses combos lexicographically and stops at the first maximal run of
    perfect scores, returning the scheme at the run's middle index. Falls
    back to the best seen score when no perfect combo exists.

    ``mode='compact'`` is the paper's 3rd-stage ABLATION (section IV-C):
    take the first index of the perfect run (comm phases packed
    back-to-back, no cushion slots) instead of the middle.
    """
    bw = np.asarray(bw, dtype=np.float64)
    ranges = scoring.shift_ranges(muls, ref_index, n_slots)
    n_total = scoring.total_combos(ranges)
    if n_total > max_exhaustive:
        return coordinate_descent_rotation(
            patterns, bw, capacity, muls, ref_index, n_slots
        )
    bank = scoring.rolled_bank(patterns, ranges)

    def psi_of(shifts: np.ndarray) -> float:
        return scoring.scheme_psi(patterns, bw, capacity, muls, shifts,
                                  n_slots)

    scan = _RunScan(ranges, n_total, mode="fast", rotation_mode=mode,
                    psi_of=psi_of)
    for pos, scores in _score_chunks(patterns, bw[None, :],
                                     np.array([capacity]), ranges, bank,
                                     chunk):
        if scan.feed(pos, scores[0]):
            break
    return scan.finish()


def find_optimal_rotation(
    patterns: np.ndarray,
    bw: Sequence[float],
    capacity: float,
    muls: Sequence[int],
    ref_index: int = 0,
    n_slots: int = DI_PRE,
    chunk: int = 8192,
    max_exhaustive: int = 1 << 22,
    scorer: Optional[Callable[[np.ndarray], np.ndarray]] = None,
) -> RotationResult:
    """Offline recalculation (3rd optimization stage), section III-C.

    Enumerates all rotation schemes; candidate set = middle indices of all
    perfect-score runs (the paper's search-space narrowing); among candidates
    maximizes Psi (Eq. 9). ``scorer`` may override the combo scorer (used to
    plug in the Pallas kernel).
    """
    bw = np.asarray(bw, dtype=np.float64)
    ranges = scoring.shift_ranges(muls, ref_index, n_slots)
    n_total = scoring.total_combos(ranges)
    if n_total > max_exhaustive:
        return coordinate_descent_rotation(
            patterns, bw, capacity, muls, ref_index, n_slots, optimize_psi=True
        )
    bank = scoring.rolled_bank(patterns, ranges)

    def psi_of(shifts: np.ndarray) -> float:
        return scoring.scheme_psi(patterns, bw, capacity, muls, shifts,
                                  n_slots)

    scan = _RunScan(ranges, n_total, mode="optimal",
                    rotation_mode="intermediate", psi_of=psi_of)
    if scorer is not None:
        pos = 0
        while pos < n_total:
            cnt = min(chunk, n_total - pos)
            combos = scoring.lex_combos(ranges, pos, cnt)
            scan.feed(pos, np.asarray(scorer(combos)))
            pos += cnt
    else:
        for pos, scores in _score_chunks(patterns, bw[None, :],
                                         np.array([capacity]), ranges, bank,
                                         chunk):
            scan.feed(pos, scores[0])
    return scan.finish(n_eval=n_total)


def coordinate_descent_rotation(
    patterns: np.ndarray,
    bw: np.ndarray,
    capacity: float,
    muls: Sequence[int],
    ref_index: int,
    n_slots: int = DI_PRE,
    optimize_psi: bool = False,
    sweeps: int = 4,
) -> RotationResult:
    """Large combo spaces: hold all but one pod fixed (paper's reduction)."""
    bw = np.asarray(bw, dtype=np.float64)
    p = patterns.shape[0]
    ranges = scoring.shift_ranges(muls, ref_index, n_slots)
    shifts = np.zeros(p, dtype=np.int64)
    n_eval = 0
    for _ in range(sweeps):
        changed = False
        for i in range(p):
            if i == ref_index or ranges[i] <= 1:
                continue
            cands = np.tile(shifts, (ranges[i], 1))
            cands[:, i] = np.arange(ranges[i])
            scores = scoring.score_combos(patterns, bw, capacity, cands)
            n_eval += ranges[i]
            best = scores.max()
            mask = scores >= best - _EPS
            if optimize_psi and best >= PERFECT - _EPS:
                # pick the perfect shift maximizing Psi
                idxs = np.nonzero(mask)[0]
                psis = [
                    scoring.scheme_psi(patterns, bw, capacity, muls, cands[k],
                                       n_slots)
                    for k in idxs
                ]
                pick = int(idxs[int(np.argmax(psis))])
            else:
                # middle of the first perfect/best run
                idxs = np.nonzero(mask)[0]
                runs = np.split(idxs, np.where(np.diff(idxs) != 1)[0] + 1)
                pick = int(runs[0][len(runs[0]) // 2])
            if pick != shifts[i]:
                shifts[i] = pick
                changed = True
        if not changed:
            break
    final = scoring.score_combos(patterns, bw, capacity, shifts[None, :])[0]
    return RotationResult(
        float(final), shifts, final >= PERFECT - _EPS,
        scoring.scheme_psi(patterns, bw, capacity, muls, shifts, n_slots),
        n_eval)


# ---------------------------------------------------------------------------
# One link's rotation problem from the LinkView
# ---------------------------------------------------------------------------

def _link_demands(view: LinkView, link_id: str, jobs: Sequence[str],
                  demand: str) -> List[float]:
    """Per-job demand on one link under the named convention.

    ``'planning'`` — the Score-phase view (the link's grouped tasks);
    ``'recalc'``  — the controller's offline-recalculation view (whole-job
    demand on host links; see :meth:`LinkView.recalc_demands`)."""
    if demand == "recalc":
        return view.recalc_demands(link_id, jobs)
    groups = view.link_groups(link_id)
    return [group_demand_gbps(groups.get(j, [])) for j in jobs]


def solve_link(
    view: LinkView,
    registry,
    link_id: str,
    *,
    self_job: Optional[str] = None,
    mode: str = "fast",
    demand: str = "planning",
    di_pre: int = DI_PRE,
    g_t_ms: float = 5.0,
    e_t_frac: float = 0.10,
    rotation_mode: str = "intermediate",
    cache: Optional[PlanCache] = None,
) -> Tuple[float, Optional[LinkScheme]]:
    """One link's rotation problem. Returns (score, scheme); scheme is None
    on the early-return paths (empty link, only the candidate's own job, or
    aggregate demand within capacity — no contention to solve).

    With a ``cache`` the solve is memoized on the full numeric content of
    the problem (scoped to the view's epoch): the Score phase solves each
    DISTINCT link problem once even when N candidate nodes share it, and a
    link whose groups are untouched by the candidate delta is never
    re-solved per candidate."""
    groups = view.link_groups(link_id)
    cap = view.cluster.link_alloc(link_id)
    total_bw = sum(group_demand_gbps(ts) for ts in groups.values())
    only_self = self_job is not None and list(groups.keys()) == [self_job]
    if not groups or only_self or total_bw <= cap:
        return PERFECT, None

    # --- two-dimensional bandwidth scheduling: interleave phases -----------
    jobs = priority_order(registry, groups.keys())
    ref_index = 0  # highest priority (ties: earliest) — Eq. 16
    periods = []
    comms = []
    prios = []
    for j in jobs:
        spec = groups[j][0].traffic
        periods.append(spec.period_ms)
        comms.append(spec.comm_ms)
        job = registry.jobs.get(j)
        prios.append(job.priority if job else 0)
    bws = _link_demands(view, link_id, jobs, demand)
    key = None
    if cache is not None:
        # the content below fully determines the solve (unification,
        # duties, patterns and ranges all derive from it); self_job and
        # link_id deliberately excluded — past the early returns they do
        # not influence the result, so identical problems share
        key = ("link", tuple(jobs), tuple(periods), tuple(comms),
               tuple(prios), tuple(bws), cap, mode, demand, rotation_mode,
               di_pre, g_t_ms, e_t_frac)
        hit = cache.get(view.epoch, key)
        if hit is not None:
            score, scheme = hit
            return score, _copy_scheme(scheme)
    unified = geometry.unify_periods(
        periods, prios, g_t_ms=g_t_ms, e_t_frac=e_t_frac
    )
    duties = []
    for idx, j in enumerate(jobs):
        # idle injection stretches the period -> duty shrinks (comm time
        # m_p is unchanged); this is the E_T mechanism's second insight.
        duties.append(min(1.0, comms[idx] / unified.periods_ms[idx]))
    patterns = geometry.pattern_matrix(unified.muls, duties, di_pre)
    if mode == "optimal":
        result = find_optimal_rotation(patterns, bws, cap, unified.muls,
                                       ref_index, di_pre)
    else:
        result = find_feasible_rotation(patterns, bws, cap, unified.muls,
                                        ref_index, di_pre,
                                        mode=rotation_mode)
    scheme = LinkScheme(
        jobs=jobs,
        shifts_slots=result.shifts,
        base_ms=unified.base_ms,
        muls=unified.muls,
        score=float(result.score),
        early_return=False,
        injected_ms={j: float(unified.injected_ms[i])
                     for i, j in enumerate(jobs)},
        ref_job=jobs[ref_index],
    )
    if cache is not None:
        cache.put(view.epoch, key, (float(result.score),
                                    _copy_scheme(scheme)))
    return float(result.score), scheme


@dataclasses.dataclass
class _LinkProblem:
    """One past-the-early-returns per-link solve (solve_link's core)."""

    jobs: List[str]
    bws: np.ndarray
    cap: float
    unified: object  # geometry.UnifiedPeriods
    comms: List[float]
    patterns: np.ndarray
    ranges: Tuple[int, ...]


def _link_scheme_of(prob: _LinkProblem, result: RotationResult,
                    ref_index: int = 0) -> Tuple[float, LinkScheme]:
    """solve_link's epilogue: wrap a RotationResult as a LinkScheme."""
    scheme = LinkScheme(
        jobs=prob.jobs,
        shifts_slots=result.shifts,
        base_ms=prob.unified.base_ms,
        muls=prob.unified.muls,
        score=float(result.score),
        early_return=False,
        injected_ms={j: float(prob.unified.injected_ms[i])
                     for i, j in enumerate(prob.jobs)},
        ref_job=prob.jobs[ref_index],
    )
    return float(result.score), scheme


def solve_link_batch(
    specs: Sequence[Tuple[LinkView, str]],
    registry,
    *,
    self_job: Optional[str] = None,
    mode: str = "fast",
    demand: str = "planning",
    di_pre: int = DI_PRE,
    g_t_ms: float = 5.0,
    e_t_frac: float = 0.10,
    rotation_mode: str = "intermediate",
    max_exhaustive: int = 1 << 22,
    chunk: int = 8192,
    cache: Optional[PlanCache] = None,
) -> List[Tuple[float, Optional[LinkScheme]]]:
    """Solve MANY per-link rotation problems (one per ``(view, link_id)``
    spec) with one shared enumeration pass per problem family.

    The Score phase raises one per-link solve for every link of every
    surviving candidate; candidates share the link's job set away from the
    candidate delta, so most problems repeat the same ``(patterns,
    ranges)`` and differ only in the demand row.  Mirroring
    :func:`joint_solve_batch`: cache hits and content-key duplicates are
    filtered first, the remainder group into families, and each family
    scores every chunk of its combo space for all members in one stacked
    :func:`_score_chunks` evaluation — each member's run scan consumes its
    own row, which is bit-for-bit the result :func:`solve_link` would
    produce for it individually.  Singleton families, ``mode='optimal'``
    and past-``max_exhaustive`` spaces take the historical per-problem
    path.  Results land in ``cache`` (when given), so the per-candidate
    ``plan()`` pass that follows hits instead of re-solving."""
    n = len(specs)
    results: List[Optional[Tuple[float, Optional[LinkScheme]]]] = [None] * n
    probs: List[Optional[_LinkProblem]] = [None] * n
    keys: List[Optional[Tuple]] = [None] * n
    epochs = [view.epoch for view, _ in specs]
    seen_keys: Dict[Tuple, int] = {}
    todo: Dict[Tuple, List[int]] = {}

    for i, (view, link_id) in enumerate(specs):
        groups = view.link_groups(link_id)
        cap = view.cluster.link_alloc(link_id)
        total_bw = sum(group_demand_gbps(ts) for ts in groups.values())
        only_self = (self_job is not None
                     and list(groups.keys()) == [self_job])
        if not groups or only_self or total_bw <= cap:
            results[i] = (PERFECT, None)
            continue
        jobs = priority_order(registry, groups.keys())
        periods, comms, prios = [], [], []
        for j in jobs:
            spec = groups[j][0].traffic
            periods.append(spec.period_ms)
            comms.append(spec.comm_ms)
            job = registry.jobs.get(j)
            prios.append(job.priority if job else 0)
        bws = _link_demands(view, link_id, jobs, demand)
        key = ("link", tuple(jobs), tuple(periods), tuple(comms),
               tuple(prios), tuple(bws), cap, mode, demand, rotation_mode,
               di_pre, g_t_ms, e_t_frac)
        keys[i] = key
        if cache is not None:
            hit = cache.get(epochs[i], key)
            if hit is not None:
                score, scheme = hit
                results[i] = (score, _copy_scheme(scheme))
                continue
        if key in seen_keys:
            continue  # duplicate: filled from the first solve below
        seen_keys[key] = i
        unified = geometry.unify_periods(periods, prios, g_t_ms=g_t_ms,
                                         e_t_frac=e_t_frac)
        duties = [min(1.0, comms[idx] / unified.periods_ms[idx])
                  for idx in range(len(jobs))]
        patterns = geometry.pattern_matrix(unified.muls, duties, di_pre)
        ranges = tuple(scoring.shift_ranges(unified.muls, 0, di_pre))
        probs[i] = _LinkProblem(jobs=jobs, bws=np.asarray(bws, np.float64),
                                cap=float(cap), unified=unified, comms=comms,
                                patterns=patterns, ranges=ranges)
        n_total = scoring.total_combos(ranges)
        fam = (patterns.tobytes(), ranges, n_total)
        todo.setdefault(fam, []).append(i)

    for fam, members in todo.items():
        group = [probs[i] for i in members]
        n_total = fam[2]
        if (len(group) == 1 or mode == "optimal"
                or n_total > max_exhaustive):
            for i in members:
                p = probs[i]
                if mode == "optimal":
                    result = find_optimal_rotation(
                        p.patterns, p.bws, p.cap, p.unified.muls, 0, di_pre)
                else:
                    result = find_feasible_rotation(
                        p.patterns, p.bws, p.cap, p.unified.muls, 0, di_pre,
                        chunk=chunk, max_exhaustive=max_exhaustive,
                        mode=rotation_mode)
                results[i] = _link_scheme_of(p, result)
            continue
        base = group[0]
        bank = scoring.rolled_bank(base.patterns, base.ranges)
        scans = []
        for p in group:
            def psi_of(shifts, _p=p):
                return scoring.scheme_psi(_p.patterns, _p.bws, _p.cap,
                                          _p.unified.muls, shifts, di_pre)
            scans.append(_RunScan(base.ranges, n_total, mode="fast",
                                  rotation_mode=rotation_mode,
                                  psi_of=psi_of))
        bw_rows = np.stack([p.bws for p in group])
        caps = np.asarray([p.cap for p in group], dtype=np.float64)
        # per-chunk combo budget shrinks with the stacked row count (the
        # scan is chunk-invariant); the minor-product floor keeps the
        # gather-free block path usable
        fam_chunk = max(scoring.minor_product(base.ranges),
                        int(chunk) // len(group))
        pending = set(range(len(group)))
        for pos, block in _score_chunks(base.patterns, bw_rows, caps,
                                        base.ranges, bank, fam_chunk):
            for pi in sorted(pending):
                if scans[pi].feed(pos, block[pi]):
                    pending.discard(pi)
            if not pending:
                break
        for i, p, scan in zip(members, group, scans):
            results[i] = _link_scheme_of(p, scan.finish())

    # propagate duplicates and fill the cache
    for i in range(n):
        if results[i] is not None or keys[i] is None:
            continue
        src = seen_keys.get(keys[i])
        if src is not None and results[src] is not None:
            score, scheme = results[src]
            results[i] = (score, _copy_scheme(scheme))
    if cache is not None:
        for i in range(n):
            if keys[i] is not None and results[i] is not None:
                score, scheme = results[i]
                if scheme is not None:
                    cache.put(epochs[i], keys[i],
                              (score, _copy_scheme(scheme)))
    return [r if r is not None else (PERFECT, None) for r in results]


def replan_link(view: LinkView, link_id: str, scheme: LinkScheme,
                capacity: float, di_pre: int = DI_PRE) -> RotationResult:
    """Offline 3rd-stage re-solve of one EXISTING scheme (the controller's
    pending-recalc path): keep the scheme's job order / unified base, re-read
    demand from the live view under the recalc convention, maximize Psi."""
    duties, bws = view.recalc_traffic(link_id, scheme.jobs, scheme.muls,
                                      scheme.base_ms)
    patterns = geometry.pattern_matrix(scheme.muls, duties, di_pre)
    ref_index = (scheme.jobs.index(scheme.ref_job)
                 if scheme.ref_job in scheme.jobs else 0)
    return find_optimal_rotation(patterns, bws, capacity, scheme.muls,
                                 ref_index, di_pre)


# ---------------------------------------------------------------------------
# Joint multi-link solve (one affinity component)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class JointResult:
    jobs: List[str]
    shifts: np.ndarray  # (P,) global slot shifts
    base_ms: float
    muls: np.ndarray
    schemes: Dict[str, LinkScheme]  # per link, restricted to its jobs
    offsets_ms: Dict[str, float]
    score: float  # min over links
    psi: float  # min over links (Eq. 9)
    feasible: bool
    n_evaluated: int = 0


def _min_link_scores(patterns: np.ndarray, bw_lp: np.ndarray,
                     caps: np.ndarray, combos: np.ndarray,
                     banks) -> np.ndarray:
    """(K,) joint score: Eq. 18 evaluated on every link, min over links."""
    out = None
    for li in range(len(caps)):
        s = scoring.score_combos(patterns, bw_lp[li], float(caps[li]),
                                 combos, banks)
        out = s if out is None else np.minimum(out, s)
    return out


def _kernel_joint_scores(patterns: np.ndarray, bw_lp: np.ndarray,
                         caps: np.ndarray, ranges: Sequence[int],
                         banks) -> Optional[np.ndarray]:
    """Batched multi-link evaluation of the FULL combo space via the
    stacked (L, R, S) kernel; None when the space has != 2 free jobs
    (the pairwise product layout does not apply)."""
    free = [i for i, r in enumerate(ranges) if r > 1]
    if len(free) != 2:
        return None
    from repro.kernels import ops as kops  # deferred: jax import is heavy
    pa, pb = free
    l, p = bw_lp.shape
    s = patterns.shape[1]
    base = np.zeros((l, s))
    for i in range(p):
        if i not in (pa, pb):
            base += bw_lp[:, i:i + 1] * patterns[i][None, :]
    bank_a = bw_lp[:, pa, None, None] * banks[pa][None, :, :]  # (L, Ra, S)
    bank_b = bw_lp[:, pb, None, None] * banks[pb][None, :, :]  # (L, Rb, S)
    scores = kops.score_multilink(base, bank_a, bank_b, np.asarray(caps))
    # C-order flatten == lexicographic combo order (free job a is the more
    # significant digit; every other range is 1)
    return np.asarray(scores).reshape(-1)


@dataclasses.dataclass
class JointProblem:
    """One affinity component's joint rotation problem, fully materialized
    (the numeric content is the memo key; the solve is a pure function of
    it)."""

    links: List[str]
    jobs: List[str]
    unified: geometry.UnifiedPeriods
    patterns: np.ndarray
    ranges: List[int]
    caps: np.ndarray  # (L,)
    bw_lp: np.ndarray  # (L, P)
    on_link: Dict[str, List[int]]  # link -> job indices present there
    key: Tuple  # content key (includes every solver knob)


def _build_joint_problem(
    view: LinkView,
    registry,
    links: Sequence[str],
    jobs: Optional[Sequence[str]],
    *,
    mode: str,
    demand: str,
    rotation_mode: str,
    di_pre: int,
    g_t_ms: float,
    e_t_frac: float,
    backend: str,
    max_exhaustive: int,
) -> Optional[JointProblem]:
    """The joint_solve prologue: groups, job order, unified periods, demand
    banks.  None when a job has no tasks in the view (stale scheme).

    The content key captures EVERY input that can change the solve's
    output, including the solver-selection knobs (``max_exhaustive`` picks
    exhaustive-vs-coordinate-descent, which produce different shifts)."""
    groups_by_link = {l: view.link_groups(l) for l in links}
    if jobs is None:
        seen: Dict[str, None] = {}
        for l in links:
            for j in groups_by_link[l]:
                seen[j] = None
        jobs = list(seen)
    jobs = priority_order(registry, jobs)
    if not jobs:
        return None
    specs = []
    for j in jobs:
        ts = view.job_tasks(j)
        if not ts:
            return None
        specs.append(ts[0].traffic)
    prios = []
    for j in jobs:
        job = registry.jobs.get(j)
        prios.append(job.priority if job else 0)
    unified = geometry.unify_periods([s.period_ms for s in specs], prios,
                                     g_t_ms=g_t_ms, e_t_frac=e_t_frac)
    duties = [min(1.0, specs[i].comm_ms / unified.periods_ms[i])
              for i in range(len(jobs))]
    patterns = geometry.pattern_matrix(unified.muls, duties, di_pre)
    ranges = scoring.shift_ranges(unified.muls, 0, di_pre)
    caps = np.array([view.cluster.link_alloc(l) for l in links])
    bw_lp = np.zeros((len(links), len(jobs)))
    on_link: Dict[str, List[int]] = {}
    for li, l in enumerate(links):
        dmds = _link_demands(view, l, jobs, demand)
        present = groups_by_link[l]
        on_link[l] = [pi for pi, j in enumerate(jobs) if j in present]
        for pi, j in enumerate(jobs):
            bw_lp[li, pi] = dmds[pi] if j in present else 0.0
    key = ("joint", tuple(links), tuple(jobs), bw_lp.tobytes(),
           caps.tobytes(),
           tuple((s.period_ms, s.comm_ms) for s in specs), tuple(prios),
           tuple(tuple(on_link[l]) for l in links),
           mode, demand, rotation_mode, di_pre, g_t_ms, e_t_frac, backend,
           max_exhaustive)
    return JointProblem(links=list(links), jobs=list(jobs), unified=unified,
                        patterns=patterns, ranges=ranges, caps=caps,
                        bw_lp=bw_lp, on_link=on_link, key=key)


def _joint_psi_of(prob: JointProblem, di_pre: int):
    def psi_of(shifts: np.ndarray) -> float:
        return min(
            scoring.scheme_psi(prob.patterns, prob.bw_lp[li],
                               float(prob.caps[li]), prob.unified.muls,
                               shifts, di_pre)
            for li in range(len(prob.links))
        )
    return psi_of


def _finish_joint(prob: JointProblem, result: RotationResult,
                  di_pre: int) -> JointResult:
    """Assemble the per-link schemes / global offsets from the chosen joint
    shifts (the joint_solve epilogue, shared with the batched path)."""
    shifts = result.shifts
    unified = prob.unified
    jobs = prob.jobs
    delays = geometry.shifts_to_delay_ms(shifts, unified.base_ms, di_pre)
    offsets = {j: float(d) for j, d in zip(jobs, delays)}
    schemes: Dict[str, LinkScheme] = {}
    link_scores: List[float] = []
    for li, l in enumerate(prob.links):
        on_link = prob.on_link[l]
        sc = float(scoring.score_combos(
            prob.patterns, prob.bw_lp[li], float(prob.caps[li]),
            shifts[None, :])[0])
        link_scores.append(sc)
        link_jobs = [jobs[pi] for pi in on_link]
        ref = link_jobs[0] if link_jobs else ""
        schemes[l] = LinkScheme(
            jobs=link_jobs,
            shifts_slots=shifts[on_link].copy(),
            base_ms=float(unified.base_ms),
            muls=unified.muls[on_link].copy(),
            score=sc,
            early_return=False,
            injected_ms={jobs[pi]: float(unified.injected_ms[pi])
                         for pi in on_link},
            ref_job=ref,
        )
    worst = min(link_scores) if link_scores else PERFECT
    return JointResult(
        jobs=list(jobs), shifts=shifts, base_ms=float(unified.base_ms),
        muls=unified.muls, schemes=schemes, offsets_ms=offsets,
        score=worst, psi=result.psi, feasible=worst >= PERFECT - _EPS,
        n_evaluated=result.n_evaluated,
    )


def _copy_joint(jr: JointResult) -> JointResult:
    return JointResult(
        jobs=list(jr.jobs), shifts=np.array(jr.shifts, copy=True),
        base_ms=jr.base_ms, muls=np.array(jr.muls, copy=True),
        schemes={l: _copy_scheme(s) for l, s in jr.schemes.items()},
        offsets_ms=dict(jr.offsets_ms), score=jr.score, psi=jr.psi,
        feasible=jr.feasible, n_evaluated=jr.n_evaluated,
    )


def _solve_joint_problem(prob: JointProblem, *, mode: str,
                         rotation_mode: str, di_pre: int, backend: str,
                         max_exhaustive: int, chunk: int) -> JointResult:
    psi_of = _joint_psi_of(prob, di_pre)
    n_total = scoring.total_combos(prob.ranges)
    if n_total > max_exhaustive:
        result = _joint_coordinate_descent(
            prob.patterns, prob.bw_lp, prob.caps, prob.unified.muls,
            prob.ranges, psi_of, optimize_psi=(mode == "optimal"))
    else:
        banks = scoring.rolled_bank(prob.patterns, prob.ranges)
        result = _joint_exhaustive(
            prob.patterns, prob.bw_lp, prob.caps, prob.ranges, banks,
            psi_of, mode=mode, rotation_mode=rotation_mode,
            backend=backend, chunk=chunk)
    return _finish_joint(prob, result, di_pre)


def joint_solve(
    view: LinkView,
    registry,
    links: Sequence[str],
    *,
    jobs: Optional[Sequence[str]] = None,
    mode: str = "fast",
    demand: str = "planning",
    rotation_mode: str = "intermediate",
    di_pre: int = DI_PRE,
    g_t_ms: float = 5.0,
    e_t_frac: float = 0.10,
    backend: str = "numpy",
    max_exhaustive: int = 1 << 22,
    chunk: int = 8192,
    cache: Optional[PlanCache] = None,
) -> Optional[JointResult]:
    """Solve one affinity component jointly over every link it touches.

    One global shift per job; Eq. 18 evaluated simultaneously on all links
    (min over links), Eq. 15 ranges on the shared base circle, Eq. 16
    reference pinned, Eq. 9 Psi (min over links) as the tie-break among
    perfect-run midpoints in ``mode='optimal'``; ``mode='fast'`` returns the
    middle of the first jointly perfect run (``rotation_mode='compact'`` is
    the no-cushion ablation).  Returns None when a job has no tasks in the
    view (stale scheme — the caller falls back to the BFS merge).

    With a ``cache``, results are memoized on the problem content within the
    view's epoch (see :class:`PlanCache`); cached results are returned as
    deep copies so consumer mutation never leaks back."""
    prob = _build_joint_problem(
        view, registry, links, jobs, mode=mode, demand=demand,
        rotation_mode=rotation_mode, di_pre=di_pre, g_t_ms=g_t_ms,
        e_t_frac=e_t_frac, backend=backend, max_exhaustive=max_exhaustive)
    if prob is None:
        return None
    if cache is not None:
        hit = cache.get(view.epoch, prob.key)
        if hit is not None:
            return _copy_joint(hit)
    result = _solve_joint_problem(
        prob, mode=mode, rotation_mode=rotation_mode, di_pre=di_pre,
        backend=backend, max_exhaustive=max_exhaustive, chunk=chunk)
    if cache is not None:
        cache.put(view.epoch, prob.key, _copy_joint(result))
    return result


def joint_solve_batch(
    specs: Sequence[Tuple[LinkView, Sequence[str]]],
    registry,
    *,
    mode: str = "fast",
    demand: str = "planning",
    rotation_mode: str = "intermediate",
    di_pre: int = DI_PRE,
    g_t_ms: float = 5.0,
    e_t_frac: float = 0.10,
    backend: str = "numpy",
    max_exhaustive: int = 1 << 22,
    chunk: int = 8192,
    cache: Optional[PlanCache] = None,
) -> List[Optional[JointResult]]:
    """Solve MANY joint problems (one per ``(view, links)`` spec) with one
    shared enumeration pass per problem family.

    The Score phase produces one such problem per surviving candidate node
    of a pod; the candidates share the component's job set — hence identical
    ``(patterns, ranges)`` — and differ only in the per-link demand banks
    (the candidate delta).  Problems of one family are therefore scored
    together: every chunk of the combo space is evaluated for ALL still-
    unresolved problems in one batched call (``backend='kernel'``: a single
    stacked ``(C, L, R, S)`` kernel dispatch covering the whole space), and
    each problem's run scan consumes its own row — bit-for-bit the result
    :func:`joint_solve` would produce for it individually.

    Results land in ``cache`` (when given), so a subsequent per-candidate
    ``plan()``/``resolve()`` pass hits instead of re-solving."""
    probs: List[Optional[JointProblem]] = []
    for view, links in specs:
        probs.append(_build_joint_problem(
            view, registry, links, None, mode=mode, demand=demand,
            rotation_mode=rotation_mode, di_pre=di_pre, g_t_ms=g_t_ms,
            e_t_frac=e_t_frac, backend=backend,
            max_exhaustive=max_exhaustive))

    results: List[Optional[JointResult]] = [None] * len(probs)
    epochs = [view.epoch for view, _ in specs]

    # families: identical (patterns, ranges, n_total) solve together
    todo: Dict[Tuple, List[int]] = {}
    seen_keys: Dict[Tuple, int] = {}
    for i, prob in enumerate(probs):
        if prob is None:
            continue
        if cache is not None:
            hit = cache.get(epochs[i], prob.key)
            if hit is not None:
                results[i] = _copy_joint(hit)
                continue
        if prob.key in seen_keys:
            continue  # duplicate problem: filled from the first solve below
        seen_keys[prob.key] = i
        n_total = scoring.total_combos(prob.ranges)
        fam = (prob.patterns.tobytes(), tuple(prob.ranges), n_total)
        todo.setdefault(fam, []).append(i)

    for fam, members in todo.items():
        group = [probs[i] for i in members]
        if len(group) == 1 or scoring.total_combos(
                group[0].ranges) > max_exhaustive:
            for i in members:
                results[i] = _solve_joint_problem(
                    probs[i], mode=mode, rotation_mode=rotation_mode,
                    di_pre=di_pre, backend=backend,
                    max_exhaustive=max_exhaustive, chunk=chunk)
        else:
            solved = _solve_joint_family(
                group, mode=mode, rotation_mode=rotation_mode,
                di_pre=di_pre, backend=backend, chunk=chunk)
            for i, res in zip(members, solved):
                results[i] = res

    # propagate duplicates and fill the cache
    for i, prob in enumerate(probs):
        if prob is None or results[i] is not None:
            continue
        src = seen_keys.get(prob.key)
        if src is not None and results[src] is not None:
            results[i] = _copy_joint(results[src])
    if cache is not None:
        for i, prob in enumerate(probs):
            if prob is not None and results[i] is not None:
                cache.put(epochs[i], prob.key, _copy_joint(results[i]))
    return results


def _solve_joint_family(probs: List[JointProblem], *, mode: str,
                        rotation_mode: str, di_pre: int, backend: str,
                        chunk: int) -> List[JointResult]:
    """One enumeration pass over a family of joint problems sharing
    (patterns, ranges): all still-unresolved problems score every chunk in
    one batched evaluation; each problem's scan state machine is fed its own
    min-over-links row, which makes the outcome chunk-layout independent and
    therefore identical to the per-problem solve."""
    base = probs[0]
    ranges = base.ranges
    n_total = scoring.total_combos(ranges)
    banks = scoring.rolled_bank(base.patterns, ranges)
    scans = [
        _RunScan(ranges, n_total, mode=mode, rotation_mode=rotation_mode,
                 psi_of=_joint_psi_of(p, di_pre), eval_scale=len(p.caps))
        for p in probs
    ]

    if backend == "kernel":
        stacked = _kernel_joint_scores_batch(probs, banks, ranges)
        if stacked is not None:
            for scan, js in zip(scans, stacked):
                scan.feed(0, js)
            return [_finish_joint(p, scan.finish(), di_pre)
                    for p, scan in zip(probs, scans)]

    # stack every problem's (L, P) rows; slice per problem after scoring
    row_of: List[Tuple[int, int]] = []
    bw_rows = []
    cap_rows = []
    for p in probs:
        start = len(bw_rows)
        bw_rows.extend(list(p.bw_lp))
        cap_rows.extend(list(p.caps))
        row_of.append((start, start + len(p.caps)))
    bw_rows = np.asarray(bw_rows, dtype=np.float64)
    cap_rows = np.asarray(cap_rows, dtype=np.float64)

    # the block buffer scales with the number of stacked rows: shrink the
    # per-chunk combo budget accordingly (the scan is chunk-invariant, so
    # results are unchanged) to keep memory at the per-problem level; the
    # minor-product floor keeps the gather-free block path usable
    chunk = max(scoring.minor_product(ranges),
                int(chunk) // max(1, len(probs)))

    pending = set(range(len(probs)))
    for pos, block in _score_chunks(base.patterns, bw_rows, cap_rows,
                                    ranges, banks, chunk):
        for pi in sorted(pending):
            lo, hi = row_of[pi]
            js = np.minimum.reduce(block[lo:hi], axis=0)
            if scans[pi].feed(pos, js):
                pending.discard(pi)
        if not pending:
            break
    return [_finish_joint(p, scan.finish(), di_pre)
            for p, scan in zip(probs, scans)]


def _kernel_joint_scores_batch(probs: List[JointProblem], banks,
                               ranges) -> Optional[List[np.ndarray]]:
    """Full-space joint scores for a problem family via ONE stacked
    (C, L, R, S) kernel dispatch; None when the pairwise layout does not
    apply (!= 2 free jobs).  Problems with fewer links than the family
    maximum are padded with zero-demand unit-capacity links, which score a
    constant 100 and cannot change the min."""
    free = [i for i, r in enumerate(ranges) if r > 1]
    if len(free) != 2:
        return None
    from repro.kernels import ops as kops  # deferred: jax import is heavy
    pa, pb = free
    c = len(probs)
    l_max = max(len(p.caps) for p in probs)
    s = probs[0].patterns.shape[1]
    ra, rb = ranges[pa], ranges[pb]
    base = np.zeros((c, l_max, s))
    bank_a = np.zeros((c, l_max, ra, s))
    bank_b = np.zeros((c, l_max, rb, s))
    caps = np.ones((c, l_max))
    for ci, p in enumerate(probs):
        l = len(p.caps)
        caps[ci, :l] = p.caps
        for i in range(p.patterns.shape[0]):
            if i not in (pa, pb):
                base[ci, :l] += p.bw_lp[:, i:i + 1] * p.patterns[i][None, :]
        bank_a[ci, :l] = p.bw_lp[:, pa, None, None] * banks[pa][None, :, :]
        bank_b[ci, :l] = p.bw_lp[:, pb, None, None] * banks[pb][None, :, :]
    scores = kops.score_multilink_batch(base, bank_a, bank_b, caps)
    # C-order flatten == lexicographic combo order (free job a is the more
    # significant digit; every other range is 1)
    return [np.asarray(scores[ci]).reshape(-1) for ci in range(c)]


def _joint_exhaustive(patterns, bw_lp, caps, ranges, banks, psi_of, *,
                      mode, rotation_mode, backend, chunk) -> RotationResult:
    n_total = scoring.total_combos(ranges)
    scan = _RunScan(ranges, n_total, mode=mode, rotation_mode=rotation_mode,
                    psi_of=psi_of, eval_scale=len(caps))
    if backend == "kernel":
        joint_all = _kernel_joint_scores(patterns, bw_lp, caps, ranges, banks)
        if joint_all is not None:
            scan.feed(0, joint_all)
            return scan.finish()
    for pos, block in _score_chunks(patterns, np.asarray(bw_lp),
                                    np.asarray(caps), ranges, banks, chunk):
        js = np.minimum.reduce(block, axis=0)
        if scan.feed(pos, js):
            break
    return scan.finish()


def _joint_coordinate_descent(patterns, bw_lp, caps, muls, ranges, psi_of, *,
                              optimize_psi, sweeps: int = 4) -> RotationResult:
    """Coordinate descent over jobs with the joint (min-over-links) score."""
    p = patterns.shape[0]
    shifts = np.zeros(p, dtype=np.int64)
    n_eval = 0
    for _ in range(sweeps):
        changed = False
        for i in range(p):
            if ranges[i] <= 1:
                continue
            cands = np.tile(shifts, (ranges[i], 1))
            cands[:, i] = np.arange(ranges[i])
            js = _min_link_scores(patterns, bw_lp, caps, cands, None)
            n_eval += ranges[i] * len(caps)
            best = js.max()
            mask = js >= best - _EPS
            idxs = np.nonzero(mask)[0]
            if optimize_psi and best >= PERFECT - _EPS:
                psis = [psi_of(cands[k]) for k in idxs]
                pick = int(idxs[int(np.argmax(psis))])
            else:
                runs = np.split(idxs, np.where(np.diff(idxs) != 1)[0] + 1)
                pick = int(runs[0][len(runs[0]) // 2])
            if pick != shifts[i]:
                shifts[i] = pick
                changed = True
        if not changed:
            break
    final = _min_link_scores(patterns, bw_lp, caps, shifts[None, :], None)[0]
    return RotationResult(float(final), shifts, final >= PERFECT - _EPS,
                          psi_of(shifts), n_eval)


# ---------------------------------------------------------------------------
# Global resolution: consistent BFS merge or joint re-solve per component
# ---------------------------------------------------------------------------

def _affinity_graph(schemes: Dict[str, LinkScheme],
                    di_pre: int = DI_PRE) -> nx.Graph:
    """The per-link relative-shift affinity graph of :func:`resolve`, in the
    canonical deterministic construction order (sorted hosts, uplinks
    last): for consistent components any order gives the same offsets; for
    the joint=False ablation it reproduces the legacy tie-break."""
    g = nx.Graph()
    link_shift_ms: Dict[Tuple[str, str], float] = {}
    ordered = sorted(schemes.items(), key=lambda kv: (is_uplink(kv[0]), kv[0]))
    for link_id, sch in ordered:
        delays = geometry.shifts_to_delay_ms(sch.shifts_slots, sch.base_ms,
                                             di_pre)
        for j, d in zip(sch.jobs, delays):
            link_shift_ms[(link_id, j)] = float(d)
            g.add_node(j)
        for i in range(len(sch.jobs)):
            for k in range(i + 1, len(sch.jobs)):
                a, b = sch.jobs[i], sch.jobs[k]
                rel = (link_shift_ms[(link_id, b)]
                       - link_shift_ms[(link_id, a)])
                if g.has_edge(a, b):
                    if g[a][b]["src"] != a:
                        rel = -rel
                    g[a][b]["rels"].append(rel)
                else:
                    g.add_edge(a, b, rels=[rel], src=a)
    return g


def _components(schemes: Dict[str, LinkScheme], di_pre: int
                ) -> Tuple[nx.Graph, List[Tuple[set, List[str], bool]]]:
    """The affinity graph plus, per connected component in iteration
    order, ``(component_jobs, component_links, conflicted)`` — the ONE
    conflict decision both :func:`resolve` and the scheduler's warm
    pre-pass consume (so they can never drift apart)."""
    g = _affinity_graph(schemes, di_pre)
    comps: List[Tuple[set, List[str], bool]] = []
    for comp in nx.connected_components(g):
        comp = set(comp)
        sub = g.subgraph(comp)
        conflicted = any(
            max(d["rels"]) - min(d["rels"]) > REL_TOL_MS
            for _, _, d in sub.edges(data=True)
        )
        comp_links = [lid for lid, sch in schemes.items()
                      if any(j in comp for j in sch.jobs)]
        comps.append((comp, comp_links, conflicted))
    return g, comps


def conflicted_components(schemes: Dict[str, LinkScheme],
                          di_pre: int = DI_PRE
                          ) -> List[Tuple[List[str], bool]]:
    """``[(component_links, conflicted)]`` in :func:`resolve`'s component
    iteration order — the pre-pass the scheduler uses to collect every
    joint problem a subsequent ``plan()`` would solve, without solving."""
    _g, comps = _components(schemes, di_pre)
    return [(comp_links, conflicted)
            for _comp, comp_links, conflicted in comps]


def resolve(
    schemes: Dict[str, LinkScheme],
    priorities: Dict[str, int],
    view: Optional[LinkView],
    registry=None,
    *,
    di_pre: int = DI_PRE,
    mode: str = "fast",
    demand: str = "planning",
    g_t_ms: float = 5.0,
    e_t_frac: float = 0.10,
    rotation_mode: str = "intermediate",
    joint: bool = True,
    backend: str = "numpy",
    cache: Optional[PlanCache] = None,
) -> PlanResult:
    """Assign each job one global circle offset from a set of per-link
    schemes (Cassini-style affinity graph anchored at the highest-priority
    job — the paper's difference vs Cassini's random reference, Eq. 16).

    Components whose per-link relative shifts all agree keep their schemes
    and the BFS traversal of the pre-planner controller bit-for-bit.  A
    component with CONFLICTING per-link shifts is re-solved jointly from the
    live ``view`` (``joint=True``); with ``joint=False`` — or when no view
    is available — the legacy reconciliation applies: links are traversed
    in canonical order (host links sorted, uplinks LAST) and the last
    writer wins, i.e. the most oversubscribed tier takes precedence."""
    g, comps = _components(schemes, di_pre)

    offsets: Dict[str, float] = {}
    joint_links: List[str] = []
    new_schemes: Dict[str, LinkScheme] = dict(schemes)
    n_eval = 0
    for comp, comp_links, conflicted in comps:
        if conflicted and joint and view is not None and registry is not None:
            jr = joint_solve(
                view, registry, comp_links, mode=mode, demand=demand,
                rotation_mode=rotation_mode, di_pre=di_pre, g_t_ms=g_t_ms,
                e_t_frac=e_t_frac, backend=backend, cache=cache,
            )
            if jr is not None:
                offsets.update(jr.offsets_ms)
                new_schemes.update(jr.schemes)
                joint_links.extend(comp_links)
                n_eval += jr.n_evaluated
                continue
        # consistent component (or legacy fallback): BFS from the
        # highest-priority reference; the last rel in canonical order is
        # the edge value (== the only value when consistent).
        comp_list = list(comp)
        ref = sorted(comp_list,
                     key=lambda j: (-priorities.get(j, 0), j))[0]
        offsets[ref] = 0.0
        for u, v in nx.bfs_edges(g, ref):
            rel = g[u][v]["rels"][-1]
            if g[u][v]["src"] != u:
                rel = -rel
            offsets[v] = offsets[u] + rel

    scores = [sch.score for sch in new_schemes.values()]
    worst = min(scores) if scores else PERFECT
    return PlanResult(
        schemes=new_schemes, offsets_ms=offsets, score=worst,
        feasible=worst >= PERFECT - _EPS, joint_links=joint_links,
        n_evaluated=n_eval,
    )


# ---------------------------------------------------------------------------
# Top-level: per-link solve + conflict resolution in one call
# ---------------------------------------------------------------------------

def plan(
    view: LinkView,
    registry,
    *,
    links: Optional[Sequence[str]] = None,
    self_job: Optional[str] = None,
    mode: str = "fast",
    demand: str = "planning",
    di_pre: int = DI_PRE,
    g_t_ms: float = 5.0,
    e_t_frac: float = 0.10,
    rotation_mode: str = "intermediate",
    joint: bool = True,
    backend: str = "numpy",
    cache: Optional[PlanCache] = None,
) -> PlanResult:
    """The planner entry point: solve every (given or contended) link, then
    resolve the per-link solutions into one consistent set of global
    offsets.  On star topologies — or whenever the per-link solutions
    already agree — this reduces bit-for-bit to the per-link solve."""
    link_ids = list(links) if links is not None else view.planning_links()
    schemes: Dict[str, LinkScheme] = {}
    worst = PERFECT
    for lid in link_ids:
        score, scheme = solve_link(
            view, registry, lid, self_job=self_job, mode=mode, demand=demand,
            di_pre=di_pre, g_t_ms=g_t_ms, e_t_frac=e_t_frac,
            rotation_mode=rotation_mode, cache=cache,
        )
        worst = min(worst, score)
        if scheme is not None:
            schemes[lid] = scheme
    if not schemes:
        return PlanResult(schemes={}, offsets_ms={}, score=worst,
                          feasible=worst >= PERFECT - _EPS, joint_links=[])
    if len(schemes) == 1:
        # single contended link: nothing to resolve — offsets are the
        # scheme's own delays (BFS from the priority-0 reference would
        # yield exactly these, ref delay being 0 per Eq. 16)
        (lid, sch), = schemes.items()
        delays = geometry.shifts_to_delay_ms(sch.shifts_slots, sch.base_ms,
                                             di_pre)
        return PlanResult(
            schemes=schemes,
            offsets_ms={j: float(d) for j, d in zip(sch.jobs, delays)},
            score=worst, feasible=worst >= PERFECT - _EPS, joint_links=[])
    priorities = {j: (registry.jobs[j].priority if j in registry.jobs else 0)
                  for sch in schemes.values() for j in sch.jobs}
    res = resolve(
        schemes, priorities, view, registry, di_pre=di_pre, mode=mode,
        demand=demand, g_t_ms=g_t_ms, e_t_frac=e_t_frac,
        rotation_mode=rotation_mode, joint=joint, backend=backend,
        cache=cache,
    )
    # resolve()'s schemes carry the FINAL per-link scores (a jointly
    # re-solved component replaces the stale per-link ones); early-return
    # links contribute exactly PERFECT and cannot lower the worst score
    return res

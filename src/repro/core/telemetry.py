"""Imperfect-information control plane: the telemetry channel model.

Everything upstream of this module assumed an oracle: the monitor, the
Score phase, and ``StopAndWaitController.on_link_change`` read exact,
instantaneous link state.  DESIGN.md section 19 replaces that assumption
with an explicit observation channel:

  * :class:`TelemetryChannel` — the channel configuration (sampling
    period, multiplicative Gaussian noise, staleness, dropout), carried
    on :class:`~repro.core.simulator.SimConfig` so it participates in
    bench fingerprints like every other result-relevant knob.
  * :class:`TelemetryView` — a :class:`~repro.core.cluster.Cluster`
    proxy.  It exposes the full cluster API (delegation), but
    ``link_alloc`` — the single authority every scheduler-side consumer
    reads allocatable bandwidth through (LinkView fill problems,
    ``expected_iteration_ms`` re-baselining, ``on_link_change`` replans)
    — returns the *observed* value: the truth as of the last sample
    time, distorted by the channel.

Determinism contract (satellite: independent RNG streams): per-sample
noise/dropout draws come from ``np.random.SeedSequence(seed,
spawn_key=(TELEMETRY_STREAM, link_index, sample_index))`` — a pure
function of the (link, sample-slot) pair, never of query order.  Two
event loops that interleave observations differently still see identical
channels, and the simulator's jitter stream (``default_rng(seed)``) is
untouched: adding a telemetry channel cannot perturb a golden-pinned
jitter sequence.

Truth is recorded eagerly: the simulator calls :meth:`record_change`
from every capacity-mutating event handler, so a sample taken at time
``t_s`` observes the capacity that was actually in force at ``t_s`` even
if it changed again before the query (last-sample-wins staleness, not
latest-truth-wins).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

# spawn-key namespace for the telemetry stream (jitter owns the root
# ``default_rng(seed)`` stream; any future stream takes the next integer)
TELEMETRY_STREAM = 1

# EWMA smoothing for the per-link fluctuation (coefficient of variation)
# history that feeds the reconfiguration-aware Score penalty
FLUCT_ALPHA = 0.3


@dataclasses.dataclass(frozen=True)
class TelemetryChannel:
    """Observation-channel configuration (all distortions off by default).

    ``sample_period_ms``  — telemetry arrives every this-many ms; queries
        between samples see the last sample (sample-and-hold).  ``<= 0``
        degenerates to continuous observation: staleness still applies,
        noise/dropout (which are per-sample notions) do not.
    ``noise_std``         — multiplicative Gaussian noise: an observed
        sample is ``true * (1 + N(0, noise_std))``, clamped at 0.
    ``staleness_ms``      — pipeline delay: a query at ``t`` sees the
        sample that had arrived by ``t - staleness_ms``.
    ``dropout``           — probability a sample is lost in transit; the
        previous sample is carried (last-sample-wins).
    """

    sample_period_ms: float = 1000.0
    noise_std: float = 0.0
    staleness_ms: float = 0.0
    dropout: float = 0.0

    def __post_init__(self) -> None:
        if not math.isfinite(self.sample_period_ms):
            raise ValueError("sample_period_ms must be finite")
        if self.noise_std < 0 or not math.isfinite(self.noise_std):
            raise ValueError("noise_std must be finite and >= 0")
        if self.staleness_ms < 0 or not math.isfinite(self.staleness_ms):
            raise ValueError("staleness_ms must be finite and >= 0")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError("dropout must be in [0, 1)")


class TelemetryView:
    """Cluster proxy observing allocatable bandwidth through a channel.

    Reads delegate to the wrapped (authoritative) cluster; only
    ``link_alloc`` is intercepted.  ``link_capacity`` stays truthful on
    purpose: physical capacity is a *declared* quantity (the
    NodeBandwidth CR), not a measurement.  Mutations made through the
    proxy (``node(...).allocate``, ``bump_epoch``) hit the real objects,
    so the scheduling framework can hold the proxy without forking
    state.
    """

    def __init__(self, cluster, channel: TelemetryChannel, *, seed: int):
        self._cluster = cluster
        self.channel = channel
        self._seed = int(seed)
        # wall clock of the simulation; the simulator advances it each tick
        self.now_ms: float = 0.0
        self._link_index: Dict[str, int] = {
            l: i for i, l in enumerate(cluster.link_ids)}
        # eager truth history per link: [(time_ms, alloc_gbps)], sorted
        self._truth: Dict[str, List[Tuple[float, float]]] = {
            l: [(-math.inf, cluster.link_alloc(l))] for l in cluster.link_ids}
        # memoized observations per (link, sample index)
        self._obs: Dict[Tuple[str, int], float] = {}
        # EWMA fluctuation state per link: (last sample idx, mean, var)
        self._fluct: Dict[str, Tuple[int, float, float]] = {}

    # ------------------------------------------------------------- delegation
    def __getattr__(self, name):
        return getattr(self._cluster, name)

    # ------------------------------------------------------------ truth feed
    def record_change(self, now_ms: float,
                      links: Optional[List[str]] = None) -> None:
        """Record the current true allocatable value of ``links`` (default:
        all) at ``now_ms``.  The simulator calls this from every event
        handler that mutates link capacity, so later samples observe the
        truth that held at their sample time."""
        for l in (links if links is not None else self._cluster.link_ids):
            hist = self._truth.get(l)
            if hist is None:  # link unknown to the wrapped cluster
                continue
            val = self._cluster.link_alloc(l)
            if hist[-1][0] == now_ms:
                hist[-1] = (now_ms, val)
            else:
                hist.append((now_ms, val))

    def _truth_at(self, link_id: str, t_ms: float) -> float:
        hist = self._truth[link_id]
        i = bisect.bisect_right(hist, (t_ms, math.inf)) - 1
        return hist[i][1]

    # ----------------------------------------------------------- observation
    def _sample_rng(self, link_id: str, k: int) -> np.random.Generator:
        ss = np.random.SeedSequence(
            self._seed,
            spawn_key=(TELEMETRY_STREAM, self._link_index[link_id], k))
        return np.random.default_rng(ss)

    def _sample(self, link_id: str, k: int) -> float:
        """Observed value of sample ``k`` (memoized; order-independent).

        Walks back through dropped samples to the newest delivered one —
        obs(k) = obs(k-1) when sample k is lost — so the carry chain is a
        pure function of sample indices, not of which queries happened
        to materialize them first."""
        ch = self.channel
        pending: List[int] = []
        j = k
        while True:
            cached = self._obs.get((link_id, j))
            if cached is not None:
                val = cached
                break
            rng = self._sample_rng(link_id, j)
            # draw order is part of the channel contract: dropout first,
            # then (only for delivered samples) the noise draw
            dropped = j > 0 and ch.dropout > 0.0 and rng.random() < ch.dropout
            if dropped:
                pending.append(j)
                j -= 1
                continue
            true = self._truth_at(link_id, j * ch.sample_period_ms)
            if ch.noise_std > 0.0:
                val = max(0.0, true * (1.0 + rng.normal(0.0, ch.noise_std)))
            else:
                val = true
            self._obs[(link_id, j)] = val
            self._update_fluct(link_id, j, val)
            break
        for p in reversed(pending):
            self._obs[(link_id, p)] = val
        return val

    def _sample_index(self, now_ms: float) -> int:
        period = self.channel.sample_period_ms
        t_s = max(0.0, now_ms - self.channel.staleness_ms)
        return int(t_s // period)

    def link_alloc(self, link_id: str) -> float:
        """Allocatable bandwidth as *observed* through the channel."""
        if link_id not in self._truth:
            # unknown links raise exactly like the wrapped cluster would
            return self._cluster.link_alloc(link_id)
        ch = self.channel
        if ch.sample_period_ms <= 0.0:
            # continuous observation: staleness only
            if ch.staleness_ms > 0.0:
                return self._truth_at(
                    link_id, max(0.0, self.now_ms - ch.staleness_ms))
            return self._cluster.link_alloc(link_id)
        return self._sample(link_id, self._sample_index(self.now_ms))

    # ----------------------------------------------------------- fluctuation
    def _update_fluct(self, link_id: str, k: int, obs: float) -> None:
        state = self._fluct.get(link_id)
        if state is None:
            self._fluct[link_id] = (k, obs, 0.0)
            return
        last_k, mean, var = state
        if k <= last_k:  # only advance on newer samples (monotone clock)
            return
        a = FLUCT_ALPHA
        mean_new = (1.0 - a) * mean + a * obs
        var_new = (1.0 - a) * var + a * (obs - mean_new) ** 2
        self._fluct[link_id] = (k, mean_new, var_new)

    def fluctuation(self, link_id: str) -> float:
        """EWMA coefficient of variation (sigma/mu) of the observed
        samples for ``link_id`` — the Score phase's reconfiguration-aware
        penalty input.  0.0 until at least two samples landed."""
        state = self._fluct.get(link_id)
        if state is None:
            return 0.0
        _, mean, var = state
        if mean <= 0.0 or var <= 0.0:
            return 0.0
        return math.sqrt(var) / mean

"""The Metronome scheduler plugin — Algorithm 1 of the paper.

Implements the five extension points:

  PreFilter      : latency score Delta_n per node + resource caching
  Filter         : dependency-loop, CPU/MEM/GPU and bandwidth (Eq. 13-14)
  Score          : Eq. 18 via the fabric-wide rotation planner (1st opt.
                   stage + Eqs. 15-17, jointly over every traversed link)
  NormalizeScore : Eq. 19 latency tie-break (2nd opt. stage)
  Reserve        : state update + SEND(shifts, SkipPhaseThree) to controller
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import networkx as nx
import numpy as np

from . import geometry, rotation
from .cluster import Cluster
from .contention import LinkView
from .framework import ScheduleContext, SchedulerPlugin, TaskRegistry
from .geometry import DI_PRE
from .rotation import LinkScheme
from .workload import Task

PERFECT = 100.0

# Beyond-paper rack-locality bonus: a candidate that makes the pod's job
# traverse a spine uplink scores this much below an intra-leaf candidate of
# equal rotation feasibility — prefer placements that need no uplink
# rotation at all.  Kept below 1.0 so rotation feasibility (and the
# dependency-loop cap at 99.0) always dominates the choice.
RACK_LOCALITY_PENALTY = 0.5

# Reconfiguration-aware Score penalty (DESIGN.md section 19): when the
# control plane observes through a telemetry channel, candidates whose
# traversed links show high observed fluctuation (EWMA coefficient of
# variation, ``TelemetryView.fluctuation``) are demoted — placing onto a
# flapping link invites reconfiguration churn.  The penalty is the worst
# traversed link's CV times this scale, so a 10%-CV link costs as much as
# the rack-locality preference; with an oracle cluster (no telemetry
# proxy) the penalty is identically 0.0 and scores are bit-for-bit the
# seed's.
FLUCTUATION_PENALTY_SCALE = 5.0


@dataclasses.dataclass
class ReserveMessage:
    """What Reserve SENDs to the stop-and-wait controller (Alg. 1 line 40).

    ``schemes`` maps every link the placement traverses and contends on
    (host link id == node name; uplinks ``uplink:<leaf>``) to its rotation
    scheme. ``skips`` carries the per-link SkipPhaseThree flag;
    ``skip_phase_three`` aggregates it (True when no link needs the offline
    3rd-stage recalculation)."""

    node: str
    schemes: Dict[str, LinkScheme]
    shifts_ms: Dict[str, float]
    skip_phase_three: bool
    skips: Dict[str, bool] = dataclasses.field(default_factory=dict)


class MetronomePlugin(SchedulerPlugin):
    name = "metronome"

    def __init__(
        self,
        controller=None,
        *,
        g_t_ms: float = 5.0,
        e_t_frac: float = 0.10,
        di_pre: int = DI_PRE,
        rotation_mode: str = "intermediate",  # 'compact' = stage-3 ablation
        joint: bool = True,  # False = legacy per-link solve (uplink-wins)
        memo: bool = True,  # False = ablation: re-solve per candidate
    ) -> None:
        self.controller = controller
        self.g_t_ms = g_t_ms
        self.e_t_frac = e_t_frac
        self.di_pre = di_pre
        self.rotation_mode = rotation_mode
        self.joint = joint
        # epoch-scoped content-keyed planner memo (DESIGN.md section 15):
        # the N candidate nodes of one Score phase share every per-link and
        # joint solve whose numeric problem coincides; ANY cluster/registry
        # mutation advances the epoch and drops the store
        self.plan_cache = rotation.PlanCache() if memo else None
        self.messages: List[ReserveMessage] = []

    # ------------------------------------------------------------------ utils
    def _candidate_view(self, cluster: Cluster, pod: Task, node_name: str,
                        registry: TaskRegistry) -> LinkView:
        """The unified demand view with ``pod`` provisionally on ``node_name``
        (the single source of truth for groupings/demand — contention.py)."""
        return LinkView.from_registry(cluster, registry, extra=pod,
                                      extra_node=node_name)

    # -------------------------------------------------------------- PreFilter
    def pre_filter(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
                   registry: TaskRegistry) -> None:
        """CALCULATELATENCYSCORE for every node + cache resources."""
        deps = registry.dependencies_of(pod)
        deployed_deps = [t for t in deps if t.node is not None]
        delta: Dict[str, float] = {}
        for n in cluster.node_names:
            total = sum(cluster.tau(n, t.node) for t in deployed_deps)
            if total == 0.0:
                # LowComm pod or no deployed dependency: use average latency
                # between the candidate node and all nodes in the cluster.
                total = float(np.mean([cluster.tau(n, m) for m in cluster.node_names]))
            delta[n] = total
        ctx.cache["delta"] = delta
        ctx.cache["deployed_deps"] = deployed_deps

    # ----------------------------------------------------------------- Filter
    def filter(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
               node_name: str, registry: TaskRegistry) -> bool:
        node = cluster.node(node_name)
        # resources (Eq. 13)
        if not pod.resources.fits_in(node.free):
            return False
        # bandwidth capacity (Eq. 14), on EVERY link the pod's flows would
        # traverse: the host link, plus the candidate leaf's uplink when the
        # placement makes the pod's job span leaves
        if pod.traffic.bw_gbps > node.alloc_bw:
            return False
        topo = cluster.topology
        if not topo.is_star and not pod.low_comm:
            view = self._candidate_view(cluster, pod, node_name, registry)
            if topo.leaf_of[node_name] in view.traversed_uplinks(pod.job):
                up = topo.uplink_of(node_name)
                if up is not None and pod.traffic.bw_gbps > up.alloc_bw:
                    return False
        # Dependency loops (Cassini) are handled at the Score phase: on a
        # loaded cluster a hard filter would leave pods unschedulable, and
        # the paper's own section V prescribes scoring toward less-contended
        # nodes instead. The loop check caps the node's score below perfect
        # so loop-free placements always win ties (see score()).
        return True

    def _dependency_loop_closure(self, view: LinkView, pod: Task,
                                 base_pairs: Optional[Dict[str, List[Tuple[
                                     str, str]]]] = None
                                 ) -> Tuple[bool, List[str]]:
        """Cassini's affinity-loop filter, restricted to edges that matter.

        Only *contending* pairs (the LinkView's Eq. 9 predicate: combined
        demand exceeding the link's allocatable capacity) constrain
        relative rotations; sub-capacity co-location imposes nothing. And a
        pre-existing loop between other jobs is not this pod's problem: we
        flag the node only when the NEW placement closes a cross-link
        cycle through the pod's own job.

        Returns ``(loop, closure_links)``: whether such a cycle exists, and
        every link of the pod's affinity component (the links a joint solve
        must cover to give the cycle one consistent set of offsets).

        ``base_pairs`` optionally carries the candidate-independent
        contending pairs (computed WITHOUT the extra pod): the candidate
        delta can only change the extra node's host link — and, off star
        topologies, uplink groupings — so every other link's pairs are
        shared across the N candidates of one Score phase.
        """
        topo = view.cluster.topology
        affected = {view.extra_node} if view.extra is not None else set()
        if not topo.is_star:
            affected.update(topo.uplink_ids)
        g = nx.Graph()
        for link_id in view.planning_links():
            if base_pairs is not None and link_id not in affected:
                pairs = base_pairs[link_id]
            else:
                pairs = view.contending_pairs(link_id)
            for a, b in pairs:
                if g.has_edge(a, b):
                    g[a][b]["links"].add(link_id)
                else:
                    g.add_edge(a, b, links={link_id})
        # a 2-job multi-link relation needs only one relative shift, which
        # the rotation planner resolves (consistent per-link solutions are
        # kept; conflicts trigger the joint multi-link solve); cross-link
        # cycles of length >= 3 THROUGH THIS JOB couple links beyond the
        # pod's own traversal — only a joint solve over the whole closure
        # can give them consistent offsets.
        if pod.job not in g:
            return False, []
        comp = nx.node_connected_component(g, pod.job)
        closure = {l for u, v, d in g.subgraph(comp).edges(data=True)
                   for l in d["links"]}
        closure_links = [l for l in view.planning_links() if l in closure]
        loop = False
        try:
            for cyc in nx.cycle_basis(g, pod.job):
                if len(cyc) < 3 or pod.job not in cyc:
                    continue
                common = None
                for a, b in zip(cyc, cyc[1:] + cyc[:1]):
                    links = g[a][b]["links"]
                    common = set(links) if common is None else common & links
                if not common:
                    loop = True
                    break
        except nx.NetworkXError:
            pass
        return loop, closure_links

    # ------------------------------------------------------------------ Score
    def _candidate_links(self, cluster: Cluster, view: LinkView, pod: Task,
                         node_name: str) -> List[str]:
        """Every link the candidate placement's flows would traverse."""
        return [node_name] + [
            cluster.topology.uplinks[leaf].id
            for leaf in view.traversed_uplinks(pod.job)
        ]

    def _loop_closure(self, ctx: ScheduleContext, view: LinkView, pod: Task,
                      node_name: str) -> Tuple[bool, List[str]]:
        """Per-candidate dependency-loop closure, computed once per Score
        phase (score_nodes pre-computes it; a direct score() call fills the
        same per-context slot).  The candidate-independent contending pairs
        are shared across candidates via the context."""
        store = ctx.cache.setdefault("loop_closure", {})
        if node_name not in store:
            base = ctx.cache.get("base_pairs")
            if base is None:
                base_view = LinkView(view.cluster, view._tasks)
                base = {l: base_view.contending_pairs(l)
                        for l in base_view.planning_links()}
                ctx.cache["base_pairs"] = base
            store[node_name] = self._dependency_loop_closure(view, pod, base)
        return store[node_name]

    def score(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
              node_name: str, registry: TaskRegistry) -> float:
        schemes: Dict[str, Dict[str, LinkScheme]] = ctx.cache.setdefault(
            "schemes", {})
        rot_scores: Dict[str, float] = ctx.cache.setdefault("rot_score", {})

        # early return 1: LowComm pod — communication need not be guaranteed
        if pod.low_comm:
            ctx.cache.setdefault("early", {})[node_name] = True
            rot_scores[node_name] = PERFECT
            return PERFECT

        # the planner's fast feasible path over every link the placement
        # would traverse: host link + uplinks, solved per link and resolved
        # jointly when the per-link solutions conflict; the node's
        # bandwidth score is the worst link score
        view = self._candidate_view(cluster, pod, node_name, registry)
        links = self._candidate_links(cluster, view, pod, node_name)
        plan = rotation.plan(
            view, registry, links=links, self_job=pod.job, mode="fast",
            demand="planning", di_pre=self.di_pre, g_t_ms=self.g_t_ms,
            e_t_frac=self.e_t_frac, rotation_mode=self.rotation_mode,
            joint=self.joint, cache=self.plan_cache,
        )
        link_schemes = plan.schemes
        worst = plan.score

        if not link_schemes:
            # no contention on any traversed link — still prefer intra-leaf
            # placements before any uplink rotation is even needed
            ctx.cache.setdefault("early", {})[node_name] = True
            rot_scores[node_name] = PERFECT
            return (PERFECT - self._rack_penalty(view, pod)
                    - self._fluct_penalty(cluster, view, pod, node_name))

        # cross-link dependency loop: the per-link rotations cannot be made
        # globally consistent by offset translation alone.  With the joint
        # planner the cycle is SOLVABLE: re-plan over the affinity
        # component's full link closure and let the joint score speak (a
        # genuinely infeasible cycle scores below perfect by itself).  In
        # legacy mode (joint=False) keep the old cap below perfect so
        # loop-free placements win ties.  The schemes keep the RAW rotation
        # scores either way: the controller's realign guard needs to know
        # whether an interleave actually exists on each link.
        loop, closure = self._loop_closure(ctx, view, pod, node_name)
        if loop:
            if self.joint:
                wanted = set(closure) | set(links)
                plan_links = [l for l in view.planning_links() if l in wanted]
                jplan = rotation.plan(
                    view, registry, links=plan_links,
                    self_job=pod.job, mode="fast", demand="planning",
                    di_pre=self.di_pre, g_t_ms=self.g_t_ms,
                    e_t_frac=self.e_t_frac, rotation_mode=self.rotation_mode,
                    joint=True, cache=self.plan_cache,
                )
                if jplan.schemes:
                    link_schemes = jplan.schemes
                    worst = jplan.score
            else:
                worst = min(worst, 99.0)

        schemes[node_name] = link_schemes
        ctx.cache.setdefault("early", {})[node_name] = False
        # the raw rotation score drives SkipPhaseThree (Reserve); the rack
        # penalty only demotes the NODE choice
        rot_scores[node_name] = float(worst)
        return float(max(0.0, worst - self._rack_penalty(view, pod)
                         - self._fluct_penalty(cluster, view, pod,
                                               node_name)))

    def score_nodes(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
                    nodes: List[str],
                    registry: TaskRegistry) -> Dict[str, float]:
        """Score every surviving candidate in one batched pass.

        A pre-pass mirrors :meth:`score`'s planning decisions per candidate
        — per-link solves (memoized, so a link untouched by the candidate
        delta is solved ONCE for all N candidates) and the dependency-loop
        closure analysis — then hands EVERY conflicted component of every
        candidate to :func:`rotation.joint_solve_batch`, which scores each
        problem family's whole combo space in shared batched evaluations
        (one stacked (C, L, R, S) kernel dispatch under
        ``backend='kernel'``).  The per-candidate :meth:`score` calls that
        follow hit the warmed cache, so results are bit-for-bit those of
        the sequential path."""
        if (self.plan_cache is not None and self.joint and not pod.low_comm
                and len(nodes) > 1):
            self._warm_candidates(ctx, cluster, pod, nodes, registry)
        return {n: self.score(ctx, cluster, pod, n, registry)
                for n in nodes}

    def _warm_candidates(self, ctx: ScheduleContext, cluster: Cluster,
                         pod: Task, nodes: List[str],
                         registry: TaskRegistry) -> None:
        """Collect every per-link AND joint problem the per-candidate Score
        pass will solve and batch-solve them into the plan cache.

        Stage 1 gathers the per-link solves of every loop candidate and
        hands them to :func:`rotation.solve_link_batch` — one shared
        enumeration pass per problem family (candidates repeat the same
        link problems away from their delta, so families are large).
        Stage 2 walks the solved schemes' conflicted components into
        :func:`rotation.joint_solve_batch` exactly as before."""
        cand = []
        link_specs = []
        for node_name in nodes:
            view = self._candidate_view(cluster, pod, node_name, registry)
            links = self._candidate_links(cluster, view, pod, node_name)
            loop, closure = self._loop_closure(ctx, view, pod, node_name)
            if not loop:
                continue
            wanted = set(closure) | set(links)
            plan_links = [l for l in view.planning_links() if l in wanted]
            cand.append((view, plan_links))
            link_specs.extend((view, lid) for lid in plan_links)
        if not cand:
            return
        solved = rotation.solve_link_batch(
            link_specs, registry, self_job=pod.job, mode="fast",
            demand="planning", di_pre=self.di_pre, g_t_ms=self.g_t_ms,
            e_t_frac=self.e_t_frac, rotation_mode=self.rotation_mode,
            cache=self.plan_cache,
        )
        specs = []
        pos = 0
        for view, plan_links in cand:
            schemes: Dict[str, LinkScheme] = {}
            for lid in plan_links:
                _score, scheme = solved[pos]
                pos += 1
                if scheme is not None:
                    schemes[lid] = scheme
            if len(schemes) < 2:
                continue  # plan() will not resolve, nothing joint to warm
            for comp_links, conflicted in rotation.conflicted_components(
                    schemes, self.di_pre):
                if conflicted:
                    specs.append((view, comp_links))
        if specs:
            rotation.joint_solve_batch(
                specs, registry, mode="fast", demand="planning",
                rotation_mode=self.rotation_mode, di_pre=self.di_pre,
                g_t_ms=self.g_t_ms, e_t_frac=self.e_t_frac,
                cache=self.plan_cache,
            )

    def _fluct_penalty(self, cluster: Cluster, view: LinkView, pod: Task,
                       node_name: str) -> float:
        """Reconfiguration-aware Score penalty: worst observed-fluctuation
        CV over the links the candidate placement would traverse, scaled
        by ``FLUCTUATION_PENALTY_SCALE``.  Exactly 0.0 on a plain
        :class:`Cluster` (no ``fluctuation`` history — the oracle path),
        so the seed's scores are untouched bit-for-bit."""
        fluct = getattr(cluster, "fluctuation", None)
        if fluct is None:
            return 0.0
        worst = 0.0
        for l in self._candidate_links(cluster, view, pod, node_name):
            worst = max(worst, fluct(l))
        return FLUCTUATION_PENALTY_SCALE * min(1.0, worst)

    def _rack_penalty(self, view: LinkView, pod: Task) -> float:
        """Rack-locality Score bonus (inverted as a penalty): demote
        candidates that make the pod's job traverse a spine uplink.  When
        the job spans leaves regardless of this pod, every candidate pays
        equally and the preference is a no-op; on star topologies no uplink
        exists and the penalty is always zero."""
        if view.traversed_uplinks(pod.job):
            return RACK_LOCALITY_PENALTY
        return 0.0

    # -------------------------------------------------------- NormalizeScore
    def normalize_scores(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
                         scores: Dict[str, float],
                         registry: TaskRegistry) -> Dict[str, float]:
        max_score = max(scores.values())
        ctx.cache["max_score"] = max_score
        candidates = [n for n, s in scores.items() if s >= max_score - 1e-9]
        if len(candidates) == 1:
            return scores
        # 2nd optimization stage: Eq. 19 reverse-mapped latency among the
        # bandwidth-optimal candidates; all other nodes are zeroed.
        delta = ctx.cache["delta"]
        dvals = [delta[n] for n in candidates]
        dmin, dmax = min(dvals), max(dvals)
        out = {n: 0.0 for n in scores}
        for n in candidates:
            if dmax != dmin:
                norm = 100.0 - math.floor(100.0 * (delta[n] - dmin) / (dmax - dmin))
            else:
                norm = 100.0 - (delta[n] - dmin)
            if pod.low_comm:
                # LowComm pods take the WORST network location
                out[n] = 100.0 - norm
            else:
                out[n] = norm
        return out

    # ---------------------------------------------------------------- Reserve
    def reserve(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
                node_name: str, registry: TaskRegistry) -> None:
        all_schemes: Dict[str, Dict[str, LinkScheme]] = ctx.cache.get(
            "schemes", {})
        early = ctx.cache.get("early", {}).get(node_name, True)
        # the raw (pre-rack-penalty) rotation scores decide SkipPhaseThree;
        # the best candidate's raw score says whether contention was
        # avoidable at all
        rot_scores = ctx.cache.get("rot_score", {})
        max_score = max(rot_scores.values()) if rot_scores else PERFECT
        link_schemes = {} if early else all_schemes.get(node_name, {})

        # per-link SkipPhaseThree (Alg. 1): skip when the best node is
        # imperfect (unavoidable contention) or the link carries only 2 jobs
        # (the intermediate rotation is already optimal)
        skips: Dict[str, bool] = {}
        for link_id, scheme in link_schemes.items():
            skips[link_id] = bool(
                max_score < PERFECT - 1e-9 or len(scheme.jobs) == 2
            )
        skip = bool(early or all(skips.values()))

        shifts_ms: Dict[str, float] = {}
        host_scheme = link_schemes.get(node_name)
        if host_scheme is not None:
            delays = geometry.shifts_to_delay_ms(
                host_scheme.shifts_slots, host_scheme.base_ms, self.di_pre
            )
            shifts_ms = {j: float(d) for j, d in zip(host_scheme.jobs, delays)}

        msg = ReserveMessage(node=node_name, schemes=link_schemes,
                             shifts_ms=shifts_ms, skip_phase_three=skip,
                             skips=skips)
        self.messages.append(msg)
        if self.controller is not None:
            self.controller.on_schedule(cluster, registry, msg)

    def unreserve(self, cluster: Cluster, pod: Task, node_name: str,
                  registry: TaskRegistry) -> None:
        if self.controller is not None:
            self.controller.on_evict(node_name, pod, registry=registry,
                                     cluster=cluster)

"""The Metronome scheduler plugin — Algorithm 1 of the paper.

Implements the five extension points:

  PreFilter      : latency score Delta_n per node + resource caching
  Filter         : dependency-loop, CPU/MEM/GPU and bandwidth (Eq. 13-14)
  Score          : Eq. 18 over rotation schemes (1st opt. stage + Eqs. 15-17)
  NormalizeScore : Eq. 19 latency tie-break (2nd opt. stage)
  Reserve        : state update + SEND(shifts, SkipPhaseThree) to controller
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import networkx as nx
import numpy as np

from . import geometry, scoring
from .cluster import Cluster
from .contention import LinkView, group_demand_gbps
from .framework import ScheduleContext, SchedulerPlugin, TaskRegistry
from .geometry import DI_PRE
from .workload import Task

PERFECT = 100.0


@dataclasses.dataclass
class LinkScheme:
    """Result of the Score phase for one candidate node's host link."""

    jobs: List[str]  # job order used in the rotation problem
    shifts_slots: np.ndarray  # theta per job (slots)
    base_ms: float
    muls: np.ndarray
    score: float
    early_return: bool
    injected_ms: Dict[str, float]  # E_T idle injection per job
    ref_job: str = ""


@dataclasses.dataclass
class ReserveMessage:
    """What Reserve SENDs to the stop-and-wait controller (Alg. 1 line 40).

    ``schemes`` maps every link the placement traverses and contends on
    (host link id == node name; uplinks ``uplink:<leaf>``) to its rotation
    scheme. ``skips`` carries the per-link SkipPhaseThree flag;
    ``skip_phase_three`` aggregates it (True when no link needs the offline
    3rd-stage recalculation)."""

    node: str
    schemes: Dict[str, LinkScheme]
    shifts_ms: Dict[str, float]
    skip_phase_three: bool
    skips: Dict[str, bool] = dataclasses.field(default_factory=dict)


class MetronomePlugin(SchedulerPlugin):
    name = "metronome"

    def __init__(
        self,
        controller=None,
        *,
        g_t_ms: float = 5.0,
        e_t_frac: float = 0.10,
        di_pre: int = DI_PRE,
        rotation_mode: str = "intermediate",  # 'compact' = stage-3 ablation
    ) -> None:
        self.controller = controller
        self.g_t_ms = g_t_ms
        self.e_t_frac = e_t_frac
        self.di_pre = di_pre
        self.rotation_mode = rotation_mode
        self.messages: List[ReserveMessage] = []

    # ------------------------------------------------------------------ utils
    def _candidate_view(self, cluster: Cluster, pod: Task, node_name: str,
                        registry: TaskRegistry) -> LinkView:
        """The unified demand view with ``pod`` provisionally on ``node_name``
        (the single source of truth for groupings/demand — contention.py)."""
        return LinkView.from_registry(cluster, registry, extra=pod,
                                      extra_node=node_name)

    def _priority_order(self, registry: TaskRegistry, jobs: Sequence[str]) -> List[str]:
        """Sort jobs by (priority desc, deployment order asc)."""
        def key(j: str):
            job = registry.jobs.get(j)
            prio = job.priority if job else 0
            sub = job.submit_time_s if job else 0.0
            return (-prio, sub, j)
        return sorted(jobs, key=key)

    # -------------------------------------------------------------- PreFilter
    def pre_filter(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
                   registry: TaskRegistry) -> None:
        """CALCULATELATENCYSCORE for every node + cache resources."""
        deps = registry.dependencies_of(pod)
        deployed_deps = [t for t in deps if t.node is not None]
        delta: Dict[str, float] = {}
        for n in cluster.node_names:
            total = sum(cluster.tau(n, t.node) for t in deployed_deps)
            if total == 0.0:
                # LowComm pod or no deployed dependency: use average latency
                # between the candidate node and all nodes in the cluster.
                total = float(np.mean([cluster.tau(n, m) for m in cluster.node_names]))
            delta[n] = total
        ctx.cache["delta"] = delta
        ctx.cache["deployed_deps"] = deployed_deps

    # ----------------------------------------------------------------- Filter
    def filter(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
               node_name: str, registry: TaskRegistry) -> bool:
        node = cluster.node(node_name)
        # resources (Eq. 13)
        if not pod.resources.fits_in(node.free):
            return False
        # bandwidth capacity (Eq. 14), on EVERY link the pod's flows would
        # traverse: the host link, plus the candidate leaf's uplink when the
        # placement makes the pod's job span leaves
        if pod.traffic.bw_gbps > node.alloc_bw:
            return False
        topo = cluster.topology
        if not topo.is_star and not pod.low_comm:
            view = self._candidate_view(cluster, pod, node_name, registry)
            if topo.leaf_of[node_name] in view.traversed_uplinks(pod.job):
                up = topo.uplink_of(node_name)
                if up is not None and pod.traffic.bw_gbps > up.alloc_bw:
                    return False
        # Dependency loops (Cassini) are handled at the Score phase: on a
        # loaded cluster a hard filter would leave pods unschedulable, and
        # the paper's own section V prescribes scoring toward less-contended
        # nodes instead. The loop check caps the node's score below perfect
        # so loop-free placements always win ties (see score()).
        return True

    def _creates_dependency_loop(self, view: LinkView, pod: Task) -> bool:
        """Cassini's affinity-loop filter, restricted to edges that matter.

        Only *contending* pairs (the LinkView's Eq. 9 predicate: combined
        demand exceeding the link's allocatable capacity) constrain
        relative rotations; sub-capacity co-location imposes nothing. And a
        pre-existing loop between other jobs is not this pod's problem: we
        reject the node only when the NEW placement closes a cross-link
        cycle through the pod's own job.
        """
        g = nx.Graph()
        for link_id in view.planning_links():
            for a, b in view.contending_pairs(link_id):
                if g.has_edge(a, b):
                    g[a][b]["links"].add(link_id)
                else:
                    g.add_edge(a, b, links={link_id})
        # a 2-job multi-link relation needs only one relative shift, which
        # the controller resolves deterministically (uplink schemes take
        # precedence when per-link solutions differ); cross-link cycles of
        # length >= 3 THROUGH THIS JOB prevent a consistent global offset.
        if pod.job not in g:
            return False
        try:
            for cyc in nx.cycle_basis(g, pod.job):
                if len(cyc) < 3 or pod.job not in cyc:
                    continue
                common = None
                for a, b in zip(cyc, cyc[1:] + cyc[:1]):
                    links = g[a][b]["links"]
                    common = set(links) if common is None else common & links
                if not common:
                    return True
        except nx.NetworkXError:
            pass
        return False

    # ------------------------------------------------------------------ Score
    def _score_link(self, registry: TaskRegistry, groups: Dict[str, List[Task]],
                    cap: float, self_job: str
                    ) -> Tuple[float, Optional[LinkScheme]]:
        """Rotation-feasibility score of one link under ``groups`` (job ->
        its tasks sourcing traffic onto the link). Returns (score, scheme);
        scheme is None on the early-return paths (no contention to solve)."""
        total_bw = sum(group_demand_gbps(ts) for ts in groups.values())
        only_self = list(groups.keys()) == [self_job]
        # early return: empty link or aggregate demand within capacity
        if not groups or only_self or total_bw <= cap:
            return PERFECT, None

        # --- two-dimensional bandwidth scheduling: interleave phases -------
        jobs = self._priority_order(registry, groups.keys())
        ref_index = 0  # highest priority (ties: earliest) — Eq. 16
        periods = []
        prios = []
        for j in jobs:
            ts = groups[j]
            periods.append(ts[0].traffic.period_ms)
            job = registry.jobs.get(j)
            prios.append(job.priority if job else 0)
        unified = geometry.unify_periods(
            periods, prios, g_t_ms=self.g_t_ms, e_t_frac=self.e_t_frac
        )
        duties = []
        bws = []
        for idx, j in enumerate(jobs):
            ts = groups[j]
            spec = ts[0].traffic
            # idle injection stretches the period -> duty shrinks (comm time
            # m_p is unchanged); this is the E_T mechanism's second insight.
            eff_period = unified.periods_ms[idx]
            duties.append(min(1.0, spec.comm_ms / eff_period))
            bws.append(group_demand_gbps(ts))
        patterns = geometry.pattern_matrix(unified.muls, duties, self.di_pre)
        result = scoring.find_feasible_rotation(
            patterns, bws, cap, unified.muls, ref_index,
            self.di_pre, mode=self.rotation_mode,
        )
        scheme = LinkScheme(
            jobs=jobs,
            shifts_slots=result.shifts,
            base_ms=unified.base_ms,
            muls=unified.muls,
            score=float(result.score),
            early_return=False,
            injected_ms={j: float(unified.injected_ms[i]) for i, j in enumerate(jobs)},
            ref_job=jobs[ref_index],
        )
        return float(result.score), scheme

    def score(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
              node_name: str, registry: TaskRegistry) -> float:
        node = cluster.node(node_name)
        schemes: Dict[str, Dict[str, LinkScheme]] = ctx.cache.setdefault(
            "schemes", {})

        # early return 1: LowComm pod — communication need not be guaranteed
        if pod.low_comm:
            ctx.cache.setdefault("early", {})[node_name] = True
            return PERFECT

        # every link the placement would traverse gets its own rotation
        # problem; the node's bandwidth score is the worst of them
        view = self._candidate_view(cluster, pod, node_name, registry)
        link_schemes: Dict[str, LinkScheme] = {}
        worst, host_scheme = self._score_link(
            registry, view.host_groups(node_name), node.alloc_bw, pod.job)
        if host_scheme is not None:
            link_schemes[node_name] = host_scheme
        for leaf in view.traversed_uplinks(pod.job):
            up = cluster.topology.uplinks[leaf]
            uscore, uscheme = self._score_link(
                registry, view.uplink_groups(leaf), up.alloc_bw, pod.job)
            worst = min(worst, uscore)
            if uscheme is not None:
                link_schemes[up.id] = uscheme

        if not link_schemes:
            # no contention on any traversed link
            ctx.cache.setdefault("early", {})[node_name] = True
            return PERFECT

        # cross-link dependency loop: the computed rotation cannot be made
        # globally consistent -> cap below perfect (loop-free nodes win).
        # The schemes keep the RAW rotation scores: the loop cap only
        # demotes the NODE choice; the controller's realign guard needs to
        # know whether an interleave actually exists on each link.
        if self._creates_dependency_loop(view, pod):
            worst = min(worst, 99.0)

        schemes[node_name] = link_schemes
        ctx.cache.setdefault("early", {})[node_name] = False
        return float(worst)

    # -------------------------------------------------------- NormalizeScore
    def normalize_scores(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
                         scores: Dict[str, float],
                         registry: TaskRegistry) -> Dict[str, float]:
        max_score = max(scores.values())
        ctx.cache["max_score"] = max_score
        candidates = [n for n, s in scores.items() if s >= max_score - 1e-9]
        if len(candidates) == 1:
            return scores
        # 2nd optimization stage: Eq. 19 reverse-mapped latency among the
        # bandwidth-optimal candidates; all other nodes are zeroed.
        delta = ctx.cache["delta"]
        dvals = [delta[n] for n in candidates]
        dmin, dmax = min(dvals), max(dvals)
        out = {n: 0.0 for n in scores}
        for n in candidates:
            if dmax != dmin:
                norm = 100.0 - math.floor(100.0 * (delta[n] - dmin) / (dmax - dmin))
            else:
                norm = 100.0 - (delta[n] - dmin)
            if pod.low_comm:
                # LowComm pods take the WORST network location
                out[n] = 100.0 - norm
            else:
                out[n] = norm
        return out

    # ---------------------------------------------------------------- Reserve
    def reserve(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
                node_name: str, registry: TaskRegistry) -> None:
        all_schemes: Dict[str, Dict[str, LinkScheme]] = ctx.cache.get(
            "schemes", {})
        early = ctx.cache.get("early", {}).get(node_name, True)
        max_score = ctx.cache.get("max_score", PERFECT)
        link_schemes = {} if early else all_schemes.get(node_name, {})

        # per-link SkipPhaseThree (Alg. 1): skip when the best node is
        # imperfect (unavoidable contention) or the link carries only 2 jobs
        # (the intermediate rotation is already optimal)
        skips: Dict[str, bool] = {}
        for link_id, scheme in link_schemes.items():
            skips[link_id] = bool(
                max_score < PERFECT - 1e-9 or len(scheme.jobs) == 2
            )
        skip = bool(early or all(skips.values()))

        shifts_ms: Dict[str, float] = {}
        host_scheme = link_schemes.get(node_name)
        if host_scheme is not None:
            delays = geometry.shifts_to_delay_ms(
                host_scheme.shifts_slots, host_scheme.base_ms, self.di_pre
            )
            shifts_ms = {j: float(d) for j, d in zip(host_scheme.jobs, delays)}

        msg = ReserveMessage(node=node_name, schemes=link_schemes,
                             shifts_ms=shifts_ms, skip_phase_three=skip,
                             skips=skips)
        self.messages.append(msg)
        if self.controller is not None:
            self.controller.on_schedule(cluster, registry, msg)

    def unreserve(self, cluster: Cluster, pod: Task, node_name: str,
                  registry: TaskRegistry) -> None:
        if self.controller is not None:
            self.controller.on_evict(node_name, pod)

"""Backend-swappable fluid rate engine (progressive-filling max-min fairness).

The rate-sharing core of the event-driven simulator, refactored out of
``ClusterSimulator`` so production-scale traces (10k+ jobs) can swap the
per-flow Python loop for a batched vectorized solve:

  * ``backend='python'`` — the seed's per-flow loop, verbatim, as the
    golden oracle: per-link water filling when every path is a single host
    link (the star topology), global progressive filling otherwise.
    Bit-for-bit identical to the historical ``ClusterSimulator`` path.
  * ``backend='jnp'`` — the fill expressed as a fixed point over a
    (flows x links) demand/route matrix, solved by the jit'd jnp oracle
    (``kernels.ref.progressive_fill_ref``), float32.
  * ``backend='kernel'`` — same matrix form through the
    ``kernels.ops.progressive_fill`` dispatch: compiled Pallas on a real
    TPU, the jit'd jnp oracle anywhere else (this CPU container).

The matrix form: routes[f, l] = 1 iff flow f's path crosses link l.  Each
round every unfrozen flow grows by the same increment — the minimum over
remaining per-flow headroom and remaining per-link capacity divided by the
link's active-flow count — and flows freeze when their demand is met or a
path link saturates.  This is exactly the per-flow loop's round structure,
so the vectorized backends agree with the oracle up to float32 tolerance.

Incremental recomputation rides the PR 5 epoch machinery: flows partition
into link-connected *affinity components* (two flows are connected when
their paths share a link), each component's allocation depends only on its
own demands and link capacities, and the engine memoizes per-component
solutions under a content key.  A dynamic-environment event (background
ramp, capacity change, departure) therefore re-fills only the component it
touches — the others hit the memo.  The python backend keeps incremental
mode OFF by default: the global progressive fill couples components through
the shared increment's float partial sums, so per-component solving is
equivalent mathematically but not bit-for-bit, and ``backend='python'``
must reproduce the seed exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

EPS = 1e-9

BACKENDS = ("python", "jnp", "kernel")


# ---------------------------------------------------------------------------
# golden oracle: the seed's per-flow loop, verbatim
# ---------------------------------------------------------------------------

def _progressive_fill(
    demands: np.ndarray,
    paths: Sequence[Sequence[str]],
    caps: Dict[str, float],
) -> np.ndarray:
    """Progressive-filling max-min fairness over multi-link flow paths.

    All unfrozen flows grow at the same rate; a flow freezes when it reaches
    its demand or when any link on its path saturates (that link becomes its
    bottleneck). Reduces to per-link water filling when every path is a
    single link. Runs in O((flows + links) * flows).
    """
    n = len(demands)
    rates = np.zeros(n)
    if n == 0:
        return rates
    remaining = dict(caps)
    active = [i for i in range(n) if demands[i] > EPS]
    # flows on a zero-capacity link can never send
    while active:
        counts: Dict[str, int] = {}
        for i in active:
            for l in paths[i]:
                counts[l] = counts.get(l, 0) + 1
        inc = min(demands[i] - rates[i] for i in active)
        for l, c in counts.items():
            inc = min(inc, remaining[l] / c)
        inc = max(0.0, inc)
        for i in active:
            rates[i] += inc
        for l, c in counts.items():
            remaining[l] -= inc * c
        nxt = []
        for i in active:
            if rates[i] >= demands[i] - EPS:
                continue  # demand met
            if any(remaining[l] <= EPS for l in paths[i]):
                continue  # bottleneck link saturated
            nxt.append(i)
        if len(nxt) == len(active):  # pragma: no cover — defensive
            break
        active = nxt
    return rates


def _max_min_fair(demands: np.ndarray, capacity: float) -> np.ndarray:
    """Water-filling max-min fair allocation, each flow capped at its demand."""
    n = len(demands)
    if n == 0:
        return demands
    if demands.sum() <= capacity:
        return demands.copy()
    rates = np.zeros(n)
    remaining = capacity
    order = np.argsort(demands)
    left = n
    for idx in order:
        fair = remaining / left
        give = min(demands[idx], fair)
        rates[idx] = give
        remaining -= give
        left -= 1
    return rates


def fill_python(
    demands: np.ndarray,
    paths: Sequence[Tuple[str, ...]],
    caps: Dict[str, float],
) -> np.ndarray:
    """The golden-oracle solve of one fill problem (float64, per-flow loop).

    Mirrors the seed's ``_assign_rates`` dispatch exactly: all-single-link
    problems take the per-link water-filling fast path, anything else the
    global progressive fill."""
    demands = np.asarray(demands, dtype=float)
    if all(len(p) == 1 for p in paths):
        rates = np.zeros(len(demands))
        by_link: Dict[str, List[int]] = {}
        for i, p in enumerate(paths):
            by_link.setdefault(p[0], []).append(i)
        for link_id, idxs in by_link.items():
            sub = _max_min_fair(demands[idxs], caps[link_id])
            for i, r in zip(idxs, sub):
                rates[i] = float(r)
        return rates
    return _progressive_fill(demands, paths, caps)


# ---------------------------------------------------------------------------
# (flows x links) matrix form
# ---------------------------------------------------------------------------

def problem_matrix(
    demands: Sequence[float],
    paths: Sequence[Tuple[str, ...]],
    caps: Dict[str, float],
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, List[str]]:
    """Build the (flows x links) demand/route matrix of one fill problem.

    Links are ordered by first appearance over the flows' paths, so the
    matrix is deterministic for a given flow ordering.  Returns
    ``(demands (F,), routes (F, L), cap_vec (L,), link_ids)``."""
    link_ids: List[str] = []
    index: Dict[str, int] = {}
    for p in paths:
        for l in p:
            if l not in index:
                index[l] = len(link_ids)
                link_ids.append(l)
    f, l = len(paths), len(link_ids)
    routes = np.zeros((f, max(l, 1)), dtype=np.float32)
    for i, p in enumerate(paths):
        for lid in p:
            routes[i, index[lid]] = 1.0
    d = np.asarray(demands, dtype=np.float32)
    cap_vec = np.asarray([caps[lid] for lid in link_ids] or [1.0],
                         dtype=np.float32)
    return d, routes, cap_vec, link_ids


@dataclasses.dataclass
class CorpusStats:
    """Bucket occupancy / padding waste of batched corpus fills.

    Every :func:`fill_corpus` call with ``stats=`` accumulates how many
    (flow, link) matrix slots it actually dispatched versus how many were
    real problem content, so batching losses are visible per run instead of
    silent (ISSUE 7 satellite): ``occupancy`` near 1.0 means the buckets are
    tight; a low value means shape rounding / batch padding dominates."""

    calls: int = 0      # fill_corpus invocations
    problems: int = 0   # real problems solved (excl. batch-padding dummies)
    buckets: int = 0    # batched dispatches (fill_many calls)
    flow_used: int = 0  # real flow slots across all problems
    flow_slots: int = 0  # dispatched flow slots (B_pad x F_pad summed)
    link_used: int = 0
    link_slots: int = 0

    @property
    def flow_occupancy(self) -> float:
        return self.flow_used / self.flow_slots if self.flow_slots else 1.0

    @property
    def link_occupancy(self) -> float:
        return self.link_used / self.link_slots if self.link_slots else 1.0

    def as_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["flow_occupancy"] = self.flow_occupancy
        d["link_occupancy"] = self.link_occupancy
        return d


def _round_pow2(n: int, floor: int = 4) -> int:
    """Smallest power of two >= max(n, floor) (jit-cache shape bucketing)."""
    p = floor
    while p < n:
        p <<= 1
    return p


def fill_many(
    problems: Sequence[Tuple[np.ndarray, np.ndarray, np.ndarray]],
    *,
    backend: str = "jnp",
    interpret: Optional[bool] = None,
    pad_to: Optional[Tuple[int, int]] = None,
) -> List[np.ndarray]:
    """Solve many fill problems in ONE batched dispatch.

    ``problems``: a list of ``(demands (F_i,), routes (F_i, L_i), caps
    (L_i,))`` matrices (see :func:`problem_matrix`).  Problems are padded to
    a common (B, F_max, L_max) block — zero-demand flows never activate and
    zero-route unit-capacity links never saturate, so padding is neutral —
    and solved by the vectorized backend in a single call.  Returns the
    unpadded per-problem rate vectors.

    ``pad_to=(F, L)`` raises the pad shape beyond the batch maximum so
    repeated calls with similar problems land on a fixed set of jit-compiled
    shapes (the event-loop steady state) instead of recompiling per tick.

    This is the production-trace throughput path: thousands of active-set
    snapshots of a 10k-job trace fill together instead of one per-flow
    Python loop each (``benchmarks/bench_trace_throughput.py``)."""
    if backend not in ("jnp", "kernel"):
        raise ValueError(f"fill_many wants a vectorized backend, got {backend!r}")
    if not problems:
        return []
    from repro.kernels import ops as kops  # deferred: core stays jax-free

    b = len(problems)
    f_max = max(max(p[0].shape[0] for p in problems), 1)
    l_max = max(max(p[2].shape[0] for p in problems), 1)
    if pad_to is not None:
        f_max = max(f_max, int(pad_to[0]))
        l_max = max(l_max, int(pad_to[1]))
    d = np.zeros((b, f_max), dtype=np.float32)
    routes = np.zeros((b, f_max, l_max), dtype=np.float32)
    caps = np.ones((b, l_max), dtype=np.float32)
    for i, (di, ri, ci) in enumerate(problems):
        fi, li = ri.shape
        d[i, :fi] = di
        routes[i, :fi, :li] = ri
        caps[i, :li] = ci
    if backend == "jnp" and interpret is None:
        out = kops.progressive_fill_ref(d, routes, caps)
    else:
        out = kops.progressive_fill(d, routes, caps, interpret=interpret)
    return [np.asarray(out[i, : p[0].shape[0]], dtype=float)
            for i, p in enumerate(problems)]


def fill_corpus(
    problems: Sequence[Tuple[np.ndarray, np.ndarray, np.ndarray]],
    *,
    backend: str = "jnp",
    interpret: Optional[bool] = None,
    chunk: int = 64,
    bucket_shapes: bool = False,
    stats: Optional[CorpusStats] = None,
) -> List[np.ndarray]:
    """Solve a large, ragged fill-problem corpus with size-bucketed batches.

    :func:`fill_many` pads every problem to the corpus-wide ``(F_max,
    L_max)``, so one 1200-flow peak snapshot makes every off-peak snapshot
    pay 1200-flow einsums.  Here problems are sorted by flow count and
    dispatched in ``chunk``-sized buckets (each padded only to its own
    maximum), which keeps the padding waste near zero on diurnal traces
    where the active set swings several-fold.  Results come back in the
    caller's order.

    ``bucket_shapes=True`` additionally rounds every bucket's (B, F, L) up
    to fixed sizes (full ``chunk`` batches, power-of-two flow/link counts)
    so a long-lived caller — the simulator's event loop re-solving dirty
    components every tick — cycles through a handful of compiled shapes
    instead of jit-recompiling whenever the active set grows by one flow.
    Batch padding uses neutral dummy problems (one zero-demand flow).

    ``stats`` (a :class:`CorpusStats`) accumulates bucket occupancy /
    padding waste so the batching losses are observable per run."""
    if not problems:
        return []
    order = sorted(range(len(problems)), key=lambda i: problems[i][0].shape[0])
    out: List[Optional[np.ndarray]] = [None] * len(problems)
    chunk = max(1, int(chunk))
    if stats is not None:
        stats.calls += 1
        stats.problems += len(problems)
        stats.flow_used += sum(p[0].shape[0] for p in problems)
        stats.link_used += sum(p[2].shape[0] for p in problems)
    dummy = (np.zeros(1, dtype=np.float32),
             np.zeros((1, 1), dtype=np.float32),
             np.ones(1, dtype=np.float32))
    for s in range(0, len(order), chunk):
        idx = order[s:s + chunk]
        batch = [problems[i] for i in idx]
        pad_to = None
        if bucket_shapes:
            pad_to = (_round_pow2(max(p[0].shape[0] for p in batch)),
                      _round_pow2(max(p[2].shape[0] for p in batch)))
            batch = batch + [dummy] * (chunk - len(batch))
        rates = fill_many(batch, backend=backend, interpret=interpret,
                          pad_to=pad_to)
        if stats is not None:
            stats.buckets += 1
            f_pad = pad_to[0] if pad_to else max(
                max(p[0].shape[0] for p in batch), 1)
            l_pad = pad_to[1] if pad_to else max(
                max(p[2].shape[0] for p in batch), 1)
            stats.flow_slots += len(batch) * f_pad
            stats.link_slots += len(batch) * l_pad
        for i, r in zip(idx, rates):
            out[i] = r
    return out  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# affinity components (incremental re-fill)
# ---------------------------------------------------------------------------

def _first_seen_links(paths: Sequence[Tuple[str, ...]]) -> List[str]:
    """Link ids in first-appearance order over the flows' paths (the
    deterministic link ordering of memo keys and problem matrices)."""
    seen = set()
    out: List[str] = []
    for p in paths:
        for l in p:
            if l not in seen:
                seen.add(l)
                out.append(l)
    return out


def affinity_components(paths: Sequence[Tuple[str, ...]]) -> List[List[int]]:
    """Partition flows into link-connected components (union-find over the
    links their paths cross).  Components are ordered by their first flow's
    index; flows keep their relative order inside each component."""
    parent: Dict[str, str] = {}

    def find(x: str) -> str:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    for p in paths:
        for l in p:
            parent.setdefault(l, l)
        for l in p[1:]:
            parent[find(p[0])] = find(l)
    comps: Dict[str, List[int]] = {}
    order: List[str] = []
    for i, p in enumerate(paths):
        root = find(p[0])
        if root not in comps:
            comps[root] = []
            order.append(root)
        comps[root].append(i)
    return [comps[r] for r in order]


@dataclasses.dataclass
class FluidStats:
    """Memo counters of one engine (incremental re-fill observability)."""

    hits: int = 0
    misses: int = 0
    solves: int = 0  # non-incremental full solves


class FluidEngine:
    """Backend-swappable progressive-filling engine.

    ``assign(flows, cap_of)`` sets ``flow.rate_gbps`` on every flow object
    (anything with ``demand_gbps`` / ``links`` / ``rate_gbps`` attributes,
    e.g. the simulator's ``FlowState``) given a per-link allocatable
    capacity function.

    ``incremental=None`` picks the backend default: OFF for ``python``
    (the global solve is the bit-for-bit seed path — see the module
    docstring) and ON for the vectorized backends, where each affinity
    component's solution is memoized under a content key of its demands,
    paths and link capacities.  An event that touches one component leaves
    every other component's key — and therefore its memoized rates —
    intact."""

    def __init__(self, backend: str = "python",
                 incremental: Optional[bool] = None,
                 memo_max: int = 4096) -> None:
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown fluid backend {backend!r}; expected one of {BACKENDS}")
        self.backend = backend
        self.incremental = (backend != "python") if incremental is None \
            else bool(incremental)
        self.memo_max = int(memo_max)
        self._memo: Dict[tuple, np.ndarray] = {}
        self.stats = FluidStats()
        self.corpus_stats = CorpusStats()
        # oracle-parity sampling (bench_dynamic_throughput): with
        # sample_stride > 0 every stride-th solve_batch problem is kept as
        # (demands, paths, caps, rates) for offline fill_python comparison
        self.sample_stride = 0
        self.sample_max = 512
        self.samples: List[tuple] = []
        self._sample_seen = 0

    # ------------------------------------------------------------- public API
    def assign(self, flows: Sequence, cap_of: Callable[[str], float]) -> None:
        if not flows:
            return
        if not self.incremental:
            self._assign_full(flows, cap_of)
            return
        for comp in affinity_components([f.links for f in flows]):
            self._assign_component([flows[i] for i in comp], cap_of)

    def fill(self, demands: np.ndarray, paths: Sequence[Tuple[str, ...]],
             caps: Dict[str, float]) -> np.ndarray:
        """Solve one fill problem with this engine's backend (no memo)."""
        if self.backend == "python":
            return fill_python(np.asarray(demands, dtype=float), paths, caps)
        d, routes, cap_vec, _ = problem_matrix(demands, paths, caps)
        return fill_many([(d, routes, cap_vec)], backend=self.backend)[0]

    def solve_batch(self, problems: Sequence[tuple]) -> List[np.ndarray]:
        """Solve many ``(demands, paths, caps)`` problems in ONE dispatch.

        The array event loop's dirty-component path: every dirty affinity
        component of one tick arrives here together; memoized components
        (content key: demands, paths, link capacities) return instantly,
        and ALL misses go through a single shape-bucketed
        :func:`fill_corpus` batch — one jit dispatch per tick instead of
        one per component.  Returns per-problem rate vectors in caller
        order.  Returned arrays are shared with the memo: treat as
        read-only."""
        out: List[Optional[np.ndarray]] = [None] * len(problems)
        keys: List[Optional[tuple]] = [None] * len(problems)
        miss: List[int] = []
        for i, (demands, paths, caps) in enumerate(problems):
            if self.incremental:
                key = (self.backend,
                       tuple((float(d), tuple(p))
                             for d, p in zip(demands, paths)),
                       tuple(caps[l] for l in _first_seen_links(paths)))
                keys[i] = key
                hit = self._memo.get(key)
                if hit is not None:
                    self.stats.hits += 1
                    out[i] = hit
                    continue
            miss.append(i)
        if miss:
            self.stats.misses += len(miss)
            if self.backend == "python":
                for i in miss:
                    d, p, c = problems[i]
                    out[i] = fill_python(np.asarray(d, dtype=float), p, c)
            else:
                mats = [problem_matrix(*problems[i])[:3] for i in miss]
                rates = fill_corpus(mats, backend=self.backend,
                                    bucket_shapes=True,
                                    stats=self.corpus_stats)
                for i, r in zip(miss, rates):
                    out[i] = r
            if self.incremental:
                for i in miss:
                    if len(self._memo) >= self.memo_max:
                        self._memo.clear()
                    self._memo[keys[i]] = out[i]
        if self.sample_stride > 0:
            for i, prob in enumerate(problems):
                self._sample_seen += 1
                if (self._sample_seen % self.sample_stride == 0
                        and len(self.samples) < self.sample_max):
                    self.samples.append((*prob, out[i]))
        return out  # type: ignore[return-value]

    # --------------------------------------------------------------- internals
    def _assign_full(self, flows: Sequence,
                     cap_of: Callable[[str], float]) -> None:
        """The seed's ``_assign_rates`` body, verbatim (python backend) or
        one global vectorized solve (jnp/kernel with incremental off)."""
        self.stats.solves += 1
        if self.backend == "python":
            if all(len(f.links) == 1 for f in flows):
                by_link: Dict[str, List] = {}
                for f in flows:
                    by_link.setdefault(f.node, []).append(f)
                for node_name, group in by_link.items():
                    demands = np.array([f.demand_gbps for f in group])
                    rates = _max_min_fair(demands, cap_of(node_name))
                    for f, r in zip(group, rates):
                        f.rate_gbps = float(r)
                return
            caps = {l: cap_of(l) for f in flows for l in f.links}
            demands = np.array([f.demand_gbps for f in flows])
            rates = _progressive_fill(demands, [f.links for f in flows], caps)
            for f, r in zip(flows, rates):
                f.rate_gbps = float(r)
            return
        caps = {l: cap_of(l) for f in flows for l in f.links}
        rates = self.fill(np.array([f.demand_gbps for f in flows]),
                          [f.links for f in flows], caps)
        for f, r in zip(flows, rates):
            f.rate_gbps = float(r)

    def _assign_component(self, flows: Sequence,
                          cap_of: Callable[[str], float]) -> None:
        links: List[str] = []
        seen = set()
        for f in flows:
            for l in f.links:
                if l not in seen:
                    seen.add(l)
                    links.append(l)
        caps = {l: cap_of(l) for l in links}
        key = (self.backend,
               tuple((f.demand_gbps, f.links) for f in flows),
               tuple(caps[l] for l in links))
        rates = self._memo.get(key)
        if rates is None:
            self.stats.misses += 1
            if self.backend == "python":
                rates = fill_python(
                    np.array([f.demand_gbps for f in flows]),
                    [f.links for f in flows], caps)
            else:
                rates = self.fill(np.array([f.demand_gbps for f in flows]),
                                  [f.links for f in flows], caps)
            if len(self._memo) >= self.memo_max:
                self._memo.clear()
            self._memo[key] = rates
        else:
            self.stats.hits += 1
        for f, r in zip(flows, rates):
            f.rate_gbps = float(r)

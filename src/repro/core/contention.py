"""Unified link-contention view — the single authority for job→link demand.

Before this module existed the bookkeeping of "which jobs place how much
demand on which fabric link" was implemented three times with subtly
different rules: the scheduler's ``_node_jobs``/``_uplink_jobs``/
``_traversed_uplinks``, the controller's ``_link_traffic``, and the
simulator's ``_job_links``/``_make_flows``.  :class:`LinkView` replaces all
three (DESIGN.md section 9).  It is built from ``(Cluster, task store,
optional candidate pod@node)`` and answers, for every link id (host link ==
node name, spine uplinks ``uplink:<leaf>``):

  * the job → tasks grouping that sources traffic onto the link,
  * per-job demand (Gbps) and the duty/period inputs of the rotation solve,
  * the contending-pair predicate of Eq. 9 (combined demand exceeding the
    link's allocatable bandwidth),
  * the fluid simulator's flow specification (source host link + full path).

Two demand conventions intentionally coexist and are both served from this
one view:

  * the **planning view** (:meth:`host_groups` / :meth:`uplink_groups`) is
    what the scheduler's Filter/Score and the dependency-loop filter see:
    LowComm pods are excluded and a co-located job's tasks count against its
    host link even when the job is single-node (conservative — Eq. 17 ties
    all tasks of a job to one rotation);
  * the **flow view** (:meth:`flows_for`) is the fluid simulator's model:
    single-node jobs synchronize over localhost and place no link traffic,
    and demand aggregates per source host link.

The controller's offline recalculation keeps its legacy whole-job host-link
demand (:meth:`recalc_traffic` / :meth:`recalc_demands`).  Since the
fabric-wide rotation planner became the single producer of schemes
(``core/rotation.py``), this divergence is an explicit, named *demand
convention* of the planner (``demand='planning'`` vs ``demand='recalc'``)
rather than two code paths: the Score phase plans with the planning view,
the offline 3rd stage re-solves with the recalc view, and both read the
same grouped tasks from this one class (DESIGN.md section 13).  Folding the
host rule into the planning view would re-scale Eq. 18's excess on every
star recalculation and is pinned out by the seed goldens.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .cluster import Cluster
from .topology import is_uplink
from .workload import Job, Task, TrafficSpec

EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class FlowSpec:
    """One fluid flow of a job: source host link, demand, full link path."""

    node: str
    demand_gbps: float
    links: Tuple[str, ...]


def group_demand_gbps(tasks: Sequence[Task]) -> float:
    """Aggregate link demand of one job's grouped tasks."""
    return sum(t.traffic.bw_gbps for t in tasks)


class LinkView:
    """Authoritative job→link demand view over a cluster + task store.

    ``extra``/``extra_node`` model a *candidate* placement: the scheduler
    scores pod ``extra`` as if it were already deployed on ``extra_node``
    (the pod's real ``node`` stays ``None`` until Reserve).

    Groupings preserve task-store iteration order (registry insertion
    order) so downstream consumers — rotation job order, networkx edge
    insertion, max-min-fair tie-breaks — are bit-for-bit reproducible.

    ``epoch`` tags the snapshot this view was built from (DESIGN.md
    section 15): :meth:`from_registry` captures the monotonic
    ``(cluster.epoch, registry.epoch)`` mutation counters, which advance on
    every reserve/unreserve, traffic change, and capacity/background event.
    Downstream planner caches (:class:`repro.core.rotation.PlanCache`) key
    on it, so reusing a result across ANY mutation is impossible by
    construction.  Views built without an epoch (``epoch=None``) disable
    caching entirely.
    """

    def __init__(self, cluster: Cluster, tasks: Sequence[Task] = (), *,
                 extra: Optional[Task] = None,
                 extra_node: Optional[str] = None,
                 epoch: Optional[Tuple[int, int]] = None) -> None:
        self.cluster = cluster
        self._tasks: List[Task] = list(tasks)
        self.extra = extra
        self.extra_node = extra_node
        self.epoch = epoch
        self._job_nodes_cache: Optional[Dict[str, Set[str]]] = None
        # flows_for(cache_epoch=...) memo: job name -> (epoch, specs)
        self._flows_cache: Dict[str, Tuple[int, List["FlowSpec"]]] = {}

    # ------------------------------------------------------------ constructors
    @classmethod
    def from_registry(cls, cluster: Cluster, registry, *,
                      extra: Optional[Task] = None,
                      extra_node: Optional[str] = None) -> "LinkView":
        """View over the deployed tasks of a :class:`TaskRegistry`, tagged
        with the current (cluster, registry) mutation epoch."""
        reg_epoch = getattr(registry, "epoch", None)
        cl_epoch = getattr(cluster, "epoch", None)
        epoch = (None if reg_epoch is None or cl_epoch is None
                 else (cl_epoch, reg_epoch))
        return cls(cluster, list(registry.tasks.values()), extra=extra,
                   extra_node=extra_node, epoch=epoch)

    # ---------------------------------------------------------------- plumbing
    def job_tasks(self, job: str) -> List[Task]:
        """All stored tasks of ``job`` in store (registry-insertion) order."""
        return [t for t in self._tasks if t.job == job]

    def _job_nodes(self) -> Dict[str, Set[str]]:
        """job -> set of nodes it occupies (candidate placement included)."""
        if self._job_nodes_cache is None:
            out: Dict[str, Set[str]] = {}
            for t in self._tasks:
                if t.node is not None:
                    out.setdefault(t.job, set()).add(t.node)
            if self.extra is not None and self.extra_node is not None:
                out.setdefault(self.extra.job, set()).add(self.extra_node)
            self._job_nodes_cache = out
        return self._job_nodes_cache

    def _uplink_leaf(self, link_id: str) -> Optional[str]:
        """Leaf owning ``link_id`` when it is an uplink, else None."""
        if not is_uplink(link_id):
            return None
        for leaf, up in self.cluster.topology.uplinks.items():
            if up.id == link_id:
                return leaf
        return None

    # ------------------------------------------------------------ planning view
    def host_groups(self, node_name: str) -> Dict[str, List[Task]]:
        """Jobs sourcing traffic onto ``node_name``'s host link -> their
        tasks there (LowComm pods excluded; Eq. 17 ties a job's co-located
        tasks to a single rotation)."""
        groups: Dict[str, List[Task]] = {}
        for t in self._tasks:
            if t.node == node_name and not t.low_comm:
                groups.setdefault(t.job, []).append(t)
        if (self.extra is not None and self.extra_node == node_name
                and not self.extra.low_comm):
            groups.setdefault(self.extra.job, []).append(self.extra)
        return groups

    def uplink_groups(self, leaf: str) -> Dict[str, List[Task]]:
        """Jobs traversing ``leaf``'s uplink -> their in-leaf tasks.

        A job crosses the uplink when it has pods both inside and outside
        the leaf; its uplink demand is the aggregate bandwidth its IN-leaf
        pods source toward the spine (the simulator's flow model)."""
        topo = self.cluster.topology
        groups: Dict[str, List[Task]] = {}
        for job, nodes in self._job_nodes().items():
            if not topo.spans_leaves(nodes):
                continue
            if not any(topo.leaf_of[n] == leaf for n in nodes):
                continue
            in_leaf = [
                t for t in self.job_tasks(job)
                if t.node is not None and topo.leaf_of[t.node] == leaf
                and not t.low_comm
            ]
            if (self.extra is not None and self.extra_node is not None
                    and self.extra.job == job and not self.extra.low_comm
                    and topo.leaf_of[self.extra_node] == leaf
                    and all(t.uid != self.extra.uid for t in in_leaf)):
                in_leaf = in_leaf + [self.extra]
            if in_leaf:
                groups[job] = in_leaf
        return groups

    def link_groups(self, link_id: str) -> Dict[str, List[Task]]:
        """Dispatch: host link (id == node name) or ``uplink:<leaf>``."""
        leaf = self._uplink_leaf(link_id)
        if leaf is not None:
            return self.uplink_groups(leaf)
        return self.host_groups(link_id)

    def demands(self, link_id: str) -> Dict[str, float]:
        """job -> aggregate demand (Gbps) on one link, in grouping order."""
        return {j: group_demand_gbps(ts)
                for j, ts in self.link_groups(link_id).items()}

    # --------------------------------------------------------- Eq. 9 predicate
    def contending_pairs(self, link_id: str) -> List[Tuple[str, str]]:
        """Job pairs whose combined demand exceeds the link's allocatable
        bandwidth (Eq. 9's criterion) — only these constrain relative
        rotations; sub-capacity co-location imposes nothing.  Pair order
        follows the grouping order (i < j)."""
        groups = self.link_groups(link_id)
        jobs = list(groups.keys())
        bws = {j: group_demand_gbps(ts) for j, ts in groups.items()}
        cap = self.cluster.link_alloc(link_id)
        out: List[Tuple[str, str]] = []
        for i in range(len(jobs)):
            for j in range(i + 1, len(jobs)):
                a, b = jobs[i], jobs[j]
                if bws[a] + bws[b] > cap:
                    out.append((a, b))
        return out

    def contends(self, link_id: str, job_a: str, job_b: str) -> bool:
        """Eq. 9 predicate for one pair on one link."""
        bws = self.demands(link_id)
        return (bws.get(job_a, 0.0) + bws.get(job_b, 0.0)
                > self.cluster.link_alloc(link_id))

    def planning_links(self) -> List[str]:
        """Every link id in the canonical traversal order: host links (node
        order), then uplinks (topology order) — the loop-filter and the
        controller's deterministic tie-break both rely on it."""
        return list(self.cluster.node_names) + self.cluster.topology.uplink_ids

    # ------------------------------------------------------------------ routing
    def traversed_uplinks(self, job: str) -> List[str]:
        """Leaves whose uplinks ``job`` traverses under the current (plus
        candidate) placement; empty on star topologies or intra-leaf jobs."""
        topo = self.cluster.topology
        if topo.is_star:
            return []
        nodes = self._job_nodes().get(job, set())
        if not nodes or not topo.spans_leaves(nodes):
            return []
        return sorted({topo.leaf_of[n] for n in nodes}
                      & set(topo.uplinks.keys()))

    # ---------------------------------------------------------------- flow view
    def flows_for(self, job: Job, *,
                  cache_epoch: Optional[int] = None) -> List[FlowSpec]:
        """The fluid simulator's flow construction: one flow per used host
        link (aggregate of the job's pods there); the path extends over the
        source leaf's uplink when the job spans leaves.  Single-node jobs
        synchronize over localhost and place no link traffic.

        ``cache_epoch`` (the simulator's event loop passes ``cluster.epoch``)
        memoizes the specs per job until the epoch advances: a job's flow
        set depends only on its own placements and per-task bandwidths, and
        every mutation of either — reserve/release, departures — bumps the
        cluster epoch, so the steady-state COMM entries of a long trace skip
        the per-task rebuild.  Duty-cycle traffic changes alter volumes (the
        caller's ``comm_ms``), never these demands/paths."""
        if cache_epoch is not None:
            hit = self._flows_cache.get(job.name)
            if hit is not None and hit[0] == cache_epoch:
                return hit[1]
        specs = self._flows_for_uncached(job)
        if cache_epoch is not None:
            self._flows_cache[job.name] = (cache_epoch, specs)
        return specs

    def _flows_for_uncached(self, job: Job) -> List[FlowSpec]:
        nodes = job.nodes_used()
        if len(nodes) <= 1:
            return []
        topo = self.cluster.topology
        agg: Dict[str, float] = {}
        for t in job.tasks:
            if t.node is None or t.traffic.bw_gbps <= 0:
                continue
            agg[t.node] = agg.get(t.node, 0.0) + t.traffic.bw_gbps
        return [FlowSpec(n, bw, topo.flow_links(n, nodes))
                for n, bw in agg.items()]

    def fill_problem(self, jobs: Sequence[Job]):
        """The (flows x links) fill-problem inputs of the fluid engine
        (``core/fluid.py``) for the given jobs' placements: per-flow demands
        and link paths from :meth:`flows_for`, plus the allocatable capacity
        of every link any path crosses.  Returns ``(demands, paths, caps)``
        ready for ``fluid.fill_python`` / ``fluid.problem_matrix`` — the
        construction path of the production-trace throughput benchmark and
        the backend-parity tests."""
        demands: List[float] = []
        paths: List[Tuple[str, ...]] = []
        for job in jobs:
            for fs in self.flows_for(job):
                demands.append(fs.demand_gbps)
                paths.append(fs.links)
        caps: Dict[str, float] = {}
        for p in paths:
            for l in p:
                if l not in caps:
                    caps[l] = self.cluster.link_alloc(l)
        return demands, paths, caps

    # -------------------------------------------------- controller recalc inputs
    def recalc_traffic(self, link_id: str, jobs: Sequence[str],
                       muls, base_ms: float
                       ) -> Tuple[List[float], List[float]]:
        """(duties, bws) inputs for the offline 3rd-stage recalculation of
        one link scheme (jobs/muls/base_ms come from the scheme).

        Uplinks use the in-leaf grouping (matching :meth:`uplink_groups`).
        Host links keep the controller's legacy whole-job convention — the
        sum over ALL deployed tasks of the job, not only those on this node
        (see :meth:`recalc_demands`)."""
        duties: List[float] = []
        for idx, j in enumerate(jobs):
            tasks = self.job_tasks(j)
            spec = tasks[0].traffic if tasks else TrafficSpec(100.0, 0.3, 1.0)
            eff_period = base_ms / max(int(muls[idx]), 1)
            duties.append(min(1.0, spec.comm_ms / eff_period))
        return duties, self.recalc_demands(link_id, jobs)

    def recalc_demands(self, link_id: str, jobs: Sequence[str]) -> List[float]:
        """Per-job demand (Gbps) under the offline-recalculation convention.

        Uplinks: the in-leaf aggregate (identical to the planning view).
        Host links: the sum over ALL deployed tasks of the job — the
        controller's legacy whole-job rule, deliberately preserved: the
        star-topology seed goldens pin the recalculated shifts bit-for-bit
        against it (DESIGN.md section 13 documents the divergence)."""
        topo = self.cluster.topology
        leaf = self._uplink_leaf(link_id)
        bws: List[float] = []
        for j in jobs:
            tasks = self.job_tasks(j)
            if leaf is None:
                bws.append(sum(t.traffic.bw_gbps for t in tasks
                               if t.node is not None))
            else:
                bws.append(sum(t.traffic.bw_gbps for t in tasks
                               if t.node is not None and not t.low_comm
                               and topo.leaf_of[t.node] == leaf))
        return bws

    # ----------------------------------------------------- reconfiguration view
    def expected_iteration_ms(self, job: str) -> Optional[float]:
        """Contention-free iteration time under the CURRENT allocatable
        bandwidths — the reconfiguration engine's baseline (DESIGN.md
        section 10).  When a link's allocatable share drops below the job's
        demand, even a perfectly rotated communication phase stretches by
        ``demand / allocatable``; the stop-and-wait monitor must not fight
        that unavoidable slowdown as if it were drift.  Uses the flow view
        (single-node jobs never touch a link) with per-leaf aggregation on
        traversed uplinks.  Returns None when the job is unknown."""
        tasks = self.job_tasks(job)
        if not tasks:
            return None
        spec = tasks[0].traffic
        nodes = sorted({t.node for t in tasks if t.node is not None})
        stretch = 1.0
        if len(nodes) > 1:
            agg: Dict[str, float] = {}
            for t in tasks:
                if t.node is None or t.traffic.bw_gbps <= 0:
                    continue
                agg[t.node] = agg.get(t.node, 0.0) + t.traffic.bw_gbps
            for n, d in agg.items():
                alloc = self.cluster.link_alloc(n)
                if alloc > EPS:
                    stretch = max(stretch, d / alloc)
            topo = self.cluster.topology
            for leaf in self.traversed_uplinks(job):
                up = topo.uplinks[leaf]
                d = group_demand_gbps(self.uplink_groups(leaf).get(job, []))
                # read through the cluster's link API (not the raw Link
                # object) so a TelemetryView proxy observes the uplink's
                # allocatable share like every other consumer
                alloc = self.cluster.link_alloc(up.id)
                if alloc > EPS:
                    stretch = max(stretch, d / alloc)
        return spec.compute_ms + spec.comm_ms * stretch

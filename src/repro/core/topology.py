"""Fabric topology: host links, leaf switches, and spine uplinks.

The seed modeled contention on *host links* only (the paper's Eq. (14)
1:1-oversubscription simplification). This module generalizes the network
model to a two-tier leaf–spine fabric:

  * every node owns one **host link** (id == the node name, so that all
    node-keyed maps from the host-link-only era keep working bit-for-bit);
  * nodes are grouped into **leaves** (racks / ToR switches);
  * each leaf owns one **uplink** to the spine (id ``uplink:<leaf>``) whose
    capacity is ``sum(host bw in leaf) / oversubscription``.

Flow routing follows the seed's source-aggregated fluid model: a
multi-node job places one flow per used host link; that flow additionally
traverses the source leaf's uplink whenever the job has peers in another
leaf. Traffic entering a leaf is accounted by the remote peers' own
(symmetric) flows, which matches the all-reduce-style synchronized traffic
the paper targets.

The :meth:`Topology.star` constructor (one leaf, no uplinks) reproduces the
seed's host-link-only model exactly and is the default everywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

HOST = "host"
UPLINK = "uplink"

UPLINK_PREFIX = "uplink:"


@dataclasses.dataclass
class Link:
    """One fabric link (an uplink; host links live on :class:`Node`)."""

    id: str
    capacity_gbps: float
    kind: str = UPLINK
    # the manager may lower the allocatable share (NodeBandwidth-CR analogue
    # for fabric links: reserved / unregulated cross-rack traffic)
    allocatable_gbps: Optional[float] = None

    @property
    def alloc_bw(self) -> float:
        return (self.capacity_gbps if self.allocatable_gbps is None
                else self.allocatable_gbps)

    def copy(self) -> "Link":
        return dataclasses.replace(self)


def uplink_id(leaf: str) -> str:
    return f"{UPLINK_PREFIX}{leaf}"


def is_uplink(link_id: str) -> bool:
    return link_id.startswith(UPLINK_PREFIX)


class Topology:
    """Leaf–spine fabric over a fixed node set.

    ``leaf_of`` maps node name -> leaf id; ``uplinks`` maps leaf id -> its
    :class:`Link`. A single-leaf topology has no uplinks and degenerates to
    the seed's star model.
    """

    def __init__(self, leaf_of: Mapping[str, str],
                 uplinks: Optional[Mapping[str, Link]] = None) -> None:
        self.leaf_of: Dict[str, str] = dict(leaf_of)
        self.uplinks: Dict[str, Link] = dict(uplinks or {})
        self.leaves: Dict[str, List[str]] = {}
        for node, leaf in self.leaf_of.items():
            self.leaves.setdefault(leaf, []).append(node)
        for leaf in self.uplinks:
            if leaf not in self.leaves:
                raise ValueError(f"uplink for unknown leaf {leaf!r}")

    # ------------------------------------------------------------ constructors
    @classmethod
    def star(cls, node_names: Iterable[str]) -> "Topology":
        """Seed model: all nodes on one switch, inter-switch never bottleneck."""
        return cls({n: "leaf0" for n in node_names})

    @classmethod
    def leaf_spine(
        cls,
        leaves: Mapping[str, Sequence[str]],
        *,
        host_bw_gbps: Mapping[str, float],
        oversubscription: float = 1.0,
        uplink_gbps: Optional[Mapping[str, float]] = None,
    ) -> "Topology":
        """Build a leaf–spine fabric.

        ``leaves``: leaf id -> node names. Uplink capacity per leaf is
        ``sum(host bw) / oversubscription`` unless pinned via ``uplink_gbps``.
        """
        if oversubscription <= 0:
            raise ValueError("oversubscription must be positive")
        leaf_of = {n: leaf for leaf, nodes in leaves.items() for n in nodes}
        uplinks: Dict[str, Link] = {}
        if len(leaves) > 1:
            for leaf, nodes in leaves.items():
                if uplink_gbps is not None and leaf in uplink_gbps:
                    cap = float(uplink_gbps[leaf])
                else:
                    cap = sum(host_bw_gbps[n] for n in nodes) / oversubscription
                uplinks[leaf] = Link(id=uplink_id(leaf), capacity_gbps=cap)
        return cls(leaf_of, uplinks)

    # ----------------------------------------------------------------- queries
    @property
    def is_star(self) -> bool:
        """True when no uplink can ever be traversed (seed-equivalent)."""
        return not self.uplinks

    @property
    def uplink_ids(self) -> List[str]:
        return [l.id for l in self.uplinks.values()]

    def leaf(self, node: str) -> str:
        return self.leaf_of[node]

    def uplink_of(self, node: str) -> Optional[Link]:
        return self.uplinks.get(self.leaf_of[node])

    def link(self, link_id: str) -> Optional[Link]:
        for l in self.uplinks.values():
            if l.id == link_id:
                return l
        return None

    def flow_links(self, src: str, dst_nodes: Iterable[str]) -> Tuple[str, ...]:
        """Links traversed by a flow sourced at ``src`` toward ``dst_nodes``:
        the source host link, plus the source leaf's uplink when any
        destination sits in another leaf."""
        src_leaf = self.leaf_of[src]
        up = self.uplinks.get(src_leaf)
        if up is not None and any(
                self.leaf_of[d] != src_leaf for d in dst_nodes if d != src):
            return (src, up.id)
        return (src,)

    def placement_links(self, nodes: Iterable[str]) -> List[str]:
        """All links a job placed on ``nodes`` would traverse (union over its
        per-source flows): every used host link, plus the uplink of every
        used leaf when the placement spans more than one leaf."""
        nodes = sorted(set(nodes))
        links: List[str] = list(nodes)
        leaves = {self.leaf_of[n] for n in nodes}
        if len(leaves) > 1:
            for leaf in sorted(leaves):
                up = self.uplinks.get(leaf)
                if up is not None:
                    links.append(up.id)
        return links

    def spans_leaves(self, nodes: Iterable[str]) -> bool:
        return len({self.leaf_of[n] for n in nodes}) > 1

    def copy(self) -> "Topology":
        return Topology(dict(self.leaf_of),
                        {k: v.copy() for k, v in self.uplinks.items()})

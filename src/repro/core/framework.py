"""A minimal K8s-scheduling-framework analogue (extension points + cycle).

The paper registers custom logic at PreFilter / Filter / Score /
NormalizeScore / Reserve of the K8s scheduling framework (v0.26.7). We keep
the same extension points and pod-by-pod scheduling cycle, plus the
Coscheduling (all-or-nothing, Eqs. 11-12) gate at the job level.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .cluster import Cluster
from .workload import Job, Task, Workload


@dataclasses.dataclass
class ScheduleContext:
    """Per-cycle scratch space shared across extension points (the paper's
    PreFilter 'CacheResource' lives here)."""

    cache: Dict = dataclasses.field(default_factory=dict)


class SchedulerPlugin:
    """Extension-point interface. Plugins override what they need."""

    name = "base"

    def pre_filter(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
                   registry: "TaskRegistry") -> None:
        return None

    def filter(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
               node_name: str, registry: "TaskRegistry") -> bool:
        return True

    def score(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
              node_name: str, registry: "TaskRegistry") -> float:
        return 0.0

    def score_nodes(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
                    nodes: List[str],
                    registry: "TaskRegistry") -> Dict[str, float]:
        """Score every feasible node of one pod.  The default simply loops
        :meth:`score`; plugins may override to batch the per-candidate work
        (Metronome solves all candidates' rotation problems in one pass)."""
        return {n: self.score(ctx, cluster, pod, n, registry) for n in nodes}

    def normalize_scores(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
                         scores: Dict[str, float],
                         registry: "TaskRegistry") -> Dict[str, float]:
        return scores

    def reserve(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
                node_name: str, registry: "TaskRegistry") -> None:
        return None

    def unreserve(self, cluster: Cluster, pod: Task, node_name: str,
                  registry: "TaskRegistry") -> None:
        return None


class TaskRegistry:
    """Cluster-wide view of deployed tasks (the operators' CR store)."""

    def __init__(self) -> None:
        self.tasks: Dict[str, Task] = {}
        self.jobs: Dict[str, Job] = {}
        self.workloads: Dict[str, Workload] = {}
        # monotonic mutation counter: advanced on every task/job store
        # change AND on in-place task mutations (traffic changes), so the
        # (cluster.epoch, registry.epoch) pair tags a LinkView snapshot for
        # sound planner-cache invalidation (DESIGN.md section 15)
        self.epoch: int = 0

    def bump(self) -> None:
        """Advance the mutation epoch (see :class:`~repro.core.rotation.
        PlanCache`); every mutation of stored tasks/jobs must call this."""
        self.epoch += 1

    def deployed_on(self, node_name: str) -> List[Task]:
        return [t for t in self.tasks.values() if t.node == node_name]

    def job_tasks(self, job_name: str) -> List[Task]:
        return [t for t in self.tasks.values() if t.job == job_name]

    def dependencies_of(self, pod: Task) -> List[Task]:
        """Dependent pods: explicit AppGroup deps + all pods of the same job
        (the paper auto-treats same-job pods as dependent)."""
        deps: Dict[str, Task] = {}
        for t in self.tasks.values():
            if t.uid == pod.uid:
                continue
            if t.job == pod.job:
                deps[t.uid] = t
        wl = self.workloads.get(pod.workload)
        if wl is not None:
            for a, b in wl.dependencies:
                other = None
                if a == pod.job:
                    other = b
                elif b == pod.job:
                    other = a
                if other is not None:
                    for t in self.job_tasks(other):
                        deps[t.uid] = t
        return list(deps.values())


@dataclasses.dataclass
class ScheduleOutcome:
    pod: Task
    node: Optional[str]  # None -> unschedulable
    score: float = 0.0


class SchedulingFramework:
    """Runs the scheduling cycle for one pod and all-or-nothing for jobs."""

    def __init__(self, cluster: Cluster, plugin: SchedulerPlugin):
        self.cluster = cluster
        self.plugin = plugin
        self.registry = TaskRegistry()

    # -- single pod cycle ---------------------------------------------------
    def schedule_pod(self, pod: Task) -> ScheduleOutcome:
        ctx = ScheduleContext()
        self.plugin.pre_filter(ctx, self.cluster, pod, self.registry)

        feasible = [
            n for n in self.cluster.node_names
            if self._spread_ok(pod, n)
            and self.plugin.filter(ctx, self.cluster, pod, n, self.registry)
        ]
        if not feasible:
            return ScheduleOutcome(pod, None)

        scores = self.plugin.score_nodes(ctx, self.cluster, pod, feasible,
                                         self.registry)
        scores = self.plugin.normalize_scores(ctx, self.cluster, pod, scores,
                                              self.registry)
        # deterministic tie-break on node order
        best = max(scores.items(), key=lambda kv: (kv[1], -self.cluster.index(kv[0])))
        node_name = best[0]
        pod.node = node_name
        self.cluster.node(node_name).allocate(pod.uid, pod.resources,
                                              pod.traffic.bw_gbps)
        self.registry.tasks[pod.uid] = pod
        # the demand view changed: advance the epochs BEFORE Reserve so the
        # controller's replan (and any later Score) sees a fresh snapshot
        self.cluster.bump_epoch()
        self.registry.bump()
        self.plugin.reserve(ctx, self.cluster, pod, node_name, self.registry)
        return ScheduleOutcome(pod, node_name, best[1])

    def _spread_ok(self, pod: Task, node_name: str) -> bool:
        """PodTopologySpread: cap same-job pods per node (pod-spec level —
        honored by every scheduler, like a K8s spread constraint)."""
        if pod.spread <= 0:
            return True
        same = sum(
            1 for t in self.registry.tasks.values()
            if t.job == pod.job and t.node == node_name
        )
        return same < pod.spread

    # -- all-or-nothing job gate (Coscheduling; Eqs. 11-12) ------------------
    def schedule_job(self, job: Job) -> bool:
        self.registry.jobs[job.name] = job
        self.registry.bump()
        placed: List[Task] = []
        for pod in job.tasks:
            out = self.schedule_pod(pod)
            if out.node is None:
                # roll back the whole job (all-or-nothing), registry entry
                # included — a failed attempt must leave no phantom job
                # behind, or every scorer that walks registry.jobs pays for
                # it on all later admissions (and a retried online queue
                # leaks one phantom per failed attempt)
                for t in placed:
                    self.evict_pod(t)
                self.registry.jobs.pop(job.name, None)
                self.registry.bump()
                return False
            placed.append(pod)
        return True

    def schedule_workload(self, wl: Workload) -> bool:
        self.registry.workloads[wl.name] = wl
        self.registry.bump()
        placed_jobs: List[Job] = []
        for job in wl.jobs:
            if not self.schedule_job(job):
                for j in placed_jobs:
                    self.evict_job(j)
                self.registry.workloads.pop(wl.name, None)
                self.registry.bump()
                return False
            placed_jobs.append(job)
        return True

    # -- teardown ------------------------------------------------------------
    def evict_pod(self, pod: Task) -> None:
        if pod.node is not None:
            self.cluster.node(pod.node).release(pod.uid, pod.resources)
            self.cluster.bump_epoch()
            self.plugin.unreserve(self.cluster, pod, pod.node, self.registry)
            pod.node = None
        self.registry.tasks.pop(pod.uid, None)
        self.registry.bump()

    def evict_job(self, job: Job) -> None:
        for t in job.tasks:
            self.evict_pod(t)
        self.registry.jobs.pop(job.name, None)
        self.registry.bump()

"""Glue: scheduler -> controller -> simulator for one experiment run.

This is the programmatic equivalent of the paper's testbed procedure:
submit workloads under a chosen scheduling mechanism, then execute them and
measure iteration times / bandwidth utilization / TCT.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .baselines import DefaultPlugin, DiktyoPlugin, ExclusivePlugin
from .cluster import Cluster
from .controller import StopAndWaitController
from .framework import SchedulerPlugin, SchedulingFramework
from .scheduler import MetronomePlugin
from .simulator import BackgroundFlow, ClusterSimulator, SimConfig, SimResult
from .workload import Job, Workload

SCHEDULERS = ("metronome", "default", "diktyo", "exclusive", "ideal")


@dataclasses.dataclass
class RunResult:
    sim: SimResult
    accepted: List[str]
    rejected: List[str]
    scheduler: str
    placements: Dict[str, List[str]]


def make_plugin(name: str, controller: Optional[StopAndWaitController] = None,
                rotation_mode: str = "intermediate",
                rotation_joint: bool = True) -> SchedulerPlugin:
    if name == "metronome":
        return MetronomePlugin(controller=controller,
                               rotation_mode=rotation_mode,
                               joint=rotation_joint)
    if name == "default":
        return DefaultPlugin()
    if name == "diktyo":
        return DiktyoPlugin()
    if name == "exclusive":
        return ExclusivePlugin()
    raise ValueError(f"unknown scheduler {name!r}")


def run_experiment(
    scheduler: str,
    cluster: Cluster,
    workloads: Sequence[Workload],
    config: Optional[SimConfig] = None,
    background: Sequence[BackgroundFlow] = (),
    traffic_changes: Sequence[Tuple[float, str, float]] = (),
    skip_third_stage: bool = False,
    rotation_mode: str = "intermediate",
    events: Sequence = (),
    reconfigure: bool = True,
    rotation_joint: bool = True,
) -> RunResult:
    """Schedule all workloads with the named mechanism, then simulate.

    ``scheduler == 'ideal'`` runs every job alone on a pristine copy of the
    cluster (dedicated-cluster reference of the paper).  ``events`` feeds
    the simulator's dynamic-environment stream (``core/events.py``);
    ``reconfigure=False`` ablates the controller's reconfiguration loop
    (capacity/background changes are then handled only by the drift
    monitor).  ``rotation_joint=False`` ablates the fabric-wide joint
    rotation planner: per-link solves are reconciled with the legacy
    "uplinks take precedence" tie-break instead (bench_rotation.py).  The
    ``'ideal'`` reference deliberately ignores ``events`` (and
    ``background``/``traffic_changes``): it is the STATIC contention-free
    bound, so dynamic-snapshot comparisons against it measure fluctuation
    cost plus contention cost together.
    """
    config = config or SimConfig()
    if scheduler == "ideal":
        return _run_ideal(cluster, workloads, config)

    cl = cluster.copy()
    controller = None
    if scheduler == "metronome":
        controller = StopAndWaitController(reconfigure=reconfigure,
                                           joint=rotation_joint)
    plugin = make_plugin(scheduler, controller, rotation_mode=rotation_mode,
                         rotation_joint=rotation_joint)
    fw = SchedulingFramework(cl, plugin)

    accepted, rejected = [], []
    jobs: List[Job] = []
    for wl in workloads:
        ok = fw.schedule_workload(wl)
        for j in wl.jobs:
            (accepted if ok else rejected).append(j.name)
            if ok:
                jobs.append(j)
    if controller is not None and not skip_third_stage:
        controller.run_offline_recalculation(fw.registry, cl)

    sim = ClusterSimulator(
        cl, jobs, config, controller=controller, background=background,
        traffic_changes=traffic_changes, registry=fw.registry, events=events,
    )
    res = sim.run()
    placements = {j.name: j.nodes_used() for j in jobs}
    return RunResult(res, accepted, rejected, scheduler, placements)


def _run_ideal(cluster: Cluster, workloads: Sequence[Workload],
               config: SimConfig) -> RunResult:
    """Each job on a dedicated cluster: no contention, no shared links."""
    merged_durations: Dict[str, List[float]] = {}
    per_1000: Dict[str, float] = {}
    finish: Dict[str, float] = {}
    iters: Dict[str, int] = {}
    utils = []
    gammas = []
    placements = {}
    for wl in workloads:
        for job in wl.jobs:
            cl = cluster.copy()
            job_copy = copy.deepcopy(job)
            job_copy.submit_time_s = 0.0
            fw = SchedulingFramework(cl, DefaultPlugin())
            if not fw.schedule_job(job_copy):
                continue
            sim = ClusterSimulator(cl, [job_copy], config)
            res = sim.run()
            merged_durations[job.name] = res.durations_ms[job_copy.name]
            per_1000[job.name] = res.time_per_1000_iters_s[job_copy.name]
            finish[job.name] = res.finish_times_ms[job_copy.name]
            iters[job.name] = res.iterations_done[job_copy.name]
            gammas.append(res.avg_bw_utilization)
            placements[job.name] = job_copy.nodes_used()
    sim_res = SimResult(
        durations_ms=merged_durations,
        time_per_1000_iters_s=per_1000,
        link_utilization={},
        avg_bw_utilization=float(np.mean(gammas)) if gammas else 0.0,
        readjustments=0,
        finish_times_ms=finish,
        total_completion_ms=max(
            (f for f in finish.values() if not np.isnan(f)), default=0.0
        ),
        iterations_done=iters,
    )
    names = list(merged_durations.keys())
    return RunResult(sim_res, names, [], "ideal", placements)


def run_trace_experiment(
    scheduler: str,
    cluster: Cluster,
    workloads: Sequence[Workload],
    config: Optional[SimConfig] = None,
    events: Sequence = (),
) -> RunResult:
    """Online (trace) mode: workloads arrive at their submit times, queue
    when the cluster is full, and release capacity on completion — the K8s
    behavior of the paper's 4 h trace (Fig. 10).

    ``events`` feeds the simulator's dynamic stream; the trace generator's
    event-driven truncation plugs in here (``trace_to_jobs(...,
    open_ended=True)`` + ``trace_departure_events``): jobs then end when
    their :class:`~repro.core.events.JobDeparture` fires — never-admitted
    jobs depart from the queue — instead of exhausting an iteration cap."""
    config = config or SimConfig()
    if scheduler == "ideal":
        return _run_ideal(cluster, workloads, config)
    cl = cluster.copy()
    controller = StopAndWaitController() if scheduler == "metronome" else None
    plugin = make_plugin(scheduler, controller)
    fw = SchedulingFramework(cl, plugin)
    sim = ClusterSimulator(
        cl, [], config, controller=controller, registry=fw.registry,
        framework=fw, arrivals=list(workloads), events=events,
    )
    res = sim.run()
    accepted = [n for n, st in sim.jobs.items()]
    placements = {n: st.job.nodes_used() for n, st in sim.jobs.items()}
    return RunResult(res, accepted, sim.pending_jobs, scheduler, placements)


def priority_split(workloads: Sequence[Workload]) -> Tuple[List[str], List[str]]:
    """Names of (high, low) priority jobs."""
    hi, lo = [], []
    for wl in workloads:
        for j in wl.jobs:
            (hi if j.priority else lo).append(j.name)
    return hi, lo

"""Legacy glue API: thin shims over the Scenario/Policy experiment layer.

``run_experiment`` / ``run_trace_experiment`` predate ``core/experiment.py``
and are kept as bit-for-bit-pinned compatibility wrappers (golden
equivalence suite in ``tests/test_experiment.py``): each translates its
kwargs into a :class:`~repro.core.experiment.Scenario` +
:class:`~repro.core.experiment.Policy` pair and delegates to
:func:`~repro.core.experiment.run`.  New code should construct scenarios
and policies directly — every knob that used to be a ``run_experiment``
kwarg is a Policy field, and trace runs accept the full Policy too (the
legacy trace path could not ablate anything).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .controller import StopAndWaitController
from .events import normalize_events
from .experiment import OFFLINE, TRACE, Policy, Scenario, build_scheduler, run
from .cluster import Cluster
from .framework import SchedulerPlugin
from .simulator import BackgroundFlow, SimConfig, SimResult
from .workload import Workload

SCHEDULERS = ("metronome", "default", "diktyo", "exclusive", "ideal")


@dataclasses.dataclass
class RunResult:
    """Legacy result shape (prefer
    :class:`~repro.core.results.ExperimentResult` from the new API)."""

    sim: SimResult
    accepted: List[str]
    rejected: List[str]
    scheduler: str
    placements: Dict[str, List[str]]


def make_plugin(name: str, controller: Optional[StopAndWaitController] = None,
                rotation_mode: str = "intermediate",
                rotation_joint: bool = True) -> SchedulerPlugin:
    """Legacy plugin factory (the registry path builds plugin + controller
    together; this keeps the old build-around-an-existing-controller shape
    for callers that drive the framework by hand)."""
    if name == "metronome":
        from .scheduler import MetronomePlugin
        return MetronomePlugin(controller=controller,
                               rotation_mode=rotation_mode,
                               joint=rotation_joint)
    plugin, _ = build_scheduler(Policy(scheduler=name))
    return plugin


def _legacy_shim(
    mode: str,
    cluster: Cluster,
    workloads: Sequence[Workload],
    config: Optional[SimConfig],
    background: Sequence[BackgroundFlow],
    events: Sequence,
    traffic_changes: Sequence[Tuple[float, str, float]],
    policy: Policy,
) -> RunResult:
    stream = normalize_events(events, traffic_changes)
    scenario = Scenario(name="legacy", mode=mode,
                        build=lambda: (cluster, workloads, background, stream))
    res = run(scenario, policy, config or SimConfig())
    return RunResult(res.sim, res.accepted, res.rejected, res.scheduler,
                     res.placements)


def run_experiment(
    scheduler: str,
    cluster: Cluster,
    workloads: Sequence[Workload],
    config: Optional[SimConfig] = None,
    background: Sequence[BackgroundFlow] = (),
    traffic_changes: Sequence[Tuple[float, str, float]] = (),
    skip_third_stage: bool = False,
    rotation_mode: str = "intermediate",
    events: Sequence = (),
    reconfigure: bool = True,
    rotation_joint: bool = True,
) -> RunResult:
    """Schedule all workloads with the named mechanism, then simulate.

    Legacy shim over ``experiment.run`` — the kwargs map 1:1 onto
    :class:`Policy` fields; legacy ``traffic_changes`` tuples are
    normalized into the typed event stream at this boundary.
    ``scheduler == 'ideal'`` runs every job alone on a pristine copy of the
    cluster (dedicated-cluster reference of the paper) and deliberately
    ignores ``events``/``background``/``traffic_changes``: it is the STATIC
    contention-free bound.
    """
    policy = Policy(scheduler=scheduler, rotation_mode=rotation_mode,
                    rotation_joint=rotation_joint, reconfigure=reconfigure,
                    skip_third_stage=skip_third_stage)
    return _legacy_shim(OFFLINE, cluster, workloads, config,
                        background, events, traffic_changes, policy)


def run_trace_experiment(
    scheduler: str,
    cluster: Cluster,
    workloads: Sequence[Workload],
    config: Optional[SimConfig] = None,
    events: Sequence = (),
    *,
    rotation_mode: str = "intermediate",
    reconfigure: bool = True,
    rotation_joint: bool = True,
) -> RunResult:
    """Online (trace) mode: workloads arrive at their submit times, queue
    when the cluster is full, and release capacity on completion — the K8s
    behavior of the paper's 4 h trace (Fig. 10).

    Legacy shim over ``experiment.run`` with a trace-mode scenario.  The
    controller knobs (``reconfigure``/``rotation_joint``/``rotation_mode``)
    now reach trace runs too — the pre-experiment-API version hardcoded a
    default ``StopAndWaitController`` and silently dropped every ablation.
    ``events`` feeds the simulator's dynamic stream; the trace generator's
    event-driven truncation plugs in here (``trace_to_jobs(...,
    open_ended=True)`` + ``trace_departure_events``)."""
    policy = Policy(scheduler=scheduler, rotation_mode=rotation_mode,
                    rotation_joint=rotation_joint, reconfigure=reconfigure)
    return _legacy_shim(TRACE, cluster, workloads, config,
                        (), events, (), policy)


def priority_split(workloads: Sequence[Workload]) -> Tuple[List[str], List[str]]:
    """Names of (high, low) priority jobs.  The new API carries this split
    on :class:`~repro.core.results.ExperimentResult` directly."""
    hi, lo = [], []
    for wl in workloads:
        for j in wl.jobs:
            (hi if j.priority else lo).append(j.name)
    return hi, lo

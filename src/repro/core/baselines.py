"""Baseline schedulers evaluated in the paper (section IV-A).

  Default   — K8s default: resource-fit filter + least-allocated scoring.
  Diktyo    — network(latency)-aware: favors lowest aggregated network cost
              to dependent pods; modified (as in the paper) to auto-detect
              dependencies within/between jobs. No bandwidth/TDM awareness.
  Exclusive — reserves bandwidth: a node is feasible only if the sum of
              deployed bandwidth + the pod's demand fits the link capacity;
              otherwise the pod (and job, all-or-nothing) is REJECTED.
  Ideal     — each job runs on a dedicated cluster (no shared links); used
              as the contention-free reference. Implemented at the harness
              level by simulating each job alone.
"""
from __future__ import annotations

from typing import List

import numpy as np

from .cluster import Cluster
from .framework import ScheduleContext, SchedulerPlugin, TaskRegistry
from .workload import Task


class DefaultPlugin(SchedulerPlugin):
    """K8s default scheduler approximation (NodeResourcesFit +
    LeastAllocated)."""

    name = "default"

    def filter(self, ctx, cluster: Cluster, pod: Task, node_name: str,
               registry: TaskRegistry) -> bool:
        return pod.resources.fits_in(cluster.node(node_name).free)

    def score(self, ctx, cluster: Cluster, pod: Task, node_name: str,
              registry: TaskRegistry) -> float:
        node = cluster.node(node_name)
        cap = node.capacity
        free_after = node.free - pod.resources
        terms = []
        for attr in ("cpu", "mem", "gpu"):
            c = getattr(cap, attr)
            if c > 0:
                terms.append(getattr(free_after, attr) / c)
        return 100.0 * float(np.mean(terms)) if terms else 0.0


class DiktyoPlugin(SchedulerPlugin):
    """Latency-aware scheduling (Diktyo, TNSM'23), with the paper's
    modification: same-job pods are automatically dependent."""

    name = "diktyo"

    def pre_filter(self, ctx: ScheduleContext, cluster: Cluster, pod: Task,
                   registry: TaskRegistry) -> None:
        deps = [t for t in registry.dependencies_of(pod) if t.node is not None]
        ctx.cache["deps"] = deps

    def filter(self, ctx, cluster: Cluster, pod: Task, node_name: str,
               registry: TaskRegistry) -> bool:
        return pod.resources.fits_in(cluster.node(node_name).free)

    def score(self, ctx, cluster: Cluster, pod: Task, node_name: str,
              registry: TaskRegistry) -> float:
        deps: List[Task] = ctx.cache.get("deps", [])
        if deps:
            cost = sum(cluster.tau(node_name, t.node) for t in deps)
            return float(100.0 / (1.0 + cost))
        # NOTE (paper section IV-B1): Diktyo "fails to detect the
        # dependencies of the job's first pod" — with no deployed dependency
        # it falls back to default resource (least-allocated) scoring, i.e.
        # it can land the first pod on a congested node.
        node = cluster.node(node_name)
        cap = node.capacity
        free_after = node.free - pod.resources
        terms = [
            getattr(free_after, a) / getattr(cap, a)
            for a in ("cpu", "mem", "gpu") if getattr(cap, a) > 0
        ]
        return float(np.mean(terms)) if terms else 0.0


class ExclusivePlugin(SchedulerPlugin):
    """Exclusive bandwidth reservation (refs [12],[13] in the paper)."""

    name = "exclusive"

    def filter(self, ctx, cluster: Cluster, pod: Task, node_name: str,
               registry: TaskRegistry) -> bool:
        node = cluster.node(node_name)
        if not pod.resources.fits_in(node.free):
            return False
        reserved = sum(node.pods.values())
        return reserved + pod.traffic.bw_gbps <= node.alloc_bw

    def score(self, ctx, cluster: Cluster, pod: Task, node_name: str,
              registry: TaskRegistry) -> float:
        node = cluster.node(node_name)
        reserved = sum(node.pods.values())
        return 100.0 * (1.0 - reserved / max(node.alloc_bw, 1e-9))

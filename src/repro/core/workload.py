"""Workload hierarchy: workload -> job -> task(pod), plus traffic specs.

Mirrors the paper's CRDs:
  - PodBandwidth -> :class:`TrafficSpec` (period t_p, duty cycle d_p, r_p^BW)
  - AppGroup     -> :class:`Workload.dependencies` (nu_w)

Priorities: the paper defines two levels (high/low) assigned via pod labels.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Tuple

from .cluster import Resources

HIGH = 1
LOW = 0

_uid_counter = itertools.count()


@dataclasses.dataclass
class TrafficSpec:
    """Periodic on-off traffic pattern of one task (PodBandwidth CR).

    period_ms : iteration time t_p under contention-free conditions.
    duty      : communication duty cycle d_p in [0, 1].
    bw_gbps   : bandwidth demand r_p^BW during the communication phase.
    """

    period_ms: float
    duty: float
    bw_gbps: float

    @property
    def comm_ms(self) -> float:
        """m_p = t_p * d_p — communication duration per iteration."""
        return self.period_ms * self.duty

    @property
    def compute_ms(self) -> float:
        return self.period_ms - self.comm_ms

    @property
    def low_comm(self) -> bool:
        """LowComm pods declare no bandwidth requirement (paper section III-B)."""
        return self.bw_gbps <= 0.0 or self.duty <= 0.0


@dataclasses.dataclass
class Task:
    """One pod of a distributed training job."""

    uid: str
    job: str
    workload: str
    resources: Resources
    traffic: TrafficSpec
    priority: int = LOW
    node: Optional[str] = None  # assigned by the scheduler
    # time-shift (ms) of the communication phase, assigned by the controller
    shift_ms: float = 0.0
    # PodTopologySpread: max pods of this job per node (0 = unlimited)
    spread: int = 0

    @property
    def low_comm(self) -> bool:
        return self.traffic.low_comm


@dataclasses.dataclass
class Job:
    """A distributed training job = a set of synchronized parallel tasks."""

    name: str
    workload: str
    tasks: List[Task]
    priority: int = LOW
    n_iterations: int = 1000
    submit_time_s: float = 0.0
    model: str = ""  # ML model name (VGG19, BERT, ...)

    @property
    def traffic(self) -> TrafficSpec:
        return self.tasks[0].traffic

    def nodes_used(self) -> List[str]:
        return sorted({t.node for t in self.tasks if t.node is not None})

    def spans_multiple_nodes(self) -> bool:
        return len(self.nodes_used()) > 1


@dataclasses.dataclass
class Workload:
    """User submission: possibly several jobs (e.g. HPO sweep) + deps nu_w."""

    name: str
    jobs: List[Job]
    # nu_w: (job_a, job_b) pairs with inter-job dependencies
    dependencies: List[Tuple[str, str]] = dataclasses.field(default_factory=list)

    def all_tasks(self) -> List[Task]:
        return [t for j in self.jobs for t in j.tasks]


def make_job(
    name: str,
    *,
    n_tasks: int,
    period_ms: float,
    duty: float,
    bw_gbps: float,
    priority: int = LOW,
    resources: Optional[Resources] = None,
    workload: str = "",
    n_iterations: int = 1000,
    submit_time_s: float = 0.0,
    model: str = "",
    spread: int = 1,
) -> Job:
    """Convenience constructor for a DP training job with uniform tasks.

    ``spread`` mirrors K8s PodTopologySpread (Kubeflow jobs spread workers
    across nodes); 0 disables the constraint.
    """
    workload = workload or name
    resources = resources or Resources(cpu=5, mem=5, gpu=1)
    tasks = []
    for i in range(n_tasks):
        uid = f"{name}/task-{i}"
        tasks.append(
            Task(
                uid=uid,
                job=name,
                workload=workload,
                resources=dataclasses.replace(resources),
                traffic=TrafficSpec(period_ms, duty, bw_gbps),
                priority=priority,
                spread=spread,
            )
        )
    return Job(
        name=name,
        workload=workload,
        tasks=tasks,
        priority=priority,
        n_iterations=n_iterations,
        submit_time_s=submit_time_s,
        model=model,
    )


def traffic_from_roofline(
    step_compute_s: float,
    step_collective_s: float,
    bw_gbps: float,
) -> TrafficSpec:
    """Derive a Metronome TrafficSpec from roofline terms of a compiled step.

    This is the bridge between the JAX training substrate and the scheduler:
    period = full step time, duty = collective fraction (the sync phase the
    paper interleaves), bandwidth = the job's DCN demand.
    """
    period_ms = (step_compute_s + step_collective_s) * 1e3
    duty = 0.0 if period_ms <= 0 else (step_collective_s * 1e3) / period_ms
    return TrafficSpec(period_ms=period_ms, duty=duty, bw_gbps=bw_gbps)


def fresh_uid(prefix: str = "pod") -> str:
    return f"{prefix}-{next(_uid_counter)}"

"""Event-driven fluid-flow cluster simulator.

Executes placed training jobs with periodic on-off traffic over the shared
fabric (the paper's contention model, generalized to multi-tier links):

  * each job iterates: compute phase -> synchronized communication phase;
  * during communication, each multi-node job places one flow per used host
    link with demand ``r^BW`` and volume ``r^BW * m_p``; when the job spans
    leaves, the flow also traverses its source leaf's spine uplink;
  * concurrent flows share bandwidth max-min fairly across their full link
    paths (progressive filling); on the default star topology every path is
    one host link and the allocation matches the seed's per-link
    water-filling bit-for-bit. Contention stretches the communication phase
    and stalls the next compute phase ("delayed flows stall the subsequent
    computations", section I);
  * compute-phase jitter models the paper's communication drift; the
    Metronome stop-and-wait controller pauses LOW priority jobs to realign.

Measured outputs per run: per-job iteration durations, average time per
1,000 iterations, per-link utilization (host links keyed by node name,
uplinks by ``uplink:<leaf>``), Gamma (Eq. 5), readjustment count, and total
completion time.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from . import events as events_mod
from . import topology
from .cluster import Cluster
from .contention import LinkView
from .controller import StopAndWaitController
from .fluid import FluidEngine
# rate-sharing primitives live in the backend-swappable fluid engine now;
# re-exported here because they are part of the simulator's historical API
from .fluid import _max_min_fair, _progressive_fill  # noqa: F401
from .telemetry import TelemetryChannel, TelemetryView
from .workload import HIGH, Job

EPS = 1e-9

COMPUTE, COMM, PAUSED, WAITING, DONE = "compute", "comm", "paused", "waiting", "done"
# a job with a task on a failed host: inert until every failed host returns
STALLED = "stalled"


@dataclasses.dataclass
class BackgroundFlow:
    """iPerf3-style unregulated traffic permanently occupying one link.

    ``node`` names a host link (the seed behavior); pass ``link`` to pin the
    traffic to any fabric link instead (e.g. ``uplink:leaf0`` for cross-rack
    background load)."""

    node: str
    rate_gbps: float
    link: Optional[str] = None

    @property
    def link_id(self) -> str:
        return self.link if self.link is not None else self.node


@dataclasses.dataclass
class SimConfig:
    duration_ms: float = 60_000.0
    jitter_std: float = 0.02  # compute-phase noise (fraction), causes drift
    startup_ms: float = 0.0
    latency_penalty_ms_per_tau: float = 1.0  # extra comm ms per unit tau above 1
    seed: int = 0
    sample_interval_ms: float = 1000.0
    monitor: bool = True  # enable the continuous monitoring mechanism
    # rate-sharing backend of the fluid engine (core/fluid.py):
    # 'python' (the bit-for-bit seed path), 'jnp', or 'kernel'
    fluid_backend: str = "python"
    # None picks the backend default (off for python, on for vectorized);
    # True memoizes per affinity component so events re-fill only the
    # component they touch
    fluid_incremental: Optional[bool] = None
    # event-loop implementation (DESIGN.md section 17): 'array' keeps flow
    # state in contiguous arrays with dirty-link rate invalidation (the
    # production hot path, bit-for-bit equal to the seed on the python
    # backend); 'legacy' is the pre-array per-object loop, retained as the
    # parity oracle and the benchmark's pre-optimization reference
    event_loop: str = "array"
    # collect per-phase counters/timings into SimResult.profile
    profile: bool = False
    # observation channel for the control plane (DESIGN.md section 19):
    # None = oracle telemetry (the seed behavior, bit-for-bit); a
    # TelemetryChannel routes every scheduler/controller read of
    # allocatable bandwidth through sampled/noisy/stale observation
    telemetry: Optional[TelemetryChannel] = None
    # event-stream boundary validation: False (default) warn-onces and
    # drops malformed-value events, keeping the historical fire-time
    # UnknownEventTargetWarning for unknown targets; True raises a
    # structured events.EventValidationError on ANY problem before the
    # run starts
    strict_events: bool = False


@dataclasses.dataclass
class SimProfile:
    """Per-phase counters/timings of one run (``SimConfig.profile``).

    Wall-clock seconds per event-loop phase plus work counters; attached to
    ``SimResult.profile`` and surfaced as rows of the dynamic-throughput
    bench artifact.  ``solves`` counts rate re-solves actually performed,
    ``skipped_assigns`` ticks where nothing was dirty — their ratio is the
    dirty-tracking win."""

    loop: str = ""
    ticks: int = 0
    assign_s: float = 0.0
    next_event_s: float = 0.0
    advance_s: float = 0.0
    events_s: float = 0.0
    step_s: float = 0.0
    events_applied: int = 0
    steps: int = 0
    solves: int = 0
    skipped_assigns: int = 0

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    def phase_seconds(self) -> Dict[str, float]:
        return {"assign": self.assign_s, "next_event": self.next_event_s,
                "advance": self.advance_s, "events": self.events_s,
                "step": self.step_s}


@dataclasses.dataclass
class FlowState:
    job: str
    node: str  # source host link
    demand_gbps: float
    remaining_gb: float
    rate_gbps: float = 0.0
    # full link path (source host link first, then fabric links); defaults
    # to the host link only — the seed's star model
    links: Tuple[str, ...] = ()

    def __post_init__(self) -> None:
        if not self.links:
            self.links = (self.node,)


@dataclasses.dataclass
class JobState:
    job: Job
    phase: str = WAITING
    phase_end: float = math.inf
    flows: List[FlowState] = dataclasses.field(default_factory=list)
    iter_index: int = 0
    iter_start: float = 0.0
    durations_ms: List[float] = dataclasses.field(default_factory=list)
    pending_pause_ms: float = 0.0
    pause_in_iter_ms: float = 0.0  # controller-initiated pause this iteration
    realign_pending: bool = False
    start_time: float = 0.0
    finish_time: Optional[float] = None
    comm_extra_ms: float = 0.0  # latency penalty tail of the comm phase
    # array event loop: position in the simulator's job arrays (admission
    # order) and the flow-table slots of the current comm phase
    index: int = -1
    flow_slots: Optional[np.ndarray] = None
    # fault injection / drift (DESIGN.md section 19): failed hosts this
    # job has tasks on (non-empty <=> STALLED); silent multiplier on the
    # job's ACTUAL comm time vs its declared profile; wall-clock start of
    # the current comm phase (feeds measured-vs-declared reconciliation)
    stall_hosts: Set[str] = dataclasses.field(default_factory=set)
    drift_mult: float = 1.0
    comm_start: float = 0.0

    @property
    def name(self) -> str:
        return self.job.name


@dataclasses.dataclass
class SimResult:
    durations_ms: Dict[str, List[float]]
    time_per_1000_iters_s: Dict[str, float]
    link_utilization: Dict[str, float]
    avg_bw_utilization: float  # Gamma, Eq. 5
    readjustments: int
    finish_times_ms: Dict[str, float]
    total_completion_ms: float
    iterations_done: Dict[str, int]
    reconfigurations: int = 0  # controller reconfiguration ops (section III-C)
    # degradation control (DESIGN.md section 19): link changes the
    # hysteresis gate debounced, and measured-vs-declared profile
    # reconciliations adopted
    suppressed_reconfigurations: int = 0
    reconciliations: int = 0
    profile: Optional[SimProfile] = None  # set when SimConfig.profile

    def mean_iter_ms(self, job: str) -> float:
        d = self.durations_ms.get(job, [])
        return float(np.mean(d)) if d else math.nan

    @property
    def uplink_utilization(self) -> Dict[str, float]:
        """Utilization of spine uplinks only (empty on star topologies)."""
        return {k: v for k, v in self.link_utilization.items()
                if topology.is_uplink(k)}


_PHASE_CODE = {WAITING: 0, COMPUTE: 1, PAUSED: 2, COMM: 3, DONE: 4,
               STALLED: 5}
_COMM_CODE = _PHASE_CODE[COMM]


class _FlowTable:
    """Array-resident flow state (struct-of-arrays with a free list).

    The array event loop's single source of truth for per-flow state:
    ``demand``/``remaining``/``rate`` are float64 (the oracle's precision),
    ``job``/``pos`` key each slot to (job admission index, position inside
    the job's flow list) — the seed's iteration order, which every
    order-sensitive float reduction must replay — and the link incidence
    lives twice: as int rows of ``links`` (``-1``-padded, for vectorized
    delivered-GB scatters and component labeling) and as the original link
    id tuples in ``paths`` (for solver inputs and dirty marking).  Slots
    are recycled through a free list; capacity doubles on demand."""

    def __init__(self, link_index: Dict[str, int], cap: int = 64) -> None:
        self.link_index = link_index
        self.cap = cap
        self.maxp = 2
        self.demand = np.zeros(cap)
        self.remaining = np.zeros(cap)
        self.rate = np.zeros(cap)
        self.job = np.full(cap, -1, dtype=np.int64)
        self.pos = np.zeros(cap, dtype=np.int64)
        self.alive = np.zeros(cap, dtype=bool)
        self.links = np.full((cap, self.maxp), -1, dtype=np.int64)
        self.paths: List[Optional[Tuple[str, ...]]] = [None] * cap
        self._free = list(range(cap - 1, -1, -1))

    def _grow(self) -> None:
        old, new = self.cap, self.cap * 2
        for name in ("demand", "remaining", "rate"):
            arr = np.zeros(new)
            arr[:old] = getattr(self, name)
            setattr(self, name, arr)
        job = np.full(new, -1, dtype=np.int64)
        job[:old] = self.job
        self.job = job
        pos = np.zeros(new, dtype=np.int64)
        pos[:old] = self.pos
        self.pos = pos
        alive = np.zeros(new, dtype=bool)
        alive[:old] = self.alive
        self.alive = alive
        links = np.full((new, self.maxp), -1, dtype=np.int64)
        links[:old] = self.links
        self.links = links
        self.paths.extend([None] * old)
        self._free.extend(range(new - 1, old - 1, -1))
        self.cap = new

    def add(self, job_idx: int, pos: int, demand: float, remaining: float,
            path: Tuple[str, ...]) -> int:
        if not self._free:
            self._grow()
        if len(path) > self.maxp:
            wider = np.full((self.cap, len(path)), -1, dtype=np.int64)
            wider[:, : self.maxp] = self.links
            self.links = wider
            self.maxp = len(path)
        s = self._free.pop()
        self.demand[s] = demand
        self.remaining[s] = remaining
        self.rate[s] = 0.0
        self.job[s] = job_idx
        self.pos[s] = pos
        self.alive[s] = True
        self.links[s, :] = -1
        for k, l in enumerate(path):
            self.links[s, k] = self.link_index[l]
        self.paths[s] = path
        return s

    def free(self, s: int) -> None:
        self.alive[s] = False
        self.job[s] = -1
        self.paths[s] = None
        self._free.append(s)


class ClusterSimulator:
    def __init__(
        self,
        cluster: Cluster,
        jobs: Sequence[Job],
        config: SimConfig,
        controller: Optional[StopAndWaitController] = None,
        background: Sequence[BackgroundFlow] = (),
        traffic_changes: Sequence[Tuple[float, str, float]] = (),
        registry=None,
        framework=None,
        arrivals: Sequence = (),
        events: Sequence[events_mod.Event] = (),
        offline_recalc: bool = True,
        telemetry: Optional[TelemetryView] = None,
    ) -> None:
        """``events``: typed dynamic-environment events (see ``events.py``);
        ``traffic_changes`` — legacy (time_ms, job, duty_multiplier) tuples —
        are folded into the same timestamp-ordered stream.

        Online mode: pass ``framework`` + ``arrivals`` (workloads whose jobs
        carry submit_time_s). Workloads are scheduled when they arrive,
        queued when the cluster is full, and their pods are evicted on
        completion (the K8s behavior the paper's trace runs under).
        ``offline_recalc=False`` skips the controller's third-stage offline
        recalculation after each online admission (the trace-mode analogue
        of ``Policy.skip_third_stage``).

        ``telemetry``: a :class:`TelemetryView` proxy over ``cluster``.
        The fluid physics always runs on the true cluster; every
        controller interaction (reconfiguration, offline recalculation,
        re-baselining) goes through the proxy so the control plane sees
        only observed state.  ``None`` (with ``config.telemetry`` unset)
        is oracle mode — the seed behavior, bit-for-bit.
        """
        self.cluster = cluster
        self.config = config
        self.controller = controller
        if telemetry is None and config.telemetry is not None:
            telemetry = TelemetryView(cluster, config.telemetry,
                                      seed=config.seed)
        self.telemetry = telemetry
        # what the CONTROL PLANE reads: the observed proxy when a channel
        # is configured, the true cluster otherwise
        self._ctl_cluster = telemetry if telemetry is not None else cluster
        # fault-injection state: failed link -> its pre-failure
        # (capacity, allocatable) pair; currently-failed hosts
        self._failed_links: Dict[str, Tuple[float, Optional[float]]] = {}
        self._failed_hosts: Set[str] = set()
        self.offline_recalc = offline_recalc
        self.rng = np.random.default_rng(config.seed)
        self.jobs: Dict[str, JobState] = {}
        self.registry = registry
        self.framework = framework
        self.background = list(background)
        # unified demand/flow view (contention layer); flows_for reads the
        # live Job objects, so one instance serves the whole run
        self._link_view = LinkView(cluster)
        # backend-swappable rate-sharing core; the allocatable-capacity map
        # is cached per cluster epoch (every capacity/background mutation
        # bumps it), so steady-state iterations skip the rebuild
        self.fluid = FluidEngine(backend=config.fluid_backend,
                                 incremental=config.fluid_incremental)
        self._caps_fn: Optional[Callable[[str], float]] = None
        self._caps_epoch: int = -1
        self._events = collections.deque(
            events_mod.normalize_events(events, traffic_changes))
        self.delivered_gb: Dict[str, float] = {l: 0.0 for l in cluster.link_ids}
        self.now = 0.0
        self.rejected: List[str] = []
        if config.event_loop not in ("array", "legacy"):
            raise ValueError(
                f"unknown event_loop {config.event_loop!r}; "
                "expected 'array' or 'legacy'")
        self._array_mode = config.event_loop == "array"
        self.profile: Optional[SimProfile] = (
            SimProfile(loop=config.event_loop) if config.profile else None)
        # ---- array-resident state (DESIGN.md section 17) ----
        # link registry: contiguous delivered-GB vector aligned with the
        # cluster's link ids (the dict above stays the external view and is
        # synced at _result time in array mode)
        self._link_ids: List[str] = list(cluster.link_ids)
        self._link_index: Dict[str, int] = {
            l: i for i, l in enumerate(self._link_ids)}
        self._delivered_vec = np.zeros(len(self._link_ids))
        self._flows = _FlowTable(self._link_index)
        # job mirrors (index = admission order == jobs-dict order; entries
        # are never removed, matching the dict): phase code, next timed
        # event (inf when the phase has none), comm-flow bookkeeping
        self._jobs_list: List[JobState] = []
        self._jp = np.zeros(64, dtype=np.int8)
        self._jnext = np.full(64, math.inf)
        self._jhasflows = np.zeros(64, dtype=bool)
        self._junfin = np.zeros(64, dtype=np.int64)
        # dirty-link rate invalidation (component-granular refills)
        self._dirty_links: Set[str] = set()
        self._all_dirty = True
        self._last_fill_mode: Optional[str] = None
        # cached (job, pos)-ordered active slots + flattened path incidence
        self._order_stale = True
        self._act = np.empty(0, dtype=np.int64)
        self._flat_links = np.empty(0, dtype=np.int64)
        self._flat_rows = np.empty(0, dtype=np.int64)
        self._warned: Set[Tuple[str, str]] = set()
        # (arrival_ms, workload) queue for online scheduling
        self._arrivals = collections.deque(sorted(
            ((min(j.submit_time_s for j in wl.jobs) * 1e3, i, wl)
             for i, wl in enumerate(arrivals)),
            key=lambda t: (t[0], t[1])))
        self._pending = []  # workloads waiting for capacity
        for job in jobs:
            self._admit_job(job)

    @property
    def pending_jobs(self) -> List[str]:
        """Names of jobs whose workloads are queued waiting for capacity
        (online mode's rejected-so-far list)."""
        return [j.name for wl in self._pending for j in wl.jobs]

    def _admit_job(self, job: Job) -> None:
        config = self.config
        controller = self.controller
        st = JobState(job=job)
        base_start = max(self.now, job.submit_time_s * 1e3) + config.startup_ms
        if controller is not None:
            controller.set_baseline(job.name, job.traffic.period_ms,
                                    job.priority)
            align = controller.job_alignment(job.name)
            if align is not None:
                # delay the job start so its FIRST comm phase lands on
                # the assigned circle offset (absolute-time epoch)
                offset, period_eff = align
                inject = controller.injected_ms.get(job.name, 0.0)
                first_comm = base_start + job.traffic.compute_ms + inject
                base_start += (offset - first_comm) % period_eff
        st.start_time = base_start
        st.phase = WAITING
        st.phase_end = st.start_time
        self.jobs[job.name] = st
        self._register_job(st)

    # ------------------------------------------------------- online arrivals
    def _try_schedule(self, wl) -> bool:
        assert self.framework is not None
        if self.framework.schedule_workload(wl):
            if self.controller is not None and self.offline_recalc:
                self.controller.run_offline_recalculation(
                    self.framework.registry, self._ctl_cluster)
            for job in wl.jobs:
                self._admit_job(job)
            # a new scheme may shift existing low-priority jobs
            if self.controller is not None:
                for name, st in self.jobs.items():
                    job = st.job
                    if (st.phase not in (DONE,) and job.priority != HIGH
                            and name not in {j.name for j in wl.jobs}):
                        self._apply_realign(name)
            return True
        return False

    def _process_arrivals(self) -> None:
        while self._arrivals and self._arrivals[0][0] <= self.now + EPS:
            _, _, wl = self._arrivals.popleft()
            if not self._try_schedule(wl):
                self._pending.append(wl)

    def _on_job_done(self, st: JobState) -> None:
        if self.framework is not None:
            job_obj = self.framework.registry.jobs.get(st.job.name)
            if job_obj is not None:
                self.framework.evict_job(job_obj)
            # freed capacity: retry the pending queue in FIFO order
            still = []
            for wl in self._pending:
                if not self._try_schedule(wl):
                    still.append(wl)
            self._pending = still

    # --------------------------------------------------------------- traffic
    def _make_flows(self, job: Job, comm_ms: float) -> List[FlowState]:
        """One flow per used host link; the path extends over the source
        leaf's uplink when the job spans leaves.  The flow specification
        (which links, how much demand) comes from the unified contention
        layer — the simulator only adds volume (demand x ACTUAL comm
        time, which silent drift may have moved off the declared
        profile)."""
        return [
            FlowState(job.name, fs.node, fs.demand_gbps,
                      fs.demand_gbps * comm_ms / 1e3, links=fs.links)
            for fs in self._flow_specs(job)
        ]

    def _latency_penalty(self, job: Job) -> float:
        nodes = job.nodes_used()
        if len(nodes) <= 1:
            return 0.0
        worst = max(
            self.cluster.tau(a, b) for a in nodes for b in nodes if a != b
        )
        return self.config.latency_penalty_ms_per_tau * max(0.0, worst - 1.0)

    # ----------------------------------------------------------- rate sharing
    def _allocatable(self) -> Callable[[str], float]:
        """Per-link allocatable capacity (physical minus background),
        rebuilt only when the cluster epoch advances — every mutation path
        (capacity events, background ramps, allocations) bumps it."""
        epoch = self.cluster.epoch
        if self._caps_fn is None or self._caps_epoch != epoch:
            bg_by_link: Dict[str, float] = {}
            for bg in self.background:
                bg_by_link[bg.link_id] = (bg_by_link.get(bg.link_id, 0.0)
                                          + bg.rate_gbps)
            cache: Dict[str, float] = {}

            def cap_of(link_id: str) -> float:
                cap = cache.get(link_id)
                if cap is None:
                    cap = max(0.0, self.cluster.link_capacity(link_id)
                              - bg_by_link.get(link_id, 0.0))
                    cache[link_id] = cap
                return cap

            self._caps_fn = cap_of
            self._caps_epoch = epoch
        return self._caps_fn

    def _assign_rates(self) -> None:
        """Max-min fair share over each flow's link path, capped at r^BW.

        Delegates to the backend-swappable fluid engine (``core/fluid.py``).
        Star topology (every path a single host link): per-link water
        filling, numerically identical to the seed. Multi-link paths
        (fabric uplinks): progressive filling with per-link bottlenecks.
        """
        active = [f for st in self.jobs.values() for f in st.flows
                  if f.remaining_gb > EPS]
        if not active:
            return
        self.fluid.assign(active, self._allocatable())

    # ------------------------------------------------- array-resident state
    def _register_job(self, st: JobState) -> None:
        """Mirror a newly admitted job into the flat job arrays."""
        st.index = len(self._jobs_list)
        self._jobs_list.append(st)
        n = self._jp.shape[0]
        if st.index >= n:
            self._jp = np.concatenate([self._jp, np.zeros(n, dtype=np.int8)])
            self._jnext = np.concatenate([self._jnext, np.full(n, math.inf)])
            self._jhasflows = np.concatenate(
                [self._jhasflows, np.zeros(n, dtype=bool)])
            self._junfin = np.concatenate(
                [self._junfin, np.zeros(n, dtype=np.int64)])
        self._sync_job(st)

    def _sync_job(self, st: JobState) -> None:
        """Re-mirror one job's phase/phase_end after any transition.

        Invariant (DESIGN.md section 17): ``_jnext[i]`` is the job's next
        timed event — ``phase_end`` for WAITING/COMPUTE/PAUSED and for a
        flowless COMM phase (single-node sync or latency tail), ``inf``
        otherwise — so the array loop's next-event reduction is one min."""
        i = st.index
        code = _PHASE_CODE[st.phase]
        self._jp[i] = code
        if code <= 2 or (code == _COMM_CODE and not self._jhasflows[i]):
            self._jnext[i] = st.phase_end
        else:
            self._jnext[i] = math.inf

    def _flow_specs(self, job: Job):
        return self._link_view.flows_for(job, cache_epoch=self.cluster.epoch)

    def _start_comm_flows(self, st: JobState, comm_ms: float) -> bool:
        """Create the job's comm-phase flows; False for single-node jobs.

        Array mode registers table slots keyed (job index, spec position) —
        the seed's flow iteration order — and marks the touched links dirty;
        legacy mode builds the historical FlowState objects."""
        if not self._array_mode:
            st.flows = self._make_flows(st.job, comm_ms)
            return bool(st.flows)
        specs = self._flow_specs(st.job)
        if not specs:
            return False
        tbl = self._flows
        slots = np.empty(len(specs), dtype=np.int64)
        unfinished = 0
        for k, fs in enumerate(specs):
            remaining = fs.demand_gbps * comm_ms / 1e3
            slots[k] = tbl.add(st.index, k, fs.demand_gbps, remaining,
                               fs.links)
            if remaining > EPS:
                unfinished += 1
            self._dirty_links.update(fs.links)
        st.flow_slots = slots
        self._jhasflows[st.index] = True
        self._junfin[st.index] = unfinished
        self._order_stale = True
        return True

    def _clear_flows(self, st: JobState) -> None:
        """Release the job's flows (comm end / departure); still-active
        flows leave their links, so those links' rates are invalidated."""
        if not self._array_mode:
            st.flows = []
            return
        if st.flow_slots is not None:
            tbl = self._flows
            for s in st.flow_slots:
                if tbl.remaining[s] > EPS:
                    self._dirty_links.update(tbl.paths[s])
                tbl.free(s)
            self._order_stale = True
        st.flow_slots = None
        self._jhasflows[st.index] = False
        self._junfin[st.index] = 0

    def _job_has_flows(self, st: JobState) -> bool:
        if self._array_mode:
            return bool(self._jhasflows[st.index])
        return bool(st.flows)

    def _job_flows_done(self, st: JobState) -> bool:
        if self._array_mode:
            return self._junfin[st.index] == 0
        return all(f.remaining_gb <= EPS for f in st.flows)

    def _active_slots(self) -> np.ndarray:
        """Alive flows with volume left, in (job index, position) order —
        the seed's iteration order, which the order-sensitive float
        reductions (delivered-GB accumulation, per-link grouping) replay
        exactly.  Rebuilt only when flow membership changes; alongside it
        the flattened (slot row, path link) incidence used by the
        delivered-GB scatter-add."""
        if self._order_stale:
            tbl = self._flows
            alive = np.nonzero(tbl.alive)[0]
            act = alive[tbl.remaining[alive] > EPS]
            if act.size:
                act = act[np.lexsort((tbl.pos[act], tbl.job[act]))]
                sub = tbl.links[act]
                mask = sub >= 0
                rows, _ = np.nonzero(mask)
                self._flat_links = sub[mask]
                self._flat_rows = rows
            else:
                self._flat_links = np.empty(0, dtype=np.int64)
                self._flat_rows = np.empty(0, dtype=np.int64)
            self._act = act
            self._order_stale = False
        return self._act

    # ------------------------------------------------------------- main loop
    def run(self) -> SimResult:
        self._validate_events()
        if self._array_mode:
            return self._run_array()
        return self._run_legacy()

    def _validate_events(self) -> None:
        """Boundary validation of the event stream (DESIGN.md section 19).

        ``strict_events=True``: any problem — malformed values OR unknown
        targets — raises a structured ``EventValidationError`` before the
        clock starts.  Default mode: malformed-value events (NaN rates,
        negative capacities) are warn-onced and DROPPED (firing them
        would corrupt the fluid state); unknown-target events keep the
        historical fire-time ``UnknownEventTargetWarning`` path, so their
        reported ``time_ms`` stays the firing time."""
        if not self._events:
            return
        known_jobs = set(self.jobs)
        for _, _, wl in self._arrivals:
            known_jobs.update(j.name for j in wl.jobs)
        for wl in self._pending:
            known_jobs.update(j.name for j in wl.jobs)
        problems = events_mod.validate_stream(
            list(self._events),
            known_links=set(self.delivered_gb),
            known_hosts=set(self.cluster.nodes),
            known_jobs=known_jobs)
        if not problems:
            return
        if self.config.strict_events:
            raise events_mod.EventValidationError(problems)
        drop = set()
        for p in problems:
            if p.category != "bad-value":
                continue
            drop.add(p.index)
            key = ("value", f"{p.kind}:{p.name}")
            if key not in self._warned:
                self._warned.add(key)
                warnings.warn(f"{p.message} — event dropped", UserWarning,
                              stacklevel=3)
        if drop:
            self._events = collections.deque(
                ev for i, ev in enumerate(self._events) if i not in drop)

    def _run_legacy(self) -> SimResult:
        """The pre-array per-object event loop, preserved verbatim: the
        parity oracle of the array loop (pinned bit-for-bit by
        ``tests/test_event_loop.py``) and the ``bench_dynamic_throughput``
        pre-optimization reference."""
        cfg = self.config
        prof = self.profile
        perf = time.perf_counter
        while self.now < cfg.duration_ms:
            t0 = perf() if prof is not None else 0.0
            self._assign_rates()
            if prof is not None:
                t1 = perf()
                prof.assign_s += t1 - t0
                prof.solves += 1
            # next event time
            nxt = cfg.duration_ms
            for st in self.jobs.values():
                if st.phase in (COMPUTE, PAUSED, WAITING):
                    nxt = min(nxt, st.phase_end)
                elif st.phase == COMM:
                    if st.flows:
                        for f in st.flows:
                            if f.remaining_gb > EPS and f.rate_gbps > EPS:
                                nxt = min(nxt, self.now + f.remaining_gb / f.rate_gbps * 1e3)
                    else:
                        nxt = min(nxt, st.phase_end)
            if self._events:
                nxt = min(nxt, self._events[0].time_ms)
            if self._arrivals:
                nxt = min(nxt, self._arrivals[0][0])
            nxt = max(nxt, self.now)  # no time travel
            dt = nxt - self.now
            if prof is not None:
                t2 = perf()
                prof.next_event_s += t2 - t1

            # advance flows and accounting
            if dt > 0:
                for st in self.jobs.values():
                    for f in st.flows:
                        if f.remaining_gb > EPS:
                            moved = min(f.remaining_gb, f.rate_gbps * dt / 1e3)
                            f.remaining_gb -= moved
                            for l in f.links:
                                self.delivered_gb[l] += moved
                for bg in self.background:
                    self.delivered_gb[bg.link_id] += bg.rate_gbps * dt / 1e3
            self.now = nxt
            if self.telemetry is not None:
                self.telemetry.now_ms = self.now
            if prof is not None:
                t3 = perf()
                prof.advance_s += t3 - t2
                prof.ticks += 1
            if self.now >= cfg.duration_ms:
                break

            # dynamic-environment events (traffic / background / capacity /
            # departures), in timestamp order
            while self._events and self._events[0].time_ms <= self.now + EPS:
                self._apply_event(self._events.popleft())
                if prof is not None:
                    prof.events_applied += 1

            # online arrivals (may add jobs)
            self._process_arrivals()
            if prof is not None:
                t4 = perf()
                prof.events_s += t4 - t3

            # job phase transitions
            done_before = {n for n, s in self.jobs.items() if s.phase == DONE}
            for st in list(self.jobs.values()):
                self._step_job(st)
            for name, st in list(self.jobs.items()):
                if st.phase == DONE and name not in done_before:
                    self._on_job_done(st)
            if prof is not None:
                prof.step_s += perf() - t4
                prof.steps += len(self.jobs)
        return self._result()

    def _run_array(self) -> SimResult:
        """The array event loop: identical tick structure to the legacy
        loop, but every per-job/per-flow scan is a vectorized reduction
        over the flat mirrors and rates re-solve only when dirty.  With
        ``fluid_backend='python'`` the outputs are bit-for-bit equal to
        ``_run_legacy`` (the oracle-parity contract, DESIGN.md section
        17)."""
        cfg = self.config
        duration = cfg.duration_ms
        prof = self.profile
        perf = time.perf_counter
        tbl = self._flows
        dv = self._delivered_vec
        link_index = self._link_index
        while self.now < duration:
            t0 = perf() if prof is not None else 0.0
            self._assign_rates_array()
            if prof is not None:
                t1 = perf()
                prof.assign_s += t1 - t0

            # next event time: one min over job mirrors + one over flows
            nxt = duration
            n = len(self._jobs_list)
            if n:
                m = self._jnext[:n].min()
                if m < nxt:
                    nxt = float(m)
            act = self._active_slots()
            if act.size:
                r = tbl.rate[act]
                mask = r > EPS
                if mask.any():
                    m = (self.now + tbl.remaining[act[mask]] / r[mask] * 1e3).min()
                    if m < nxt:
                        nxt = float(m)
            if self._events:
                nxt = min(nxt, self._events[0].time_ms)
            if self._arrivals:
                nxt = min(nxt, self._arrivals[0][0])
            nxt = max(nxt, self.now)  # no time travel
            dt = nxt - self.now
            if prof is not None:
                t2 = perf()
                prof.next_event_s += t2 - t1

            # advance flows; delivered-GB scatter replays the seed's
            # (job, flow, path-link) accumulation order, then background
            if dt > 0:
                if act.size:
                    rem = tbl.remaining[act]
                    moved = np.minimum(rem, tbl.rate[act] * dt / 1e3)
                    new_rem = rem - moved
                    tbl.remaining[act] = new_rem
                    np.add.at(dv, self._flat_links, moved[self._flat_rows])
                    fin = new_rem <= EPS
                    if fin.any():
                        done_slots = act[fin]
                        for s in done_slots:
                            self._dirty_links.update(tbl.paths[s])
                        np.subtract.at(self._junfin, tbl.job[done_slots], 1)
                        self._order_stale = True
                for bg in self.background:
                    dv[link_index[bg.link_id]] += bg.rate_gbps * dt / 1e3
            self.now = nxt
            if self.telemetry is not None:
                self.telemetry.now_ms = self.now
            if prof is not None:
                t3 = perf()
                prof.advance_s += t3 - t2
                prof.ticks += 1
            if self.now >= duration:
                break

            # dynamic-environment events, in timestamp order
            while self._events and self._events[0].time_ms <= self.now + EPS:
                self._apply_event(self._events.popleft())
                if prof is not None:
                    prof.events_applied += 1

            # online arrivals (may add jobs)
            self._process_arrivals()
            if prof is not None:
                t4 = perf()
                prof.events_s += t4 - t3

            # job phase transitions: only DUE jobs step (the seed steps
            # every job every tick, but _step_job is a strict no-op unless
            # due — pinned by the oracle-parity tests), in admission order
            n = len(self._jobs_list)
            thresh = self.now + EPS
            due_mask = self._jnext[:n] <= thresh
            due_mask |= ((self._jp[:n] == _COMM_CODE)
                         & self._jhasflows[:n] & (self._junfin[:n] == 0))
            newly_done: List[JobState] = []
            due = np.nonzero(due_mask)[0]
            for i in due:
                st = self._jobs_list[i]
                self._step_job(st)
                if st.phase == DONE:
                    newly_done.append(st)
            for st in newly_done:
                self._on_job_done(st)
            if prof is not None:
                prof.step_s += perf() - t4
                prof.steps += int(due.size)
        return self._result()

    # ------------------------------------------- dirty-component rate solves
    def _assign_rates_array(self) -> None:
        """Re-solve rates only where invalidated (DESIGN.md section 17).

        Dirty marks come from flow creation/finish/removal (their links),
        capacity/background events (the event's link), and fill-mode
        transitions (everything).  Clean links keep their stored rates —
        bitwise-identical to the seed re-solving them, because the solve is
        deterministic in inputs that have not changed.

        python backend: all-single-link active sets refill per dirty link
        with the seed's ``_max_min_fair`` (groups in (job, pos) order);
        any multi-link path forces the seed's one global progressive fill.
        Vectorized backends: dirty affinity components are batched through
        one memo-aware ``fluid.solve_batch`` per tick."""
        act = self._active_slots()
        if act.size == 0:
            return
        if not self._dirty_links and not self._all_dirty:
            if self.profile is not None:
                self.profile.skipped_assigns += 1
            return
        tbl = self._flows
        link0 = tbl.links[act, 0]
        single = bool((tbl.links[act, 1:] < 0).all())
        mode = "single" if single else "multi"
        if mode != self._last_fill_mode:
            # per-link and global fills agree mathematically but not
            # bitwise; a mode flip invalidates every stored rate
            self._all_dirty = True
        self._last_fill_mode = mode
        cap_of = self._allocatable()
        if self.profile is not None:
            self.profile.solves += 1
        if self.fluid.backend == "python":
            if single:
                if self._all_dirty:
                    targets = np.unique(link0)
                else:
                    targets = sorted(self._link_index[l]
                                     for l in self._dirty_links)
                for li in targets:
                    grp = act[link0 == li]
                    if grp.size == 0:
                        continue
                    demands = tbl.demand[grp]
                    rates = _max_min_fair(demands, cap_of(self._link_ids[li]))
                    tbl.rate[grp] = rates
            else:
                demands = tbl.demand[act]
                paths = [tbl.paths[s] for s in act]
                caps = {l: cap_of(l) for p in paths for l in p}
                tbl.rate[act] = _progressive_fill(demands, paths, caps)
        else:
            self._assign_vectorized(act, cap_of)
        self._dirty_links.clear()
        self._all_dirty = False

    def _assign_vectorized(self, act: np.ndarray,
                           cap_of: Callable[[str], float]) -> None:
        """Batch every dirty affinity component through ONE memo-aware
        ``fluid.solve_batch`` call (= at most one shape-bucketed
        ``fill_corpus`` dispatch per tick)."""
        tbl = self._flows
        comps = self._components(act)
        dirty_vec = None
        if not self._all_dirty:
            dirty_vec = np.zeros(len(self._link_ids), dtype=bool)
            for l in self._dirty_links:
                dirty_vec[self._link_index[l]] = True
        problems = []
        targets = []
        for comp in comps:
            if dirty_vec is not None:
                sub = tbl.links[comp]
                if not dirty_vec[sub[sub >= 0]].any():
                    continue  # untouched component: stored rates stand
            paths = [tbl.paths[s] for s in comp]
            caps = {l: cap_of(l) for p in paths for l in p}
            problems.append((tbl.demand[comp], paths, caps))
            targets.append(comp)
        if problems:
            for comp, rates in zip(targets, self.fluid.solve_batch(problems)):
                tbl.rate[comp] = rates

    def _components(self, act: np.ndarray) -> List[np.ndarray]:
        """Affinity components of the active flows (flows connected when
        their paths share a link) by vectorized label propagation over the
        flow x link incidence — no per-flow Python union-find in the hot
        path.  Components keep (job, pos) flow order; ordered by first
        flow."""
        tbl = self._flows
        sub = tbl.links[act]
        mask = sub >= 0
        rows, _ = np.nonzero(mask)
        flat = sub[mask]
        lab = np.arange(len(self._link_ids), dtype=np.int64)
        n = act.size
        while True:
            flow_lab = np.full(n, np.iinfo(np.int64).max)
            np.minimum.at(flow_lab, rows, lab[flat])
            new_lab = lab.copy()
            np.minimum.at(new_lab, flat, flow_lab[rows])
            if (new_lab == lab).all():
                break
            lab = new_lab
        comps: Dict[int, List[int]] = {}
        for i in range(n):
            comps.setdefault(int(flow_lab[i]), []).append(int(act[i]))
        return [np.asarray(v, dtype=np.int64) for v in comps.values()]

    # -------------------------------------------------------- dynamic events
    def _apply_event(self, ev: events_mod.Event) -> None:
        if isinstance(ev, events_mod.TrafficChange):
            self._apply_traffic_change(ev.job, ev.duty_mult,
                                       declared=ev.declared)
        elif isinstance(ev, events_mod.BackgroundFlowChange):
            self._apply_bg_change(ev)
        elif isinstance(ev, events_mod.LinkCapacityChange):
            self._apply_capacity_change(ev)
        elif isinstance(ev, events_mod.JobDeparture):
            self._apply_departure(ev)
        elif isinstance(ev, events_mod.LinkFailure):
            self._apply_link_failure(ev)
        elif isinstance(ev, events_mod.LinkRecovery):
            self._apply_link_recovery(ev)
        elif isinstance(ev, events_mod.HostFailure):
            self._apply_host_failure(ev)
        elif isinstance(ev, events_mod.HostRecovery):
            self._apply_host_recovery(ev)
        else:  # pragma: no cover — defensive
            raise TypeError(f"unknown event {ev!r}")

    def _warn_unknown(self, kind: str, name: str) -> None:
        """Structured once-per-offender warning for events that name a
        link/job the simulator does not know (the event itself is still
        ignored, the seed behavior)."""
        key = (kind, name)
        if key in self._warned:
            return
        self._warned.add(key)
        warnings.warn(
            events_mod.UnknownEventTargetWarning(kind, name, self.now),
            stacklevel=2)

    def _apply_bg_change(self, ev: events_mod.BackgroundFlowChange) -> None:
        """Unregulated traffic on one link starts / ramps / stops."""
        if ev.link not in self.delivered_gb:
            self._warn_unknown("link", ev.link)
            return  # unknown link: ignore (mirrors unknown-job traffic change)
        self._dirty_links.add(ev.link)  # allocatable share changes
        kept = [bg for bg in self.background if bg.link_id != ev.link]
        if ev.rate_gbps > EPS:
            node = ev.link if ev.link in self.cluster.nodes else ""
            kept.append(BackgroundFlow(node=node, rate_gbps=ev.rate_gbps,
                                       link=ev.link))
        self.background = kept
        self.cluster.bump_epoch()  # background conditions changed
        if ev.adjust_allocatable:
            # NodeBandwidth-CR path (section III-A): the manager lowers the
            # allocatable share by the observed unregulated rate
            cap = self.cluster.link_capacity(ev.link)
            alloc = max(0.0, cap - max(0.0, ev.rate_gbps))
            self._set_allocatable(ev.link, alloc)
        self._reconfigure_links([ev.link])

    def _apply_capacity_change(self, ev: events_mod.LinkCapacityChange) -> None:
        """NodeBandwidth-CR update: allocatable and/or physical capacity.

        An explicit allocatable share from an earlier event never survives
        above the new physical capacity — the scheduler must not be told a
        link can allocate more than it can carry."""
        if ev.link in self.cluster.nodes:
            target = self.cluster.node(ev.link)
            cap_field = "bw_gbps"
        else:
            target = self.cluster.topology.link(ev.link)
            if target is None:
                self._warn_unknown("link", ev.link)
                return
            cap_field = "capacity_gbps"
        self._dirty_links.add(ev.link)
        if ev.capacity_gbps is not None:
            setattr(target, cap_field, float(ev.capacity_gbps))
        if ev.allocatable_gbps is not None:
            target.allocatable_gbps = float(ev.allocatable_gbps)
        if (target.allocatable_gbps is not None
                and target.allocatable_gbps > getattr(target, cap_field)):
            target.allocatable_gbps = float(getattr(target, cap_field))
        self.cluster.bump_epoch()  # invalidate epoch-scoped planner caches
        self._record_telemetry([ev.link])
        self._reconfigure_links([ev.link])

    # ---------------------------------------------------- fault injection
    def _link_target(self, link_id: str):
        """(object, capacity-field) pair for any known link id."""
        if link_id in self.cluster.nodes:
            return self.cluster.node(link_id), "bw_gbps"
        link = self.cluster.topology.link(link_id)
        if link is None:
            return None, ""
        return link, "capacity_gbps"

    def _fail_link(self, link_id: str) -> bool:
        """Drop a link's capacity and allocatable share to 0, remembering
        the pre-failure pair; False when already failed (flap overlap)."""
        if link_id in self._failed_links:
            return False
        target, cap_field = self._link_target(link_id)
        self._failed_links[link_id] = (getattr(target, cap_field),
                                       target.allocatable_gbps)
        setattr(target, cap_field, 0.0)
        target.allocatable_gbps = 0.0
        self._dirty_links.add(link_id)
        self.cluster.bump_epoch()
        self._record_telemetry([link_id])
        return True

    def _recover_link(self, link_id: str,
                      capacity_gbps: Optional[float] = None) -> bool:
        """Restore a failed link (optionally at a degraded physical
        capacity); False when the link is not failed."""
        saved = self._failed_links.pop(link_id, None)
        if saved is None:
            return False
        cap, alloc = saved
        if capacity_gbps is not None:
            cap = float(capacity_gbps)
            if alloc is not None:
                alloc = min(alloc, cap)
        target, cap_field = self._link_target(link_id)
        setattr(target, cap_field, cap)
        target.allocatable_gbps = alloc
        self._dirty_links.add(link_id)
        self.cluster.bump_epoch()
        self._record_telemetry([link_id])
        return True

    def _apply_link_failure(self, ev: events_mod.LinkFailure) -> None:
        if ev.link not in self.delivered_gb:
            self._warn_unknown("link", ev.link)
            return
        if self._fail_link(ev.link):
            self._reconfigure_links([ev.link])

    def _apply_link_recovery(self, ev: events_mod.LinkRecovery) -> None:
        if ev.link not in self.delivered_gb:
            self._warn_unknown("link", ev.link)
            return
        if self._recover_link(ev.link, ev.capacity_gbps):
            self._reconfigure_links([ev.link])

    def _apply_host_failure(self, ev: events_mod.HostFailure) -> None:
        """A worker dies: its host link fails and every job with a task
        on it stalls — flows drop (their links' rates re-solve), the
        interrupted iteration is abandoned, and the job stays inert (both
        loops: STALLED never appears in next-event reductions) until
        every failed host of the job recovers."""
        host = ev.host
        if host not in self.cluster.nodes:
            self._warn_unknown("host", host)
            return
        if host in self._failed_hosts:
            return
        self._failed_hosts.add(host)
        changed = self._fail_link(host)
        for st in self.jobs.values():
            if st.phase == DONE:
                continue
            if any(t.node == host for t in st.job.tasks):
                st.stall_hosts.add(host)
                if st.phase != STALLED:
                    self._clear_flows(st)
                    st.phase = STALLED
                    st.phase_end = math.inf
                    st.comm_extra_ms = 0.0
                    self._sync_job(st)
        if changed:
            self._reconfigure_links([host])

    def _apply_host_recovery(self, ev: events_mod.HostRecovery) -> None:
        """The worker returns: the host link recovers and jobs stalled
        only on it restart their interrupted iteration from its top
        (pending re-admission: the aborted partial iteration is not
        measured)."""
        host = ev.host
        if host not in self.cluster.nodes:
            self._warn_unknown("host", host)
            return
        if host not in self._failed_hosts:
            return
        self._failed_hosts.discard(host)
        changed = self._recover_link(host)
        for st in self.jobs.values():
            if host in st.stall_hosts:
                st.stall_hosts.discard(host)
                if not st.stall_hosts and st.phase == STALLED:
                    st.phase = WAITING
                    st.phase_end = max(self.now, st.start_time)
                    self._sync_job(st)
        if changed:
            self._reconfigure_links([host])

    def _record_telemetry(self, links: Sequence[str]) -> None:
        """Feed a capacity mutation into the telemetry truth history so
        samples taken later observe the value in force at sample time."""
        if self.telemetry is not None:
            self.telemetry.record_change(self.now, list(links))

    def _apply_departure(self, ev: events_mod.JobDeparture) -> None:
        st = self.jobs.get(ev.job)
        if st is None:
            # never admitted: the job departs from the arrival/pending
            # queues instead (trace truncation of a job that waited out its
            # whole window without getting capacity).  Strip just the
            # departed job — a multi-job workload (HPO sweep) keeps its
            # siblings queued; an emptied workload is dropped.
            def keep(wl) -> bool:
                wl.jobs = [j for j in wl.jobs if j.name != ev.job]
                return bool(wl.jobs)

            self._arrivals = collections.deque(
                t for t in self._arrivals if keep(t[2]))
            self._pending = [wl for wl in self._pending if keep(wl)]
            return
        if st.phase == DONE:
            return
        self._clear_flows(st)
        st.phase = DONE
        st.finish_time = self.now
        self._sync_job(st)
        if self.framework is not None:
            self._on_job_done(st)
            return
        # no framework: release placements and retire the job's schemes so
        # the live LinkView stops seeing the departed job (tasks keep their
        # node fields as a historical record for placement reporting)
        for t in st.job.tasks:
            if t.node is None:
                continue
            if t.node in self.cluster.nodes:
                self.cluster.node(t.node).release(t.uid, t.resources)
                self.cluster.bump_epoch()
            if self.controller is not None:
                self.controller.on_evict(t.node, t, registry=self.registry,
                                         cluster=self._ctl_cluster)
            if self.registry is not None:
                self.registry.tasks.pop(t.uid, None)
                self.registry.bump()
        if self.registry is not None:
            self.registry.jobs.pop(ev.job, None)
            self.registry.bump()

    def _set_allocatable(self, link_id: str, alloc: float) -> None:
        self._dirty_links.add(link_id)
        if link_id in self.cluster.nodes:
            self.cluster.node(link_id).allocatable_gbps = alloc
        else:
            link = self.cluster.topology.link(link_id)
            if link is not None:
                link.allocatable_gbps = alloc
        self.cluster.bump_epoch()  # invalidate epoch-scoped planner caches
        self._record_telemetry([link_id])

    def _reconfigure_links(self, link_ids: Sequence[str]) -> None:
        """The reconfiguration loop (paper section III-C): tell the
        controller which links changed; when it re-derives schemes, snap
        low-priority jobs to the new offsets (high priority never pays).
        The controller reads through ``_ctl_cluster`` — the telemetry
        proxy when one is configured — and gets the clock so its
        hysteresis gate can debounce."""
        if self.controller is None or self.registry is None:
            return
        n = 0
        for l in link_ids:
            n += self.controller.on_link_change(
                self.registry, self._ctl_cluster, l, now_ms=self.now)
        if n:
            for name, st in self.jobs.items():
                if st.phase != DONE and st.job.priority != HIGH:
                    self._apply_realign(name)

    def _apply_traffic_change(self, jname: str, duty_mult: float,
                              declared: bool = True) -> None:
        st = self.jobs.get(jname)
        if st is None:
            self._warn_unknown("job", jname)
            return
        if not declared:
            # silent drift: the job's ACTUAL comm volume/time changes but
            # its declared profile (and the controller's plans) do not —
            # only measured-vs-declared reconciliation can close the gap
            st.drift_mult *= duty_mult
            return
        spec = st.job.traffic
        new_comm = min(spec.period_ms, spec.comm_ms * duty_mult)
        new_spec = dataclasses.replace(
            spec, duty=new_comm / spec.period_ms
        )
        for t in st.job.tasks:
            t.traffic = dataclasses.replace(new_spec)
        if self.registry is not None:
            self.registry.bump()  # stored tasks' traffic changed in place
        if self.controller is not None and self.registry is not None:
            self.controller.report_traffic_change(
                self.registry, self._ctl_cluster, jname, new_spec
            )

    def _step_job(self, st: JobState) -> None:
        if st.phase == DONE:
            return
        job = st.job
        spec = job.traffic
        inject = 0.0
        if self.controller is not None:
            inject = self.controller.injected_ms.get(job.name, 0.0)

        if st.phase == WAITING and self.now + EPS >= st.phase_end:
            st.iter_start = self.now
            self._enter_compute(st, inject)
            return
        if st.phase in (COMPUTE, PAUSED) and self.now + EPS >= st.phase_end:
            # phase-aware drift detection (controller.report_phase_error)
            if self.controller is not None and self.config.monitor:
                align = self.controller.job_alignment(job.name)
                if align is not None:
                    offset, period_eff = align
                    err = (self.now - offset) % period_eff
                    for act in self.controller.report_phase_error(
                            job.name, err, period_eff):
                        self._apply_realign(act.job)
            # start synchronized communication; silent drift moves the
            # ACTUAL comm time off the declared profile (clipped at the
            # period, like a declared change would be)
            comm_ms = spec.comm_ms
            if st.drift_mult != 1.0:
                comm_ms = min(spec.period_ms, spec.comm_ms * st.drift_mult)
            has_flows = self._start_comm_flows(st, comm_ms)
            st.comm_extra_ms = self._latency_penalty(job)
            st.comm_start = self.now
            st.phase = COMM
            if not has_flows:
                # single-node job: loopback sync takes the ideal comm time
                st.phase_end = self.now + comm_ms + st.comm_extra_ms
            else:
                st.phase_end = math.inf
            self._sync_job(st)
            return
        if st.phase == COMM:
            if self._job_has_flows(st):
                if self._job_flows_done(st):
                    # flows done -> latency tail, then iteration completes
                    if st.comm_extra_ms > 0:
                        self._clear_flows(st)
                        st.phase_end = self.now + st.comm_extra_ms
                        st.comm_extra_ms = 0.0
                        self._sync_job(st)
                        return
                    self._clear_flows(st)
                    self._complete_iteration(st, inject)
            else:
                if self.now + EPS >= st.phase_end:
                    self._complete_iteration(st, inject)

    def _enter_compute(self, st: JobState, inject: float) -> None:
        spec = st.job.traffic
        jitter = 1.0 + self.rng.normal(0.0, self.config.jitter_std)
        dur = max(0.0, spec.compute_ms * max(0.1, jitter)) + inject
        dur += st.pending_pause_ms
        st.pause_in_iter_ms += st.pending_pause_ms
        st.pending_pause_ms = 0.0
        if st.realign_pending and self.controller is not None:
            align = self.controller.job_alignment(st.name)
            if align is not None:
                offset, period_eff = align
                pause = (offset - ((self.now + dur) % period_eff)) % period_eff
                dur += pause
                st.pause_in_iter_ms += pause
            st.realign_pending = False
        st.phase = COMPUTE
        st.phase_end = self.now + dur
        self._sync_job(st)

    def _complete_iteration(self, st: JobState, inject: float) -> None:
        dur = self.now - st.iter_start
        st.durations_ms.append(dur)
        st.iter_index += 1
        job = st.job
        ctl = self.controller
        if ctl is not None and self.config.monitor:
            # the controller knows which pauses IT injected — report the
            # organic iteration time so its own actions don't re-trigger
            # the drift rule (a realign storm otherwise)
            organic = max(0.0, dur - st.pause_in_iter_ms)
            actions = ctl.report_iteration(job.name, organic)
            for act in actions:
                self._apply_realign(act.job)
        if ctl is not None and getattr(ctl, "reconcile", False):
            # measured-vs-declared reconciliation: the controller sees
            # only the measured comm duration; when it decides the
            # declared profile has drifted, the simulator rewrites the
            # profile and rescales drift_mult so the job's ACTUAL
            # traffic is unchanged by the bookkeeping
            measured = max(0.0, self.now - st.comm_start)
            new_comm = ctl.reconcile_measurement(
                job.name, measured, job.traffic.comm_ms)
            if new_comm is not None:
                self._reconcile_traffic(st, new_comm)
        st.pause_in_iter_ms = 0.0
        if st.iter_index >= job.n_iterations:
            st.phase = DONE
            st.finish_time = self.now
            self._sync_job(st)
            return
        st.iter_start = self.now
        self._enter_compute(st, inject)

    def _reconcile_traffic(self, st: JobState, new_comm_ms: float) -> None:
        """Adopt a reconciled declared comm time for one job.

        The declared profile moves to ``new_comm_ms`` (the controller's
        measured estimate) and ``drift_mult`` is rescaled so the job's
        actual comm time is preserved — reconciliation is bookkeeping
        about *knowledge*, not a change of the underlying traffic."""
        spec = st.job.traffic
        new_comm_ms = min(spec.period_ms, new_comm_ms)
        if new_comm_ms <= EPS:
            return
        actual = min(spec.period_ms, spec.comm_ms * st.drift_mult)
        st.drift_mult = actual / new_comm_ms
        new_spec = dataclasses.replace(spec, duty=new_comm_ms / spec.period_ms)
        for t in st.job.tasks:
            t.traffic = dataclasses.replace(new_spec)
        if self.registry is not None:
            self.registry.bump()
        if self.controller is not None and self.registry is not None:
            self.controller.report_traffic_change(
                self.registry, self._ctl_cluster, st.name, new_spec)

    def _apply_realign(self, jname: str) -> None:
        """Stop-and-wait: pause a low-priority job so its next comm phase
        starts at its assigned offset on the circle (absolute-time epoch)."""
        st = self.jobs.get(jname)
        if st is None or st.phase == DONE or self.controller is None:
            return
        align = self.controller.job_alignment(jname)
        if align is None:
            return
        offset, period_eff = align
        if st.phase in (COMPUTE, PAUSED):
            projected = st.phase_end
            pause = (offset - (projected % period_eff)) % period_eff
            st.phase_end += pause
            st.pause_in_iter_ms += pause
            st.phase = PAUSED
            self._sync_job(st)
        else:
            # mid-comm: realign when the next compute phase begins
            st.realign_pending = True

    # ---------------------------------------------------------------- metrics
    def _result(self) -> SimResult:
        if self._array_mode:
            # delivered-GB lived in the float64 vector during the run (same
            # addition sequence as the legacy dict); publish it back
            for l, i in self._link_index.items():
                self.delivered_gb[l] = float(self._delivered_vec[i])
        elapsed = max(self.now, 1.0)
        link_ids = self.cluster.link_ids
        link_util = {}
        for l in link_ids:
            cap = self.cluster.link_capacity(l)
            if cap > 0:
                link_util[l] = min(1.0,
                                   self.delivered_gb[l] / (cap * elapsed / 1e3))
            else:  # link down at sim end (fault injection)
                link_util[l] = 0.0
        b_max = self.cluster.b_max
        caps = np.array([self.cluster.link_capacity(l) for l in link_ids])
        utils = np.array([link_util[l] for l in link_ids])
        # Eq. 5: capacity-weighted mean over links, normalized by B^max
        # (B^max stays the max HOST-link capacity; on the star topology this
        # is exactly the seed computation). Only links that carried (or
        # could carry) job traffic are counted.
        active = [i for i, l in enumerate(link_ids)
                  if self.delivered_gb[l] > 0]
        if active:
            gamma = float(np.mean(caps[active] * utils[active] / b_max))
        else:
            gamma = 0.0
        per_1000 = {}
        finish = {}
        iters = {}
        for name, st in self.jobs.items():
            if st.durations_ms:
                per_1000[name] = float(np.mean(st.durations_ms)) * 1000.0 / 1e3  # s
            else:
                per_1000[name] = math.nan
            finish[name] = st.finish_time if st.finish_time is not None else math.nan
            iters[name] = st.iter_index
        tct = max((f for f in finish.values() if not math.isnan(f)), default=self.now)
        return SimResult(
            durations_ms={n: st.durations_ms for n, st in self.jobs.items()},
            time_per_1000_iters_s=per_1000,
            link_utilization=link_util,
            avg_bw_utilization=gamma,
            readjustments=self.controller.readjust_count if self.controller else 0,
            finish_times_ms=finish,
            total_completion_ms=tct,
            iterations_done=iters,
            reconfigurations=(self.controller.reconf_count
                              if self.controller else 0),
            suppressed_reconfigurations=(
                self.controller.suppressed_reconf_count
                if self.controller else 0),
            reconciliations=(self.controller.reconcile_count
                             if self.controller else 0),
            profile=self.profile,
        )



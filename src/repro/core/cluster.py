"""Cluster model: nodes, host links, latency topology.

Mirrors the paper's CRDs:
  - NodeBandwidth  -> :class:`Node` (capacity + deployed pods)
  - NetworkTopology-> :class:`Cluster.latency` (tau_{x,y} matrix)

Per the paper's Eq. (14) simplification (1:1 oversubscription), contention
is modeled on *host links* only: every node owns one host link of capacity
``bw_gbps``; inter-switch links are never the bottleneck.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Resources:
    """Multi-dimensional resource vector (paper's r_p^s, R^s(n))."""

    cpu: float = 0.0
    mem: float = 0.0  # GB
    gpu: float = 0.0  # logical GPUs (MIG slices in the testbed)

    def fits_in(self, other: "Resources") -> bool:
        return self.cpu <= other.cpu and self.mem <= other.mem and self.gpu <= other.gpu

    def __add__(self, other: "Resources") -> "Resources":
        return Resources(self.cpu + other.cpu, self.mem + other.mem, self.gpu + other.gpu)

    def __sub__(self, other: "Resources") -> "Resources":
        return Resources(self.cpu - other.cpu, self.mem - other.mem, self.gpu - other.gpu)


@dataclasses.dataclass
class Node:
    """A worker node and its host link (NodeBandwidth CR)."""

    name: str
    capacity: Resources
    bw_gbps: float  # physical host-link bandwidth capacity B_l(n)
    # NodeBandwidth CR: the manager may lower the ALLOCATABLE bandwidth to
    # account for reserved/unregulated traffic (paper section III-A); the
    # schedulers see this value, the fluid simulator uses the physical one.
    allocatable_gbps: Optional[float] = None
    # pods deployed on this node (pod uid -> bandwidth demand in Gbps)
    pods: Dict[str, float] = dataclasses.field(default_factory=dict)
    allocated: Resources = dataclasses.field(default_factory=Resources)

    @property
    def alloc_bw(self) -> float:
        return self.bw_gbps if self.allocatable_gbps is None else self.allocatable_gbps

    @property
    def free(self) -> Resources:
        return self.capacity - self.allocated

    def allocate(self, uid: str, req: Resources, bw_gbps: float) -> None:
        self.pods[uid] = bw_gbps
        self.allocated = self.allocated + req

    def release(self, uid: str, req: Resources) -> None:
        if uid in self.pods:
            del self.pods[uid]
            self.allocated = self.allocated - req


class Cluster:
    """A set of nodes plus the latency matrix tau (NetworkTopology CR)."""

    def __init__(self, nodes: List[Node], latency_ms: Optional[np.ndarray] = None):
        self.nodes: Dict[str, Node] = {n.name: n for n in nodes}
        self.node_names: List[str] = [n.name for n in nodes]
        self._index = {name: i for i, name in enumerate(self.node_names)}
        n = len(nodes)
        if latency_ms is None:
            # default: uniform 1ms between distinct nodes, 1 on the diagonal
            # (the paper defines tau_{x,x} = 1)
            latency_ms = np.ones((n, n), dtype=np.float64)
        self.latency = np.asarray(latency_ms, dtype=np.float64)
        assert self.latency.shape == (n, n)

    # -- helpers -----------------------------------------------------------
    def node(self, name: str) -> Node:
        return self.nodes[name]

    def index(self, name: str) -> int:
        return self._index[name]

    def tau(self, a: str, b: str) -> float:
        return float(self.latency[self._index[a], self._index[b]])

    @property
    def b_max(self) -> float:
        """B^max — maximum host-link capacity across the cluster."""
        return max(n.bw_gbps for n in self.nodes.values())

    def set_latency(self, a: str, b: str, ms: float) -> None:
        i, j = self._index[a], self._index[b]
        self.latency[i, j] = ms
        self.latency[j, i] = ms

    def copy(self) -> "Cluster":
        nodes = [
            Node(
                name=n.name,
                capacity=dataclasses.replace(n.capacity),
                bw_gbps=n.bw_gbps,
                allocatable_gbps=n.allocatable_gbps,
                pods=dict(n.pods),
                allocated=dataclasses.replace(n.allocated),
            )
            for n in self.nodes.values()
        ]
        return Cluster(nodes, self.latency.copy())


def make_testbed_cluster() -> Cluster:
    """The paper's Fig. 4 testbed: 3x A30 workers @25G + 1x T4 worker @10G.

    Each A30 is MIG-sliced into 4 logical GPUs.
    """
    nodes = [
        Node("worker-a30-0", Resources(cpu=32, mem=1024, gpu=4), bw_gbps=25.0),
        Node("worker-a30-1", Resources(cpu=32, mem=1024, gpu=4), bw_gbps=25.0),
        Node("worker-a30-2", Resources(cpu=32, mem=1024, gpu=4), bw_gbps=25.0),
        Node("worker-t4-0", Resources(cpu=20, mem=32, gpu=1), bw_gbps=10.0),
    ]
    lat = np.ones((4, 4))
    # paper introduces a congested node with a high-latency link via iPerf3;
    # benchmarks override this as needed.
    return Cluster(nodes, lat)


def make_tpu_host_cluster(n_hosts: int = 8, bw_gbps: float = 25.0,
                          chips_per_host: int = 4) -> Cluster:
    """TPU-adapted cluster: v5e hosts (4 chips each) with DCN uplinks.

    Metronome schedules training jobs onto hosts; "gpu" counts map to TPU
    chips. See DESIGN.md section 2.
    """
    nodes = [
        Node(f"host-{i}", Resources(cpu=112, mem=384, gpu=chips_per_host), bw_gbps=bw_gbps)
        for i in range(n_hosts)
    ]
    return Cluster(nodes)

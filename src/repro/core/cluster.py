"""Cluster model: nodes, host links, fabric topology, latency.

Mirrors the paper's CRDs:
  - NodeBandwidth  -> :class:`Node` (capacity + deployed pods)
  - NetworkTopology-> :class:`Cluster.latency` (tau_{x,y} matrix)

The default :class:`~repro.core.topology.Topology` is the paper's Eq. (14)
simplification (1:1 oversubscription): contention on *host links* only,
every node owning one host link of capacity ``bw_gbps``. Passing a
leaf–spine topology additionally models leaf->spine uplinks, which CAN be
the bottleneck on oversubscribed fabrics (see ``topology.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from .topology import Topology


@dataclasses.dataclass
class Resources:
    """Multi-dimensional resource vector (paper's r_p^s, R^s(n))."""

    cpu: float = 0.0
    mem: float = 0.0  # GB
    gpu: float = 0.0  # logical GPUs (MIG slices in the testbed)

    def fits_in(self, other: "Resources") -> bool:
        return self.cpu <= other.cpu and self.mem <= other.mem and self.gpu <= other.gpu

    def __add__(self, other: "Resources") -> "Resources":
        return Resources(self.cpu + other.cpu, self.mem + other.mem, self.gpu + other.gpu)

    def __sub__(self, other: "Resources") -> "Resources":
        return Resources(self.cpu - other.cpu, self.mem - other.mem, self.gpu - other.gpu)


@dataclasses.dataclass
class Node:
    """A worker node and its host link (NodeBandwidth CR)."""

    name: str
    capacity: Resources
    bw_gbps: float  # physical host-link bandwidth capacity B_l(n)
    # NodeBandwidth CR: the manager may lower the ALLOCATABLE bandwidth to
    # account for reserved/unregulated traffic (paper section III-A); the
    # schedulers see this value, the fluid simulator uses the physical one.
    allocatable_gbps: Optional[float] = None
    # pods deployed on this node (pod uid -> bandwidth demand in Gbps)
    pods: Dict[str, float] = dataclasses.field(default_factory=dict)
    allocated: Resources = dataclasses.field(default_factory=Resources)

    @property
    def alloc_bw(self) -> float:
        return self.bw_gbps if self.allocatable_gbps is None else self.allocatable_gbps

    @property
    def free(self) -> Resources:
        return self.capacity - self.allocated

    def allocate(self, uid: str, req: Resources, bw_gbps: float) -> None:
        self.pods[uid] = bw_gbps
        self.allocated = self.allocated + req

    def release(self, uid: str, req: Resources) -> None:
        if uid in self.pods:
            del self.pods[uid]
            self.allocated = self.allocated - req


class Cluster:
    """A set of nodes plus fabric topology and the latency matrix tau."""

    def __init__(self, nodes: List[Node], latency_ms: Optional[np.ndarray] = None,
                 topology: Optional[Topology] = None):
        self.nodes: Dict[str, Node] = {n.name: n for n in nodes}
        self.node_names: List[str] = [n.name for n in nodes]
        self._index = {name: i for i, name in enumerate(self.node_names)}
        n = len(nodes)
        if latency_ms is None:
            # default: uniform 1ms between distinct nodes, 1 on the diagonal
            # (the paper defines tau_{x,x} = 1)
            latency_ms = np.ones((n, n), dtype=np.float64)
        self.latency = np.asarray(latency_ms, dtype=np.float64)
        assert self.latency.shape == (n, n)
        self.topology = topology or Topology.star(self.node_names)
        missing = set(self.node_names) - set(self.topology.leaf_of)
        if missing:
            raise ValueError(f"topology missing nodes {sorted(missing)}")
        # monotonic mutation counter (DESIGN.md section 15): every change to
        # scheduler-visible link state (allocations, allocatable/physical
        # capacities, latency) advances it so epoch-scoped planner caches
        # (repro.core.rotation.PlanCache) can invalidate soundly
        self.epoch: int = 0

    def bump_epoch(self) -> None:
        """Advance the mutation epoch; callers mutating any Node/link state
        the schedulers read must invoke this (the scheduling framework and
        the simulator's event paths do)."""
        self.epoch += 1

    # -- helpers -----------------------------------------------------------
    def node(self, name: str) -> Node:
        return self.nodes[name]

    # -- unified link view --------------------------------------------------
    # Host-link ids equal node names; uplinks use ``uplink:<leaf>``. Node
    # objects stay authoritative for host-link capacities (the NodeBandwidth
    # CR path), the topology for uplinks.
    @property
    def link_ids(self) -> List[str]:
        return list(self.node_names) + self.topology.uplink_ids

    def link_capacity(self, link_id: str) -> float:
        if link_id in self.nodes:
            return self.nodes[link_id].bw_gbps
        link = self.topology.link(link_id)
        if link is None:
            raise KeyError(f"unknown link {link_id!r}")
        return link.capacity_gbps

    def link_alloc(self, link_id: str) -> float:
        """Allocatable bandwidth of a link (schedulers' Eq. 13-14 view)."""
        if link_id in self.nodes:
            return self.nodes[link_id].alloc_bw
        link = self.topology.link(link_id)
        if link is None:
            raise KeyError(f"unknown link {link_id!r}")
        return link.alloc_bw

    def index(self, name: str) -> int:
        return self._index[name]

    def tau(self, a: str, b: str) -> float:
        return float(self.latency[self._index[a], self._index[b]])

    @property
    def b_max(self) -> float:
        """B^max — maximum host-link capacity across the cluster."""
        return max(n.bw_gbps for n in self.nodes.values())

    def set_latency(self, a: str, b: str, ms: float) -> None:
        i, j = self._index[a], self._index[b]
        self.latency[i, j] = ms
        self.latency[j, i] = ms
        self.bump_epoch()

    def copy(self) -> "Cluster":
        nodes = [
            Node(
                name=n.name,
                capacity=dataclasses.replace(n.capacity),
                bw_gbps=n.bw_gbps,
                allocatable_gbps=n.allocatable_gbps,
                pods=dict(n.pods),
                allocated=dataclasses.replace(n.allocated),
            )
            for n in self.nodes.values()
        ]
        return Cluster(nodes, self.latency.copy(), self.topology.copy())


def make_testbed_cluster() -> Cluster:
    """The paper's Fig. 4 testbed: 3x A30 workers @25G + 1x T4 worker @10G.

    Each A30 is MIG-sliced into 4 logical GPUs.
    """
    nodes = [
        Node("worker-a30-0", Resources(cpu=32, mem=1024, gpu=4), bw_gbps=25.0),
        Node("worker-a30-1", Resources(cpu=32, mem=1024, gpu=4), bw_gbps=25.0),
        Node("worker-a30-2", Resources(cpu=32, mem=1024, gpu=4), bw_gbps=25.0),
        Node("worker-t4-0", Resources(cpu=20, mem=32, gpu=1), bw_gbps=10.0),
    ]
    lat = np.ones((4, 4))
    # paper introduces a congested node with a high-latency link via iPerf3;
    # benchmarks override this as needed.
    return Cluster(nodes, lat)


def make_tpu_host_cluster(n_hosts: int = 8, bw_gbps: float = 25.0,
                          chips_per_host: int = 4) -> Cluster:
    """TPU-adapted cluster: v5e hosts (4 chips each) with DCN uplinks.

    Metronome schedules training jobs onto hosts; "gpu" counts map to TPU
    chips. See DESIGN.md section 2.
    """
    nodes = [
        Node(f"host-{i}", Resources(cpu=112, mem=384, gpu=chips_per_host), bw_gbps=bw_gbps)
        for i in range(n_hosts)
    ]
    return Cluster(nodes)


def make_fabric_cluster(
    n_leaves: int = 2,
    hosts_per_leaf: int = 2,
    bw_gbps: float = 25.0,
    oversubscription: float = 2.0,
    chips_per_host: int = 4,
) -> Cluster:
    """Leaf–spine cluster: ``n_leaves`` racks of identical hosts, each rack's
    uplink carrying ``hosts_per_leaf * bw_gbps / oversubscription``.

    ``oversubscription=1.0`` makes uplinks as fat as their racks (they can
    still be shared by concurrent cross-rack jobs); the paper's star model is
    recovered with ``n_leaves=1``.
    """
    nodes = []
    leaves: Dict[str, List[str]] = {}
    for l in range(n_leaves):
        leaf = f"leaf{l}"
        leaves[leaf] = []
        for h in range(hosts_per_leaf):
            name = f"{leaf}-host{h}"
            nodes.append(Node(name, Resources(cpu=32, mem=256, gpu=chips_per_host),
                              bw_gbps=bw_gbps))
            leaves[leaf].append(name)
    topo = Topology.leaf_spine(
        leaves,
        host_bw_gbps={n.name: n.bw_gbps for n in nodes},
        oversubscription=oversubscription,
    )
    return Cluster(nodes, topology=topo)

"""Declarative Scenario/Policy experiment API (DESIGN.md section 14).

The paper evaluates a grid — 13 models x {static, fluctuating, trace}
scenarios x {Metronome, Default, Diktyo, Exclusive, Ideal} mechanisms — so
the entry point is grid-shaped instead of kwarg-shaped:

  * :class:`Scenario` — WHAT runs: a factory producing a fresh cluster,
    workloads, background flows and dynamic events per materialization.
    Offline-vs-trace is a scenario property (``mode``), not a separate
    function.
  * :class:`Policy` — HOW it is scheduled: the mechanism name (resolved
    through a pluggable registry, :func:`register_scheduler`) plus the
    Metronome ablation knobs (rotation mode, joint planner, reconfiguration
    loop, third stage) and scheduler-specific options (A_T/O_T, ...).
  * :func:`run` — one entry point subsuming the legacy ``run_experiment``
    AND ``run_trace_experiment`` (the shims in ``harness.py`` delegate here
    and are pinned bit-for-bit by ``tests/test_experiment.py``).  Trace
    runs accept every Policy knob — the legacy trace path hardcoded a
    default controller and could not ablate anything.
  * :func:`sweep` — the grid runner: every (scenario, policy) cell runs
    isolated (a raising cell records its traceback instead of aborting the
    grid) and the result serializes to schema-versioned JSON
    (``core/results.py``; benchmarks persist it as ``BENCH_sweep.json``).
"""
from __future__ import annotations

import copy
import dataclasses
import traceback
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)

import numpy as np

from .baselines import DefaultPlugin, DiktyoPlugin, ExclusivePlugin
from .cluster import Cluster
from .controller import StopAndWaitController
from .events import Event
from .framework import SchedulerPlugin, SchedulingFramework
from .results import ExperimentResult, SweepCell, SweepResult
from .scheduler import MetronomePlugin
from .simulator import BackgroundFlow, ClusterSimulator, SimConfig, SimResult
from .telemetry import TelemetryView
from .workload import Job, Workload

OFFLINE, TRACE = "offline", "trace"

# (cluster, workloads[, background[, events]]) — what a Scenario's build
# callable returns; trailing elements optional
ScenarioData = Tuple[Cluster, List[Workload], List[BackgroundFlow],
                     List[Event]]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A declarative experiment input.

    ``build`` is called once per :func:`run` and must return a FRESH
    ``(cluster, workloads[, background[, events]])`` tuple — jobs are
    mutated by scheduling, so materializations must not share them (this is
    what the benchmarks' per-scheduler ``make_snapshot`` loop did by hand).

    ``mode='offline'`` schedules every workload up front (the paper's
    snapshot runs); ``mode='trace'`` feeds workloads to the simulator as
    online arrivals honoring ``submit_time_s`` (the paper's Fig. 10 K8s
    behavior) — jobs queue when the cluster is full and release capacity on
    completion.

    ``sim_config`` optionally pins the scenario's simulator configuration;
    an explicit ``sim_config=`` to :func:`run`/:func:`sweep` wins.
    """

    name: str
    build: Callable[[], Sequence]
    mode: str = OFFLINE
    sim_config: Optional[SimConfig] = None

    def __post_init__(self) -> None:
        if self.mode not in (OFFLINE, TRACE):
            raise ValueError(f"mode must be {OFFLINE!r} or {TRACE!r}, "
                             f"got {self.mode!r}")

    @classmethod
    def offline(cls, name: str, build: Callable[[], Sequence],
                **kw) -> "Scenario":
        return cls(name=name, build=build, mode=OFFLINE, **kw)

    @classmethod
    def trace(cls, name: str, build: Callable[[], Sequence],
              **kw) -> "Scenario":
        return cls(name=name, build=build, mode=TRACE, **kw)

    def materialize(self) -> ScenarioData:
        out = tuple(self.build())
        if not 2 <= len(out) <= 4:
            raise ValueError(
                f"scenario {self.name!r}: build() must return (cluster, "
                f"workloads[, background[, events]]), got {len(out)} items")
        cluster, workloads = out[0], list(out[1])
        background = list(out[2]) if len(out) > 2 else []
        events = list(out[3]) if len(out) > 3 else []
        return cluster, workloads, background, events


@dataclasses.dataclass(frozen=True)
class Policy:
    """A scheduling mechanism plus its ablation knobs.

    ``scheduler`` resolves through the registry (:func:`register_scheduler`);
    ``options`` carries scheduler-specific keyword options as a sorted
    tuple of pairs (hashable — use :meth:`with_options`), e.g. the
    controller thresholds ``a_t``/``o_t`` for Metronome.
    """

    scheduler: str
    rotation_mode: str = "intermediate"  # "compact" = no cushion slots
    rotation_joint: bool = True   # False = legacy uplink-wins tie-break
    reconfigure: bool = True      # False = no section III-C reconfiguration
    skip_third_stage: bool = False  # True = no offline recalculation
    options: Tuple[Tuple[str, Any], ...] = ()
    label: Optional[str] = None
    # fluid-engine rate-sharing backend for the simulation: None inherits
    # the SimConfig default ('python', the bit-for-bit seed path);
    # 'jnp'/'kernel' swap in the vectorized fill (core/fluid.py)
    sim_backend: Optional[str] = None

    @property
    def name(self) -> str:
        """Cell key in sweeps: the label, or an auto-name encoding every
        deviation from the defaults (so unlabeled ablations never collide)."""
        if self.label is not None:
            return self.label
        parts = [self.scheduler]
        if self.rotation_mode != "intermediate":
            parts.append(self.rotation_mode)
        if not self.rotation_joint:
            parts.append("legacyrot")
        if not self.reconfigure:
            parts.append("noreconf")
        if self.skip_third_stage:
            parts.append("wo3")
        if self.sim_backend is not None:
            parts.append(f"fluid={self.sim_backend}")
        parts.extend(f"{k}={v}" for k, v in self.options)
        return "-".join(parts)

    def scheduler_options(self) -> Dict[str, Any]:
        return dict(self.options)

    def with_options(self, **kw) -> "Policy":
        """A copy with ``kw`` merged into the scheduler-specific options."""
        merged = dict(self.options)
        merged.update(kw)
        return dataclasses.replace(
            self, options=tuple(sorted(merged.items())))


# ------------------------------------------------------------------ registry
# name -> factory(policy) -> (plugin, controller); the controller is None
# for mechanisms without a stop-and-wait stage.  "ideal" is the dedicated-
# cluster reference and is dispatched before the registry lookup.
SchedulerFactory = Callable[[Policy], Tuple[SchedulerPlugin,
                                            Optional[StopAndWaitController]]]
_SCHEDULERS: Dict[str, SchedulerFactory] = {}
IDEAL = "ideal"


def register_scheduler(name: str, factory: SchedulerFactory,
                       *, overwrite: bool = False) -> None:
    """Plug a scheduling mechanism into :func:`run`/:func:`sweep`.

    ``factory(policy)`` returns ``(plugin, controller)``; the controller
    (may be ``None``) receives the offline recalculation and reconfiguration
    callbacks exactly like Metronome's."""
    if name == IDEAL:
        raise ValueError("'ideal' is the built-in dedicated-cluster "
                         "reference and cannot be re-registered")
    if name in _SCHEDULERS and not overwrite:
        raise ValueError(f"scheduler {name!r} already registered "
                         "(pass overwrite=True to replace)")
    _SCHEDULERS[name] = factory


def scheduler_names() -> Tuple[str, ...]:
    """Every runnable mechanism name (registry + the ideal reference)."""
    return tuple(_SCHEDULERS) + (IDEAL,)


def build_scheduler(policy: Policy) -> Tuple[SchedulerPlugin,
                                             Optional[StopAndWaitController]]:
    """Resolve ``policy.scheduler`` to a fresh (plugin, controller) pair."""
    try:
        factory = _SCHEDULERS[policy.scheduler]
    except KeyError:
        raise ValueError(f"unknown scheduler {policy.scheduler!r}; "
                         f"registered: {sorted(_SCHEDULERS)} + ['ideal']")
    return factory(policy)


def _metronome_factory(policy: Policy):
    controller = StopAndWaitController(reconfigure=policy.reconfigure,
                                       joint=policy.rotation_joint,
                                       **policy.scheduler_options())
    plugin = MetronomePlugin(controller=controller,
                             rotation_mode=policy.rotation_mode,
                             joint=policy.rotation_joint)
    return plugin, controller


register_scheduler("metronome", _metronome_factory)
register_scheduler("default", lambda policy: (DefaultPlugin(), None))
register_scheduler("diktyo", lambda policy: (DiktyoPlugin(), None))
register_scheduler("exclusive", lambda policy: (ExclusivePlugin(), None))


# ----------------------------------------------------------------------- run
def _priority_split(workloads: Sequence[Workload]
                    ) -> Tuple[List[str], List[str]]:
    hi, lo = [], []
    for wl in workloads:
        for j in wl.jobs:
            (hi if j.priority else lo).append(j.name)
    return hi, lo


def run(scenario: Scenario, policy: Policy,
        sim_config: Optional[SimConfig] = None) -> ExperimentResult:
    """Run one (scenario, policy) cell and return the typed result.

    Offline mode reproduces the legacy ``run_experiment`` bit-for-bit;
    trace mode reproduces ``run_trace_experiment`` bit-for-bit under the
    default :class:`Policy` and additionally honors every ablation knob the
    legacy trace path silently dropped (reconfigure / rotation_joint /
    rotation_mode / skip_third_stage / controller options).  Legacy
    ``traffic_changes`` tuples are normalized into the typed event stream
    at this boundary (``harness.run_experiment``), so the simulator sees a
    single dynamic-input path.

    ``policy.scheduler == 'ideal'`` runs every job alone on a pristine copy
    of the cluster (the paper's dedicated-cluster reference).  It is the
    STATIC contention-free bound: background flows and events are
    deliberately ignored.
    """
    config = sim_config or scenario.sim_config or SimConfig()
    if (policy.sim_backend is not None
            and config.fluid_backend != policy.sim_backend):
        config = dataclasses.replace(config,
                                     fluid_backend=policy.sim_backend)
    cluster, workloads, background, events = scenario.materialize()
    hi, lo = _priority_split(workloads)

    if policy.scheduler == IDEAL:
        sim_res, accepted, placements = _run_ideal(cluster, workloads, config)
        return ExperimentResult(
            scenario=scenario.name, policy=policy.name, scheduler=IDEAL,
            accepted=accepted, rejected=[], placements=placements,
            high_priority=hi, low_priority=lo, sim=sim_res)

    cl = cluster.copy()
    plugin, controller = build_scheduler(policy)
    # Imperfect-information control plane (DESIGN.md section 19): when the
    # config carries a telemetry channel, EVERY control-plane read — Score/
    # Filter inside the framework, the controller's offline recalculation,
    # and the simulator's reconfiguration callbacks — observes link state
    # through one shared TelemetryView; the fluid physics keeps the truth.
    tel = (TelemetryView(cl, config.telemetry, seed=config.seed)
           if config.telemetry is not None else None)
    fw = SchedulingFramework(cl if tel is None else tel, plugin)

    if scenario.mode == OFFLINE:
        accepted, rejected = [], []
        jobs: List[Job] = []
        for wl in workloads:
            ok = fw.schedule_workload(wl)
            for j in wl.jobs:
                (accepted if ok else rejected).append(j.name)
                if ok:
                    jobs.append(j)
        if controller is not None and not policy.skip_third_stage:
            controller.run_offline_recalculation(
                fw.registry, cl if tel is None else tel)
        sim = ClusterSimulator(
            cl, jobs, config, controller=controller, background=background,
            registry=fw.registry, events=events, telemetry=tel,
        )
        res = sim.run()
        placements = {j.name: j.nodes_used() for j in jobs}
    else:  # TRACE: online arrivals at submit times, queueing, eviction
        sim = ClusterSimulator(
            cl, [], config, controller=controller, background=background,
            registry=fw.registry, framework=fw, arrivals=workloads,
            events=events, offline_recalc=not policy.skip_third_stage,
            telemetry=tel,
        )
        res = sim.run()
        accepted = list(sim.jobs)
        rejected = sim.pending_jobs
        placements = {n: st.job.nodes_used() for n, st in sim.jobs.items()}

    return ExperimentResult(
        scenario=scenario.name, policy=policy.name,
        scheduler=policy.scheduler, accepted=accepted, rejected=rejected,
        placements=placements, high_priority=hi, low_priority=lo, sim=res)


def _run_ideal(cluster: Cluster, workloads: Sequence[Workload],
               config: SimConfig):
    """Each job on a dedicated cluster: no contention, no shared links."""
    if config.telemetry is not None:
        # the dedicated-cluster reference is a STATIC contention-free bound;
        # observing it through a noisy channel would make it non-ideal
        config = dataclasses.replace(config, telemetry=None)
    merged_durations: Dict[str, List[float]] = {}
    per_1000: Dict[str, float] = {}
    finish: Dict[str, float] = {}
    iters: Dict[str, int] = {}
    gammas = []
    placements = {}
    for wl in workloads:
        for job in wl.jobs:
            cl = cluster.copy()
            job_copy = copy.deepcopy(job)
            job_copy.submit_time_s = 0.0
            fw = SchedulingFramework(cl, DefaultPlugin())
            if not fw.schedule_job(job_copy):
                continue
            sim = ClusterSimulator(cl, [job_copy], config)
            res = sim.run()
            merged_durations[job.name] = res.durations_ms[job_copy.name]
            per_1000[job.name] = res.time_per_1000_iters_s[job_copy.name]
            finish[job.name] = res.finish_times_ms[job_copy.name]
            iters[job.name] = res.iterations_done[job_copy.name]
            gammas.append(res.avg_bw_utilization)
            placements[job.name] = job_copy.nodes_used()
    sim_res = SimResult(
        durations_ms=merged_durations,
        time_per_1000_iters_s=per_1000,
        link_utilization={},
        avg_bw_utilization=float(np.mean(gammas)) if gammas else 0.0,
        readjustments=0,
        finish_times_ms=finish,
        total_completion_ms=max(
            (f for f in finish.values() if not np.isnan(f)), default=0.0
        ),
        iterations_done=iters,
    )
    return sim_res, list(merged_durations.keys()), placements


# --------------------------------------------------------------------- sweep
def _run_cell(scenario: Scenario, policy: Policy,
              sim_config: Optional[SimConfig]) -> SweepCell:
    """One isolated grid cell: a result, or the captured traceback."""
    try:
        res = run(scenario, policy, sim_config)
    except Exception:  # noqa: BLE001 — isolation is the contract
        return SweepCell(scenario=scenario.name, policy=policy.name,
                         status="error", error=traceback.format_exc())
    return SweepCell(scenario=scenario.name, policy=policy.name,
                     status="ok", result=res)


def sweep(scenarios: Sequence[Scenario], policies: Sequence[Policy],
          sim_config: Optional[SimConfig] = None,
          *, meta: Optional[Dict[str, Any]] = None,
          workers: int = 1, mode: str = "thread") -> SweepResult:
    """Run the full scenario x policy grid (row-major over scenarios).

    Per-cell error isolation: a cell that raises records its traceback in
    its :class:`~repro.core.results.SweepCell` (``status="error"``) and the
    rest of the grid still runs.  Check ``result.errors`` (or use
    ``SweepResult.get``, which re-raises) when failures must surface.

    ``workers > 1`` fans the cells over a pool: every cell materializes its
    OWN scenario (fresh cluster/jobs — nothing shared) and runs a seeded,
    self-contained simulation, so cells are independent and the result —
    including the row-major cell order and per-cell error isolation — is
    identical to the serial run.  ``workers=1`` (the default) keeps the
    historical strictly-serial execution path.

    ``mode='thread'`` (default) uses a thread pool; ``mode='process'``
    fans cells over spawned worker processes — true parallelism for
    CPU-bound grids (10k-job production traces).  Process mode requires
    picklable scenarios/policies: use module-level build callables (the
    ``configs.metronome_testbed`` builders are dataclass instances for
    exactly this) and schedulers registered at import time of their
    defining module."""
    if mode not in ("thread", "process"):
        raise ValueError(f"mode must be 'thread' or 'process', got {mode!r}")
    grid = [(scenario, policy) for scenario in scenarios
            for policy in policies]
    if workers <= 1 or len(grid) <= 1:
        cells = [_run_cell(s, p, sim_config) for s, p in grid]
        return SweepResult(cells=cells, meta=dict(meta or {}))
    if mode == "process":
        import multiprocessing
        from concurrent.futures import ProcessPoolExecutor
        # spawn, not fork: workers re-import repro cleanly (no inherited
        # jax/BLAS state), matching how a fresh serial run would behave
        ctx = multiprocessing.get_context("spawn")
        with ProcessPoolExecutor(max_workers=min(workers, len(grid)),
                                 mp_context=ctx) as pool:
            futures = [pool.submit(_run_cell, s, p, sim_config)
                       for s, p in grid]
            cells = [f.result() for f in futures]  # row-major order
        return SweepResult(cells=cells, meta=dict(meta or {}))
    from concurrent.futures import ThreadPoolExecutor
    with ThreadPoolExecutor(max_workers=min(workers, len(grid))) as pool:
        futures = [pool.submit(_run_cell, s, p, sim_config) for s, p in grid]
        cells = [f.result() for f in futures]  # preserves row-major order
    return SweepResult(cells=cells, meta=dict(meta or {}))

"""The Metronome stop-and-wait controller — paper section III-C.

Three duties:
  1. **Global offset**: per-link rotation schemes arrive from the scheduler;
     jobs spanning several links need consistent time-shifts. Offset
     resolution is delegated to the fabric-wide rotation planner
     (:func:`repro.core.rotation.resolve`): consistent per-link solutions
     keep the Cassini-style affinity-graph BFS anchored at the *highest
     priority* job (the paper's difference vs Cassini's random reference);
     conflicting per-link solutions are re-solved jointly over every link
     the component touches.  ``joint=False`` restores the legacy
     "uplinks take precedence" tie-break as an ablation.
  2. **Offline recalculation**: when SkipPhaseThree == 0, re-run the
     exhaustive 3rd-stage search (maximize Psi among perfect-score interval
     midpoints) and update the scheme.
  3. **Continuous regulation**: monitor per-job iteration times; within a
     window of 10 iterations, if a job exceeds ``A_T`` x baseline more than
     ``O_T`` times, pause LOW priority jobs to realign their communication
     phases. High priority jobs are never touched.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from . import geometry, rotation
from .cluster import Cluster
from .contention import LinkView
from .framework import TaskRegistry
from .geometry import DI_PRE
from .rotation import LinkScheme
from .scheduler import ReserveMessage
from .topology import is_uplink
from .workload import HIGH, Task, TrafficSpec

MONITOR_WINDOW = 10  # fixed time window (iterations) — paper section III-C


@dataclasses.dataclass
class RealignAction:
    """Instruction to the node agent: pause a low-priority job."""

    job: str
    reason: str  # 'drift' | 'traffic_change'


@dataclasses.dataclass
class LinkState:
    """Current scheme on one fabric link.

    Keyed in :attr:`StopAndWaitController.links` by link id: host links use
    the node name (seed-compatible), spine uplinks ``uplink:<leaf>``."""

    scheme: LinkScheme
    optimal: bool  # False until offline recalculation has run


class StopAndWaitController:
    def __init__(
        self,
        *,
        a_t: float = 1.10,  # iteration-time factor threshold A_T
        o_t: int = 5,  # occurrence threshold O_T within the window
        di_pre: int = DI_PRE,
        recalc_hook: Optional[Callable[[str], None]] = None,
        phase_monitor: bool = False,
        reconfigure: bool = True,
        joint: bool = True,  # False = legacy uplink-wins reconciliation
        hysteresis_ms: float = 0.0,
        hysteresis_frac: float = 0.0,
        reconcile: bool = False,
        reconcile_frac: float = 0.25,
        reconcile_window: int = 8,
    ) -> None:
        self.a_t = a_t
        self.o_t = o_t
        self.di_pre = di_pre
        # dynamic reconfiguration (paper section III-C): react to capacity /
        # background changes by re-deriving schemes; False = ablation
        self.reconfigure = reconfigure
        self.reconf_count = 0
        # degradation control (DESIGN.md section 19): debounce the
        # reconfiguration loop so sampled/noisy telemetry cannot trigger
        # replan storms.  A link change is acted on only if at least
        # ``hysteresis_ms`` passed since its last acted-on change AND the
        # observed allocatable share moved by more than
        # ``hysteresis_frac`` x capacity since then.  Both 0 (default)
        # = the seed behavior: every reported change replans.
        self.hysteresis_ms = hysteresis_ms
        self.hysteresis_frac = hysteresis_frac
        self.suppressed_reconf_count = 0
        self._last_reconf_ms: Dict[str, float] = {}
        self._reconf_alloc: Dict[str, float] = {}
        # measured-vs-declared demand reconciliation: when a job's
        # measured comm time drifts off its declared profile by more than
        # ``reconcile_frac`` (median over ``reconcile_window``
        # iterations), adopt the measurement as the new declared profile
        self.reconcile = reconcile
        self.reconcile_frac = reconcile_frac
        self.reconcile_window = reconcile_window
        self.reconcile_count = 0
        self._measured_comm: Dict[str, collections.deque] = {}
        self.joint = joint
        self.joint_resolve_count = 0  # components re-solved jointly
        # epoch-scoped memo for the joint re-solves of offset resolution
        # (on_schedule replans after EVERY reserve; within one epoch the
        # conflicted components repeat — see DESIGN.md section 15)
        self.plan_cache = rotation.PlanCache()
        self.links: Dict[str, LinkState] = {}  # link id -> state (see LinkState)
        self.global_offsets_ms: Dict[str, float] = {}
        self.injected_ms: Dict[str, float] = {}  # per-job E_T idle injection
        self._history: Dict[str, collections.deque] = {}
        self._baseline_ms: Dict[str, float] = {}
        self._priorities: Dict[str, int] = {}
        self.readjust_count = 0
        self.recalc_count = 0
        self.pending_recalc: List[str] = []
        self.recalc_hook = recalc_hook
        self.phase_monitor = phase_monitor
        self._phase_strikes: Dict[str, int] = {}
        self._last_phase: Dict[str, float] = {}  # folded drift per job (ms)

    # ------------------------------------------------------------- scheduling
    def on_schedule(self, cluster: Cluster, registry: TaskRegistry,
                    msg: ReserveMessage) -> None:
        """Receive SEND(Shifts, SkipPhaseThree, P_l(n*)) from the scheduler."""
        for link_id, scheme in msg.schemes.items():
            skip = msg.skips.get(link_id, msg.skip_phase_three)
            self.links[link_id] = LinkState(scheme=scheme, optimal=skip)
            for j, inj in scheme.injected_ms.items():
                if inj > 0:
                    self.injected_ms[j] = inj
            if not skip:
                self.pending_recalc.append(link_id)
        for jname, job in registry.jobs.items():
            self._priorities[jname] = job.priority
        self._replan_offsets(registry, cluster)
        # offline recalculation is delegated (the paper decouples it from the
        # scheduling fast path); callers may run run_offline_recalculation()
        # asynchronously or via the hook.
        if self.recalc_hook is not None:
            while self.pending_recalc:
                self.recalc_hook(self.pending_recalc.pop())

    @staticmethod
    def _drop_job(state: LinkState, job: str) -> bool:
        """Remove ``job`` from a link scheme; True when the scheme empties."""
        sch = state.scheme
        if job in sch.jobs:
            idx = sch.jobs.index(job)
            sch.jobs.pop(idx)
            sch.shifts_slots = np.delete(sch.shifts_slots, idx)
            sch.muls = np.delete(sch.muls, idx)
        return not sch.jobs

    def on_evict(self, node: str, pod: Task,
                 registry: Optional[TaskRegistry] = None,
                 cluster: Optional[Cluster] = None) -> None:
        """Pod eviction: retire the job from the node's host-link scheme and
        from every uplink scheme it appears in (evictions are all-or-nothing
        at the job level, so the job's cross-leaf flows disappear too)."""
        dead: List[str] = []
        state = self.links.get(node)
        if state is not None and self._drop_job(state, pod.job):
            dead.append(node)
        for link_id, st in self.links.items():
            if is_uplink(link_id) and pod.job in st.scheme.jobs:
                if self._drop_job(st, pod.job):
                    dead.append(link_id)
        for link_id in dead:
            del self.links[link_id]
        self._replan_offsets(registry, cluster)

    # ---------------------------------------------------------- global offset
    def _replan_offsets(self, registry: Optional[TaskRegistry] = None,
                        cluster: Optional[Cluster] = None, *,
                        mode: str = "fast", demand: str = "planning") -> None:
        """Resolve the stored per-link schemes into global offsets via the
        rotation planner.  With a live (registry, cluster) the planner can
        re-solve conflicting components jointly; without one — or with
        ``joint=False`` — the legacy last-link-wins reconciliation applies
        (canonical order: host links sorted, uplinks LAST)."""
        schemes = {lid: st.scheme for lid, st in self.links.items()}
        view = None
        if registry is not None and cluster is not None:
            view = LinkView.from_registry(cluster, registry)
        res = rotation.resolve(
            schemes, self._priorities, view, registry, di_pre=self.di_pre,
            mode=mode, demand=demand, joint=self.joint,
            cache=self.plan_cache,
        )
        for lid, sch in res.schemes.items():
            if lid in self.links and sch is not schemes.get(lid):
                self.links[lid].scheme = sch
                # the joint re-solve owns its jobs' E_T injections: a new
                # commensurate unification may DROP an injection to zero,
                # and a stale positive entry would keep stretching the
                # job's period off the re-planned circle
                for j, inj in sch.injected_ms.items():
                    if inj > 0:
                        self.injected_ms[j] = inj
                    else:
                        self.injected_ms.pop(j, None)
        if res.joint_links:
            self.joint_resolve_count += 1
        self.global_offsets_ms = res.offsets_ms

    def job_offset_ms(self, job: str) -> float:
        base = 0.0
        for state in self.links.values():
            if job in state.scheme.jobs:
                base = state.scheme.base_ms
                break
        off = self.global_offsets_ms.get(job, 0.0)
        if base > 0:
            off = off % base
        return off

    def job_alignment(self, job: str) -> Optional[Tuple[float, float]]:
        """(offset_ms, effective_period_ms) for aligning the job's comm
        phases on the unified circle, or None if the job is unconstrained.

        The job's communication phases must start at absolute times
        ``t ≡ offset (mod period_eff)`` where period_eff = T_l / mul_p.
        """
        for state in self.links.values():
            sch = state.scheme
            if job in sch.jobs:
                mul = int(sch.muls[sch.jobs.index(job)])
                period_eff = sch.base_ms / max(mul, 1)
                off = self.global_offsets_ms.get(job, 0.0)
                # track the reference job's measured drift: alignment is
                # relative (common-mode fleet drift must not be fought).
                # Only under the experimental phase monitor — the paper's
                # iteration-time rule realigns to absolute offsets.
                if self.phase_monitor:
                    ref = sch.ref_job
                    if ref and ref != job:
                        off += self._last_phase.get(ref, 0.0)
                return off % period_eff, period_eff
        return None

    # ---------------------------------------------------- offline recalculation
    def run_offline_recalculation(
        self, registry: TaskRegistry, cluster: Cluster
    ) -> int:
        """Process pending SkipPhaseThree==0 links: exhaustive 3rd stage."""
        done = 0
        view = LinkView.from_registry(cluster, registry)
        while self.pending_recalc:
            link_id = self.pending_recalc.pop()
            state = self.links.get(link_id)
            if state is None:
                continue
            sch = state.scheme
            result = rotation.replan_link(view, link_id, sch,
                                          cluster.link_alloc(link_id),
                                          self.di_pre)
            sch.shifts_slots = result.shifts
            sch.score = result.score
            state.optimal = True
            self.recalc_count += 1
            done += 1
        self._replan_offsets(registry, cluster, mode="optimal",
                             demand="recalc")
        return done

    # -------------------------------------------------------- reconfiguration
    def on_link_change(self, registry: TaskRegistry, cluster: Cluster,
                       link_id: str, *,
                       now_ms: Optional[float] = None) -> int:
        """Dynamic reconfiguration (paper section III-C): the monitor reports
        that ``link_id``'s capacity/background conditions changed.

        Re-derives the link's rotation scheme from the live
        :class:`~repro.core.contention.LinkView` (the new allocatable
        bandwidth feeds the 3rd-stage search) and re-baselines every job on
        the re-derived links to the *expected* iteration time under the new
        allocatable share — when a link can no longer carry a job's full
        demand, even a perfectly rotated comm phase stretches, and the
        A_T/O_T drift rule must not fight that unavoidable slowdown with
        realign pauses.  The planner's conflict resolution applies to the
        re-derived scheme too: when the new per-link solution disagrees
        with the schemes of other links the jobs traverse, the component is
        re-solved jointly.  Returns the number of schemes re-derived (0
        when reconfiguration is disabled, no scheme lives on the link,
        the link is observed dead, or the hysteresis gate suppressed the
        change).

        ``cluster`` may be a :class:`~repro.core.telemetry.TelemetryView`
        proxy — the replan then works from the *observed* allocatable
        share; ``now_ms`` (the simulator clock) arms the hysteresis
        gate: changes within ``hysteresis_ms`` of the last acted-on
        change, or moving the observed share by no more than
        ``hysteresis_frac`` x capacity since then, are counted in
        ``suppressed_reconf_count`` and ignored."""
        state = self.links.get(link_id)
        if not self.reconfigure or state is None:
            return 0
        alloc = cluster.link_alloc(link_id)
        if alloc <= 1e-9:
            # link (observed) dead: there is no bandwidth to plan a
            # rotation against — flows are rate-0 regardless; the
            # recovery event replans and re-baselines
            return 0
        if now_ms is not None and (self.hysteresis_ms > 0.0
                                   or self.hysteresis_frac > 0.0):
            last_t = self._last_reconf_ms.get(link_id)
            if last_t is not None and now_ms - last_t < self.hysteresis_ms:
                self.suppressed_reconf_count += 1
                return 0
            ref = self._reconf_alloc.get(link_id)
            if ref is not None:
                cap = max(cluster.link_capacity(link_id), 1e-9)
                if abs(alloc - ref) <= self.hysteresis_frac * cap:
                    self.suppressed_reconf_count += 1
                    return 0
            self._last_reconf_ms[link_id] = now_ms
            self._reconf_alloc[link_id] = alloc
        if link_id not in self.pending_recalc:
            self.pending_recalc.append(link_id)
        affected = list(state.scheme.jobs)
        done = self.run_offline_recalculation(registry, cluster)
        view = LinkView.from_registry(cluster, registry)
        for j in affected:
            expected = view.expected_iteration_ms(j)
            if expected is not None and j in self._baseline_ms:
                self.set_baseline(j, expected, self._priorities.get(j, 0))
        self.reconf_count += 1
        return done

    # ------------------------------------------------------ continuous monitor
    def set_baseline(self, job: str, baseline_ms: float, priority: int) -> None:
        """Baseline = ideal contention-free iteration time (+ injected idle)."""
        self._baseline_ms[job] = baseline_ms + self.injected_ms.get(job, 0.0)
        self._priorities[job] = priority
        self._history[job] = collections.deque(maxlen=MONITOR_WINDOW)

    @staticmethod
    def _fold(err: float, pe: float) -> float:
        return ((err + pe / 2.0) % pe) - pe / 2.0

    def report_phase_error(self, job: str, error_ms: float,
                           period_eff_ms: float) -> List[RealignAction]:
        """BEYOND-PAPER (DESIGN.md section 11): agents also report the comm
        phase error vs the assigned offset. Sub-A_T partial overlaps drift
        forever under the paper's iteration-time rule; realigning when the
        RELATIVE error vs the link's reference job exceeds ~2 circle slots
        restores the cushion before it costs iteration time. The whole
        fleet drifts common-mode (iterations average above the ideal
        period), so only reference-relative error matters — absolute error
        would thrash.

        EXPERIMENTAL (default off): measured on S1-S5, chasing the
        reference's drift with one-report-old data lags the actual phase by
        ~one period of drift, so the realign pauses cost low-priority jobs
        more than the restored cushion saves (S2 lo +10% vs +2% under the
        paper's iteration-time rule). A drift-rate predictor would be
        needed to make this win; the paper-faithful monitor remains the
        default."""
        self._last_phase[job] = self._fold(error_ms, period_eff_ms)
        if not self.phase_monitor:
            return []
        ref = self._ref_of(job)
        if ref is None or ref == job:
            return []
        rel = self._fold(
            self._last_phase[job] - self._last_phase.get(ref, 0.0),
            period_eff_ms)
        tol = 2.0 * period_eff_ms * max(int(self._link_mul(job)), 1) / self.di_pre
        if abs(rel) <= tol:
            self._phase_strikes[job] = 0
            return []
        self._phase_strikes[job] = self._phase_strikes.get(job, 0) + 1
        if self._phase_strikes[job] < 3:  # debounce transient jitter
            return []
        self._phase_strikes[job] = 0
        actions = self._realign_actions(job)
        if actions:
            self.readjust_count += 1
            for a in actions:
                if a.job in self._history:
                    self._history[a.job].clear()
        return actions

    def _ref_of(self, job: str) -> Optional[str]:
        for state in self.links.values():
            sch = state.scheme
            if job in sch.jobs:
                return sch.ref_job or None
        return None

    def _link_mul(self, job: str) -> int:
        for state in self.links.values():
            sch = state.scheme
            if job in sch.jobs:
                return int(sch.muls[sch.jobs.index(job)])
        return 1

    def report_iteration(self, job: str, iter_ms: float) -> List[RealignAction]:
        """DDP/DeepSpeed-style iteration report. Returns realign actions when
        the A_T/O_T drift rule trips."""
        if job not in self._history:
            self.set_baseline(job, iter_ms, self._priorities.get(job, 0))
            return []
        hist = self._history[job]
        hist.append(iter_ms)
        base = self._baseline_ms.get(job, iter_ms)
        n_slow = sum(1 for x in hist if x > self.a_t * base)
        if n_slow > self.o_t:
            hist.clear()
            actions = self._realign_actions(job)
            if actions:
                self.readjust_count += 1
                # realignment perturbs every affected job's next iterations;
                # restart their windows so the pauses themselves don't trip
                # the rule again
                for a in actions:
                    if a.job in self._history:
                        self._history[a.job].clear()
            return actions
        return []

    def _realign_actions(self, job: str) -> List[RealignAction]:
        """Pause every LOW priority job sharing a link with ``job`` (including
        itself if low priority); high priority jobs are never paused.

        Realignment only makes sense where an interleave actually exists:
        links whose best scheme is imperfect (unavoidable contention, the
        SkipPhaseThree case 2 of the paper) are left alone — pausing cannot
        restore a separation that never existed."""
        affected: List[str] = []
        for state in self.links.values():
            sch = state.scheme
            if job in sch.jobs and sch.score >= 100.0 - 1e-6:
                affected.extend(sch.jobs)
        actions = []
        for j in sorted(set(affected)):
            if self._priorities.get(j, 0) != HIGH:
                actions.append(RealignAction(job=j, reason="drift"))
        return actions

    # ----------------------------------------------------- traffic-change path
    def reconcile_measurement(self, job: str, measured_ms: float,
                              declared_ms: float) -> Optional[float]:
        """Measured-vs-declared demand reconciliation (DESIGN.md sec. 19).

        The node agent reports each iteration's measured comm duration;
        when the median over ``reconcile_window`` reports deviates from
        the declared comm time by more than ``reconcile_frac``, return
        the median as the new declared comm time (the caller rewrites
        the profile and replans via ``report_traffic_change``).  Returns
        None while the evidence is insufficient.  The median over a full
        window is deliberately sluggish: transient contention stretches
        individual comm phases without representing a profile change."""
        if not self.reconcile or declared_ms <= 0.0:
            return None
        hist = self._measured_comm.get(job)
        if hist is None or hist.maxlen != self.reconcile_window:
            hist = collections.deque(maxlen=self.reconcile_window)
            self._measured_comm[job] = hist
        hist.append(measured_ms)
        if len(hist) < self.reconcile_window:
            return None
        med = float(np.median(list(hist)))
        if abs(med - declared_ms) <= self.reconcile_frac * declared_ms:
            return None
        hist.clear()
        self.reconcile_count += 1
        return med

    def report_traffic_change(self, registry: TaskRegistry, cluster: Cluster,
                              job: str, new_spec: TrafficSpec) -> None:
        """Duty-cycle / period change (batch-size change, congestion onset):
        update CRs and recalculate rotation angles (paper section III-C)."""
        view = LinkView.from_registry(cluster, registry)
        for t in view.job_tasks(job):
            t.traffic = dataclasses.replace(new_spec)
        registry.bump()  # stored tasks mutated in place -> new epoch
        for node, state in self.links.items():
            if job in state.scheme.jobs:
                # re-unify periods for this link and recalc
                jobs = state.scheme.jobs
                periods, prios = [], []
                for j in jobs:
                    tasks = view.job_tasks(j)
                    periods.append(tasks[0].traffic.period_ms if tasks else 100.0)
                    prios.append(self._priorities.get(j, 0))
                unified = geometry.unify_periods(periods, prios)
                state.scheme.base_ms = unified.base_ms
                state.scheme.muls = unified.muls
                state.scheme.injected_ms = {
                    j: float(unified.injected_ms[i]) for i, j in enumerate(jobs)
                }
                self.pending_recalc.append(node)
        self.run_offline_recalculation(registry, cluster)
        if job in self._history:
            self._history[job].clear()
        # measured-comm evidence referred to the OLD declared profile
        self._measured_comm.pop(job, None)
        # baseline must track the new traffic
        tasks = view.job_tasks(job)
        if tasks:
            self.set_baseline(job, tasks[0].traffic.period_ms,
                              self._priorities.get(job, 0))
    # NOTE: the legacy ``_recompute_global_offsets`` (BFS with add_edge
    # overwrite + uplink-LAST tie-break) is gone; offset resolution lives in
    # rotation.resolve() and the ablation flag ``joint=False`` preserves the
    # old tie-break semantics for comparison (bench_rotation.py).

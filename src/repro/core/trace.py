"""Gavel-style workload trace generator (paper section IV-A, "Traces").

Generates a job arrival sequence with priorities and durations (0.5-1.5 h)
drawn from the 13-model fleet, targeting a cluster load (fraction of GPUs
serving active jobs) above a configurable threshold. All randomness is
seeded for reproducibility.

Trace truncation can be expressed two ways: the legacy iteration cap
(``trace_to_jobs`` derives ``n_iterations`` from the duration) or the
event-driven form (``open_ended=True`` + :func:`trace_departure_events`):
each job runs until its :class:`~repro.core.events.JobDeparture` fires on
the simulator clock — the K8s behavior where a job's deadline, not a
pre-computed iteration count, ends it.  The event form survives contention
honestly (a slowed job does FEWER iterations in its window instead of
holding its GPUs longer) and feeds ``harness.run_trace_experiment`` via its
``events=`` stream.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from .events import JobDeparture
from .workload import HIGH, LOW, Job, make_job

# iteration ceiling of open-ended (departure-truncated) jobs: high enough
# that no realistic trace window ever exhausts it
OPEN_ENDED_ITERATIONS = 1_000_000_000


def trace_job_name(spec: "TraceJobSpec", index: int) -> str:
    """Canonical job name of the ``index``-th trace entry (shared by
    :func:`trace_to_jobs` and :func:`trace_departure_events`)."""
    return f"{spec.model.lower()}-{index}"


@dataclasses.dataclass
class TraceJobSpec:
    model: str
    submit_time_s: float
    duration_s: float
    priority: int
    n_tasks: int


def generate_trace(
    model_fleet: Dict[str, dict],
    *,
    duration_s: float = 4 * 3600.0,
    total_gpus: int = 13,
    target_load: float = 0.7,
    high_priority_frac: float = 0.4,
    seed: int = 0,
    job_duration_range_s: Sequence[float] = (1800.0, 5400.0),
) -> List[TraceJobSpec]:
    """Sample a trace. ``model_fleet`` maps model name -> traffic dict with
    keys period_ms/duty/bw_gbps/n_tasks (see configs.metronome_testbed)."""
    rng = np.random.default_rng(seed)
    names = sorted(model_fleet.keys())
    jobs: List[TraceJobSpec] = []
    # Poisson arrivals sized so that expected concurrent GPU demand ~= target
    mean_tasks = float(np.mean([model_fleet[m].get("n_tasks", 2) for m in names]))
    mean_dur = float(np.mean(job_duration_range_s))
    rate = target_load * total_gpus / (mean_tasks * mean_dur)  # jobs per second
    t = 0.0
    i = 0
    while t < duration_s:
        t += float(rng.exponential(1.0 / rate))
        if t >= duration_s:
            break
        model = names[int(rng.integers(len(names)))]
        dur = float(rng.uniform(*job_duration_range_s))
        prio = HIGH if rng.random() < high_priority_frac else LOW
        jobs.append(
            TraceJobSpec(
                model=model,
                submit_time_s=t,
                duration_s=dur,
                priority=prio,
                n_tasks=int(model_fleet[model].get("n_tasks", 2)),
            )
        )
        i += 1
    return jobs


def generate_production_trace(
    model_fleet: Dict[str, dict],
    *,
    n_jobs: int = 10_000,
    duration_s: float = 24 * 3600.0,
    seed: int = 0,
    diurnal_amplitude: float = 0.6,
    peak_hour: float = 14.0,
    day_s: float = 24 * 3600.0,
    median_duration_s: float = 1200.0,
    duration_sigma: float = 1.2,
    duration_clip_s: Sequence[float] = (60.0, 6 * 3600.0),
    high_priority_frac: float = 0.3,
    task_multipliers: Sequence[int] = (1, 2, 4),
    task_weights: Sequence[float] = (0.7, 0.2, 0.1),
) -> List[TraceJobSpec]:
    """Synthetic production trace: diurnal arrivals, heavy-tailed sizes,
    mixed priorities — the 10k-job scale the fluid-engine benchmark and the
    roadmap's learning-to-schedule corpus need (production cluster traces
    look like this; Gavel's constant-rate Poisson does not).

      * Arrivals: a nonhomogeneous Poisson process via thinning with rate
        ``lam(t) = base * (1 + A * cos(2*pi*(t - peak)/day))`` — a diurnal
        sinusoid peaking at ``peak_hour``; ``base`` is sized so the window
        yields ~``n_jobs`` arrivals, then the sequence is clipped/extended
        to exactly ``n_jobs``.
      * Durations: lognormal around ``median_duration_s`` with shape
        ``duration_sigma`` (heavy right tail — most jobs are minutes, a few
        run hours), clipped to ``duration_clip_s``.
      * Sizes: the fleet model's ``n_tasks`` times a multiplier drawn from
        ``task_multipliers``/``task_weights`` (mostly small, few big).
      * Priorities: Bernoulli(``high_priority_frac``).

    Deterministic per seed; entries are sorted by submit time."""
    rng = np.random.default_rng(seed)
    names = sorted(model_fleet.keys())
    amp = min(max(float(diurnal_amplitude), 0.0), 1.0)
    base_rate = n_jobs / duration_s
    lam_max = base_rate * (1.0 + amp)
    peak_s = peak_hour * 3600.0
    lo, hi = duration_clip_s
    weights = np.asarray(task_weights, dtype=float)
    weights = weights / weights.sum()

    jobs: List[TraceJobSpec] = []
    t = 0.0
    while len(jobs) < n_jobs:
        t += float(rng.exponential(1.0 / lam_max))
        if t >= duration_s:
            # sparse tail (rounding of the thinning acceptance): wrap into
            # the next day so the trace always reaches n_jobs entries
            duration_s += day_s
        lam_t = base_rate * (
            1.0 + amp * np.cos(2.0 * np.pi * (t - peak_s) / day_s))
        if rng.random() * lam_max > lam_t:
            continue  # thinned: off-peak candidate rejected
        model = names[int(rng.integers(len(names)))]
        dur = float(np.clip(
            median_duration_s * np.exp(duration_sigma * rng.standard_normal()),
            lo, hi))
        mult = int(rng.choice(np.asarray(task_multipliers), p=weights))
        jobs.append(TraceJobSpec(
            model=model,
            submit_time_s=t,
            duration_s=dur,
            priority=HIGH if rng.random() < high_priority_frac else LOW,
            n_tasks=int(model_fleet[model].get("n_tasks", 2)) * mult,
        ))
    return jobs


def active_jobs_at(trace: Sequence[TraceJobSpec], t_s: float) -> List[int]:
    """Indices of trace entries live at ``t_s`` (submitted, not departed)."""
    return [i for i, spec in enumerate(trace)
            if spec.submit_time_s <= t_s < spec.submit_time_s + spec.duration_s]


def trace_to_jobs(trace: List[TraceJobSpec], model_fleet: Dict[str, dict],
                  time_scale: float = 1.0, *,
                  open_ended: bool = False) -> List[Job]:
    """Materialize Job objects; ``time_scale`` compresses the trace (e.g.
    0.1 -> a 4 h trace plays in 24 min of simulated time).

    ``open_ended=True`` switches truncation from the iteration cap to
    :class:`~repro.core.events.JobDeparture` events: jobs get an
    effectively unbounded iteration budget and the caller feeds
    :func:`trace_departure_events` into the simulator's event stream."""
    jobs = []
    for i, spec in enumerate(trace):
        fleet = model_fleet[spec.model]
        period = fleet["period_ms"]
        if open_ended:
            n_iter = OPEN_ENDED_ITERATIONS
        else:
            n_iter = max(1, int(spec.duration_s * time_scale * 1e3 / period))
        jobs.append(
            make_job(
                trace_job_name(spec, i),
                n_tasks=spec.n_tasks,
                period_ms=period,
                duty=fleet["duty"],
                bw_gbps=fleet["bw_gbps"],
                priority=spec.priority,
                n_iterations=n_iter,
                submit_time_s=spec.submit_time_s * time_scale,
                model=spec.model,
            )
        )
    return jobs


def trace_departure_events(trace: List[TraceJobSpec],
                           time_scale: float = 1.0) -> List[JobDeparture]:
    """The event-driven form of trace truncation: one
    :class:`~repro.core.events.JobDeparture` per trace entry at
    ``(submit + duration) * time_scale`` on the simulator clock (ms).
    Pair with ``trace_to_jobs(..., open_ended=True)``."""
    return [
        JobDeparture(
            time_ms=(spec.submit_time_s + spec.duration_s) * time_scale * 1e3,
            job=trace_job_name(spec, i),
        )
        for i, spec in enumerate(trace)
    ]


def cluster_load(trace: List[TraceJobSpec], total_gpus: int,
                 duration_s: float) -> float:
    """Average fraction of GPUs serving active jobs (Gavel's load metric)."""
    events = []
    for spec in trace:
        events.append((spec.submit_time_s, spec.n_tasks))
        events.append((spec.submit_time_s + spec.duration_s, -spec.n_tasks))
    events.sort()
    load_time = 0.0
    active = 0
    prev = 0.0
    for t, d in events:
        t = min(t, duration_s)
        load_time += active * (t - prev)
        active += d
        prev = t
        if prev >= duration_s:
            break
    load_time += active * max(0.0, duration_s - prev)
    return load_time / (total_gpus * duration_s)

"""Typed dynamic-environment events for the cluster simulator.

Metronome's third pillar — "adapts to the dynamic environment by monitoring
the cluster and performing reconfiguration operations" (paper section
III-C) — needs a first-class event stream instead of ad-hoc
``(time, job, duty_mult)`` tuples threaded through the harness.  Each event
carries a timestamp (ms on the simulator clock); ``ClusterSimulator.run()``
consumes the merged stream in timestamp order and the stop-and-wait
controller reacts to capacity/background changes by re-deriving rotation
schemes from the live LinkView (DESIGN.md section 10).

Event types:

  * :class:`TrafficChange` — duty-cycle change of one job (batch-size
    change, congestion onset); the path that already existed in the seed.
  * :class:`BackgroundFlowChange` — iPerf3-style unregulated traffic on one
    link starts / ramps up / ramps down / stops.  The cluster manager's
    NodeBandwidth-CR reaction (lower the allocatable share by the observed
    unregulated rate, section III-A) is modeled by ``adjust_allocatable``.
  * :class:`LinkCapacityChange` — the NodeBandwidth-CR update path for any
    link: the manager changes a link's allocatable share (and optionally
    the physical capacity, e.g. a degraded uplink).
  * :class:`JobDeparture` — a job leaves the cluster early (user abort /
    preemption); its flows vanish and its rotation schemes are retired.
  * :class:`LinkFailure` / :class:`LinkRecovery` — fault injection
    (DESIGN.md section 19): a link's capacity AND allocatable share drop
    to 0 and are later restored; :func:`flapping_schedule` builds the
    alternating failure/recovery trains used by the robustness bench.
  * :class:`HostFailure` / :class:`HostRecovery` — a worker node dies:
    its host link fails and every job with a task on it stalls (flows
    dropped); on recovery stalled jobs restart their interrupted
    iteration (pending re-admission).

Streams are validated at the ``run()`` boundary by
:func:`validate_stream`; ``SimConfig.strict_events`` escalates problems
from warn-once-and-drop to a structured :class:`EventValidationError`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Set, Tuple


class UnknownEventTargetWarning(UserWarning):
    """An event named a link/job the simulator does not know.

    The event is ignored (the seed behavior), but silently dropping a
    mistyped link id in a long trace makes experiments quietly wrong — so
    the simulator emits this structured warning ONCE per (kind, name)
    offender.  ``kind`` is ``'link'`` or ``'job'``; ``name`` the unknown
    target; ``time_ms`` the first offending event's firing time."""

    def __init__(self, kind: str, name: str, time_ms: float) -> None:
        self.kind = kind
        self.name = name
        self.time_ms = time_ms
        super().__init__(
            f"ignoring event for unknown {kind} {name!r} "
            f"(first at t={time_ms:.3f}ms); further events for this "
            f"{kind} are dropped silently")


@dataclasses.dataclass(frozen=True)
class Event:
    """Base: anything with a firing time on the simulator clock."""

    time_ms: float


@dataclasses.dataclass(frozen=True)
class TrafficChange(Event):
    """Job ``job`` multiplies its communication duty by ``duty_mult``
    (clipped so the comm phase never exceeds the period).

    ``declared=True`` (the seed behavior) models the job *announcing* the
    change: the profile is updated and the controller replans from it.
    ``declared=False`` models silent drift — the job's actual traffic
    changes but its declared profile does not, so only the controller's
    measured-vs-declared reconciliation (``reconcile=True``) can close
    the gap."""

    job: str
    duty_mult: float
    declared: bool = True


@dataclasses.dataclass(frozen=True)
class BackgroundFlowChange(Event):
    """Set the unregulated background rate on ``link`` to ``rate_gbps``.

    ``rate_gbps <= 0`` stops the background traffic on the link; a positive
    rate starts it or re-rates the existing flow.  With
    ``adjust_allocatable`` (default) the cluster manager mirrors the change
    into the link's allocatable bandwidth (capacity - background rate, the
    NodeBandwidth-CR path) so schedulers and the reconfiguration loop see
    the reduced share."""

    link: str
    rate_gbps: float
    adjust_allocatable: bool = True


@dataclasses.dataclass(frozen=True)
class LinkCapacityChange(Event):
    """NodeBandwidth-CR update for ``link`` (host link id == node name,
    uplinks ``uplink:<leaf>``): set the allocatable share and/or the
    physical capacity.  ``None`` leaves the respective value untouched."""

    link: str
    allocatable_gbps: Optional[float] = None
    capacity_gbps: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class JobDeparture(Event):
    """Job ``job`` leaves the cluster at ``time_ms`` regardless of its
    remaining iterations (user abort / preemption)."""

    job: str


@dataclasses.dataclass(frozen=True)
class LinkFailure(Event):
    """Link ``link`` fails outright: physical capacity and allocatable
    share both drop to 0 until a :class:`LinkRecovery`.  Failing an
    already-failed link is a no-op (flapping schedules may overlap)."""

    link: str


@dataclasses.dataclass(frozen=True)
class LinkRecovery(Event):
    """Link ``link`` comes back.  By default the pre-failure capacity and
    allocatable share are restored; ``capacity_gbps`` recovers at a
    degraded physical capacity instead.  Recovering a link that is not
    failed is a no-op."""

    link: str
    capacity_gbps: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class HostFailure(Event):
    """Worker node ``host`` dies: its host link fails and every job with
    a task placed on it stalls (in-flight flows drop, the interrupted
    iteration is abandoned) until every failed host of the job has
    recovered."""

    host: str


@dataclasses.dataclass(frozen=True)
class HostRecovery(Event):
    """Worker node ``host`` returns: its host link recovers and jobs
    stalled only on it restart their interrupted iteration."""

    host: str


def flapping_schedule(link: str, *, start_ms: float, period_ms: float,
                      down_ms: float, n_cycles: int,
                      host: bool = False) -> List[Event]:
    """An alternating failure/recovery train: ``n_cycles`` failures of
    ``down_ms`` each, one every ``period_ms`` starting at ``start_ms``.
    ``host=True`` emits host failures instead of link failures."""
    if down_ms >= period_ms:
        raise ValueError("down_ms must be < period_ms (link must recover "
                         "before the next failure)")
    events: List[Event] = []
    for i in range(n_cycles):
        t = start_ms + i * period_ms
        if host:
            events.append(HostFailure(time_ms=t, host=link))
            events.append(HostRecovery(time_ms=t + down_ms, host=link))
        else:
            events.append(LinkFailure(time_ms=t, link=link))
            events.append(LinkRecovery(time_ms=t + down_ms, link=link))
    return events


# ------------------------------------------------- boundary validation
@dataclasses.dataclass(frozen=True)
class EventProblem:
    """One defect found by :func:`validate_stream`.

    ``category`` is ``'bad-value'`` (malformed numbers: NaN times/rates,
    negative capacities) or ``'unknown-target'`` (the event names a
    link/host/job the simulator does not know)."""

    index: int  # position in the (normalized) stream
    category: str
    kind: str  # 'link' | 'host' | 'job' | 'event'
    name: str
    time_ms: float
    message: str


class EventValidationError(ValueError):
    """Raised by ``run(strict_events=True)`` when the event stream has
    problems; carries the full structured list."""

    def __init__(self, problems: Sequence[EventProblem]) -> None:
        self.problems = list(problems)
        lines = "\n".join(f"  - [{p.category}] {p.message}"
                          for p in self.problems)
        super().__init__(
            f"event stream has {len(self.problems)} problem(s):\n{lines}")


def _bad(v: Optional[float]) -> bool:
    return v is not None and not math.isfinite(float(v))


def validate_stream(events: Sequence[Event], *, known_links: Set[str],
                    known_hosts: Set[str],
                    known_jobs: Set[str]) -> List[EventProblem]:
    """Check a normalized stream against the simulator's world.

    Returns every problem found (empty list == valid).  The caller
    decides severity: ``strict_events=True`` raises
    :class:`EventValidationError` on any problem; the default mode
    warn-onces and drops only ``bad-value`` events (unknown targets keep
    the historical fire-time :class:`UnknownEventTargetWarning` path)."""
    problems: List[EventProblem] = []

    def add(i: int, category: str, kind: str, name: str, t: float,
            msg: str) -> None:
        problems.append(EventProblem(index=i, category=category, kind=kind,
                                     name=name, time_ms=t, message=msg))

    for i, ev in enumerate(events):
        t = ev.time_ms
        if _bad(t) or t < 0:
            add(i, "bad-value", "event", type(ev).__name__, t,
                f"{type(ev).__name__} at index {i} has invalid "
                f"time_ms={t!r}")
            continue
        if isinstance(ev, TrafficChange):
            if _bad(ev.duty_mult) or ev.duty_mult <= 0:
                add(i, "bad-value", "job", ev.job, t,
                    f"TrafficChange({ev.job!r}) at t={t:g}ms has invalid "
                    f"duty_mult={ev.duty_mult!r}")
            elif ev.job not in known_jobs:
                add(i, "unknown-target", "job", ev.job, t,
                    f"TrafficChange targets unknown job {ev.job!r}")
        elif isinstance(ev, BackgroundFlowChange):
            if _bad(ev.rate_gbps):
                add(i, "bad-value", "link", ev.link, t,
                    f"BackgroundFlowChange({ev.link!r}) at t={t:g}ms has "
                    f"NaN/inf rate_gbps")
            elif ev.link not in known_links:
                add(i, "unknown-target", "link", ev.link, t,
                    f"BackgroundFlowChange targets unknown link "
                    f"{ev.link!r}")
        elif isinstance(ev, LinkCapacityChange):
            if _bad(ev.allocatable_gbps) or _bad(ev.capacity_gbps) or \
                    (ev.allocatable_gbps is not None
                     and ev.allocatable_gbps < 0) or \
                    (ev.capacity_gbps is not None and ev.capacity_gbps < 0):
                add(i, "bad-value", "link", ev.link, t,
                    f"LinkCapacityChange({ev.link!r}) at t={t:g}ms has "
                    f"negative/NaN capacity "
                    f"(allocatable={ev.allocatable_gbps!r}, "
                    f"capacity={ev.capacity_gbps!r})")
            elif ev.link not in known_links:
                add(i, "unknown-target", "link", ev.link, t,
                    f"LinkCapacityChange targets unknown link {ev.link!r}")
        elif isinstance(ev, (LinkFailure, LinkRecovery)):
            cap = getattr(ev, "capacity_gbps", None)
            if _bad(cap) or (cap is not None and cap < 0):
                add(i, "bad-value", "link", ev.link, t,
                    f"{type(ev).__name__}({ev.link!r}) at t={t:g}ms has "
                    f"negative/NaN capacity_gbps={cap!r}")
            elif ev.link not in known_links:
                add(i, "unknown-target", "link", ev.link, t,
                    f"{type(ev).__name__} targets unknown link {ev.link!r}")
        elif isinstance(ev, (HostFailure, HostRecovery)):
            if ev.host not in known_hosts:
                add(i, "unknown-target", "host", ev.host, t,
                    f"{type(ev).__name__} targets unknown host {ev.host!r}")
        elif isinstance(ev, JobDeparture):
            if ev.job not in known_jobs:
                add(i, "unknown-target", "job", ev.job, t,
                    f"JobDeparture targets unknown job {ev.job!r}")
    return problems


LegacyTrafficChange = Tuple[float, str, float]


def normalize_events(
    events: Sequence[Event] = (),
    traffic_changes: Sequence[LegacyTrafficChange] = (),
) -> List[Event]:
    """Merge typed events with legacy ``(time, job, duty_mult)`` tuples into
    one timestamp-ordered stream.

    Legacy tuples keep their historical full-tuple sort (time, job name,
    multiplier) before conversion; the merged stream is then stably sorted
    by timestamp, so same-time events preserve their relative order."""
    stream: List[Event] = [
        TrafficChange(time_ms=float(t), job=j, duty_mult=float(m))
        for t, j, m in sorted(traffic_changes)
    ]
    stream.extend(events)
    return sorted(stream, key=lambda e: e.time_ms)

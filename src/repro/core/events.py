"""Typed dynamic-environment events for the cluster simulator.

Metronome's third pillar — "adapts to the dynamic environment by monitoring
the cluster and performing reconfiguration operations" (paper section
III-C) — needs a first-class event stream instead of ad-hoc
``(time, job, duty_mult)`` tuples threaded through the harness.  Each event
carries a timestamp (ms on the simulator clock); ``ClusterSimulator.run()``
consumes the merged stream in timestamp order and the stop-and-wait
controller reacts to capacity/background changes by re-deriving rotation
schemes from the live LinkView (DESIGN.md section 10).

Event types:

  * :class:`TrafficChange` — duty-cycle change of one job (batch-size
    change, congestion onset); the path that already existed in the seed.
  * :class:`BackgroundFlowChange` — iPerf3-style unregulated traffic on one
    link starts / ramps up / ramps down / stops.  The cluster manager's
    NodeBandwidth-CR reaction (lower the allocatable share by the observed
    unregulated rate, section III-A) is modeled by ``adjust_allocatable``.
  * :class:`LinkCapacityChange` — the NodeBandwidth-CR update path for any
    link: the manager changes a link's allocatable share (and optionally
    the physical capacity, e.g. a degraded uplink).
  * :class:`JobDeparture` — a job leaves the cluster early (user abort /
    preemption); its flows vanish and its rotation schemes are retired.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


class UnknownEventTargetWarning(UserWarning):
    """An event named a link/job the simulator does not know.

    The event is ignored (the seed behavior), but silently dropping a
    mistyped link id in a long trace makes experiments quietly wrong — so
    the simulator emits this structured warning ONCE per (kind, name)
    offender.  ``kind`` is ``'link'`` or ``'job'``; ``name`` the unknown
    target; ``time_ms`` the first offending event's firing time."""

    def __init__(self, kind: str, name: str, time_ms: float) -> None:
        self.kind = kind
        self.name = name
        self.time_ms = time_ms
        super().__init__(
            f"ignoring event for unknown {kind} {name!r} "
            f"(first at t={time_ms:.3f}ms); further events for this "
            f"{kind} are dropped silently")


@dataclasses.dataclass(frozen=True)
class Event:
    """Base: anything with a firing time on the simulator clock."""

    time_ms: float


@dataclasses.dataclass(frozen=True)
class TrafficChange(Event):
    """Job ``job`` multiplies its communication duty by ``duty_mult``
    (clipped so the comm phase never exceeds the period)."""

    job: str
    duty_mult: float


@dataclasses.dataclass(frozen=True)
class BackgroundFlowChange(Event):
    """Set the unregulated background rate on ``link`` to ``rate_gbps``.

    ``rate_gbps <= 0`` stops the background traffic on the link; a positive
    rate starts it or re-rates the existing flow.  With
    ``adjust_allocatable`` (default) the cluster manager mirrors the change
    into the link's allocatable bandwidth (capacity - background rate, the
    NodeBandwidth-CR path) so schedulers and the reconfiguration loop see
    the reduced share."""

    link: str
    rate_gbps: float
    adjust_allocatable: bool = True


@dataclasses.dataclass(frozen=True)
class LinkCapacityChange(Event):
    """NodeBandwidth-CR update for ``link`` (host link id == node name,
    uplinks ``uplink:<leaf>``): set the allocatable share and/or the
    physical capacity.  ``None`` leaves the respective value untouched."""

    link: str
    allocatable_gbps: Optional[float] = None
    capacity_gbps: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class JobDeparture(Event):
    """Job ``job`` leaves the cluster at ``time_ms`` regardless of its
    remaining iterations (user abort / preemption)."""

    job: str


LegacyTrafficChange = Tuple[float, str, float]


def normalize_events(
    events: Sequence[Event] = (),
    traffic_changes: Sequence[LegacyTrafficChange] = (),
) -> List[Event]:
    """Merge typed events with legacy ``(time, job, duty_mult)`` tuples into
    one timestamp-ordered stream.

    Legacy tuples keep their historical full-tuple sort (time, job name,
    multiplier) before conversion; the merged stream is then stably sorted
    by timestamp, so same-time events preserve their relative order."""
    stream: List[Event] = [
        TrafficChange(time_ms=float(t), job=j, duty_mult=float(m))
        for t, j, m in sorted(traffic_changes)
    ]
    stream.extend(events)
    return sorted(stream, key=lambda e: e.time_ms)

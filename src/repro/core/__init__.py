# The paper's primary contribution: the Metronome scheduling mechanism.
#   geometry  — TDM circle abstraction (Eqs. 1-6, 9)
#   scoring   — per-candidate Eq. 18 evaluators (ranges, banks, Psi)
#   rotation  — fabric-wide joint rotation planner (single scheme producer)
#   framework — K8s-scheduling-framework analogue (extension points)
#   scheduler — Algorithm 1 (MetronomePlugin)
#   controller— stop-and-wait controller (global offset, recalc, regulation)
#   contention— unified job→link demand view (LinkView; Eq. 9 predicate)
#   events    — typed dynamic-environment events (reconfiguration inputs)
#   baselines — Default / Diktyo / Exclusive
#   simulator — event-driven fluid-flow cluster simulator
#   topology  — leaf–spine fabric model (star = paper's Eq. 14 default)
#   trace     — Gavel-style workload generator
#   experiment— declarative Scenario/Policy API + sweep grid runner
#   results   — typed, schema-versioned experiment results (JSON)
#   harness   — legacy run_experiment/run_trace_experiment shims
from . import (baselines, cluster, contention, controller, events, experiment,
               framework, geometry, harness, results, rotation, scheduler,
               scoring, simulator, topology, trace, workload)

__all__ = [
    "baselines", "cluster", "contention", "controller", "events",
    "experiment", "framework", "geometry", "harness", "results", "rotation",
    "scheduler", "scoring", "simulator", "topology", "trace", "workload",
]

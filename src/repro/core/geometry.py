"""Geometric (TDM circle) abstraction of periodic traffic — paper section II-B.

A group of tasks sharing a link is unified to a base period
``T_l = LCM(t_1..t_p)`` and each task's traffic pattern becomes ``mul_p``
equally spaced communication arcs on a circle of perimeter ``T_l``
(Eqs. 1-3). The circle is discretized into ``Di-Pre`` slots (the paper uses
72, after Cassini); rotation angles become integer slot shifts.

All hot paths are vectorized (numpy here; the enumeration over rotation
schemes additionally has a jnp / Pallas implementation in
``repro.kernels.metronome_score``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

DI_PRE = 72  # angular discretization precision (paper section IV-A, after Cassini)


# ---------------------------------------------------------------------------
# Period unification (LCM with G_T averaging and E_T idle injection)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class UnifiedPeriods:
    """Result of unifying task periods onto one circle.

    base_ms    : the base period T_l (circle perimeter).
    muls       : mul_p — how many times each task's pattern repeats.
    periods_ms : effective per-task period after averaging/injection.
    injected_ms: idle time injected into each task's compute phase (E_T rule).
    ok         : False -> the task could not be made commensurate (the caller
                 must treat the group as incompatible, paper snapshot 0).
    """

    base_ms: float
    muls: np.ndarray
    periods_ms: np.ndarray
    injected_ms: np.ndarray
    ok: np.ndarray


# content-keyed memo: unification is a pure function of its inputs and the
# scheduler re-derives the same groups for every candidate node of a pod
_UNIFY_CACHE: dict = {}
_UNIFY_CACHE_MAX = 512


def unify_periods(
    periods_ms: Sequence[float],
    priorities: Optional[Sequence[int]] = None,
    *,
    g_t_ms: float = 5.0,
    e_t_frac: float = 0.10,
    max_mul: int = 16,
) -> UnifiedPeriods:
    """Find a common base period T_l for a set of task periods.

    Implements the paper's two thresholds (section III-B):
      - if the mismatch between a task's period and the nearest integer
        divisor of the base is <= ``G_T`` -> merge by averaging;
      - if the mismatch is in (G_T, E_T * period] -> inject idle time into
        the task's computation phase (only meaningful for low priority
        tasks; the caller enforces priority semantics);
      - otherwise the task is flagged not-ok (incompatible).

    The base period is anchored on the highest-priority task (its period is
    never altered — Eq. 16's "reference" semantics), scanning multipliers up
    to ``max_mul``.
    """
    key = (tuple(float(p) for p in periods_ms),
           None if priorities is None else tuple(int(p) for p in priorities),
           g_t_ms, e_t_frac, max_mul)
    hit = _UNIFY_CACHE.get(key)
    if hit is not None:
        # arrays copied out: LinkScheme consumers rebind/slice them freely
        return UnifiedPeriods(hit.base_ms, hit.muls.copy(),
                              hit.periods_ms.copy(), hit.injected_ms.copy(),
                              hit.ok.copy())
    periods = np.asarray(periods_ms, dtype=np.float64)
    n = len(periods)
    if priorities is None:
        priorities = [0] * n
    prios = np.asarray(priorities)

    # reference: highest priority, ties -> earliest (lowest index)
    ref = int(np.lexsort((np.arange(n), -prios))[0])
    t_ref = periods[ref]

    best: Optional[UnifiedPeriods] = None
    best_bad = n + 1
    # scan multipliers ASCENDING and take the first base where every task is
    # commensurate — an "excessively large LCM period would significantly
    # complicate the scheduling calculation" (section III-B).
    for m_ref in range(1, max_mul + 1):
        base = t_ref * m_ref
        muls = np.maximum(1, np.round(base / periods)).astype(np.int64)
        if np.any(muls > max_mul * 4):
            continue
        eff = base / muls  # implied per-task period
        delta = eff - periods  # >0 -> task must slow down (idle injection)
        ok = np.abs(delta) <= g_t_ms
        inject = np.zeros(n)
        # E_T rule: inject idle when the implied period is LONGER by more
        # than G_T but within E_T fraction of the task's own period. Idle is
        # only ever injected into LOW priority pods (the paper never slows a
        # high priority job).
        low = prios < prios[ref] if np.any(prios != prios[ref]) else prios == prios
        low = np.asarray(low) & (np.arange(n) != ref)
        need_inject = (~ok) & (delta > 0) & (delta <= e_t_frac * periods) & low
        # Also compensate sub-G_T positive mismatches of low-priority tasks:
        # without it the task's comm phase drifts by |delta| every iteration
        # and the monitor must re-align continuously (defeats the cushion).
        need_inject |= ok & (delta > g_t_ms * 0.0) & (delta > 0) & low
        inject[need_inject] = delta[need_inject]
        ok = ok | need_inject
        n_bad = int(np.sum(~ok))
        if n_bad < best_bad:
            best_bad = n_bad
            best = UnifiedPeriods(
                base_ms=float(base),
                muls=muls,
                periods_ms=eff,
                injected_ms=inject,
                ok=ok,
            )
        if n_bad == 0:
            break  # smallest feasible base period found
    assert best is not None
    if len(_UNIFY_CACHE) >= _UNIFY_CACHE_MAX:
        _UNIFY_CACHE.clear()
    _UNIFY_CACHE[key] = UnifiedPeriods(
        best.base_ms, best.muls.copy(), best.periods_ms.copy(),
        best.injected_ms.copy(), best.ok.copy())
    return best


# ---------------------------------------------------------------------------
# Discretized traffic patterns
# ---------------------------------------------------------------------------

def pattern_vector(mul: int, duty: float, n_slots: int = DI_PRE) -> np.ndarray:
    """Boolean comm-phase indicator over the discretized circle (Eq. 2).

    ``duty`` is the task's duty cycle w.r.t. its own (effective) period, so a
    single communication arc spans ``duty * n_slots / mul`` slots and repeats
    ``mul`` times at offsets ``i * n_slots / mul``.
    """
    pat = np.zeros(n_slots, dtype=np.float64)
    if duty <= 0:
        return pat
    arc = duty * n_slots / mul  # slots per communication burst
    for i in range(mul):
        start = i * n_slots / mul
        # cover [start, start+arc) with partial-slot weighting at the edges
        a, b = start, start + arc
        lo, hi = int(math.floor(a)), int(math.ceil(b))
        for s in range(lo, hi):
            cover = min(b, s + 1) - max(a, s)
            if cover > 0:
                pat[s % n_slots] += cover
    return np.minimum(pat, 1.0)


# content-keyed memo for the (pure) pattern construction; callers treat
# pattern matrices as read-only (they are only ever scored or rolled)
_PATTERN_CACHE: dict = {}
_PATTERN_CACHE_MAX = 512


def pattern_matrix(
    muls: Sequence[int], duties: Sequence[float], n_slots: int = DI_PRE
) -> np.ndarray:
    """(P, S) matrix of per-task comm indicators (read-only: cached by
    content — the per-slot construction loops are pure Python)."""
    key = (tuple(int(m) for m in muls), tuple(float(d) for d in duties),
           n_slots)
    hit = _PATTERN_CACHE.get(key)
    if hit is not None:
        return hit
    out = np.stack(
        [pattern_vector(int(m), float(d), n_slots) for m, d in zip(muls, duties)]
    )
    if len(_PATTERN_CACHE) >= _PATTERN_CACHE_MAX:
        _PATTERN_CACHE.clear()
    _PATTERN_CACHE[key] = out
    return out


def roll_patterns(patterns: np.ndarray, shifts: np.ndarray) -> np.ndarray:
    """Rotate each task's pattern by its integer slot shift theta_{l,p}."""
    p, s = patterns.shape
    idx = (np.arange(s)[None, :] - np.asarray(shifts)[:, None]) % s
    return np.take_along_axis(patterns, idx, axis=1)


def demand(patterns: np.ndarray, bw: np.ndarray, shifts: np.ndarray) -> np.ndarray:
    """Total bandwidth demand S_l(theta) over the circle (Eq. 4)."""
    rolled = roll_patterns(patterns, shifts)
    return np.einsum("p,ps->s", np.asarray(bw, dtype=np.float64), rolled)


def link_utilization(
    patterns: np.ndarray, bw: np.ndarray, shifts: np.ndarray, capacity: float
) -> float:
    """xi_l — Eq. (6): integral of min(S_l, B_l) / integral of B_l."""
    s = demand(patterns, bw, shifts)
    return float(np.mean(np.minimum(s, capacity)) / capacity)


def avg_bw_utilization(per_link_util: Sequence[float], capacities: Sequence[float],
                       b_max: float) -> float:
    """Gamma — Eq. (5): capacity-weighted average across links."""
    caps = np.asarray(capacities, dtype=np.float64)
    utils = np.asarray(per_link_util, dtype=np.float64)
    if len(caps) == 0:
        return 0.0
    return float(np.mean(caps * utils / b_max))


def excess(patterns: np.ndarray, bw: np.ndarray, shifts: np.ndarray,
           capacity: float) -> float:
    """Sum over slots of demand exceeding the link capacity (Eq. 18 numerator)."""
    s = demand(patterns, bw, shifts)
    return float(np.sum(np.maximum(s - capacity, 0.0)))


def score(patterns: np.ndarray, bw: np.ndarray, shifts: np.ndarray,
          capacity: float) -> float:
    """Node bandwidth score — Eq. (18), scaled to [0, 100].

    100 <=> the wait pod is fully compatible (no slot exceeds capacity).
    """
    n_slots = patterns.shape[1]
    ex = excess(patterns, bw, shifts, capacity)
    return float(max(0.0, 100.0 * (1.0 - ex / (capacity * n_slots))))


# ---------------------------------------------------------------------------
# Communication intervals and the Psi (cushion) metric — Eq. (9)
# ---------------------------------------------------------------------------

def comm_midpoints(mul: int, duty: float, shift: int, n_slots: int = DI_PRE) -> np.ndarray:
    """Circle angles (in slots) of the midpoints of each communication arc."""
    arc = duty * n_slots / mul
    starts = np.arange(mul) * (n_slots / mul) + shift
    return (starts + arc / 2.0) % n_slots


def circular_distance(a: np.ndarray, b: np.ndarray, n_slots: int = DI_PRE) -> np.ndarray:
    """Distance(phi, psi) = min(|phi-psi|, 2pi - |phi-psi|) in slot units."""
    d = np.abs(a[..., :, None] - b[..., None, :])
    return np.minimum(d, n_slots - d)


def min_comm_interval(
    muls: Sequence[int],
    duties: Sequence[float],
    bw: Sequence[float],
    shifts: Sequence[int],
    capacity: float,
    n_slots: int = DI_PRE,
) -> float:
    """Psi — Eq. (9): min circular distance between arc midpoints of every
    *contending* task pair (pairs whose combined demand >= link capacity)."""
    k = len(muls)
    best = math.inf
    for i in range(k):
        for j in range(i + 1, k):
            if bw[i] + bw[j] < capacity:
                continue  # not contending
            mi = comm_midpoints(int(muls[i]), float(duties[i]), int(shifts[i]), n_slots)
            mj = comm_midpoints(int(muls[j]), float(duties[j]), int(shifts[j]), n_slots)
            best = min(best, float(np.min(circular_distance(mi, mj, n_slots))))
    return best if best < math.inf else float(n_slots)


# ---------------------------------------------------------------------------
# Conversions
# ---------------------------------------------------------------------------

def shifts_to_delay_ms(shifts: np.ndarray, base_ms: float, n_slots: int = DI_PRE) -> np.ndarray:
    """Rotation angles -> time shifts: Shifts = Ro / Di-Pre * T_l (section III-B)."""
    return np.asarray(shifts, dtype=np.float64) / n_slots * base_ms


def delay_to_shift_slots(delay_ms: float, base_ms: float, n_slots: int = DI_PRE) -> int:
    return int(round(delay_ms / base_ms * n_slots)) % n_slots

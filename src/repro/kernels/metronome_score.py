"""Pallas TPU kernels for Metronome's rotation-scheme scoring (Eq. 18).

The paper calls the Score phase "computationally intensive" (section III-B):
for every candidate rotation scheme, sum the bandwidth demand over the
discretized circle and measure the excess over link capacity. We adapt the
enumeration to the TPU as a *pairwise* product core: two free tasks' rolled
banks (Ra, S) and (Rb, S) are resident in VMEM and a (block_a x Rb x S)
broadcast-accumulate + relu-reduce produces a block of the (Ra, Rb) score
matrix per grid step. Outer tasks (if any) are folded into ``base_demand``
by the caller (repro.core.rotation holds all but the innermost two fixed —
the paper's own reduction argument).

:func:`metronome_score_multilink` extends the pairwise core to the
fabric-wide joint solve (``core/rotation.py``): the demand banks are
stacked per link — ``(L, Ra, S)`` / ``(L, Rb, S)`` with per-link capacities
— and the relu-excess is reduced over links *and* slots in one kernel.  The
joint score of a rotation pair is the worst per-link Eq. 18 score
(feasible iff every link is perfect), computed as the max over links of the
normalized excess fraction.

The slot axis S (Di-Pre = 72) is padded to the 128-wide TPU lane dimension;
padded slots carry zero demand so they never contribute excess.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.6 compat: CompilerParams was named TPUCompilerParams (same kwargs)
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

LANE = 128


def _score_kernel(base_ref, bank_a_ref, bank_b_ref, out_ref, *,
                  capacity: float, n_slots: int, block_a: int, rb: int):
    base = base_ref[...]           # (1, S_pad)
    bank_a = bank_a_ref[...]       # (block_a, S_pad)
    bank_b = bank_b_ref[...]       # (Rb, S_pad)
    # total[a, b, s] = base[s] + bank_a[a, s] + bank_b[b, s]
    total = (base[None, :, :] + bank_a[:, None, :] + bank_b[None, :, :]
             )  # (block_a, Rb, S_pad)
    excess = jnp.maximum(total - capacity, 0.0)
    ex = jnp.sum(excess, axis=-1)  # (block_a, Rb)
    score = jnp.maximum(0.0, 100.0 * (1.0 - ex / (capacity * n_slots)))
    out_ref[...] = score.astype(out_ref.dtype)


def metronome_score_pairwise(
    base_demand: jax.Array,  # (S,)
    bank_a: jax.Array,  # (Ra, S)
    bank_b: jax.Array,  # (Rb, S)
    capacity: float,
    *,
    block_a: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Scores (Ra, Rb) for every rotation pair of two free tasks."""
    s = base_demand.shape[-1]
    ra, rb = bank_a.shape[0], bank_b.shape[0]
    s_pad = -(-s // LANE) * LANE
    ra_pad = -(-ra // block_a) * block_a

    def pad(x, rows):
        out = jnp.zeros((rows, s_pad), jnp.float32)
        return out.at[: x.shape[0], :s].set(x.astype(jnp.float32))

    base = pad(base_demand[None, :], 1)
    a = pad(bank_a, ra_pad)
    b = pad(bank_b, rb)

    kernel = functools.partial(_score_kernel, capacity=float(capacity),
                               n_slots=s, block_a=block_a, rb=rb)
    out = pl.pallas_call(
        kernel,
        grid=(ra_pad // block_a,),
        in_specs=[
            pl.BlockSpec((1, s_pad), lambda i: (0, 0)),
            pl.BlockSpec((block_a, s_pad), lambda i: (i, 0)),
            pl.BlockSpec((rb, s_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_a, rb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ra_pad, rb), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(base, a, b)
    return out[:ra, :rb]


def _multilink_kernel(caps_ref, base_ref, bank_a_ref, bank_b_ref, out_ref, *,
                      n_slots: int, n_links: int):
    caps = caps_ref[...]           # (L, LANE) — capacity broadcast per lane
    base = base_ref[...]           # (L, 1, S_pad)
    bank_a = bank_a_ref[...]       # (L, block_a, S_pad)
    bank_b = bank_b_ref[...]       # (L, Rb, S_pad)
    cap_col = caps[:, :1]          # (L, 1)
    # total[l, a, b, s] = base[l, s] + bank_a[l, a, s] + bank_b[l, b, s]
    total = (base[:, :, None, :] + bank_a[:, :, None, :]
             + bank_b[:, None, :, :])  # (L, block_a, Rb, S_pad)
    excess = jnp.maximum(total - cap_col[:, None, :, None], 0.0)
    ex = jnp.sum(excess, axis=-1)  # (L, block_a, Rb) — reduce over slots
    # per-link normalized excess fraction, then reduce over links: the worst
    # link dominates (min over per-link scores == 100 * (1 - max frac))
    frac = ex / (cap_col[:, None, :] * n_slots)
    worst = jnp.max(frac, axis=0)  # (block_a, Rb)
    score = jnp.maximum(0.0, 100.0 * (1.0 - worst))
    out_ref[...] = score.astype(out_ref.dtype)


def _multilink_batch_kernel(caps_ref, base_ref, bank_a_ref, bank_b_ref,
                            out_ref, *, n_slots: int):
    caps = caps_ref[...][0]        # (L, LANE) — one candidate's capacities
    base = base_ref[...][0]        # (L, 1, S_pad)
    bank_a = bank_a_ref[...][0]    # (L, block_a, S_pad)
    bank_b = bank_b_ref[...][0]    # (L, Rb, S_pad)
    cap_col = caps[:, :1]          # (L, 1)
    total = (base[:, :, None, :] + bank_a[:, :, None, :]
             + bank_b[:, None, :, :])  # (L, block_a, Rb, S_pad)
    excess = jnp.maximum(total - cap_col[:, None, :, None], 0.0)
    ex = jnp.sum(excess, axis=-1)  # (L, block_a, Rb)
    frac = ex / (cap_col[:, None, :] * n_slots)
    worst = jnp.max(frac, axis=0)  # (block_a, Rb)
    score = jnp.maximum(0.0, 100.0 * (1.0 - worst))
    out_ref[...] = score[None].astype(out_ref.dtype)


def metronome_score_multilink_batch(
    base_demand: jax.Array,  # (C, L, S) fixed demand per candidate and link
    bank_a: jax.Array,  # (C, L, Ra, S)
    bank_b: jax.Array,  # (C, L, Rb, S)
    capacities: jax.Array,  # (C, L)
    *,
    block_a: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Joint scores (C, Ra, Rb) for EVERY candidate in one dispatch.

    The Score phase's one-shot batched evaluation: each of the C surviving
    candidate placements of a pod contributes its own stacked per-link
    demand banks and capacities, and the grid walks (candidate, Ra-block)
    pairs so a single kernel launch replaces the historical per-candidate
    ``metronome_score_multilink`` calls.  Candidates with fewer links are
    padded with zero-demand unit-capacity links, which score a constant 100
    and cannot change the min-over-links."""
    c, l, s = base_demand.shape
    ra, rb = bank_a.shape[2], bank_b.shape[2]
    s_pad = -(-s // LANE) * LANE
    ra_pad = -(-ra // block_a) * block_a

    def pad(x, rows):
        out = jnp.zeros((c, l, rows, s_pad), jnp.float32)
        return out.at[:, :, : x.shape[2], :s].set(x.astype(jnp.float32))

    base = pad(base_demand[:, :, None, :], 1)
    a = pad(bank_a, ra_pad)
    b = pad(bank_b, rb)
    caps = jnp.broadcast_to(
        jnp.asarray(capacities, jnp.float32)[:, :, None], (c, l, LANE))

    kernel = functools.partial(_multilink_batch_kernel, n_slots=s)
    out = pl.pallas_call(
        kernel,
        grid=(c, ra_pad // block_a),
        in_specs=[
            pl.BlockSpec((1, l, LANE), lambda ci, i: (ci, 0, 0)),
            pl.BlockSpec((1, l, 1, s_pad), lambda ci, i: (ci, 0, 0, 0)),
            pl.BlockSpec((1, l, block_a, s_pad), lambda ci, i: (ci, 0, i, 0)),
            pl.BlockSpec((1, l, rb, s_pad), lambda ci, i: (ci, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_a, rb), lambda ci, i: (ci, i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, ra_pad, rb), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(caps, base, a, b)
    return out[:, :ra, :rb]


def metronome_score_multilink(
    base_demand: jax.Array,  # (L, S) fixed demand per link
    bank_a: jax.Array,  # (L, Ra, S)
    bank_b: jax.Array,  # (L, Rb, S)
    capacities: jax.Array,  # (L,)
    *,
    block_a: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Joint scores (Ra, Rb): min over links of Eq. 18 for every rotation
    pair of two free jobs, all links evaluated in one kernel.

    Links where a job is absent carry zero rows in its bank; padded slots
    carry zero demand — neither can contribute excess."""
    l, s = base_demand.shape
    ra, rb = bank_a.shape[1], bank_b.shape[1]
    s_pad = -(-s // LANE) * LANE
    ra_pad = -(-ra // block_a) * block_a

    def pad(x, rows):
        out = jnp.zeros((l, rows, s_pad), jnp.float32)
        return out.at[:, : x.shape[1], :s].set(x.astype(jnp.float32))

    base = pad(base_demand[:, None, :], 1)
    a = pad(bank_a, ra_pad)
    b = pad(bank_b, rb)
    caps = jnp.broadcast_to(
        jnp.asarray(capacities, jnp.float32)[:, None], (l, LANE))

    kernel = functools.partial(_multilink_kernel, n_slots=s, n_links=l)
    out = pl.pallas_call(
        kernel,
        grid=(ra_pad // block_a,),
        in_specs=[
            pl.BlockSpec((l, LANE), lambda i: (0, 0)),
            pl.BlockSpec((l, 1, s_pad), lambda i: (0, 0, 0)),
            pl.BlockSpec((l, block_a, s_pad), lambda i: (0, i, 0)),
            pl.BlockSpec((l, rb, s_pad), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_a, rb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ra_pad, rb), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(caps, base, a, b)
    return out[:ra, :rb]

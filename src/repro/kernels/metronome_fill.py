"""Pallas TPU kernel for the batched progressive-filling fluid solve.

``core/fluid.py`` reduces max-min fair rate sharing to a fixed point over a
(flows x links) demand/route matrix; this kernel runs that fixed point for
a whole batch of fill problems — one grid step per problem, the per-round
state (rates, remaining capacity, active mask) resident in VMEM.  It is the
``backend='kernel'`` path of the fluid engine and the throughput core of
``benchmarks/bench_trace_throughput.py``, where thousands of active-set
snapshots of a 10k-job production trace fill in one dispatch.

Shape discipline mirrors ``metronome_score_multilink``: the link axis is
padded to the 128-wide TPU lane dimension and the flow axis to the sublane
multiple; padded flows carry zero demand (never activate) and padded links
carry zero routes with unit capacity (never saturate), so padding cannot
perturb the fixed point.  Each round freezes at least one flow of every
unfinished problem, so the in-kernel loop is bounded by the padded flow
count; parity with ``ref.progressive_fill_ref`` is exercised in interpret
mode by the tier-1 suite (``tests/test_fluid.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import FILL_EPS

# jax<0.6 compat: CompilerParams was named TPUCompilerParams (same kwargs)
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

LANE = 128
SUBLANE = 8
_INF = 1e30


def _fill_kernel(demands_ref, routes_ref, caps_ref, out_ref, *, f_pad: int):
    d = demands_ref[...][0]        # (F_pad, 1)
    routes = routes_ref[...][0]    # (F_pad, L_pad)
    caps = caps_ref[...]           # (1, L_pad)

    act0 = (d > FILL_EPS).astype(jnp.float32)
    state0 = (jnp.zeros_like(d), caps, act0)

    def body(_, state):
        rates, rem, act = state
        counts = jnp.sum(routes * act, axis=0, keepdims=True)  # (1, L_pad)
        ratio = jnp.where(counts > 0.5,
                          rem / jnp.maximum(counts, 1.0), _INF)
        head = jnp.where(act > 0.5, d - rates, _INF)
        inc = jnp.maximum(jnp.minimum(jnp.min(ratio), jnp.min(head)), 0.0)
        inc = jnp.where(jnp.any(act > 0.5), inc, 0.0)  # drained problem
        rates = rates + inc * act
        rem = rem - inc * counts
        sat = (rem <= FILL_EPS).astype(jnp.float32)    # (1, L_pad)
        blocked = jnp.max(routes * sat, axis=1, keepdims=True) > 0.5
        met = rates >= d - FILL_EPS
        act = jnp.where(jnp.logical_or(met, blocked), 0.0, act)
        return rates, rem, act

    rates, _, _ = jax.lax.fori_loop(0, f_pad + 1, body, state0)
    out_ref[...] = rates[None].astype(out_ref.dtype)


def metronome_fill(
    demands: jax.Array,  # (B, F) per-flow demand caps
    routes: jax.Array,   # (B, F, L) 0/1 route matrix
    caps: jax.Array,     # (B, L) per-link capacities
    *,
    interpret: bool = False,
) -> jax.Array:
    """Batched progressive-fill rates (B, F), one grid step per problem."""
    b, f = demands.shape
    l = routes.shape[-1]
    f_pad = -(-f // SUBLANE) * SUBLANE
    l_pad = -(-l // LANE) * LANE

    d = jnp.zeros((b, f_pad, 1), jnp.float32)
    d = d.at[:, :f, 0].set(demands.astype(jnp.float32))
    r = jnp.zeros((b, f_pad, l_pad), jnp.float32)
    r = r.at[:, :f, :l].set(routes.astype(jnp.float32))
    # padded links: unit capacity, zero routes — they never saturate
    c = jnp.ones((b, l_pad), jnp.float32)
    c = c.at[:, :l].set(caps.astype(jnp.float32))

    kernel = functools.partial(_fill_kernel, f_pad=f_pad)
    out = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, f_pad, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, f_pad, l_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, l_pad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, f_pad, 1), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f_pad, 1), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(d, r, c)
    return out[:, :f, 0]

"""Pallas TPU kernel for the RG-LRU linear recurrence (Griffin).

y_t = a_t * y_{t-1} + x_t over the sequence, blocked (B, S, W) ->
grid (b, w_blocks, s_blocks). The sequence axis is the innermost
("arbitrary") grid dimension so the carried state h lives in VMEM scratch
across sequence blocks; within a block the recurrence runs as a fori_loop
over rows of the VMEM-resident (block_s, block_w) tile.

This is the decode/training-friendly linear-depth form; the pure-jnp oracle
(ref.rg_lru_ref) and the model's associative_scan path are its references.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.6 compat: CompilerParams was named TPUCompilerParams (same kwargs)
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _rg_lru_kernel(a_ref, x_ref, y_ref, h_ref, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)  # (block_s, block_w)
    x = x_ref[0].astype(jnp.float32)

    def body(i, h):
        ai = jax.lax.dynamic_slice_in_dim(a, i, 1, axis=0)
        xi = jax.lax.dynamic_slice_in_dim(x, i, 1, axis=0)
        h = ai * h + xi  # (1, block_w)
        # leading axis via dslice, not a bare 0: jax<0.6 interpret-mode
        # discharge chokes on int indices mixed with slices
        pl.store(y_ref, (pl.dslice(0, 1), pl.dslice(i, 1), slice(None)),
                 h.astype(y_ref.dtype)[None])
        return h

    h0 = h_ref[...][None, :] if h_ref.ndim == 1 else h_ref[...]
    h = jax.lax.fori_loop(0, block_s, body, h0.reshape(1, -1))
    h_ref[...] = h.reshape(h_ref.shape)


def rg_lru_pallas(
    a: jax.Array,  # (B, S, W) decay gates in (0, 1)
    x: jax.Array,  # (B, S, W) gated inputs
    *,
    block_s: int = 256,
    block_w: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, s, w = x.shape
    block_s = min(block_s, s)
    block_w = min(block_w, w)
    assert s % block_s == 0 and w % block_w == 0, (s, w, block_s, block_w)
    ns, nw = s // block_s, w // block_w

    kernel = functools.partial(_rg_lru_kernel, block_s=block_s)
    out = pl.pallas_call(
        kernel,
        grid=(b, nw, ns),
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si: (bi, si, wi)),
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si: (bi, si, wi)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w),
                               lambda bi, wi, si: (bi, si, wi)),
        out_shape=jax.ShapeDtypeStruct((b, s, w), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, x)
    return out

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, window=0,
                  sm_scale: Optional[float] = None) -> jax.Array:
    """Naive full-softmax GQA attention. q: (B,H,S,D), k/v: (B,Hkv,S,D)."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)


def metronome_score_ref(base_demand: np.ndarray, bank_a: np.ndarray,
                        bank_b: np.ndarray, capacity: float) -> np.ndarray:
    """Pairwise rotation-score enumeration oracle.

    base_demand: (S,) demand of all FIXED tasks (already rotated).
    bank_a:      (Ra, S) demand of free task A at every candidate rotation.
    bank_b:      (Rb, S) demand of free task B at every candidate rotation.
    Returns scores (Ra, Rb) per Eq. 18, scaled to [0, 100].
    """
    s = base_demand.shape[-1]
    total = (base_demand[None, None, :] + bank_a[:, None, :]
             + bank_b[None, :, :])
    excess = np.maximum(total - capacity, 0.0).sum(axis=-1)
    return np.maximum(0.0, 100.0 * (1.0 - excess / (capacity * s)))


def metronome_score_multilink_ref(base_demand, bank_a, bank_b,
                                  capacities) -> jnp.ndarray:
    """Multi-link joint rotation-score oracle (jnp; jit-able).

    base_demand: (L, S) demand of all FIXED jobs per link (already rotated).
    bank_a:      (L, Ra, S) demand of free job A per link at every rotation.
    bank_b:      (L, Rb, S) demand of free job B per link at every rotation.
    capacities:  (L,) per-link allocatable bandwidth.
    Returns (Ra, Rb): min over links of the per-link Eq. 18 score — the
    joint feasibility score of the fabric-wide rotation planner.
    """
    base = jnp.asarray(base_demand, jnp.float32)
    a = jnp.asarray(bank_a, jnp.float32)
    b = jnp.asarray(bank_b, jnp.float32)
    caps = jnp.asarray(capacities, jnp.float32)
    s = base.shape[-1]
    total = (base[:, None, None, :] + a[:, :, None, :]
             + b[:, None, :, :])  # (L, Ra, Rb, S)
    excess = jnp.maximum(total - caps[:, None, None, None], 0.0).sum(axis=-1)
    frac = excess / (caps[:, None, None] * s)
    return jnp.maximum(0.0, 100.0 * (1.0 - jnp.max(frac, axis=0)))


def metronome_score_multilink_batch_ref(base_demand, bank_a, bank_b,
                                        capacities) -> jnp.ndarray:
    """Candidate-batched multi-link joint rotation-score oracle (jnp).

    base_demand: (C, L, S) fixed demand per candidate placement and link.
    bank_a:      (C, L, Ra, S) free job A's demand bank per candidate/link.
    bank_b:      (C, L, Rb, S) free job B's demand bank per candidate/link.
    capacities:  (C, L) per-candidate per-link allocatable bandwidth.
    Returns (C, Ra, Rb): per candidate, the min over its links of the
    per-link Eq. 18 score — one batched invocation covering every surviving
    candidate of a pod's Score phase.  Zero-demand padding links (see the
    kernel) score exactly 100 and never change the min.
    """
    base = jnp.asarray(base_demand, jnp.float32)
    a = jnp.asarray(bank_a, jnp.float32)
    b = jnp.asarray(bank_b, jnp.float32)
    caps = jnp.asarray(capacities, jnp.float32)
    s = base.shape[-1]
    total = (base[:, :, None, None, :] + a[:, :, :, None, :]
             + b[:, :, None, :, :])  # (C, L, Ra, Rb, S)
    excess = jnp.maximum(
        total - caps[:, :, None, None, None], 0.0).sum(axis=-1)
    frac = excess / (caps[:, :, None, None] * s)
    return jnp.maximum(0.0, 100.0 * (1.0 - jnp.max(frac, axis=1)))


# float32 analogue of the fluid engine's 1e-9 freeze threshold: link
# capacities are O(25-200) Gbps where the f32 ulp is ~1.5e-5, so a 1e-4
# saturation band keeps every "link just drained" round from ping-ponging
# on rounding residue (core/fluid.py keeps 1e-9 under float64)
FILL_EPS = 1e-4
_FILL_INF = 1e30


def progressive_fill_ref(demands, routes, caps) -> jnp.ndarray:
    """Batched progressive-filling max-min fairness oracle (jnp; jit-able).

    demands: (B, F) per-flow demand caps.
    routes:  (B, F, L) 0/1 route matrix — flow f crosses link l.
    caps:    (B, L) per-link capacity.
    Returns rates (B, F).

    Mirrors the per-flow loop of ``core/fluid.py`` round for round: every
    unfrozen flow grows by the common increment (the min over per-flow
    headroom and per-link remaining/active-count), then flows freeze on
    demand met or a saturated path link.  Each round freezes at least one
    flow per unfinished problem, so F rounds always suffice; the while_loop
    exits as soon as every problem in the batch has drained.  Padding
    discipline: zero-demand flows never activate, zero-route unit-capacity
    links never saturate — both are excess-neutral (see the fill kernel).
    """
    d = jnp.asarray(demands, jnp.float32)
    r = jnp.asarray(routes, jnp.float32)
    c = jnp.asarray(caps, jnp.float32)
    b, f = d.shape
    act0 = (d > FILL_EPS).astype(jnp.float32)
    state0 = (jnp.zeros_like(d), c, act0, jnp.int32(0))

    def cond(state):
        _, _, act, i = state
        return jnp.logical_and(jnp.any(act > 0.5), i < f + 1)

    def body(state):
        rates, rem, act, i = state
        counts = jnp.einsum("bfl,bf->bl", r, act)  # (B, L)
        ratio = jnp.where(counts > 0.5,
                          rem / jnp.maximum(counts, 1.0), _FILL_INF)
        inc_link = jnp.min(ratio, axis=1)  # (B,)
        head = jnp.where(act > 0.5, d - rates, _FILL_INF)
        inc = jnp.maximum(jnp.minimum(inc_link, jnp.min(head, axis=1)), 0.0)
        inc = jnp.where(jnp.any(act > 0.5, axis=1), inc, 0.0)  # drained rows
        rates = rates + inc[:, None] * act
        rem = rem - inc[:, None] * counts
        sat = (rem <= FILL_EPS).astype(jnp.float32)  # (B, L)
        blocked = jnp.einsum("bfl,bl->bf", r, sat) > 0.5
        met = rates >= d - FILL_EPS
        act = jnp.where(jnp.logical_or(met, blocked), 0.0, act)
        return rates, rem, act, i + 1

    rates, _, _, _ = jax.lax.while_loop(cond, body, state0)
    return rates


def rg_lru_ref(a: jax.Array, x: jax.Array, h0: Optional[jax.Array] = None
               ) -> jax.Array:
    """Linear recurrence oracle: y_t = a_t * y_{t-1} + x_t. (B, S, W)."""
    b, s, w = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, w), x.dtype)

    def step(h, inputs):
        at, xt = inputs
        h = at * h + xt
        return h, h

    _, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                         (jnp.moveaxis(a, 1, 0).astype(jnp.float32),
                          jnp.moveaxis(x, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, window=0,
                  sm_scale: Optional[float] = None) -> jax.Array:
    """Naive full-softmax GQA attention. q: (B,H,S,D), k/v: (B,Hkv,S,D)."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = h // hkv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return o.astype(q.dtype)


def metronome_score_ref(base_demand: np.ndarray, bank_a: np.ndarray,
                        bank_b: np.ndarray, capacity: float) -> np.ndarray:
    """Pairwise rotation-score enumeration oracle.

    base_demand: (S,) demand of all FIXED tasks (already rotated).
    bank_a:      (Ra, S) demand of free task A at every candidate rotation.
    bank_b:      (Rb, S) demand of free task B at every candidate rotation.
    Returns scores (Ra, Rb) per Eq. 18, scaled to [0, 100].
    """
    s = base_demand.shape[-1]
    total = (base_demand[None, None, :] + bank_a[:, None, :]
             + bank_b[None, :, :])
    excess = np.maximum(total - capacity, 0.0).sum(axis=-1)
    return np.maximum(0.0, 100.0 * (1.0 - excess / (capacity * s)))


def metronome_score_multilink_ref(base_demand, bank_a, bank_b,
                                  capacities) -> jnp.ndarray:
    """Multi-link joint rotation-score oracle (jnp; jit-able).

    base_demand: (L, S) demand of all FIXED jobs per link (already rotated).
    bank_a:      (L, Ra, S) demand of free job A per link at every rotation.
    bank_b:      (L, Rb, S) demand of free job B per link at every rotation.
    capacities:  (L,) per-link allocatable bandwidth.
    Returns (Ra, Rb): min over links of the per-link Eq. 18 score — the
    joint feasibility score of the fabric-wide rotation planner.
    """
    base = jnp.asarray(base_demand, jnp.float32)
    a = jnp.asarray(bank_a, jnp.float32)
    b = jnp.asarray(bank_b, jnp.float32)
    caps = jnp.asarray(capacities, jnp.float32)
    s = base.shape[-1]
    total = (base[:, None, None, :] + a[:, :, None, :]
             + b[:, None, :, :])  # (L, Ra, Rb, S)
    excess = jnp.maximum(total - caps[:, None, None, None], 0.0).sum(axis=-1)
    frac = excess / (caps[:, None, None] * s)
    return jnp.maximum(0.0, 100.0 * (1.0 - jnp.max(frac, axis=0)))


def metronome_score_multilink_batch_ref(base_demand, bank_a, bank_b,
                                        capacities) -> jnp.ndarray:
    """Candidate-batched multi-link joint rotation-score oracle (jnp).

    base_demand: (C, L, S) fixed demand per candidate placement and link.
    bank_a:      (C, L, Ra, S) free job A's demand bank per candidate/link.
    bank_b:      (C, L, Rb, S) free job B's demand bank per candidate/link.
    capacities:  (C, L) per-candidate per-link allocatable bandwidth.
    Returns (C, Ra, Rb): per candidate, the min over its links of the
    per-link Eq. 18 score — one batched invocation covering every surviving
    candidate of a pod's Score phase.  Zero-demand padding links (see the
    kernel) score exactly 100 and never change the min.
    """
    base = jnp.asarray(base_demand, jnp.float32)
    a = jnp.asarray(bank_a, jnp.float32)
    b = jnp.asarray(bank_b, jnp.float32)
    caps = jnp.asarray(capacities, jnp.float32)
    s = base.shape[-1]
    total = (base[:, :, None, None, :] + a[:, :, :, None, :]
             + b[:, :, None, :, :])  # (C, L, Ra, Rb, S)
    excess = jnp.maximum(
        total - caps[:, :, None, None, None], 0.0).sum(axis=-1)
    frac = excess / (caps[:, :, None, None] * s)
    return jnp.maximum(0.0, 100.0 * (1.0 - jnp.max(frac, axis=1)))


def rg_lru_ref(a: jax.Array, x: jax.Array, h0: Optional[jax.Array] = None
               ) -> jax.Array:
    """Linear recurrence oracle: y_t = a_t * y_{t-1} + x_t. (B, S, W)."""
    b, s, w = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, w), x.dtype)

    def step(h, inputs):
        at, xt = inputs
        h = at * h + xt
        return h, h

    _, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                         (jnp.moveaxis(a, 1, 0).astype(jnp.float32),
                          jnp.moveaxis(x, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)

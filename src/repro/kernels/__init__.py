# Pallas TPU kernels for the compute hot-spots (DESIGN.md section 5):
#   flash_attention — training/prefill attention (causal / window / GQA)
#   metronome_score — the paper's Score-phase rotation enumeration (Eq. 18)
#   rg_lru          — Griffin's linear recurrence
# Each has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py;
# on non-TPU backends the wrappers run the kernels in interpret mode.
from . import ops, ref

__all__ = ["ops", "ref"]

"""Pallas TPU flash attention (forward): causal / sliding-window / GQA.

Online-softmax over KV blocks with accumulators resident in VMEM. Grid:
(batch*q_heads, q_blocks, kv_blocks) — the kv axis is the innermost,
sequential ("arbitrary") dimension so the (m, l, acc) scratch carries across
kv steps. GQA is handled in the K/V index maps (q head -> kv head) so the
grouped KV never gets materialized at q-head width.

VMEM working set per program:
  q block (bq, d) + k/v blocks (bk, d) + scores (bq, bk) + acc (bq, d)
with bq = bk = 512 and d = 128 in bf16/f32 this is ~1.9 MB « 16 MB VMEM,
and every matmul dimension is a multiple of the 128-wide MXU.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.6 compat: CompilerParams was named TPUCompilerParams (same kwargs)
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                      sm_scale: float, causal: bool, window: int,
                      block_q: int, block_k: int, n_kv_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # static-shape positions for masking
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    run = True
    if causal:
        # skip blocks entirely in the future
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window > 0:
        # skip blocks entirely outside the attention window
        run = jnp.logical_and(run, q_start - (k_start + block_k - 1) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window > 0:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_ref[:, 0] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, 0]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    sm_scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = h // hkv
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k

    qf = q.reshape(b * h, sq, d)
    kf = k.reshape(b * hkv, sk, d)
    vf = v.reshape(b * hkv, sk, d)

    def kv_index(bh, iq, ik):
        bb = bh // h
        hh = bh % h
        return (bb * hkv + hh // g, ik, 0)

    kernel = functools.partial(
        _flash_fwd_kernel, sm_scale=sm_scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d)

"""jit'd public wrappers around the Pallas kernels.

Each op dispatches: real TPU -> compiled Pallas; anything else (this CPU
container, tests) -> interpret mode or the jnp reference. Training gets a
``custom_vjp`` whose backward recomputes through the jnp oracle (flash
forward is exact, so gradients match the reference path).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .flash_attention import flash_attention_fwd
from .metronome_fill import metronome_fill
from .metronome_score import (metronome_score_multilink,
                              metronome_score_multilink_batch,
                              metronome_score_pairwise)
from .rg_lru import rg_lru_pallas


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    interpret: Optional[bool] = None):
    """(B,H,S,D) x (B,Hkv,S,D)^2 -> (B,H,S,D)."""
    itp = (not _on_tpu()) if interpret is None else interpret
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               interpret=itp)


def _fa_fwd(q, k, v, causal, window, interpret):
    out = flash_attention(q, k, v, causal, window, interpret)
    return out, (q, k, v)


def _fa_bwd(causal, window, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=causal,
                                             window=window), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# metronome rotation scoring
# ---------------------------------------------------------------------------

def score_pairwise(base_demand, bank_a, bank_b, capacity: float,
                   interpret: Optional[bool] = None) -> np.ndarray:
    """Eq. 18 scores for every (rot_a, rot_b) pair; see core/rotation.py."""
    itp = (not _on_tpu()) if interpret is None else interpret
    out = metronome_score_pairwise(
        jnp.asarray(base_demand), jnp.asarray(bank_a), jnp.asarray(bank_b),
        capacity, interpret=itp)
    return np.asarray(out)


_score_multilink_jit = jax.jit(ref.metronome_score_multilink_ref)


def score_multilink(base_demand, bank_a, bank_b, capacities,
                    interpret: Optional[bool] = None) -> np.ndarray:
    """Joint (min-over-links) Eq. 18 scores for every rotation pair of two
    free jobs over stacked (L, R, S) per-link demand banks.

    Dispatch: real TPU -> compiled Pallas multi-link kernel; anything else
    -> the jit'd jnp reference (the batched CPU fallback of the fabric-wide
    planner).  ``interpret=True`` forces the Pallas kernel in interpret
    mode (parity tests only — far slower than the jnp path)."""
    if interpret:
        out = metronome_score_multilink(
            jnp.asarray(base_demand), jnp.asarray(bank_a),
            jnp.asarray(bank_b), jnp.asarray(capacities), interpret=True)
    elif _on_tpu():
        out = metronome_score_multilink(
            jnp.asarray(base_demand), jnp.asarray(bank_a),
            jnp.asarray(bank_b), jnp.asarray(capacities), interpret=False)
    else:
        out = _score_multilink_jit(
            jnp.asarray(base_demand), jnp.asarray(bank_a),
            jnp.asarray(bank_b), jnp.asarray(capacities))
    return np.asarray(out)


_score_multilink_batch_jit = jax.jit(ref.metronome_score_multilink_batch_ref)


def score_multilink_batch(base_demand, bank_a, bank_b, capacities,
                          interpret: Optional[bool] = None) -> np.ndarray:
    """Candidate-batched joint Eq. 18 scores: ONE dispatch over stacked
    (C, L, R, S) banks returning (C, Ra, Rb) — the Score phase's surviving
    candidates evaluated together instead of one kernel launch each.

    Dispatch mirrors :func:`score_multilink`: real TPU -> compiled Pallas
    batch kernel; anything else -> the jit'd jnp reference;
    ``interpret=True`` forces the Pallas kernel in interpret mode (parity
    tests only)."""
    if interpret:
        out = metronome_score_multilink_batch(
            jnp.asarray(base_demand), jnp.asarray(bank_a),
            jnp.asarray(bank_b), jnp.asarray(capacities), interpret=True)
    elif _on_tpu():
        out = metronome_score_multilink_batch(
            jnp.asarray(base_demand), jnp.asarray(bank_a),
            jnp.asarray(bank_b), jnp.asarray(capacities), interpret=False)
    else:
        out = _score_multilink_batch_jit(
            jnp.asarray(base_demand), jnp.asarray(bank_a),
            jnp.asarray(bank_b), jnp.asarray(capacities))
    return np.asarray(out)


# ---------------------------------------------------------------------------
# progressive-filling fluid solve
# ---------------------------------------------------------------------------

_progressive_fill_jit = jax.jit(ref.progressive_fill_ref)


def progressive_fill_ref(demands, routes, caps) -> np.ndarray:
    """The jit'd jnp fixed-point fill — the fluid engine's ``backend='jnp'``
    path, always the vectorized reference regardless of platform."""
    return np.asarray(_progressive_fill_jit(
        jnp.asarray(demands), jnp.asarray(routes), jnp.asarray(caps)))


def progressive_fill(demands, routes, caps,
                     interpret: Optional[bool] = None) -> np.ndarray:
    """Batched progressive-fill rates (B, F) over (B, F, L) route matrices.

    Dispatch mirrors :func:`score_multilink`: real TPU -> compiled Pallas
    fill kernel; anything else -> the jit'd jnp reference;
    ``interpret=True`` forces the Pallas kernel in interpret mode (parity
    tests only — far slower than the jnp path)."""
    if interpret:
        out = metronome_fill(
            jnp.asarray(demands), jnp.asarray(routes), jnp.asarray(caps),
            interpret=True)
    elif _on_tpu():
        out = metronome_fill(
            jnp.asarray(demands), jnp.asarray(routes), jnp.asarray(caps),
            interpret=False)
    else:
        out = _progressive_fill_jit(
            jnp.asarray(demands), jnp.asarray(routes), jnp.asarray(caps))
    return np.asarray(out)


# ---------------------------------------------------------------------------
# rg-lru recurrence
# ---------------------------------------------------------------------------

def rg_lru(a, x, interpret: Optional[bool] = None):
    itp = (not _on_tpu()) if interpret is None else interpret
    return rg_lru_pallas(a, x, interpret=itp)

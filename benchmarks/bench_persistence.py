"""Table VI: short vs extended observation windows (gain persistence)."""
from __future__ import annotations

import numpy as np

from repro.configs.metronome_testbed import make_snapshot
from repro.core.harness import priority_split, run_experiment
from repro.core.simulator import SimConfig

from . import common
from .common import Timer, emit


def run() -> None:
    for sid in ("S1", "S2", "S3"):
        rows = {}
        for label, dur, iters in (
                ("short", common.pick(150_000.0, 15_000.0),
                 common.pick(400, 30)),
                ("long", common.pick(600_000.0, 30_000.0),
                 common.pick(5000, 60))):
            cluster, wls, bg = make_snapshot(sid, n_iterations=iters)
            cfg = SimConfig(duration_ms=dur, seed=3, jitter_std=0.01)
            with Timer() as t:
                rows[label] = (run_experiment("metronome", cluster, wls, cfg,
                                              background=bg), wls, t)
        res_s, wls, t = rows["short"]
        res_l, _, _ = rows["long"]
        hi, lo = priority_split(wls)

        def agg(r, names):
            v = [r.sim.time_per_1000_iters_s[j] for j in names]
            return float(np.mean(v)) if v else float("nan")

        emit(f"tableVI_{sid}", t.us,
             f"lo_short={agg(res_s, lo):.2f};lo_long={agg(res_l, lo):.2f};"
             f"hi_short={agg(res_s, hi):.2f};hi_long={agg(res_l, hi):.2f}")

"""Table VI: short vs extended observation windows (gain persistence)."""
from __future__ import annotations

import dataclasses

from repro.configs.metronome_testbed import snapshot_scenario
from repro.core.experiment import Policy
from repro.core.simulator import SimConfig

from . import common
from .common import Timer, emit


def run() -> None:
    metronome = [Policy("metronome")]
    for sid in ("S1", "S2", "S3"):
        # per-variant SimConfig rides on the Scenario itself
        scenarios = []
        for label, dur, iters in (
                ("short", common.pick(150_000.0, 15_000.0),
                 common.pick(400, 30)),
                ("long", common.pick(600_000.0, 30_000.0),
                 common.pick(5000, 60))):
            scn = snapshot_scenario(
                sid, n_iterations=iters,
                sim_config=SimConfig(duration_ms=dur, seed=3,
                                     jitter_std=0.01))
            scenarios.append(dataclasses.replace(scn, name=f"{sid}-{label}"))
        with Timer() as t:
            sw = common.run_sweep(scenarios, metronome, None,
                                  origin="persistence")
        res_s = sw.get(f"{sid}-short", "metronome")
        res_l = sw.get(f"{sid}-long", "metronome")
        hi, lo = res_s.high_priority, res_s.low_priority
        emit(f"tableVI_{sid}", t.us / 2,
             f"lo_short={res_s.mean_s_per_1000(lo):.2f};"
             f"lo_long={res_l.mean_s_per_1000(lo):.2f};"
             f"hi_short={res_s.mean_s_per_1000(hi):.2f};"
             f"hi_long={res_l.mean_s_per_1000(hi):.2f}")
